//! Std-only subset of the `anyhow` crate (offline vendor shim).
//!
//! Provides the pieces this repository uses: [`Error`] (a flat
//! message-with-context error), [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension trait for `Result` and
//! `Option`. Context is flattened eagerly into the message
//! (`"context: cause"`), which matches how the real crate renders with
//! the alternate `{:#}` format.

use std::fmt::{self, Display};

/// A flattened dynamic error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display + Send + Sync + 'static>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Attach outer context (rendered as `"context: self"`).
    pub fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`; that
// is what lets the blanket `From` below coexist with `From<T> for T`
// (same trick as the real crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Display> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: context.to_string() })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn inner() -> Result<u32> {
            let r: std::result::Result<u32, std::io::Error> = Err(io_err());
            let v = r?;
            Ok(v + 1)
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading x").unwrap_err();
        assert_eq!(e.to_string(), "loading x: missing");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("need {}", "y")).unwrap_err();
        assert_eq!(e.to_string(), "need y");
        assert_eq!(Some(3u32).context("ok").unwrap(), 3);
    }

    #[test]
    fn macros_cover_all_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 4;
        let b = anyhow!("got {n} and {}", 5);
        assert_eq!(b.to_string(), "got 4 and 5");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }

    #[test]
    fn error_msg_as_fn_pointer() {
        let r: std::result::Result<u8, String> = Err("bad".into());
        let e = r.map_err(Error::msg).unwrap_err();
        assert_eq!(e.to_string(), "bad");
    }
}
