//! Std-only subset of the `log` crate's facade (offline vendor shim).
//!
//! Implements exactly what this repository uses: the five level macros,
//! the [`Log`] trait, [`Level`]/[`LevelFilter`], and the global
//! `set_logger` / `set_max_level` / `max_level` plumbing. API names and
//! semantics match the real crate so it can be swapped back in.

use std::cmp::Ordering;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::OnceLock;

/// Verbosity level of a log record (most to least severe).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Level filter: like [`Level`] plus `Off`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Metadata of a record: level + target (module path by default).
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, as handed to [`Log::log`].
#[derive(Debug, Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> fmt::Arguments<'a> {
        self.args
    }
}

/// A log sink.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off until init

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum log level.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, AtomicOrdering::Relaxed);
}

/// Current global maximum log level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(AtomicOrdering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not public API.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orderings() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Off < Level::Error);
    }

    #[test]
    fn max_level_round_trip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn macros_compile_and_run_without_logger() {
        // No logger installed in this test binary: must be a no-op.
        info!("hello {}", 1);
        warn!("w");
        error!("e");
        debug!("d");
        trace!("t");
    }
}
