//! Stub of the `xla` (xla_extension / PJRT) binding (offline vendor
//! shim).
//!
//! Mirrors the API surface `coral::runtime` uses so the serving stack
//! compiles everywhere; every entry point that would need the native
//! xla_extension library returns [`Error::Unavailable`]. Callers
//! (integration tests, `bench_runtime`, `coral serve`) treat that error
//! as "runtime not present" and skip. On images bundling xla_extension,
//! point `rust/Cargo.toml` at the real crate instead.

use std::fmt;
use std::path::Path;

/// XLA/PJRT error.
#[derive(Debug, Clone)]
pub enum Error {
    /// The native xla_extension backend is not linked into this build.
    Unavailable(&'static str),
    /// Any other failure (I/O, parse, shape mismatch).
    Message(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: xla_extension is not available in this build \
                 (vendor/xla stub; see vendor/README.md)"
            ),
            Error::Message(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for Error {}

/// `Result` specialized to [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// CPU PJRT client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable_not_panic() {
        let e = PjRtClient::cpu().err().expect("stub must error");
        assert!(e.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[0.0; 4]);
        assert!(lit.reshape(&[1, 2, 2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
