"""L1 correctness: Pallas box-decode vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import box_decode
from compile.kernels import ref


def _case(m, c, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    pred = jax.random.normal(k1, (m, 5 + c), jnp.float32) * 3.0
    anchors = jnp.abs(jax.random.normal(k2, (m, 4), jnp.float32)) * 20.0 + 1.0
    return pred, anchors


def check(m, c, rows, seed=0):
    pred, anchors = _case(m, c, seed)
    bx, sc = box_decode(pred, anchors, rows=rows)
    rbx, rsc = ref.ref_box_decode(pred, anchors)
    np.testing.assert_allclose(np.asarray(bx), np.asarray(rbx), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(rsc), rtol=1e-5, atol=1e-5)


def test_exact_panel():
    check(128, 8, 128)


def test_ragged_rows():
    check(100, 8, 32)


def test_single_row():
    check(1, 1, 128)


def test_many_classes():
    check(64, 40, 16)


def test_scores_in_unit_interval():
    pred, anchors = _case(256, 8, seed=3)
    _, sc = box_decode(pred, anchors)
    s = np.asarray(sc)
    assert (s >= 0).all() and (s <= 1).all()


def test_boxes_well_formed():
    # x2 >= x1, y2 >= y1 always (widths/heights are non-negative).
    pred, anchors = _case(256, 8, seed=4)
    bx, _ = box_decode(pred, anchors)
    b = np.asarray(bx)
    assert (b[:, 2] >= b[:, 0]).all()
    assert (b[:, 3] >= b[:, 1]).all()


def test_bad_shapes_raise():
    pred, anchors = _case(16, 8)
    with pytest.raises(ValueError):
        box_decode(pred[:, :5], anchors)  # no class columns
    with pytest.raises(ValueError):
        box_decode(pred, anchors[:, :3])


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 300),
    c=st.integers(1, 16),
    rows=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 10_000),
)
def test_hypothesis_sweep(m, c, rows, seed):
    check(m, c, rows, seed)
