"""AOT path: lowering produces loadable HLO text + a complete manifest."""

import json
import os

import pytest

from compile import aot, model


def test_lower_yolo_b1_is_hlo_text():
    text = aot.lower_variant("yolo", 1)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Tuple return (rust side unwraps a 2-tuple).
    assert "tuple" in text.lower()


def test_lowered_text_mentions_f32_io():
    text = aot.lower_variant("yolo", 1)
    assert "f32[1,128,128,3]" in text.replace(" ", "")


def test_lower_is_deterministic():
    a = aot.lower_variant("yolo", 1, seed=0)
    b = aot.lower_variant("yolo", 1, seed=0)
    assert a == b


def test_build_all_manifest(tmp_path):
    manifest = aot.build_all(str(tmp_path), variants=("yolo",), batches=(1, 2),
                             verbose=False)
    assert len(manifest["artifacts"]) == 2
    for entry in manifest["artifacts"]:
        p = tmp_path / entry["file"]
        assert p.exists()
        assert p.stat().st_size == entry["bytes"]
        assert entry["param_count"] == model.param_count(model.SPECS["yolo"])
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk["format"] == "hlo-text"
    assert on_disk["outputs"] == ["boxes[B,P,4]", "scores[B,P]"]


def test_manifest_batch_input_shapes(tmp_path):
    manifest = aot.build_all(str(tmp_path), variants=("yolo",), batches=(4,),
                             verbose=False)
    e = manifest["artifacts"][0]
    assert e["input_shape"] == [4, model.INPUT_SIZE, model.INPUT_SIZE, 3]
    assert e["predictions"] == model.SPECS["yolo"].num_predictions
