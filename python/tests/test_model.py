"""L2 correctness: detector forward — kernel path vs oracle path, shapes,
determinism, and the Table-3 model-size spread."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def images():
    k = jax.random.PRNGKey(42)
    return jax.random.uniform(k, (2, model.INPUT_SIZE, model.INPUT_SIZE, 3))


@pytest.mark.parametrize("variant", model.VARIANTS)
def test_forward_shapes(variant, images):
    spec = model.SPECS[variant]
    params = model.init_params(spec)
    boxes, scores = model.forward(params, spec, images)
    assert boxes.shape == (2, spec.num_predictions, 4)
    assert scores.shape == (2, spec.num_predictions)


def test_kernel_path_matches_oracle_path(images):
    # The whole L2 graph routed through Pallas kernels must match the
    # pure-jnp reference graph end to end.
    spec = model.SPECS["yolo"]
    params = model.init_params(spec)
    bk, sk = model.forward(params, spec, images, use_kernel=True)
    br, sr = model.forward(params, spec, images, use_kernel=False)
    np.testing.assert_allclose(np.asarray(bk), np.asarray(br), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-4, atol=1e-4)


def test_deterministic_weights():
    spec = model.SPECS["yolo"]
    a = model.init_params(spec, seed=7)
    b = model.init_params(spec, seed=7)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa["w"]), np.asarray(pb["w"]))


def test_seed_changes_weights():
    spec = model.SPECS["yolo"]
    a = model.init_params(spec, seed=0)
    b = model.init_params(spec, seed=1)
    assert not np.allclose(np.asarray(a[0]["w"]), np.asarray(b[0]["w"]))


def test_param_count_matches_init():
    for variant in model.VARIANTS:
        spec = model.SPECS[variant]
        params = model.init_params(spec)
        actual = sum(int(np.prod(p["w"].shape)) + int(p["b"].shape[0])
                     for p in params)
        assert actual == model.param_count(spec), variant


def test_table3_size_spread():
    # Paper Table 3: 1.9 M → 38 M is a ~20× spread; our 1/1000-scale
    # variants preserve the ordering and roughly the spread.
    py = model.param_count(model.SPECS["yolo"])
    pf = model.param_count(model.SPECS["frcnn"])
    pr = model.param_count(model.SPECS["retinanet"])
    assert py < pf < pr
    assert 15.0 <= pr / py <= 30.0
    assert 7.0 <= pf / py <= 15.0


def test_flops_ordering():
    f = [model.flops_per_image(model.SPECS[v]) for v in model.VARIANTS]
    assert f == sorted(f)
    assert f[0] > 1e6  # sanity: megaflop class, not trivially small


def test_scores_are_probabilities(images):
    spec = model.SPECS["yolo"]
    params = model.init_params(spec)
    _, scores = model.forward(params, spec, images)
    s = np.asarray(scores)
    assert (s >= 0).all() and (s <= 1).all()


def test_batch_independence():
    # Row i of a batch must equal the same image run at batch 1.
    spec = model.SPECS["yolo"]
    params = model.init_params(spec)
    k = jax.random.PRNGKey(3)
    imgs = jax.random.uniform(k, (3, spec.input_size, spec.input_size, 3))
    b_all, s_all = model.forward(params, spec, imgs)
    b_one, s_one = model.forward(params, spec, imgs[1:2])
    np.testing.assert_allclose(np.asarray(b_all[1]), np.asarray(b_one[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_all[1]), np.asarray(s_one[0]),
                               rtol=1e-4, atol=1e-4)


def test_bad_input_shape_raises():
    spec = model.SPECS["yolo"]
    params = model.init_params(spec)
    with pytest.raises(ValueError):
        model.forward(params, spec, jnp.zeros((1, 16, 16, 4)))


def test_anchors_cover_grid():
    spec = model.SPECS["yolo"]
    a = np.asarray(model.make_anchors(spec))
    assert a.shape == (spec.num_predictions, 4)
    assert a[:, 0].min() >= 0 and a[:, 0].max() <= spec.input_size
    assert a[:, 1].min() >= 0 and a[:, 1].max() <= spec.input_size
    assert (a[:, 2:] > 0).all()


def test_build_forward_closure():
    fn, in_spec = model.build_forward("yolo", batch=2)
    assert in_spec.shape == (2, model.INPUT_SIZE, model.INPUT_SIZE, 3)
    out = fn(jnp.zeros(in_spec.shape, in_spec.dtype))
    assert out[0].shape[0] == 2
