"""L1 correctness: Pallas fused GEMM vs pure-jnp oracle.

hypothesis sweeps shapes/blocks/activations; every case asserts
allclose against ref.ref_fused_gemm — the core correctness signal of the
kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_gemm
from compile.kernels.fused_gemm import mxu_utilization, vmem_bytes
from compile.kernels import ref


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def check(m, k, n, act, block):
    x = _rand(m * 7 + 1, (m, k))
    w = _rand(n * 13 + 2, (k, n))
    b = _rand(k * 3 + 5, (n,))
    got = fused_gemm(x, w, b, act=act, block=block)
    want = ref.ref_fused_gemm(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("act", ["none", "relu", "silu"])
def test_exact_tile_multiple(act):
    check(32, 16, 24, act, (16, 8, 8))


@pytest.mark.parametrize("act", ["none", "relu", "silu"])
def test_ragged_shapes(act):
    check(70, 33, 17, act, (16, 16, 8))


def test_single_tile():
    check(8, 8, 8, "silu", (8, 8, 8))


def test_tile_larger_than_problem():
    # Blocks are clamped to the problem shape.
    check(5, 3, 4, "relu", (128, 128, 128))


def test_wide_k_accumulation():
    # Many k steps: accumulator correctness across the grid's k loop.
    check(16, 300, 16, "none", (16, 16, 32))


def test_default_block():
    x, w, b = _rand(1, (130, 64)), _rand(2, (64, 130)), _rand(3, (130,))
    got = fused_gemm(x, w, b, act="silu")
    want = ref.ref_fused_gemm(x, w, b, "silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bad_act_raises():
    x, w, b = _rand(1, (4, 4)), _rand(2, (4, 4)), _rand(3, (4,))
    with pytest.raises(ValueError):
        fused_gemm(x, w, b, act="gelu")


def test_bad_shapes_raise():
    x, w, b = _rand(1, (4, 5)), _rand(2, (4, 4)), _rand(3, (4,))
    with pytest.raises(ValueError):
        fused_gemm(x, w, b)


def test_zero_bias_identity():
    x = jnp.eye(8, dtype=jnp.float32)
    w = _rand(11, (8, 8))
    b = jnp.zeros((8,), jnp.float32)
    got = fused_gemm(x, w, b, act="none", block=(8, 8, 8))
    np.testing.assert_allclose(np.asarray(got), np.asarray(w), rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    act=st.sampled_from(["none", "relu", "silu"]),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16]),
    bk=st.sampled_from([8, 16]),
)
def test_hypothesis_shape_sweep(m, k, n, act, bm, bn, bk):
    check(m, k, n, act, (bm, bn, bk))


# --- §Perf estimators (used by EXPERIMENTS.md §Perf, sanity-pinned here) ---

def test_vmem_budget_default_block():
    # Default 128³ block must fit comfortably in a 16 MiB VMEM core.
    assert vmem_bytes((128, 128, 128)) < 16 * 1024 * 1024 // 4


def test_mxu_utilization_bounds():
    assert mxu_utilization(128, 128, 128) == pytest.approx(1.0)
    u = mxu_utilization(130, 100, 27, (128, 128, 128))
    assert 0.0 < u < 1.0


def test_mxu_utilization_improves_with_fitting_block():
    bad = mxu_utilization(129, 100, 27, (128, 128, 128))
    good = mxu_utilization(129, 100, 27, (16, 16, 16))
    assert good > bad
