"""Build-time Python package: L1 Pallas kernels, L2 JAX detector, AOT lowering.

Never imported on the serving path — `make artifacts` runs once and the
rust binary consumes artifacts/*.hlo.txt + artifacts/manifest.json.
"""
