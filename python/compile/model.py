"""L2: single-shot object-detector forward pass in JAX, on the L1 kernels.

Stand-ins for the paper's three detection models (Table 3):

  ===========  =====================  =========================
  variant      stands in for          paper params / this repo
  ===========  =====================  =========================
  ``yolo``     YOLOv5-N (1.9 M)       scaled ~1/1000
  ``frcnn``    FRCNN-MobileNetV3      scaled ~1/1000  (19.4 M)
  ``retinanet``RetinaNet-ResNet50     scaled ~1/1000  (38 M)
  ===========  =====================  =========================

The substitution (DESIGN.md §2): CORAL only needs per-model compute/power
*scale*, which the device simulator carries at paper magnitude; the serving
path still executes real inference through PJRT, so the models here are the
same architecture family (conv backbone → detection head → box decode) at
~1/1000 width so CPU inference stays real-time on the test machine. The
~20× parameter spread between the smallest and largest variant is
preserved (asserted in python/tests/test_model.py).

Every convolution lowers to the L1 ``fused_gemm`` Pallas kernel via
im2col; the detection head decode runs in the L1 ``box_decode`` kernel —
so the whole forward pass is kernel-dominated, like the TensorRT engines
the paper profiles.

Weights are deterministic (seeded) and baked into the lowered HLO as
constants: the serving binary only feeds image batches.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import fused_gemm, box_decode
from .kernels import ref as kref

# Input resolution. Paper: 640×640; scaled with the model widths so real
# CPU inference sustains edge-class frame rates (DESIGN.md §2).
INPUT_SIZE = 128
NUM_CLASSES = 8

# GEMM tile profiles (EXPERIMENTS.md §Perf). The kernel is authored for
# the MXU (128³ tiles); under interpret=True every grid step costs a
# functional full-buffer update, so the CPU artifacts are lowered with
# huge blocks that collapse the grid to a handful of steps — 7× faster
# per frame at batch 4, identical numerics (pytest covers both).
BLOCK_PROFILES = {
    "tpu": (128, 128, 128),        # MXU-native; deployment default
    "cpu": (16384, 256, 256),      # interpret-mode: minimize grid steps
}


@dataclasses.dataclass(frozen=True)
class DetectorSpec:
    """Architecture hyper-parameters of one variant."""

    name: str
    widths: Tuple[int, ...]      # channels per stage (each stage strides 2)
    depth: int                   # extra stride-1 convs per stage
    input_size: int = INPUT_SIZE
    num_classes: int = NUM_CLASSES

    @property
    def head_channels(self) -> int:
        return 5 + self.num_classes

    @property
    def final_grid(self) -> int:
        return self.input_size // (2 ** len(self.widths))

    @property
    def num_predictions(self) -> int:
        return self.final_grid * self.final_grid


# Widths chosen so param counts sit at ~1/1000 of Table 3 and the
# yolo→retinanet spread stays ≈20× (test_model.py pins the ratio).
SPECS: Dict[str, DetectorSpec] = {
    "yolo": DetectorSpec("yolo", widths=(8, 16, 32), depth=1),
    "frcnn": DetectorSpec("frcnn", widths=(16, 40, 80), depth=2),
    "retinanet": DetectorSpec("retinanet", widths=(32, 64, 88), depth=3),
}

VARIANTS: Tuple[str, ...] = tuple(SPECS)


def _conv_param_count(cin: int, cout: int, k: int = 3) -> int:
    return cin * cout * k * k + cout


def param_count(spec: DetectorSpec) -> int:
    """Exact trainable-parameter count of a variant."""
    total = 0
    cin = 3
    for w in spec.widths:
        total += _conv_param_count(cin, w)          # stride-2 stage conv
        total += spec.depth * _conv_param_count(w, w)
        cin = w
    total += _conv_param_count(cin, spec.head_channels, k=1)
    return total


def flops_per_image(spec: DetectorSpec) -> int:
    """MACs·2 of one forward pass (conv layers only — they dominate)."""
    total = 0
    size = spec.input_size
    cin = 3
    for w in spec.widths:
        size //= 2
        total += 2 * size * size * cin * w * 9
        total += spec.depth * 2 * size * size * w * w * 9
        cin = w
    total += 2 * size * size * cin * spec.head_channels
    return total


def init_params(spec: DetectorSpec, seed: int = 0) -> List[Dict[str, jax.Array]]:
    """He-init weights, deterministic in ``seed`` (baked into the HLO)."""
    key = jax.random.PRNGKey(seed)
    layers: List[Dict[str, jax.Array]] = []

    def conv(key, cin, cout, k):
        wkey, key = jax.random.split(key)
        fan_in = cin * k * k
        w = jax.random.normal(wkey, (k, k, cin, cout), jnp.float32)
        w = w * math.sqrt(2.0 / fan_in)
        return key, {"w": w, "b": jnp.zeros((cout,), jnp.float32)}

    cin = 3
    for width in spec.widths:
        key, p = conv(key, cin, width, 3)
        layers.append(p)
        for _ in range(spec.depth):
            key, p = conv(key, width, width, 3)
            layers.append(p)
        cin = width
    key, head = conv(key, cin, spec.head_channels, 1)
    layers.append(head)
    return layers


def _im2col(x: jax.Array, k: int, stride: int) -> Tuple[jax.Array, int]:
    """NHWC → (N·H'·W', C·k·k) patch matrix (SAME padding).

    Features stay in the C-major (C, kh, kw) order
    ``conv_general_dilated_patches`` emits — transposing the (tiny, baked)
    filter matrix instead of the (large, per-frame) activation tensor
    saves one full-activation permute per layer (EXPERIMENTS.md §Perf,
    L2 iteration 2: −7…16 % forward latency).
    """
    n, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(k, k),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    hh = patches.shape[1]
    return patches.reshape(n * hh * hh, c * k * k), hh


def _conv_block(
    x: jax.Array,
    p: Dict[str, jax.Array],
    stride: int,
    act: str,
    use_kernel: bool,
    block: Tuple[int, int, int],
) -> jax.Array:
    """3×3 (or 1×1) conv + bias + act via im2col → fused GEMM."""
    k = p["w"].shape[0]
    cout = p["w"].shape[3]
    n = x.shape[0]
    cols, hh = _im2col(x, k, stride)
    # HWIO → (C, kh, kw, cout): match the patch matrix's C-major features.
    wmat = jnp.transpose(p["w"], (2, 0, 1, 3)).reshape(k * k * x.shape[3], cout)
    if use_kernel:
        y = fused_gemm(cols, wmat, p["b"], act=act, block=block)
    else:
        y = kref.ref_fused_gemm(cols, wmat, p["b"], act=act)
    return y.reshape(n, hh, hh, cout)


def make_anchors(spec: DetectorSpec) -> jax.Array:
    """(P, 4) grid-centre + anchor-size table, stride folded in (pixels)."""
    g = spec.final_grid
    stride = spec.input_size // g
    ys, xs = jnp.meshgrid(jnp.arange(g), jnp.arange(g), indexing="ij")
    cx = (xs.reshape(-1).astype(jnp.float32) + 0.5) * stride
    cy = (ys.reshape(-1).astype(jnp.float32) + 0.5) * stride
    aw = jnp.full((g * g,), float(stride) * 1.5, jnp.float32)
    ah = jnp.full((g * g,), float(stride) * 1.5, jnp.float32)
    return jnp.stack([cx, cy, aw, ah], axis=1)


def forward(
    params: Sequence[Dict[str, jax.Array]],
    spec: DetectorSpec,
    images: jax.Array,
    use_kernel: bool = True,
    block_profile: str = "cpu",
) -> Tuple[jax.Array, jax.Array]:
    """Detector forward pass.

    Args:
      params: layer list from :func:`init_params`.
      spec: architecture spec.
      images: ``(B, H, W, 3)`` f32 in [0, 1].
      use_kernel: route GEMMs + decode through the Pallas kernels (the
        production path) or the jnp reference (oracle path for tests).
      block_profile: GEMM tile profile (``BLOCK_PROFILES`` key).

    Returns:
      ``(boxes, scores)`` with shapes ``(B, P, 4)`` and ``(B, P)`` where
      ``P = spec.num_predictions``.
    """
    if images.ndim != 4 or images.shape[3] != 3:
        raise ValueError(f"expected (B,{spec.input_size},{spec.input_size},3), got {images.shape}")
    block = BLOCK_PROFILES[block_profile]
    x = images.astype(jnp.float32)
    li = 0
    for width in spec.widths:
        x = _conv_block(x, params[li], 2, "silu", use_kernel, block)
        li += 1
        for _ in range(spec.depth):
            x = _conv_block(x, params[li], 1, "silu", use_kernel, block)
            li += 1
    raw = _conv_block(x, params[li], 1, "none", use_kernel, block)  # head, 1×1

    b = raw.shape[0]
    p = spec.num_predictions
    flat = raw.reshape(b * p, spec.head_channels)
    anchors = jnp.tile(make_anchors(spec), (b, 1))
    if use_kernel:
        # Row panel sized to the full prediction set: one interpret-mode
        # grid step (EXPERIMENTS.md §Perf).
        rows = 2048 if block_profile == "cpu" else 128
        boxes, scores = box_decode(flat, anchors, rows=rows)
    else:
        boxes, scores = kref.ref_box_decode(flat, anchors)
    return boxes.reshape(b, p, 4), scores.reshape(b, p)


def build_forward(variant: str, batch: int, seed: int = 0, use_kernel: bool = True,
                  block_profile: str = "cpu"):
    """Close over baked weights: returns ``fn(images) -> (boxes, scores)``
    plus the input ShapeDtypeStruct — the unit aot.py lowers."""
    spec = SPECS[variant]
    params = init_params(spec, seed)

    def fn(images):
        return forward(params, spec, images, use_kernel=use_kernel,
                       block_profile=block_profile)

    in_spec = jax.ShapeDtypeStruct(
        (batch, spec.input_size, spec.input_size, 3), jnp.float32
    )
    return fn, in_spec
