"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest asserts the Pallas kernels
match these references to float tolerance across a hypothesis-driven sweep
of shapes and dtypes (python/tests/).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ref_fused_gemm(
    x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none"
) -> jax.Array:
    """Reference ``act(x @ w + b)`` — plain jnp, no tiling, f32 accumulate."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "silu":
        return y * jax.nn.sigmoid(y)
    if act == "none":
        return y
    raise ValueError(f"unknown act {act!r}")


def ref_box_decode(
    pred: jax.Array, anchors: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Reference YOLO-style decode — mirrors boxdecode._decode_kernel."""
    p = pred.astype(jnp.float32)
    a = anchors.astype(jnp.float32)
    xy = jax.nn.sigmoid(p[:, 0:2]) * 2.0 - 0.5
    cx = xy[:, 0:1] + a[:, 0:1]
    cy = xy[:, 1:2] + a[:, 1:2]
    wh = (jax.nn.sigmoid(p[:, 2:4]) * 2.0) ** 2
    w = wh[:, 0:1] * a[:, 2:3]
    h = wh[:, 1:2] * a[:, 3:4]
    obj = jax.nn.sigmoid(p[:, 4:5])
    best = jnp.max(jax.nn.sigmoid(p[:, 5:]), axis=1, keepdims=True)
    boxes = jnp.concatenate(
        [cx - w * 0.5, cy - h * 0.5, cx + w * 0.5, cy + h * 0.5], axis=1
    )
    return boxes, obj * best
