"""Detection-head box-decode Pallas kernel.

Decodes raw head logits into screen-space boxes + confidence, YOLO-style:

  cx = (2·σ(tx) − 0.5 + gx) · stride        w = (2·σ(tw))² · aw
  cy = (2·σ(ty) − 0.5 + gy) · stride        h = (2·σ(th))² · ah
  score = σ(obj) · max_c σ(cls_c)

and emits corner boxes ``(x1, y1, x2, y2)`` plus the best-class score.

Purely element/row-wise, so it runs on the VPU (8×128 lanes): the grid
tiles the prediction rows; each step streams a ``(bm, D)`` logit panel and
a ``(bm, 4)`` anchor panel through VMEM and writes ``(bm, 4)`` boxes and
``(bm, 1)`` scores. Fusing the decode here saves one HBM round-trip of
the raw head tensor — the same fusion TensorRT performs on the paper's
Jetson path. interpret=True for CPU PJRT (see fused_gemm.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-panel height: one VPU sublane group of 8 rows × 16 = 128 rows keeps
# the panel lane-aligned while staying tiny in VMEM.
DEFAULT_ROWS = 128


def _decode_kernel(pred_ref, anchor_ref, boxes_ref, score_ref):
    p = pred_ref[...]                      # (bm, 5 + C) logits
    a = anchor_ref[...]                    # (bm, 4): gx, gy, aw, ah (px)
    xy = jax.nn.sigmoid(p[:, 0:2]) * 2.0 - 0.5
    cx = (xy[:, 0:1] + a[:, 0:1])
    cy = (xy[:, 1:2] + a[:, 1:2])
    wh = (jax.nn.sigmoid(p[:, 2:4]) * 2.0) ** 2
    w = wh[:, 0:1] * a[:, 2:3]
    h = wh[:, 1:2] * a[:, 3:4]
    obj = jax.nn.sigmoid(p[:, 4:5])
    cls = jax.nn.sigmoid(p[:, 5:])
    best = jnp.max(cls, axis=1, keepdims=True)
    boxes_ref[...] = jnp.concatenate(
        [cx - w * 0.5, cy - h * 0.5, cx + w * 0.5, cy + h * 0.5], axis=1
    )
    score_ref[...] = obj * best


@functools.partial(jax.jit, static_argnames=("rows",))
def box_decode(
    pred: jax.Array, anchors: jax.Array, rows: int = DEFAULT_ROWS
) -> Tuple[jax.Array, jax.Array]:
    """Decode raw head logits into boxes + scores.

    Args:
      pred: ``(M, 5 + C)`` raw logits — tx, ty, tw, th, obj, C classes.
        Grid offset and stride are pre-folded into ``anchors`` so the
        kernel stays a pure row map.
      anchors: ``(M, 4)`` — grid-centre x, grid-centre y (pixels), anchor
        width, anchor height (pixels).
      rows: row-panel height (VMEM tile).

    Returns:
      ``(boxes, scores)``: ``(M, 4)`` corner boxes and ``(M, 1)``
      objectness·best-class confidences.
    """
    if pred.ndim != 2 or anchors.ndim != 2 or anchors.shape[1] != 4:
        raise ValueError(f"bad shapes pred{pred.shape} anchors{anchors.shape}")
    if pred.shape[1] < 6:
        raise ValueError("pred must be (M, 5 + C) with C >= 1")
    m, d = pred.shape
    bm = min(rows, m)
    pad = (-m) % bm
    if pad:
        pred = jnp.pad(pred, ((0, pad), (0, 0)))
        anchors = jnp.pad(anchors, ((0, pad), (0, 0)), constant_values=1.0)
    mp = pred.shape[0]
    grid = (mp // bm,)

    boxes, scores = pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, 4), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, 4), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, 4), jnp.float32),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=True,
    )(pred.astype(jnp.float32), anchors.astype(jnp.float32))
    return boxes[:m], scores[:m]
