"""L1 Pallas kernels for the CORAL detector models.

Kernels are authored for a TPU-shaped machine (MXU matmul tiles, VMEM
block streaming via BlockSpec) but are always lowered with
``interpret=True`` so the resulting HLO runs on any PJRT backend,
including the rust CPU client on the serving path.
"""

from .fused_gemm import fused_gemm, DEFAULT_BLOCK
from .boxdecode import box_decode
from . import ref

__all__ = ["fused_gemm", "box_decode", "ref", "DEFAULT_BLOCK"]
