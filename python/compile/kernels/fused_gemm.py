"""Fused GEMM + bias + activation Pallas kernel.

This is the compute hot-spot of the detector models: every convolution is
lowered to an im2col patch-matrix times filter-matrix GEMM, so one kernel
serves the whole backbone and head.

TPU adaptation of the paper's CUDA/TensorRT conv path (DESIGN.md
§Hardware-Adaptation):

* the GEMM targets the MXU systolic array — tiles default to 128×128,
  the MXU native shape, instead of tensor-core WMMA fragments;
* ``BlockSpec`` expresses the HBM→VMEM streaming schedule that a CUDA
  kernel would express with threadblocks + shared memory: for grid step
  ``(i, j, k)`` an LHS row panel ``(bm, bk)`` and an RHS col panel
  ``(bk, bn)`` are resident in VMEM while the f32 accumulator tile
  ``(bm, bn)`` stays pinned across the ``k`` loop;
* bias add + activation (SiLU / ReLU) are fused into the epilogue on the
  VPU, saving one HBM round-trip of the activation tensor.

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is emulated as plain HLO (grid → loop). Real
TPU efficiency is estimated from VMEM footprint + MXU occupancy in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile. On real TPU hardware (bm, bn) = (128, 128) maps one
# accumulator tile onto the systolic array; bk = 128 keeps the K panels
# lane-aligned (8×128 VPU lanes).
DEFAULT_BLOCK: Tuple[int, int, int] = (128, 128, 128)

_ACTS = ("none", "relu", "silu")


def _apply_act(y: jax.Array, act: str) -> jax.Array:
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "silu":
        return y * jax.nn.sigmoid(y)
    return y


def _gemm_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str, k_steps: int):
    """Grid point (i, j, k): accumulate x[i,k] @ w[k,j] into o[i,j].

    The output tile doubles as the f32 accumulator (it is pinned in VMEM
    across the k loop because its BlockSpec ignores the k grid axis); the
    epilogue (bias + activation) runs once, on the final k step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        o_ref[...] = _apply_act(o_ref[...] + b_ref[...], act)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("act", "block"))
def fused_gemm(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    act: str = "none",
    block: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    """``act(x @ w + b)`` via the Pallas MXU kernel.

    Args:
      x: LHS, shape ``(M, K)`` (im2col patch matrix), f32.
      w: RHS, shape ``(K, N)`` (filter matrix), f32.
      b: bias, shape ``(N,)``, f32.
      act: one of ``"none" | "relu" | "silu"`` fused into the epilogue.
      block: optional ``(bm, bn, bk)`` tile override; defaults to the
        MXU-native 128³ clamped to the (padded) problem shape.

    Returns:
      f32 array of shape ``(M, N)``.
    """
    if act not in _ACTS:
        raise ValueError(f"act must be one of {_ACTS}, got {act!r}")
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError("fused_gemm expects x:(M,K) w:(K,N) b:(N,)")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape[0] != n:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    bm, bn, bk = block or DEFAULT_BLOCK
    # Clamp tiles to the problem (small layers), then pad the operands so
    # every axis is an exact multiple of its tile — BlockSpec grids must
    # cover the array exactly, mirroring the paper's TensorRT padding.
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, bk), 1, bn)
    bp = _pad_to(b.astype(jnp.float32).reshape(1, n), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, act=act, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(block: Tuple[int, int, int] = DEFAULT_BLOCK) -> int:
    """Estimated VMEM residency of one grid step (f32): LHS + RHS panels,
    bias row, and the pinned accumulator tile. Used by the §Perf roofline
    estimate — must stay well under the ~16 MiB/core TPU VMEM budget."""
    bm, bn, bk = block
    return 4 * (bm * bk + bk * bn + bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int,
                    block: Tuple[int, int, int] = DEFAULT_BLOCK) -> float:
    """Fraction of MXU issue slots doing useful work after padding —
    the §Perf efficiency proxy (real-TPU wall-clock is unavailable here)."""
    bm, bn, bk = (min(block[0], m), min(block[1], n), min(block[2], k))
    mp = m + (-m) % bm
    np_ = n + (-n) % bn
    kp = k + (-k) % bk
    return (m * n * k) / float(mp * np_ * kp)
