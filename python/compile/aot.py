"""AOT lowering: JAX detector forward → HLO text artifacts for the rust runtime.

Interchange is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 (behind the `xla` rust crate) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowered with ``return_tuple=True`` — the rust
side unwraps a 2-tuple (boxes, scores).

Emits one artifact per (variant, batch) plus ``manifest.json`` describing
every artifact (shapes, param counts, FLOPs) for the rust model registry.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model

# Batch variants the rust dynamic batcher can dispatch to. Keep the list
# short: each entry is a separate XLA compile at rust start-up.
BATCH_SIZES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant: str, batch: int, seed: int = 0,
                  block_profile: str = "cpu") -> str:
    fn, in_spec = model.build_forward(variant, batch, seed=seed,
                                      block_profile=block_profile)
    return to_hlo_text(jax.jit(fn).lower(in_spec))


def build_all(out_dir: str, variants=model.VARIANTS, batches=BATCH_SIZES,
              seed: int = 0, verbose: bool = True,
              block_profile: str = "cpu") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text",
        "input_layout": "NHWC_f32_0to1",
        "outputs": ["boxes[B,P,4]", "scores[B,P]"],
        "seed": seed,
        "block_profile": block_profile,
        "artifacts": [],
    }
    for variant in variants:
        spec = model.SPECS[variant]
        for batch in batches:
            t0 = time.time()
            text = lower_variant(variant, batch, seed, block_profile)
            name = f"{variant}_b{batch}.hlo.txt"
            path = os.path.join(out_dir, name)
            with open(path, "w") as f:
                f.write(text)
            entry = {
                "model": variant,
                "batch": batch,
                "file": name,
                "input_shape": [batch, spec.input_size, spec.input_size, 3],
                "predictions": spec.num_predictions,
                "num_classes": spec.num_classes,
                "param_count": model.param_count(spec),
                "flops_per_image": model.flops_per_image(spec),
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
            manifest["artifacts"].append(entry)
            if verbose:
                print(
                    f"  {name}: {len(text)/1e6:.2f} MB HLO text, "
                    f"{entry['param_count']:,} params, "
                    f"{time.time()-t0:.1f}s",
                    file=sys.stderr,
                )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", nargs="*", default=list(model.VARIANTS))
    ap.add_argument("--batches", nargs="*", type=int, default=list(BATCH_SIZES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--block-profile", choices=list(model.BLOCK_PROFILES),
                    default="cpu",
                    help="GEMM tile profile: tpu=MXU 128^3 (deployment), "
                         "cpu=interpret-friendly huge blocks (this runtime)")
    args = ap.parse_args()
    manifest = build_all(args.out_dir, args.variants, tuple(args.batches), args.seed,
                         block_profile=args.block_profile)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
