//! Tiny argv parser: one positional command (+ optional subcommand),
//! `--key value` options, `--flag` booleans.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments (command first).
    pub positional: Vec<String>,
    /// `--key value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv (without the program name).
    pub fn parse(argv: Vec<String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("empty option name".into());
                }
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn sub(&self) -> Option<&str> {
        self.positional.get(1).map(|s| s.as_str())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn opt_u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect()).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse("experiment dual --out results --seeds 5 --verbose");
        assert_eq!(a.command(), Some("experiment"));
        assert_eq!(a.sub(), Some("dual"));
        assert_eq!(a.opt("out"), Some("results"));
        assert_eq!(a.opt_u64_or("seeds", 1).unwrap(), 5);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn eq_style_options() {
        let a = parse("optimize --target=30.5 --budget=6500");
        assert_eq!(a.opt_f64("target").unwrap(), Some(30.5));
        assert_eq!(a.opt_f64("budget").unwrap(), Some(6500.0));
        assert_eq!(a.opt_f64("missing").unwrap(), None);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse("serve --fast yolo");
        // "--fast yolo": yolo is consumed as the value of --fast.
        assert_eq!(a.opt("fast"), Some("yolo"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --seeds abc");
        assert!(a.opt_u64_or("seeds", 1).is_err());
        assert!(a.opt_f64("seeds").is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.opt_or("out", "results"), "results");
        assert_eq!(a.opt_u64_or("seeds", 7).unwrap(), 7);
        assert!(!a.has_flag("x"));
    }
}
