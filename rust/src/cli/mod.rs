//! Command-line interface (std-only — the offline mirror has no clap).
//!
//! ```text
//! coral experiment <fig1|table4|single|dual|ablation|all> [--out DIR] [--seeds N]
//! coral optimize  --device D --model M [--target FPS] [--budget MW]
//!                 [--method NAME] [--iters N] [--seed N]
//! coral sweep     --device D --model M [--out DIR]
//! coral serve     [--model M] [--requests N] [--concurrency C] [--batch B]
//! coral tenants   [--scenario S] [--policy P] [--rounds N]
//! coral hetero    [--scenario S] [--iters N] [--seed N]
//! coral report    <specs|models|scenarios>
//! coral artifacts-check [--dir DIR]
//! ```

pub mod args;
pub mod commands;

pub use args::Args;

/// Entry point: parse + dispatch. Returns the process exit code.
pub fn main_with(argv: Vec<String>) -> i32 {
    crate::util::logging::init();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{}", commands::USAGE);
            return 2;
        }
    };
    match commands::dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
