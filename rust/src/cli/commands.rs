//! CLI command implementations.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::args::Args;
use crate::control::{
    drive_coral, BudgetPolicy, ControlLoop, Environment, SimEnv, CHAOS_HOLD_WINDOWS,
};
use crate::coordinator::{BatcherConfig, Server, ServerConfig};
use crate::device::{failure, Device, DeviceKind, Dim};
use crate::experiments::{self, runner, scenarios};
use crate::models::{artifacts_dir, Manifest, ModelKind};
use crate::optimizer::{Constraints, CoralOptimizer};
use crate::runtime::PjrtRuntime;
use crate::util::table;
use crate::workload::VideoSource;

pub const USAGE: &str = "\
coral — Covariance-Guided Resource Adaptive Learning (CS.DC 2026 reproduction)

USAGE:
  coral experiment <fig1|table4|single|dual|ablation|convergence|robustness|all> [--out DIR] [--seeds N]
  coral optimize  --device <nx|orin> --model <yolo|frcnn|retinanet>
                  [--target FPS] [--budget MW] [--method NAME] [--iters N] [--seed N]
                  [--trace FILE.csv] [--cached]
  coral sweep     --device <nx|orin> --model <yolo|frcnn|retinanet> [--out DIR]
  coral serve     [--model M] [--requests N] [--concurrency C] [--batch B] [--inflight K]
  coral tenants   [--scenario nx-pair|nx-triple|orin-triple] [--policy static|demand|waterfill|independent]
                  [--rounds N] [--seed N] [--sequential] [--cached]
  coral hetero    [--scenario hetero-<model>-<pair|triple>] [--iters N] [--seed N] [--sequential]
  coral chaos     [--scenario chaos-<dropout|thermal|glitch|combined>-pair] [--windows N] [--seed N]
  coral fleetscale [--scenario fleet-<10|100|1k|10k>] [--rounds N] [--seed N] [--workers N]
  coral load      [--scenario load-<name>] [--iters N] [--seed N]
  coral variants  [--scenario acc-<dev>-<model>|nx-pair-accuracy] [--iters N] [--rounds N] [--seed N]
  coral report    <specs|models|scenarios>
  coral artifacts-check [--dir DIR]

Methods: coral, oracle, alert, alert-online, max-power, default, random.
";

/// Dispatch a parsed command line.
pub fn dispatch(args: &Args) -> Result<()> {
    match args.command() {
        Some("experiment") => cmd_experiment(args),
        Some("optimize") => cmd_optimize(args),
        Some("sweep") => cmd_sweep(args),
        Some("serve") => cmd_serve(args),
        Some("tenants") => cmd_tenants(args),
        Some("hetero") => cmd_hetero(args),
        Some("chaos") => cmd_chaos(args),
        Some("fleetscale") => cmd_fleetscale(args),
        Some("load") => cmd_load(args),
        Some("variants") => cmd_variants(args),
        Some("report") => cmd_report(args),
        Some("artifacts-check") => cmd_artifacts_check(args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn parse_device(args: &Args) -> Result<DeviceKind> {
    let name = args.opt("device").context("--device required (nx|orin)")?;
    DeviceKind::parse(name).with_context(|| format!("unknown device '{name}'"))
}

fn parse_model(args: &Args) -> Result<ModelKind> {
    let name = args.opt_or("model", "yolo");
    ModelKind::parse(&name).with_context(|| format!("unknown model '{name}'"))
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("out", "results"))
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let out = out_dir(args);
    let seeds = args.opt_u64_or("seeds", 10).map_err(anyhow::Error::msg)?;
    std::fs::create_dir_all(&out)?;
    match args.sub() {
        Some("fig1") => experiments::fig1::run(&out)?,
        Some("table4") => experiments::table4::run(&out)?,
        Some("single") => experiments::single::run(&out, seeds)?,
        Some("dual") => experiments::dual::run_all(&out, seeds)?,
        Some("ablation") => experiments::ablation::run(&out, seeds)?,
        Some("robustness") => experiments::robustness::run(&out, seeds)?,
        Some("convergence") => experiments::convergence::run(&out, seeds)?,
        Some("all") | None => experiments::run_all(&out, seeds)?,
        Some(other) => bail!("unknown experiment '{other}'"),
    }
    println!("\nCSV written to {}", out.display());
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let device = parse_device(args)?;
    let model = parse_model(args)?;
    let seed = args.opt_u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let iters = args.opt_u64_or("iters", 10).map_err(anyhow::Error::msg)? as usize;
    let target = args.opt_f64("target").map_err(anyhow::Error::msg)?;
    let budget = args.opt_f64("budget").map_err(anyhow::Error::msg)?;
    let cons = match (target, budget) {
        (Some(t), Some(b)) => Constraints::dual(t, b),
        (Some(t), None) => Constraints::throughput_only(t),
        (None, Some(b)) => Constraints::dual(0.0, b),
        (None, None) => Constraints::max_throughput(),
    };
    let method = args.opt_or("method", "coral");

    let trace_path = args.opt("trace").map(std::path::PathBuf::from);
    if method == "coral" {
        // Verbose per-iteration trace with the dCor weights, driven by
        // the canonical control loop. `--cached` interposes the
        // measurement cache, so re-proposed configurations replay from
        // the store instead of re-running windows.
        let dev = Device::new(device, model, seed);
        let opt = CoralOptimizer::new(dev.space().clone(), cons, seed);
        let env: Box<dyn Environment + Send> = if args.has_flag("cached") {
            Box::new(crate::control::CachedEnv::new(SimEnv::new(dev)))
        } else {
            Box::new(SimEnv::new(dev))
        };
        let mut cl = ControlLoop::with_budget(env, opt, cons, iters);
        println!(
            "CORAL on {device}/{model} — target {:?} fps, budget {:?} mW",
            cons.throughput_target_fps, cons.power_budget_mw
        );
        let out = cl.run_observed(|step, opt| {
            let m = &step.measured;
            println!(
                "  it{:>2}: {} -> {:6.1} fps {:6.0} mW {}",
                step.iter,
                step.config,
                m.throughput_fps,
                m.power_mw,
                if m.failed.is_some() { "[FAILED]" } else { "" }
            );
            let (a, b) = opt.weights();
            let names: Vec<String> = Dim::ALL
                .iter()
                .enumerate()
                .map(|(d, dim)| format!("{}={:.2}/{:.2}", dim.name(), a[d], b[d]))
                .collect();
            println!("        dCor(tput/power): {}", names.join(" "));
        });
        let best = out.best.context("no observations")?;
        println!(
            "\nbest: {} -> {:.1} fps @ {:.0} mW  feasible={} (PS size {})",
            best.config,
            best.throughput_fps,
            best.power_mw,
            best.feasible,
            cl.opt().prohibited_len()
        );
        println!(
            "search cost: {:.0} simulated seconds ({} measurement windows)",
            out.cost_s, out.iters
        );
        if let Some(st) = out.cache {
            println!(
                "cache: {} hits / {} misses ({:.0}% hit rate), {} windows saved, \
                 {:.0} s of measurement avoided (epoch {})",
                st.hits,
                st.misses,
                st.hit_rate() * 100.0,
                st.windows_saved(),
                st.cost_saved_s,
                st.epoch
            );
        }
        if let Some(path) = trace_path {
            out.trace.save(&path)?;
            println!("trace written to {}", path.display());
        }
    } else {
        let kind = runner::MethodKind::parse(&method)
            .with_context(|| format!("unknown method '{method}'"))?;
        let o = runner::run_method(kind, device, model, cons, seed);
        println!(
            "{}: {:.1} fps @ {:.0} mW feasible={} ({} online + {} offline windows)\n  config: {}",
            o.method, o.throughput_fps, o.power_mw, o.feasible, o.online_windows,
            o.offline_windows, o.config
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let device = parse_device(args)?;
    let model = parse_model(args)?;
    let out = out_dir(args);
    std::fs::create_dir_all(&out)?;
    let mut dev = Device::new(device, model, 0x53EE9);
    let mut csv = crate::util::csv::Csv::new(&[
        "cpu_freq_mhz", "cpu_cores", "gpu_freq_mhz", "mem_freq_mhz", "concurrency",
        "max_batch", "throughput_fps", "power_mw", "latency_ms",
    ]);
    for cfg in failure::valid_configs(device, model) {
        let m = dev.run(cfg);
        csv.push(vec![
            cfg.cpu_freq_mhz.to_string(),
            cfg.cpu_cores.to_string(),
            cfg.gpu_freq_mhz.to_string(),
            cfg.mem_freq_mhz.to_string(),
            cfg.concurrency.to_string(),
            cfg.max_batch.to_string(),
            format!("{:.2}", m.throughput_fps),
            format!("{:.0}", m.power_mw),
            format!("{:.2}", m.latency_ms),
        ]);
    }
    let path = out.join(format!("sweep_{}_{}.csv", device.name(), model.name()));
    csv.save(&path)?;
    println!(
        "swept {} valid configs ({} simulated hours) -> {}",
        csv.rows.len(),
        dev.sim_clock_s() / 3600.0,
        path.display()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let requests = args.opt_u64_or("requests", 200).map_err(anyhow::Error::msg)?;
    let concurrency =
        args.opt_u64_or("concurrency", 2).map_err(anyhow::Error::msg)? as usize;
    let batch = args.opt_u64_or("batch", 4).map_err(anyhow::Error::msg)? as usize;
    let inflight = args.opt_u64_or("inflight", 16).map_err(anyhow::Error::msg)? as usize;

    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)
        .with_context(|| format!("loading artifacts from {} (run `make artifacts`)", dir.display()))?;
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let model_rt = rt.load_model(&manifest, model)?;
    let side = model_rt.input_side();
    let mut server = Server::new(
        model_rt,
        ServerConfig {
            concurrency,
            batcher: BatcherConfig { max_batch: batch, max_wait: Duration::from_millis(5) },
        },
    );
    let mut video = VideoSource::new(side, 30, 0xCAFE);
    println!(
        "serving {requests} frames of synthetic traffic video ({side}x{side}) \
         with c={concurrency}, batch<={batch} ..."
    );
    let report = server.run_closed_loop(&mut video, requests, inflight)?;
    println!("{report}");
    println!(
        "pump: {} wake-ups ({} deadline fires) for {} requests — event-driven, \
         no sleep-polling",
        report.pump_iterations, report.deadline_fires, report.requests
    );
    server.shutdown();
    Ok(())
}

fn cmd_tenants(args: &Args) -> Result<()> {
    let name = args.opt_or("scenario", "nx-triple");
    let s = scenarios::TenantScenario::by_name(&name).with_context(|| {
        let names: Vec<&str> = scenarios::MULTI_TENANT_SCENARIOS.iter().map(|s| s.name).collect();
        format!("unknown tenant scenario '{name}' (expected one of: {})", names.join(", "))
    })?;
    let rounds = args.opt_u64_or("rounds", 3).map_err(anyhow::Error::msg)? as usize;
    let seed = args.opt_u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let policy_name = args.opt_or("policy", "waterfill");
    let cached = args.has_flag("cached");
    let policy = match policy_name.as_str() {
        "static" => Some(BudgetPolicy::Static(s.static_shares())),
        "demand" => Some(BudgetPolicy::DemandWeighted),
        "waterfill" => Some(BudgetPolicy::WaterFill),
        "independent" => None,
        other => bail!("unknown policy '{other}' (static|demand|waterfill|independent)"),
    };
    let mut arb = match (policy, cached) {
        (Some(p), true) => s.arbiter_cached(p, seed),
        (Some(p), false) => s.arbiter(p, seed),
        (None, false) => s.independent(seed),
        (None, true) => bail!("--cached requires an arbitrated policy (static|demand|waterfill)"),
    };
    if args.has_flag("sequential") {
        arb = arb.sequential();
    }
    println!(
        "{} — {} tenants on one {} box, {:.1} W global envelope, policy {policy_name}, \
         {rounds} round(s)",
        s.name,
        s.tenants.len(),
        s.device,
        s.global_budget_mw / 1000.0
    );
    let mut rows = Vec::new();
    for _ in 0..rounds {
        let report = arb.run_round();
        for t in &report.tenants {
            rows.push(vec![
                report.round.to_string(),
                t.name.to_string(),
                t.model.to_string(),
                format!("{:.2}", t.sub_budget_mw / 1000.0),
                format!("{:.1}/{:.0}", t.chosen.throughput_fps, tenant_target(s, t.name)),
                format!("{:.2}", t.chosen.power_mw / 1000.0),
                if t.fell_back {
                    "floor".into()
                } else if t.feasible {
                    "ok".into()
                } else {
                    "infeas".into()
                },
                t.restarts.to_string(),
            ]);
        }
        rows.push(vec![
            report.round.to_string(),
            "= box".to_string(),
            String::new(),
            format!("{:.2}", s.global_budget_mw / 1000.0),
            String::new(),
            format!("{:.2}", report.aggregate_power_mw / 1000.0),
            if report.overshoot_mw > 0.0 {
                format!("OVER +{:.2} W", report.overshoot_mw / 1000.0)
            } else {
                "within".into()
            },
            String::new(),
        ]);
    }
    print!(
        "{}",
        table::render(
            &["round", "tenant", "model", "budget W", "fps/target", "power W", "state", "restarts"],
            &rows
        )
    );
    if cached {
        // Per-tenant measurement-cache accounting over the whole run
        // (environment-lifetime counters). `epoch` counts the tenant's
        // own drift invalidations — neighbours never bump it.
        let mut crows = Vec::new();
        for (spec, st) in arb.specs().iter().zip(arb.tenant_cache_stats()) {
            let st = st.expect("cached arbiter wraps every tenant");
            crows.push(vec![
                spec.name.to_string(),
                st.hits.to_string(),
                st.misses.to_string(),
                st.refreshes.to_string(),
                format!("{:.0}%", st.hit_rate() * 100.0),
                st.windows_saved().to_string(),
                format!("{:.0}", st.cost_saved_s),
                st.epoch.to_string(),
            ]);
        }
        println!("\nmeasurement cache (per tenant, whole run):");
        print!(
            "{}",
            table::render(
                &["tenant", "hits", "misses", "refresh", "hit rate", "saved w", "saved s", "epoch"],
                &crows
            )
        );
    }
    let max_over = arb
        .history()
        .iter()
        .map(|r| r.overshoot_mw)
        .fold(0.0, f64::max);
    println!(
        "\nmax aggregate overshoot across rounds: {:.2} W (search cost {:.0} s)",
        max_over / 1000.0,
        arb.cost_s()
    );
    Ok(())
}

fn cmd_hetero(args: &Args) -> Result<()> {
    let name = args.opt_or("scenario", "hetero-yolo-pair");
    let s = scenarios::HeteroScenario::by_name(&name).with_context(|| {
        let names: Vec<&str> = scenarios::HETERO_SCENARIOS.iter().map(|s| s.name).collect();
        format!("unknown hetero scenario '{name}' (expected one of: {})", names.join(", "))
    })?;
    let seed = args.opt_u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let iters = args.opt_u64_or("iters", 10).map_err(anyhow::Error::msg)? as usize;
    let mut fleet = s.fleet(seed);
    if args.has_flag("sequential") {
        fleet = fleet.sequential();
    }
    let cons = s.constraints();
    let space = fleet.space().clone();
    let boards: Vec<&str> = s.devices.iter().map(|d| d.name()).collect();
    println!(
        "{} — one CORAL tuning a mixed fleet [{}] serving {} through the normalized \
         rank-fraction grid\nfleet-mean target {} fps, fleet-mean budget {} mW \
         (common envelope {:.1} W)",
        s.name,
        boards.join(" + "),
        s.model,
        s.target_fps,
        s.budget_mw,
        s.devices.len() as f64 * s.budget_mw / 1000.0
    );
    let opt = CoralOptimizer::new(space.clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(fleet, opt, cons, iters);
    let out = cl.run_observed(|step, _| {
        let m = &step.measured;
        println!(
            "  it{:>2}: {} -> fleet mean {:6.1} fps {:6.0} mW {}",
            step.iter,
            space.describe(&step.config),
            m.throughput_fps,
            m.power_mw,
            if m.failed.is_some() { "[FAILED on some member]" } else { "" }
        );
    });
    let best = out.best.context("no observations")?;
    let fleet = cl.into_env();
    println!(
        "\nchosen: {} -> fleet mean {:.1} fps @ {:.0} mW  feasible={}",
        space.describe(&best.config),
        best.throughput_fps,
        best.power_mw,
        best.feasible
    );
    let ns = fleet.norm().expect("hetero fleets are normalized");
    let mut rows = Vec::new();
    for (i, native) in fleet.decoded(best.config).iter().enumerate() {
        rows.push(vec![
            format!("{i}"),
            s.devices[i].name().to_string(),
            ns.members()[i].describe(native),
        ]);
    }
    print!(
        "{}",
        table::render(&["member", "device", "decoded native configuration"], &rows)
    );
    println!(
        "\nsearch cost: {:.0} simulated seconds for the whole fleet ({} fleet windows; \
         every window measures all {} boards in parallel — one search instead of {})",
        out.cost_s,
        out.iters,
        s.devices.len(),
        s.devices.len()
    );
    Ok(())
}

fn cmd_chaos(args: &Args) -> Result<()> {
    let picked: Vec<&scenarios::ChaosScenario> = match args.opt("scenario") {
        Some(name) => {
            let s = scenarios::ChaosScenario::by_name(name).with_context(|| {
                let names: Vec<&str> =
                    scenarios::CHAOS_SCENARIOS.iter().map(|s| s.name).collect();
                format!("unknown chaos scenario '{name}' (expected one of: {})", names.join(", "))
            })?;
            vec![s]
        }
        None => scenarios::CHAOS_SCENARIOS.iter().collect(),
    };
    let seed = args.opt_u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let windows_opt = args.opt_u64_or("windows", 0).map_err(anyhow::Error::msg)?;
    println!(
        "chaos fleet — CORAL driven through a deterministic fault schedule \
         (search → hold → re-search every {CHAOS_HOLD_WINDOWS}-window hold; \
         recovery = windows from event to first re-feasible measurement)"
    );
    let mut rows = Vec::new();
    let mut summaries = Vec::new();
    for s in picked {
        let windows = if windows_opt > 0 { windows_opt } else { s.windows };
        println!(
            "\n{}: [{}] serving {} — target {} fps, budget {} mW, {} windows, {} scheduled events",
            s.name,
            s.devices.iter().map(|d| d.name()).collect::<Vec<_>>().join(" + "),
            s.model,
            s.target_fps,
            s.budget_mw,
            windows,
            s.schedule(seed ^ 0x0DD5_EED5).len(),
        );
        let env = s.chaos(seed);
        let done = drive_coral(env, s.constraints(), seed, windows);
        for r in done.recoveries() {
            rows.push(vec![
                s.name.to_string(),
                r.label.clone(),
                r.at_window.to_string(),
                r.recovered_at.map_or("never".to_string(), |w| w.to_string()),
                r.windows().map_or("∞".to_string(), |w| w.to_string()),
            ]);
        }
        summaries.push((s.name, done.mean_recovery_windows(), done.all_recovered()));
    }
    print!(
        "{}",
        table::render(&["scenario", "event", "at window", "recovered at", "windows"], &rows)
    );
    println!();
    for (name, mean, all) in summaries {
        println!(
            "{name}: mean recovery {:.1} windows, all events recovered: {all}",
            mean
        );
    }
    Ok(())
}

fn cmd_fleetscale(args: &Args) -> Result<()> {
    let picked: Vec<&scenarios::FleetScaleScenario> = match args.opt("scenario") {
        Some(name) => {
            let s = scenarios::FleetScaleScenario::by_name(name).with_context(|| {
                let names: Vec<&str> =
                    scenarios::FLEET_SCALE_SCENARIOS.iter().map(|s| s.name).collect();
                format!("unknown fleet scenario '{name}' (expected one of: {})", names.join(", "))
            })?;
            vec![s]
        }
        None => scenarios::FLEET_SCALE_SCENARIOS.iter().collect(),
    };
    let seed = args.opt_u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let rounds = args.opt_u64_or("rounds", 3).map_err(anyhow::Error::msg)?.max(1);
    let workers = args.opt_u64_or("workers", 0).map_err(anyhow::Error::msg)? as usize;
    let workers_label = if workers > 0 {
        workers.to_string()
    } else {
        "auto".to_string()
    };
    println!(
        "fleet-scale sweep — {rounds} measurement rounds per fleet on one persistent \
         work-stealing pool per fleet (workers: {workers_label})"
    );
    let mut rows = Vec::new();
    for s in picked {
        let mut fleet = s.fleet(seed);
        if workers > 0 {
            fleet = fleet.with_workers(workers);
        }
        let space = fleet.space().clone();
        let mut rng = crate::util::Rng::new(seed);
        // Warm-up window builds the pool; after this, spawn counts must
        // never move (that is the whole point of the pool).
        fleet.measure(space.midpoint());
        let spawned_after_warmup = fleet.spawned_threads();
        let mut best_s = f64::INFINITY;
        let mut sum_s = 0.0;
        let mut feasible = 0u64;
        let cons = s.constraints();
        for _ in 0..rounds {
            let cfg = space.random(&mut rng);
            let t0 = std::time::Instant::now();
            let m = fleet.measure(cfg);
            let dt = t0.elapsed().as_secs_f64();
            best_s = best_s.min(dt);
            sum_s += dt;
            if cons.feasible(m.throughput_fps, m.power_mw) {
                feasible += 1;
            }
        }
        assert_eq!(
            fleet.spawned_threads(),
            spawned_after_warmup,
            "pool must not respawn threads once measuring starts"
        );
        let mean_s = sum_s / rounds as f64;
        rows.push(vec![
            s.name.to_string(),
            s.members.to_string(),
            fleet.pool_workers().to_string(),
            fleet.spawned_threads().to_string(),
            fleet.pool_steals().to_string(),
            format!("{:.2}", best_s * 1e3),
            format!("{:.2}", mean_s * 1e3),
            format!("{:.2}", mean_s * 1e6 / s.members as f64),
            format!("{feasible}/{rounds}"),
        ]);
    }
    print!(
        "{}",
        table::render(
            &[
                "scenario", "members", "workers", "spawned", "steals", "best ms", "mean ms",
                "us/member", "feasible",
            ],
            &rows
        )
    );
    println!(
        "\nspawned == workers for every fleet: threads are spawned once at pool construction, \
         then every round is O(1)-dispatch index jobs (see bench_fleet_scale for the asserted \
         scaling curve)."
    );
    Ok(())
}

fn tenant_target(s: &scenarios::TenantScenario, name: &str) -> f64 {
    s.tenants
        .iter()
        .find(|t| t.name == name)
        .map(|t| t.target_fps)
        .unwrap_or(0.0)
}

fn cmd_load(args: &Args) -> Result<()> {
    let name = args.opt_or("scenario", "load-nx-yolo-steady");
    let s = scenarios::LoadScenario::by_name(&name).with_context(|| {
        let names: Vec<&str> = scenarios::LOAD_SCENARIOS.iter().map(|s| s.name).collect();
        format!("unknown load scenario '{name}' (one of: {})", names.join(", "))
    })?;
    let iters = args.opt_u64_or("iters", 10).map_err(anyhow::Error::msg)? as usize;
    let seed = args.opt_u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let cons = s.constraints();
    println!(
        "{}: {}/{} under '{}' arrivals at {:.0} fps — {}",
        s.name,
        s.device,
        s.model,
        s.profile,
        s.base_rate_fps,
        cons.describe()
    );

    // CORAL over the 6-dim space, every window queued against the load.
    let opt = CoralOptimizer::new(s.env(seed).space().clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(s.env(seed), opt, cons, iters);
    let out = cl.run();
    let best = out.best.context("no observations")?;
    println!(
        "best after {} windows: {} -> {:.1} fps @ {:.0} mW, p99 {:.1} ms  feasible={}",
        out.iters,
        best.config,
        best.throughput_fps,
        best.power_mw,
        best.p99_latency_ms,
        best.feasible
    );

    // Noise-free shed ramp: the offered rate each policy sustains. The
    // oracle ramps over the opened 6-dim grid; the batch=1 slice is the
    // legacy 5-dim ceiling the sixth dimension buys headroom over.
    let step = s.base_rate_fps * 0.25;
    let valid6: Vec<_> = s
        .env(seed)
        .space()
        .enumerate()
        .into_iter()
        .filter(|c| failure::check(s.device, s.model, c).is_none())
        .collect();
    let valid5: Vec<_> = valid6.iter().filter(|c| c.max_batch == 1).copied().collect();
    let rows = vec![
        vec![
            "oracle (batch axis open)".to_string(),
            format!("{:.1}", s.shed_point_fps(&valid6, step)),
        ],
        vec![
            "oracle (batch=1)".to_string(),
            format!("{:.1}", s.shed_point_fps(&valid5, step)),
        ],
        vec![
            "coral best".to_string(),
            format!("{:.1}", s.shed_point_fps(&[best.config], step)),
        ],
        vec![
            "preset max-power".to_string(),
            format!("{:.1}", s.shed_point_fps(&[s.device.preset_max_power()], step)),
        ],
        vec![
            "preset default".to_string(),
            format!("{:.1}", s.shed_point_fps(&[s.device.preset_default()], step)),
        ],
    ];
    print!("{}", table::render(&["policy", "shed point (fps)"], &rows));
    Ok(())
}

fn cmd_variants(args: &Args) -> Result<()> {
    let name = args.opt_or("scenario", "acc-nx-yolo");
    if name == scenarios::ACCURACY_TENANT_SCENARIO.name {
        return cmd_variants_tenants(args);
    }
    let s = scenarios::AccuracyScenario::by_name(&name).with_context(|| {
        let mut names: Vec<&str> =
            scenarios::ACCURACY_SCENARIOS.iter().map(|s| s.name).collect();
        names.push(scenarios::ACCURACY_TENANT_SCENARIO.name);
        format!("unknown variant scenario '{name}' (one of: {})", names.join(", "))
    })?;
    let iters = args.opt_u64_or("iters", 40).map_err(anyhow::Error::msg)? as usize;
    let seed = args.opt_u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let cons = s.constraints();
    println!("{}: {}/{} — {}", s.name, s.device, s.model, cons.describe());

    // The degradation ladder, with the noise-free feasible-region size
    // each rung opens under all three clauses. Rung 0 is the full model:
    // a zero there is the whole point of the scenario.
    let manifest = s.manifest();
    let space = s.device.space().with_variant_axis(manifest.len());
    let grid = space.enumerate();
    let mut rows = Vec::new();
    for (i, v) in manifest.variants().iter().enumerate() {
        let feasible = grid
            .iter()
            .filter(|c| c.variant == i as u32 && s.config_feasible(c))
            .count();
        rows.push(vec![
            i.to_string(),
            v.label(),
            format!("{:.1}", v.accuracy),
            format!("x{:.2}", v.perf_mult),
            format!("x{:.2}", v.power_mult),
            format!("x{:.2}", v.mem_mult),
            feasible.to_string(),
        ]);
    }
    print!(
        "{}",
        table::render(
            &["idx", "variant", "mAP", "perf", "power", "mem", "feasible cfgs"],
            &rows
        )
    );

    // CORAL over the 7-dim space (variant axis open).
    let opt = CoralOptimizer::new(s.env(seed).space().clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(s.env(seed), opt, cons, iters);
    let out = cl.run();
    let best = out.best.context("no observations")?;
    let v = manifest.get(best.config.variant);
    println!(
        "best after {} windows: {} ({}) -> {:.1} fps @ {:.0} mW, mAP {:.1}  feasible={}",
        out.iters,
        best.config,
        v.label(),
        best.throughput_fps,
        best.power_mw,
        best.accuracy,
        best.feasible
    );
    Ok(())
}

/// The `nx-pair-accuracy` leg of `coral variants`: the same contended
/// box arbitrated twice — variant axis closed (a tenant must starve or
/// overdraw) and open (the floored tenant degrades itself instead).
fn cmd_variants_tenants(args: &Args) -> Result<()> {
    let s = &scenarios::ACCURACY_TENANT_SCENARIO;
    let rounds = args.opt_u64_or("rounds", 3).map_err(anyhow::Error::msg)? as usize;
    let seed = args.opt_u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    println!(
        "{} — {} tenants on one {} box, {:.1} W global envelope, demand-weighted, \
         {rounds} round(s), fixed vs variants",
        s.name,
        s.tenants.len(),
        s.device,
        s.global_budget_mw / 1000.0
    );
    let mut rows = Vec::new();
    for (run, mut arb) in [
        ("fixed", s.arbiter(BudgetPolicy::DemandWeighted, seed)),
        ("variants", s.arbiter_variants(BudgetPolicy::DemandWeighted, seed)),
    ] {
        for _ in 0..rounds {
            let report = arb.run_round();
            for t in &report.tenants {
                let manifest = t.model.standard_variants();
                let v = if run == "variants" {
                    manifest.get(t.chosen.config.variant).label()
                } else {
                    "fixed".to_string()
                };
                rows.push(vec![
                    report.round.to_string(),
                    run.to_string(),
                    t.name.to_string(),
                    v,
                    format!("{:.1}/{:.0}", t.chosen.throughput_fps, tenant_target(s, t.name)),
                    format!("{:.2}", t.chosen.power_mw / 1000.0),
                    format!("{:.1}", t.chosen.accuracy),
                    if t.fell_back {
                        "floor".into()
                    } else if t.feasible {
                        "ok".into()
                    } else {
                        "infeas".into()
                    },
                ]);
            }
            rows.push(vec![
                report.round.to_string(),
                run.to_string(),
                "= box".to_string(),
                String::new(),
                String::new(),
                format!("{:.2}", report.aggregate_power_mw / 1000.0),
                String::new(),
                if report.overshoot_mw > 0.0 {
                    format!("OVER +{:.2} W", report.overshoot_mw / 1000.0)
                } else {
                    "within".into()
                },
            ]);
        }
    }
    print!(
        "{}",
        table::render(
            &["round", "run", "tenant", "variant", "fps/target", "power W", "mAP", "state"],
            &rows
        )
    );
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    match args.sub() {
        Some("specs") => {
            println!("Table 1/2 — devices and tunable ranges");
            let mut rows = Vec::new();
            for d in DeviceKind::ALL {
                let s = d.space();
                rows.push(vec![
                    d.name().to_string(),
                    format!("{}-{} MHz x{}", s.min(Dim::CpuFreq), s.max(Dim::CpuFreq),
                            s.values(Dim::CpuFreq).len()),
                    format!("{}-{}", s.min(Dim::CpuCores), s.max(Dim::CpuCores)),
                    format!("{}-{} MHz x{}", s.min(Dim::GpuFreq), s.max(Dim::GpuFreq),
                            s.values(Dim::GpuFreq).len()),
                    format!("{:?}", s.values(Dim::MemFreq)),
                    format!("1-{}", s.max(Dim::Concurrency)),
                    s.raw_size().to_string(),
                ]);
            }
            print!(
                "{}",
                table::render(
                    &["device", "cpu freq", "cores", "gpu freq", "mem MHz", "conc", "raw size"],
                    &rows
                )
            );
        }
        Some("models") => {
            println!("Table 3 — evaluation models");
            let mut rows = Vec::new();
            for m in ModelKind::ALL {
                let p = m.profile();
                rows.push(vec![
                    m.name().to_string(),
                    format!("{:.1} M", m.params_m()),
                    format!("{:.1}", m.map()),
                    format!("{:.0}", p.gpu_work),
                    format!("{:.2} GB", p.mem_gb_per_instance),
                ]);
            }
            print!(
                "{}",
                table::render(&["model", "params (paper)", "mAP", "gpu work", "mem/inst"], &rows)
            );
        }
        Some("scenarios") => {
            println!("Dual-constraint scenarios (Figs 5-10)");
            let mut rows = Vec::new();
            for s in scenarios::DUAL_SCENARIOS {
                rows.push(vec![
                    s.figures.to_string(),
                    s.device.name().to_string(),
                    s.model.name().to_string(),
                    format!("{}", s.target_fps),
                    format!("{}", s.budget_mw),
                ]);
            }
            print!(
                "{}",
                table::render(&["figures", "device", "model", "target fps", "budget mW"], &rows)
            );
            println!("\nMulti-tenant scenarios (`coral tenants`)");
            let mut rows = Vec::new();
            for s in scenarios::MULTI_TENANT_SCENARIOS {
                let tenants: Vec<String> = s
                    .tenants
                    .iter()
                    .map(|t| format!("{}@{}fps", t.model.name(), t.target_fps))
                    .collect();
                rows.push(vec![
                    s.name.to_string(),
                    s.device.name().to_string(),
                    format!("{}", s.global_budget_mw),
                    tenants.join(" + "),
                ]);
            }
            print!(
                "{}",
                table::render(&["scenario", "device", "global mW", "tenants"], &rows)
            );
            println!("\nHeterogeneous-fleet scenarios (`coral hetero`)");
            let mut rows = Vec::new();
            for s in scenarios::HETERO_SCENARIOS {
                let boards: Vec<&str> = s.devices.iter().map(|d| d.name()).collect();
                rows.push(vec![
                    s.name.to_string(),
                    boards.join(" + "),
                    s.model.name().to_string(),
                    format!("{}", s.target_fps),
                    format!("{}", s.budget_mw),
                ]);
            }
            print!(
                "{}",
                table::render(
                    &["scenario", "fleet", "model", "mean target fps", "mean budget mW"],
                    &rows
                )
            );
            println!("\nFleet-scale scenarios (`coral fleetscale`)");
            let mut rows = Vec::new();
            for s in scenarios::FLEET_SCALE_SCENARIOS {
                rows.push(vec![
                    s.name.to_string(),
                    s.members.to_string(),
                    "nx/orin alternating".to_string(),
                    s.model.name().to_string(),
                    format!("{}", s.target_fps),
                    format!("{}", s.budget_mw),
                ]);
            }
            print!(
                "{}",
                table::render(
                    &["scenario", "members", "fleet", "model", "mean target fps", "mean budget mW"],
                    &rows
                )
            );
        }
        _ => bail!("report expects: specs | models | scenarios"),
    }
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = args
        .opt("dir")
        .map(PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let manifest = Manifest::load(&dir)
        .with_context(|| format!("no manifest in {} — run `make artifacts`", dir.display()))?;
    let mut rows = Vec::new();
    for a in &manifest.artifacts {
        let exists = a.path.exists();
        rows.push(vec![
            a.model.name().to_string(),
            a.batch.to_string(),
            format!("{:?}", a.input_shape),
            a.param_count.to_string(),
            if exists { "ok".into() } else { "MISSING".into() },
        ]);
        if !exists {
            bail!("artifact missing: {}", a.path.display());
        }
    }
    print!(
        "{}",
        table::render(&["model", "batch", "input", "params", "file"], &rows)
    );
    println!("{} artifacts OK in {}", manifest.artifacts.len(), dir.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect()).unwrap()
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&args("frobnicate")).is_err());
    }

    #[test]
    fn help_is_ok() {
        assert!(dispatch(&args("help")).is_ok());
    }

    #[test]
    fn report_subcommands() {
        assert!(dispatch(&args("report specs")).is_ok());
        assert!(dispatch(&args("report models")).is_ok());
        assert!(dispatch(&args("report scenarios")).is_ok());
        assert!(dispatch(&args("report bogus")).is_err());
    }

    #[test]
    fn experiment_table4_smoke() {
        let dir = std::env::temp_dir().join("coral_cli_exp");
        let _ = std::fs::remove_dir_all(&dir);
        let a = args(&format!("experiment table4 --out {} --seeds 1", dir.display()));
        assert!(dispatch(&a).is_ok());
        assert!(dir.join("table4.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn optimize_with_trace_writes_csv() {
        let path = std::env::temp_dir().join("coral_cli_trace.csv");
        let _ = std::fs::remove_file(&path);
        let a = args(&format!(
            "optimize --device orin --model yolo --target 60 --budget 5600 --iters 4 --seed 2 --trace {}",
            path.display()
        ));
        assert!(dispatch(&a).is_ok());
        let trace = crate::workload::Trace::load(&path).unwrap();
        assert_eq!(trace.len(), 4);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn optimize_smoke() {
        let a = args("optimize --device nx --model yolo --target 30 --budget 6500 --iters 3 --seed 1");
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn optimize_validates_device() {
        let a = args("optimize --device toaster");
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn tenants_smoke_all_policies() {
        for policy in ["static", "demand", "waterfill", "independent"] {
            let a = args(&format!(
                "tenants --scenario nx-pair --policy {policy} --rounds 1 --seed 3 --sequential"
            ));
            assert!(dispatch(&a).is_ok(), "policy {policy}");
        }
    }

    #[test]
    fn tenants_validates_scenario_and_policy() {
        assert!(dispatch(&args("tenants --scenario mars-rover")).is_err());
        assert!(dispatch(&args("tenants --scenario nx-pair --policy greedy")).is_err());
    }

    #[test]
    fn tenants_cached_smoke_and_validation() {
        let a = args(
            "tenants --scenario nx-pair --policy waterfill --rounds 2 --seed 3 --sequential --cached",
        );
        assert!(dispatch(&a).is_ok());
        // The unarbitrated baseline carries no cache layer.
        assert!(dispatch(&args("tenants --scenario nx-pair --policy independent --cached")).is_err());
    }

    #[test]
    fn optimize_cached_smoke() {
        let a = args(
            "optimize --device nx --model yolo --target 30 --budget 6500 --iters 3 --seed 1 --cached",
        );
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn hetero_smoke() {
        let a = args("hetero --scenario hetero-yolo-pair --iters 3 --seed 7 --sequential");
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn hetero_validates_scenario() {
        assert!(dispatch(&args("hetero --scenario mono-fleet")).is_err());
    }

    #[test]
    fn chaos_smoke() {
        let a = args("chaos --scenario chaos-dropout-pair --windows 30 --seed 5");
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn chaos_validates_scenario() {
        assert!(dispatch(&args("chaos --scenario chaos-meteor-strike")).is_err());
    }

    #[test]
    fn fleetscale_smoke() {
        let a = args("fleetscale --scenario fleet-10 --rounds 2 --seed 7 --workers 2");
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn fleetscale_validates_scenario() {
        assert!(dispatch(&args("fleetscale --scenario fleet-of-foot")).is_err());
    }

    #[test]
    fn load_smoke() {
        let a = args("load --scenario load-nx-yolo-steady --iters 3 --seed 7");
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn load_validates_scenario() {
        assert!(dispatch(&args("load --scenario load-shedding-grid")).is_err());
    }

    #[test]
    fn variants_smoke() {
        let a = args("variants --scenario acc-nx-yolo --iters 3 --seed 7");
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn variants_tenants_smoke() {
        let a = args("variants --scenario nx-pair-accuracy --rounds 1 --seed 7");
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn variants_validates_scenario() {
        assert!(dispatch(&args("variants --scenario acc-toaster-alexnet")).is_err());
    }
}
