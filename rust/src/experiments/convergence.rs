//! Convergence curves: best-so-far reward/feasibility per iteration for
//! CORAL vs the online baselines. The paper asserts "converging to valid
//! configurations within 10 iterations" (§I) but never plots it; this
//! harness regenerates the per-iteration series behind that claim.

use std::path::Path;

use anyhow::Result;

use crate::control::{ControlLoop, SimEnv};
use crate::device::Device;
use crate::models::ModelKind;
use crate::optimizer::{
    AlertOnlineOptimizer, Constraints, CoralOptimizer, Optimizer, RandomOptimizer,
};
use crate::util::csv::Csv;
use crate::util::table;

use super::scenarios::{DualScenario, DUAL_SCENARIOS};

/// Best-so-far series of one method on one scenario (averaged rates).
#[derive(Debug, Clone)]
pub struct Curve {
    pub method: &'static str,
    /// `feasible_rate[i]` = fraction of seeds whose best-so-far at
    /// iteration i (1-based internally, index 0 = after 1st observation)
    /// satisfies both constraints.
    pub feasible_rate: Vec<f64>,
}

fn run_curve<F>(s: DualScenario, seeds: u64, iters: usize, make: F) -> Curve
where
    F: Fn(&Device, Constraints, u64) -> (&'static str, Box<dyn Optimizer>),
{
    let cons = Constraints::dual(s.target_fps, s.budget_mw);
    let mut hits = vec![0u64; iters];
    let mut name = "";
    for seed in 0..seeds {
        let dev = Device::new(s.device, s.model, 0xC09E + seed);
        let (n, opt) = make(&dev, cons, seed);
        name = n;
        let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, iters);
        let out = cl.run();
        // Best-so-far feasibility per iteration is exactly the loop's
        // convergence record.
        for (i, feasible) in out.feasible_by_iter.iter().enumerate() {
            if *feasible {
                hits[i] += 1;
            }
        }
    }
    Curve {
        method: name,
        feasible_rate: hits.iter().map(|&h| h as f64 / seeds as f64).collect(),
    }
}

/// Curves for one scenario: CORAL, ALERT-Online, random.
pub fn curves(s: DualScenario, seeds: u64, iters: usize) -> Vec<Curve> {
    vec![
        run_curve(s, seeds, iters, |dev, cons, seed| {
            ("coral", Box::new(CoralOptimizer::new(dev.space().clone(), cons, seed)))
        }),
        run_curve(s, seeds, iters, |dev, cons, seed| {
            (
                "alert-online",
                Box::new(AlertOnlineOptimizer::new(dev.space().clone(), cons, seed)),
            )
        }),
        run_curve(s, seeds, iters, |dev, cons, seed| {
            ("random", Box::new(RandomOptimizer::new(dev.space().clone(), cons, seed)))
        }),
    ]
}

/// Regenerate convergence curves for every dual scenario into
/// `<out>/convergence.csv`.
pub fn run(out_dir: &Path, seeds: u64) -> Result<()> {
    const ITERS: usize = 10;
    let mut csv = Csv::new(&["device", "model", "method", "iteration", "feasible_rate"]);
    println!("Convergence — feasible-by-iteration (dual constraints, {seeds} seeds)");
    for s in DUAL_SCENARIOS.iter().filter(|s| s.model == ModelKind::Yolo) {
        let mut rows = Vec::new();
        for c in curves(*s, seeds, ITERS) {
            for (i, r) in c.feasible_rate.iter().enumerate() {
                csv.push(vec![
                    s.device.name().into(),
                    s.model.name().into(),
                    c.method.into(),
                    (i + 1).to_string(),
                    format!("{r:.2}"),
                ]);
            }
            rows.push(
                std::iter::once(c.method.to_string())
                    .chain(c.feasible_rate.iter().map(|r| format!("{:.0}", r * 100.0)))
                    .collect::<Vec<_>>(),
            );
        }
        let mut header = vec!["method".to_string()];
        header.extend((1..=ITERS).map(|i| format!("it{i}")));
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        println!("{} / {} (% of seeds feasible by iteration):", s.device, s.model);
        print!("{}", table::render(&header_refs, &rows));
    }
    csv.save(&out_dir.join("convergence.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coral_converges_earlier_than_random() {
        let s = DUAL_SCENARIOS[0]; // NX / YOLO
        let cs = curves(s, 10, 10);
        let coral = cs.iter().find(|c| c.method == "coral").unwrap();
        let random = cs.iter().find(|c| c.method == "random").unwrap();
        // Monotone best-so-far.
        assert!(coral
            .feasible_rate
            .windows(2)
            .all(|w| w[1] >= w[0] - 1e-9));
        // By the budget's end CORAL dominates.
        assert!(
            coral.feasible_rate[9] > random.feasible_rate[9],
            "coral {:?} vs random {:?}",
            coral.feasible_rate,
            random.feasible_rate
        );
        assert!(coral.feasible_rate[9] >= 0.9);
    }
}
