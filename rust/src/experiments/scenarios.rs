//! Evaluation scenarios: the paper's constraint settings per
//! (device, model) pair.
//!
//! YOLO budgets/targets are the paper's (§IV-B): NX 6500 mW / 30 fps,
//! Orin 5600 mW / 60 fps. The paper does not state the FRCNN/RETINANET
//! numbers; ours are chosen the same way the paper describes the YOLO
//! ones — tight enough that the feasible region is a few percent of the
//! valid space (DESIGN.md §6), which is what makes the baselines fail.

use crate::device::DeviceKind;
use crate::models::ModelKind;
use crate::optimizer::Constraints;

/// One dual-constraint scenario (paper Figs 5–10).
#[derive(Debug, Clone, Copy)]
pub struct DualScenario {
    pub device: DeviceKind,
    pub model: ModelKind,
    pub target_fps: f64,
    pub budget_mw: f64,
    /// Paper figure ids this scenario regenerates.
    pub figures: &'static str,
}

/// All six dual-constraint scenarios (2 devices × 3 models).
pub const DUAL_SCENARIOS: [DualScenario; 6] = [
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::Yolo,
        target_fps: 30.0,
        budget_mw: 6500.0,
        figures: "fig5,fig6",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::Yolo,
        target_fps: 60.0,
        budget_mw: 5600.0,
        figures: "fig5,fig6",
    },
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::Frcnn,
        target_fps: 8.0,
        budget_mw: 6000.0,
        figures: "fig7,fig8",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::Frcnn,
        target_fps: 15.0,
        budget_mw: 4500.0,
        figures: "fig7,fig8",
    },
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::RetinaNet,
        target_fps: 4.0,
        budget_mw: 6000.0,
        figures: "fig9,fig10",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::RetinaNet,
        target_fps: 8.0,
        budget_mw: 4600.0,
        figures: "fig9,fig10",
    },
];

/// Constraints of the dual scenario for (device, model).
pub fn dual_constraints(device: DeviceKind, model: ModelKind) -> Constraints {
    let s = DUAL_SCENARIOS
        .iter()
        .find(|s| s.device == device && s.model == model)
        .expect("scenario exists for every (device, model)");
    Constraints::dual(s.target_fps, s.budget_mw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{failure, perf, power};

    #[test]
    fn every_pair_covered() {
        for d in DeviceKind::ALL {
            for m in ModelKind::ALL {
                let _ = dual_constraints(d, m); // must not panic
            }
        }
    }

    #[test]
    fn feasible_regions_are_narrow_but_nonempty() {
        // The paper's premise: the dual-constraint region is a thin slice
        // of the valid space (hence random search fails) yet reachable
        // (hence CORAL/ORACLE succeed).
        for s in DUAL_SCENARIOS {
            let valid = failure::valid_configs(s.device, s.model);
            let feasible = valid
                .iter()
                .filter(|c| {
                    let pf = perf::evaluate(s.device, s.model, c);
                    let pw = power::evaluate(s.device, c, &pf).total_mw();
                    pf.throughput_fps >= s.target_fps && pw <= s.budget_mw
                })
                .count();
            let frac = feasible as f64 / valid.len() as f64;
            assert!(feasible > 0, "{:?}: empty feasible region", s);
            assert!(
                frac < 0.12,
                "{}/{}: feasible region too wide ({:.1}%)",
                s.device,
                s.model,
                frac * 100.0
            );
        }
    }
}
