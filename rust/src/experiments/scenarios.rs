//! Evaluation scenarios: the paper's constraint settings per
//! (device, model) pair, plus the large-window telemetry family that
//! stresses the O(n log n) dCor path beyond the paper's W=10.
//!
//! YOLO budgets/targets are the paper's (§IV-B): NX 6500 mW / 30 fps,
//! Orin 5600 mW / 60 fps. The paper does not state the FRCNN/RETINANET
//! numbers; ours are chosen the same way the paper describes the YOLO
//! ones — tight enough that the feasible region is a few percent of the
//! valid space (DESIGN.md §6), which is what makes the baselines fail.

use crate::device::DeviceKind;
use crate::models::ModelKind;
use crate::optimizer::{Constraints, CoralConfig};
use crate::telemetry::Sampler;

/// One dual-constraint scenario (paper Figs 5–10).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DualScenario {
    pub device: DeviceKind,
    pub model: ModelKind,
    pub target_fps: f64,
    pub budget_mw: f64,
    /// Paper figure ids this scenario regenerates.
    pub figures: &'static str,
}

/// All six dual-constraint scenarios (2 devices × 3 models).
pub const DUAL_SCENARIOS: [DualScenario; 6] = [
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::Yolo,
        target_fps: 30.0,
        budget_mw: 6500.0,
        figures: "fig5,fig6",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::Yolo,
        target_fps: 60.0,
        budget_mw: 5600.0,
        figures: "fig5,fig6",
    },
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::Frcnn,
        target_fps: 8.0,
        budget_mw: 6000.0,
        figures: "fig7,fig8",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::Frcnn,
        target_fps: 15.0,
        budget_mw: 4500.0,
        figures: "fig7,fig8",
    },
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::RetinaNet,
        target_fps: 4.0,
        budget_mw: 6000.0,
        figures: "fig9,fig10",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::RetinaNet,
        target_fps: 8.0,
        budget_mw: 4600.0,
        figures: "fig9,fig10",
    },
];

/// Large-window telemetry scenario: how much observation history the
/// optimizer and the coordinator's sampler retain. The paper runs W=10;
/// fleet-scale serving wants orders of magnitude more context, which is
/// feasible only with the O(n log n) dCor engine (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowScenario {
    pub name: &'static str,
    /// Sliding-window / telemetry-history size W.
    pub window: usize,
    /// Online iterations a stress run should drive (> W so the window
    /// actually wraps).
    pub iters: usize,
}

/// The window-scaling family: the paper's W=10 plus 100 / 1k / 10k.
pub const WINDOW_SCENARIOS: [WindowScenario; 4] = [
    WindowScenario { name: "paper-w10", window: 10, iters: 15 },
    WindowScenario { name: "fleet-w100", window: 100, iters: 140 },
    WindowScenario { name: "fleet-w1k", window: 1_000, iters: 1_200 },
    WindowScenario { name: "fleet-w10k", window: 10_000, iters: 12_000 },
];

impl WindowScenario {
    /// CORAL tunables for this window size (paper defaults otherwise).
    pub fn coral_config(&self) -> CoralConfig {
        CoralConfig::with_window(self.window)
    }

    /// Coordinator telemetry sampler retaining W samples.
    pub fn sampler(&self) -> Sampler {
        Sampler::with_window(self.window)
    }
}

/// Constraints of the dual scenario for (device, model).
pub fn dual_constraints(device: DeviceKind, model: ModelKind) -> Constraints {
    let s = DUAL_SCENARIOS
        .iter()
        .find(|s| s.device == device && s.model == model)
        .expect("scenario exists for every (device, model)");
    Constraints::dual(s.target_fps, s.budget_mw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{failure, perf, power, Device};
    use crate::optimizer::CoralOptimizer;

    #[test]
    fn window_family_spans_three_orders_of_magnitude() {
        assert!(WINDOW_SCENARIOS.windows(2).all(|w| w[0].window < w[1].window));
        assert!(WINDOW_SCENARIOS.iter().all(|s| s.iters > s.window));
        assert_eq!(WINDOW_SCENARIOS[0].window, 10, "paper default first");
        assert_eq!(WINDOW_SCENARIOS.last().unwrap().window, 10_000);
        for s in WINDOW_SCENARIOS {
            assert_eq!(s.coral_config().window, s.window);
            assert_eq!(s.sampler().window_capacity(), s.window);
        }
    }

    #[test]
    fn fleet_w100_scenario_drives_coral_end_to_end() {
        // The first fleet-scale window: W exceeds the dCor fast-path
        // threshold, the stress run wraps the window, and the search
        // keeps functioning end to end through the canonical ControlLoop.
        let s = WINDOW_SCENARIOS[1];
        let device = DeviceKind::OrinNano;
        let model = ModelKind::Yolo;
        let cons = dual_constraints(device, model);
        let dev = Device::new(device, model, 27);
        let opt = CoralOptimizer::with_config(dev.space().clone(), cons, s.coral_config(), 27);
        let mut cl = crate::control::ControlLoop::with_budget(
            crate::control::SimEnv::new(dev),
            opt,
            cons,
            s.iters,
        );
        let out = cl.run();
        assert_eq!(out.iters, s.iters);
        assert!(cl.opt().window_len() <= s.window);
        assert!(
            cl.opt().window_len() > crate::stats::dcov::FAST_PATH_MIN_N,
            "window {} should engage the fast path",
            cl.opt().window_len()
        );
        assert!(out.best.is_some());
    }

    #[test]
    fn every_pair_covered() {
        for d in DeviceKind::ALL {
            for m in ModelKind::ALL {
                let _ = dual_constraints(d, m); // must not panic
            }
        }
    }

    #[test]
    fn feasible_regions_are_narrow_but_nonempty() {
        // The paper's premise: the dual-constraint region is a thin slice
        // of the valid space (hence random search fails) yet reachable
        // (hence CORAL/ORACLE succeed).
        for s in DUAL_SCENARIOS {
            let valid = failure::valid_configs(s.device, s.model);
            let feasible = valid
                .iter()
                .filter(|c| {
                    let pf = perf::evaluate(s.device, s.model, c);
                    let pw = power::evaluate(s.device, c, &pf).total_mw();
                    pf.throughput_fps >= s.target_fps && pw <= s.budget_mw
                })
                .count();
            let frac = feasible as f64 / valid.len() as f64;
            assert!(feasible > 0, "{:?}: empty feasible region", s);
            assert!(
                frac < 0.12,
                "{}/{}: feasible region too wide ({:.1}%)",
                s.device,
                s.model,
                frac * 100.0
            );
        }
    }
}
