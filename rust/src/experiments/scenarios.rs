//! Evaluation scenarios: the paper's constraint settings per
//! (device, model) pair, plus the large-window telemetry family that
//! stresses the O(n log n) dCor path beyond the paper's W=10.
//!
//! YOLO budgets/targets are the paper's (§IV-B): NX 6500 mW / 30 fps,
//! Orin 5600 mW / 60 fps. The paper does not state the FRCNN/RETINANET
//! numbers; ours are chosen the same way the paper describes the YOLO
//! ones — tight enough that the feasible region is a few percent of the
//! valid space (DESIGN.md §6), which is what makes the baselines fail.

use crate::control::chaos::{ChaosEnv, ChaosEvent, ChaosSchedule, GlitchKind};
use crate::control::tenant::{BudgetPolicy, Tenant, TenantArbiter};
use crate::control::{FleetEnv, SimEnv};
use crate::device::thermal::ThermalModel;
use crate::device::{Device, DeviceKind};
use crate::models::ModelKind;
use crate::optimizer::{Constraints, CoralConfig};
use crate::telemetry::Sampler;
use crate::util::Rng;

/// One dual-constraint scenario (paper Figs 5–10).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DualScenario {
    pub device: DeviceKind,
    pub model: ModelKind,
    pub target_fps: f64,
    pub budget_mw: f64,
    /// Paper figure ids this scenario regenerates.
    pub figures: &'static str,
}

/// All six dual-constraint scenarios (2 devices × 3 models).
pub const DUAL_SCENARIOS: [DualScenario; 6] = [
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::Yolo,
        target_fps: 30.0,
        budget_mw: 6500.0,
        figures: "fig5,fig6",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::Yolo,
        target_fps: 60.0,
        budget_mw: 5600.0,
        figures: "fig5,fig6",
    },
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::Frcnn,
        target_fps: 8.0,
        budget_mw: 6000.0,
        figures: "fig7,fig8",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::Frcnn,
        target_fps: 15.0,
        budget_mw: 4500.0,
        figures: "fig7,fig8",
    },
    DualScenario {
        device: DeviceKind::XavierNx,
        model: ModelKind::RetinaNet,
        target_fps: 4.0,
        budget_mw: 6000.0,
        figures: "fig9,fig10",
    },
    DualScenario {
        device: DeviceKind::OrinNano,
        model: ModelKind::RetinaNet,
        target_fps: 8.0,
        budget_mw: 4600.0,
        figures: "fig9,fig10",
    },
];

/// Large-window telemetry scenario: how much observation history the
/// optimizer and the coordinator's sampler retain. The paper runs W=10;
/// fleet-scale serving wants orders of magnitude more context, which is
/// feasible only with the O(n log n) dCor engine (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowScenario {
    pub name: &'static str,
    /// Sliding-window / telemetry-history size W.
    pub window: usize,
    /// Online iterations a stress run should drive (> W so the window
    /// actually wraps).
    pub iters: usize,
}

/// The window-scaling family: the paper's W=10 plus 100 / 1k / 10k.
pub const WINDOW_SCENARIOS: [WindowScenario; 4] = [
    WindowScenario { name: "paper-w10", window: 10, iters: 15 },
    WindowScenario { name: "fleet-w100", window: 100, iters: 140 },
    WindowScenario { name: "fleet-w1k", window: 1_000, iters: 1_200 },
    WindowScenario { name: "fleet-w10k", window: 10_000, iters: 12_000 },
];

impl WindowScenario {
    /// CORAL tunables for this window size (paper defaults otherwise).
    pub fn coral_config(&self) -> CoralConfig {
        CoralConfig::with_window(self.window)
    }

    /// Coordinator telemetry sampler retaining W samples.
    pub fn sampler(&self) -> Sampler {
        Sampler::with_window(self.window)
    }
}

/// Multi-tenant arbitration scenario: several models sharing one box
/// under one global power envelope (`control::tenant`). Tenant weights
/// are the paper's per-model power budgets — demand splits then give
/// each tenant a sub-budget a little above its single-tenant scenario,
/// so the per-tenant feasible regions stay nonempty while the *sum*
/// stays capped at a global budget no unarbitrated trio would respect.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantScenario {
    pub name: &'static str,
    pub device: DeviceKind,
    /// Shared box power envelope (mW).
    pub global_budget_mw: f64,
    pub tenants: &'static [Tenant],
}

/// The multi-tenant family: a two-model NX box plus the full
/// three-detector mixes on both boards.
pub const MULTI_TENANT_SCENARIOS: [TenantScenario; 3] = [
    TenantScenario {
        name: "nx-pair",
        device: DeviceKind::XavierNx,
        global_budget_mw: 13_500.0,
        tenants: &[
            Tenant {
                name: "cam-yolo",
                model: ModelKind::Yolo,
                target_fps: 30.0,
                weight: 6.5,
                min_accuracy: None,
            },
            Tenant {
                name: "lidar-frcnn",
                model: ModelKind::Frcnn,
                target_fps: 8.0,
                weight: 6.0,
                min_accuracy: None,
            },
        ],
    },
    TenantScenario {
        name: "nx-triple",
        device: DeviceKind::XavierNx,
        global_budget_mw: 21_000.0,
        tenants: &[
            Tenant {
                name: "cam-yolo",
                model: ModelKind::Yolo,
                target_fps: 30.0,
                weight: 6.5,
                min_accuracy: None,
            },
            Tenant {
                name: "lidar-frcnn",
                model: ModelKind::Frcnn,
                target_fps: 8.0,
                weight: 6.0,
                min_accuracy: None,
            },
            Tenant {
                name: "map-retinanet",
                model: ModelKind::RetinaNet,
                target_fps: 4.0,
                weight: 6.0,
                min_accuracy: None,
            },
        ],
    },
    TenantScenario {
        name: "orin-triple",
        device: DeviceKind::OrinNano,
        global_budget_mw: 16_500.0,
        tenants: &[
            Tenant {
                name: "cam-yolo",
                model: ModelKind::Yolo,
                target_fps: 60.0,
                weight: 5.6,
                min_accuracy: None,
            },
            Tenant {
                name: "lidar-frcnn",
                model: ModelKind::Frcnn,
                target_fps: 15.0,
                weight: 4.5,
                min_accuracy: None,
            },
            Tenant {
                name: "map-retinanet",
                model: ModelKind::RetinaNet,
                target_fps: 8.0,
                weight: 4.6,
                min_accuracy: None,
            },
        ],
    },
];

impl TenantScenario {
    /// Find a scenario by name.
    pub fn by_name(name: &str) -> Option<&'static TenantScenario> {
        MULTI_TENANT_SCENARIOS.iter().find(|s| s.name == name)
    }

    /// The tenant weights frozen into fixed fractional shares (what
    /// `BudgetPolicy::Static` means for this scenario).
    pub fn static_shares(&self) -> Vec<f64> {
        let total: f64 = self.tenants.iter().map(|t| t.weight).sum();
        self.tenants.iter().map(|t| t.weight / total).collect()
    }

    /// Build the arbiter over fresh simulated boards (tenant i's device
    /// seeded `base_seed + i`, its optimizer stream `base_seed + 100 + i`).
    pub fn arbiter(&self, policy: BudgetPolicy, base_seed: u64) -> TenantArbiter {
        let mut arb = TenantArbiter::new(self.global_budget_mw, policy);
        self.add_tenants(&mut arb, base_seed);
        arb
    }

    /// [`TenantScenario::arbiter`] with every tenant's board behind a
    /// private measurement cache (`control::cache::CachedEnv`): repeat
    /// proposals across rounds replay from each tenant's store, and a
    /// drift restart of tenant *i* invalidates only tenant *i*'s
    /// entries. Same boards, same seeds, per-tenant epochs.
    pub fn arbiter_cached(&self, policy: BudgetPolicy, base_seed: u64) -> TenantArbiter {
        let mut arb = TenantArbiter::new(self.global_budget_mw, policy).cached(true);
        self.add_tenants(&mut arb, base_seed);
        arb
    }

    /// The unarbitrated baseline over the same boards and seeds (every
    /// tenant believes it owns the whole envelope).
    pub fn independent(&self, base_seed: u64) -> TenantArbiter {
        let mut arb = TenantArbiter::independent(self.global_budget_mw);
        self.add_tenants(&mut arb, base_seed);
        arb
    }

    fn add_tenants(&self, arb: &mut TenantArbiter, base_seed: u64) {
        for (i, t) in self.tenants.iter().enumerate() {
            let dev = Device::new(self.device, t.model, base_seed + i as u64);
            arb.add_tenant(*t, Box::new(SimEnv::new(dev)), base_seed + 100 + i as u64);
        }
    }

    /// [`TenantScenario::arbiter`] over variant-equipped boards: every
    /// tenant's device carries its model's standard manifest, so a
    /// tenant whose sub-budget cannot sustain its target at full
    /// accuracy may degrade its served variant (down to its
    /// [`Tenant::min_accuracy`] floor) instead of falling back and
    /// starving — the accuracy axis becomes the arbitration pressure
    /// valve ([`ACCURACY_TENANT_SCENARIO`]).
    pub fn arbiter_variants(&self, policy: BudgetPolicy, base_seed: u64) -> TenantArbiter {
        let mut arb = TenantArbiter::new(self.global_budget_mw, policy);
        for (i, t) in self.tenants.iter().enumerate() {
            let dev = Device::new(self.device, t.model, base_seed + i as u64)
                .with_variants(t.model.standard_variants());
            arb.add_tenant(*t, Box::new(SimEnv::new(dev)), base_seed + 100 + i as u64);
        }
        arb
    }
}

/// The accuracy-arbitration scenario: an NX box whose global envelope
/// is deliberately too small for both tenants at full accuracy. Under
/// demand-weighted shares the YOLO tenant's sub-budget (5 000 mW) sits
/// below the ~5 970 mW its full-accuracy 30 fps needs, while a degraded
/// standard variant reaches 30 fps from ~3 500 mW — so with the variant
/// axis open ([`TenantScenario::arbiter_variants`]) it degrades within
/// its 24.0 mAP floor and stays feasible, and without it
/// ([`TenantScenario::arbiter`]) it falls back and starves. The FRCNN
/// tenant's share (5 600 mW) covers its full-accuracy ~5 250 mW need
/// either way: its neighbour's shortfall is absorbed by the accuracy
/// axis, not by its throughput.
pub const ACCURACY_TENANT_SCENARIO: TenantScenario = TenantScenario {
    name: "nx-pair-accuracy",
    device: DeviceKind::XavierNx,
    global_budget_mw: 10_600.0,
    tenants: &[
        Tenant {
            name: "cam-yolo",
            model: ModelKind::Yolo,
            target_fps: 30.0,
            weight: 5.0,
            min_accuracy: Some(24.0),
        },
        Tenant {
            name: "lidar-frcnn",
            model: ModelKind::Frcnn,
            target_fps: 8.0,
            weight: 5.6,
            min_accuracy: None,
        },
    ],
};

/// Accuracy trade-off scenario: one (device, model) pair whose
/// dual-constraint region is **empty at full accuracy** — the budget
/// cannot buy the target throughput from the baseline variant — yet
/// nonempty at some degraded variant of the standard manifest whose
/// mAP still clears `min_accuracy`. The seventh search dimension is
/// what makes these solvable: a 6-dimensional search (or any fixed
/// preset) can only fail or overdraw (`coral variants`, the
/// `variant_switch` example, `bench_variants`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracyScenario {
    pub name: &'static str,
    pub device: DeviceKind,
    pub model: ModelKind,
    /// τ_target (fps) — chosen above the full-accuracy variant's best
    /// sustainable throughput under the budget.
    pub target_fps: f64,
    /// Power budget (mW).
    pub budget_mw: f64,
    /// mAP floor: the lowest accuracy the operator will serve. Chosen
    /// so at least one standard variant sits *below* it — the floor is
    /// binding, not decorative.
    pub min_accuracy: f64,
}

/// The accuracy trade-off family. Calibrated on the noise-free
/// surfaces (the scenario test re-derives all three properties by grid
/// scan): full-accuracy max sustainable throughput under the budget /
/// the first feasible variant's —
/// `acc-nx-yolo`: 32.8 fps < 45 target; int8-640 (26.4 mAP) reaches 56.5.
/// `acc-nx-frcnn`: 9.2 < 16; int8-512 (29.8 mAP) reaches 20.4.
/// `acc-nx-retinanet`: 4.6 < 6.5; int8-640 (40.3 mAP) reaches 7.5.
/// `acc-orin-yolo`: 70.2 < 100; int8-640 (26.4 mAP) reaches 112.9.
pub const ACCURACY_SCENARIOS: [AccuracyScenario; 4] = [
    AccuracyScenario {
        name: "acc-nx-yolo",
        device: DeviceKind::XavierNx,
        model: ModelKind::Yolo,
        target_fps: 45.0,
        budget_mw: 6_500.0,
        min_accuracy: 26.0,
    },
    AccuracyScenario {
        name: "acc-nx-frcnn",
        device: DeviceKind::XavierNx,
        model: ModelKind::Frcnn,
        target_fps: 16.0,
        budget_mw: 6_000.0,
        min_accuracy: 29.0,
    },
    AccuracyScenario {
        name: "acc-nx-retinanet",
        device: DeviceKind::XavierNx,
        model: ModelKind::RetinaNet,
        target_fps: 6.5,
        budget_mw: 6_000.0,
        min_accuracy: 40.0,
    },
    AccuracyScenario {
        name: "acc-orin-yolo",
        device: DeviceKind::OrinNano,
        model: ModelKind::Yolo,
        target_fps: 100.0,
        budget_mw: 5_600.0,
        min_accuracy: 26.0,
    },
];

impl AccuracyScenario {
    /// Find a scenario by name.
    pub fn by_name(name: &str) -> Option<&'static AccuracyScenario> {
        ACCURACY_SCENARIOS.iter().find(|s| s.name == name)
    }

    /// All three clauses: throughput target, power budget, mAP floor.
    pub fn constraints(&self) -> Constraints {
        Constraints::dual(self.target_fps, self.budget_mw).with_min_accuracy(self.min_accuracy)
    }

    /// The standard degradation ladder the scenario searches.
    pub fn manifest(&self) -> crate::models::VariantManifest {
        self.model.standard_variants()
    }

    /// The measured environment: a simulated board with the variant
    /// axis opened to the standard manifest.
    pub fn env(&self, seed: u64) -> SimEnv {
        SimEnv::new(Device::new(self.device, self.model, seed).with_variants(self.manifest()))
    }

    /// Noise-free, lottery-free feasibility of one config (its variant
    /// index included) against all three clauses — the scenario tests'
    /// and benches' ground truth, bypassing measurement noise entirely.
    pub fn config_feasible(&self, cfg: &crate::device::HwConfig) -> bool {
        use crate::device::{failure, perf, power};
        let manifest = self.manifest();
        let v = manifest.get(cfg.variant);
        if failure::check_variant(self.device, self.model, v, cfg).is_some() {
            return false;
        }
        let pf = perf::evaluate_variant(self.device, self.model, v, cfg);
        let pw = power::evaluate_variant(self.device, v, cfg, &pf).total_mw();
        self.constraints().satisfied(pf.throughput_fps, pw, 0.0, v.accuracy)
    }
}

/// Heterogeneous-fleet scenario: one detector on a mixed NX/Orin fleet,
/// tuned by a **single** CORAL instance through the normalized
/// rank-fraction grid (`device::NormSpace`; EXPERIMENTS.md
/// §Heterogeneous fleets).
///
/// Constraints govern the **fleet-mean** observation [`FleetEnv`]
/// reports. The paper states no mixed-fleet numbers, so they are derived
/// from the members' own dual scenarios the way the paper derives its
/// YOLO numbers: `target_fps` ≈ 0.9 × the mean of the member targets (a
/// fleet SLO keeps a margin under the sum of per-board bests) and
/// `budget_mw` ≈ 1.06 × the mean of the member budgets (one shared
/// fraction vector cannot sit in every member's private sweet spot at
/// once). The scenario test grid-scans every normalized point and
/// asserts the fleet-mean feasible slice is thin but nonempty.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HeteroScenario {
    pub name: &'static str,
    pub model: ModelKind,
    /// Fleet members, one board each (mixed device kinds).
    pub devices: &'static [DeviceKind],
    /// Fleet-mean throughput target (fps).
    pub target_fps: f64,
    /// Fleet-mean power budget (mW); the common envelope is
    /// `devices.len() × budget_mw`.
    pub budget_mw: f64,
}

/// One NX + one Orin board.
const PAIR: &[DeviceKind] = &[DeviceKind::XavierNx, DeviceKind::OrinNano];
/// One NX + two Orin boards (edge fleets skew toward newer hardware).
const TRIPLE: &[DeviceKind] =
    &[DeviceKind::XavierNx, DeviceKind::OrinNano, DeviceKind::OrinNano];

/// The heterogeneous-fleet family: nx+orin pairs and triples across all
/// three detectors (`coral hetero`, the `hetero_fleet` example,
/// `bench_hetero`).
pub const HETERO_SCENARIOS: [HeteroScenario; 6] = [
    HeteroScenario {
        name: "hetero-yolo-pair",
        model: ModelKind::Yolo,
        devices: PAIR,
        target_fps: 40.0,
        budget_mw: 6_400.0,
    },
    HeteroScenario {
        name: "hetero-frcnn-pair",
        model: ModelKind::Frcnn,
        devices: PAIR,
        target_fps: 10.0,
        budget_mw: 5_600.0,
    },
    HeteroScenario {
        name: "hetero-retinanet-pair",
        model: ModelKind::RetinaNet,
        devices: PAIR,
        target_fps: 5.0,
        budget_mw: 5_600.0,
    },
    HeteroScenario {
        name: "hetero-yolo-triple",
        model: ModelKind::Yolo,
        devices: TRIPLE,
        target_fps: 45.0,
        budget_mw: 6_250.0,
    },
    HeteroScenario {
        name: "hetero-frcnn-triple",
        model: ModelKind::Frcnn,
        devices: TRIPLE,
        target_fps: 11.0,
        budget_mw: 5_300.0,
    },
    HeteroScenario {
        name: "hetero-retinanet-triple",
        model: ModelKind::RetinaNet,
        devices: TRIPLE,
        target_fps: 6.0,
        budget_mw: 5_350.0,
    },
];

impl HeteroScenario {
    /// Find a scenario by name.
    pub fn by_name(name: &str) -> Option<&'static HeteroScenario> {
        HETERO_SCENARIOS.iter().find(|s| s.name == name)
    }

    /// Fleet-mean constraints governing the shared search.
    pub fn constraints(&self) -> Constraints {
        Constraints::dual(self.target_fps, self.budget_mw)
    }

    /// The mixed fleet over fresh simulated boards (member `i` seeded
    /// `base_seed + i`); heterogeneous by construction, so it exposes
    /// the normalized search grid.
    pub fn fleet(&self, base_seed: u64) -> FleetEnv {
        FleetEnv::mixed(self.devices, self.model, base_seed)
    }

    /// The member's own paper dual scenario.
    fn member_paper(&self, i: usize) -> &'static DualScenario {
        let d = self.devices[i];
        DUAL_SCENARIOS
            .iter()
            .find(|s| s.device == d && s.model == self.model)
            .expect("hetero fleets draw from the dual scenarios")
    }

    /// Per-member constraints for the independent-controllers baseline
    /// (`bench_hetero`): each member's paper scenario scaled by exactly
    /// the relaxation this scenario applied to the member means, so both
    /// sides face the same aggregate target and the same common envelope
    /// (`devices.len() × budget_mw`).
    pub fn member_constraints(&self, i: usize) -> Constraints {
        let n = self.devices.len() as f64;
        let mean_t: f64 = (0..self.devices.len())
            .map(|j| self.member_paper(j).target_fps)
            .sum::<f64>()
            / n;
        let mean_b: f64 = (0..self.devices.len())
            .map(|j| self.member_paper(j).budget_mw)
            .sum::<f64>()
            / n;
        let paper = self.member_paper(i);
        Constraints::dual(
            paper.target_fps * self.target_fps / mean_t,
            paper.budget_mw * self.budget_mw / mean_b,
        )
    }
}

/// Which fault family a chaos scenario injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosFamily {
    /// Member dropout + rejoin mid-round (survivor aggregation).
    Dropout,
    /// Thermal-throttle phases: enable mid-run, heat soaks, ambient
    /// shifts.
    Thermal,
    /// Sensor-glitch bursts (NaN and stuck-at throughput readings).
    Glitch,
    /// All of the above plus a power-budget step.
    Combined,
}

/// Chaos-fleet scenario: a mixed NX/Orin fleet (the `hetero-yolo-pair`
/// surface and constraints) driven through a deterministic, seeded
/// fault schedule (`control::chaos`; EXPERIMENTS.md §Chaos fleet).
/// `coral chaos`, the `chaos_fleet` example and `bench_chaos` all run
/// this family; the acceptance test bounds every event's recovery.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosScenario {
    pub name: &'static str,
    pub family: ChaosFamily,
    pub model: ModelKind,
    /// Fleet members, one board each (mixed device kinds).
    pub devices: &'static [DeviceKind],
    /// Fleet-mean throughput target (fps).
    pub target_fps: f64,
    /// Fleet-mean power budget (mW).
    pub budget_mw: f64,
    /// Budget floor a `BudgetStep` may step down to (member-mean mW);
    /// the scenario test asserts the noise-free feasible region stays
    /// nonempty even there, so recovery is always *possible*.
    pub min_budget_mw: f64,
    /// Nominal run length (windows) the schedule is laid out for.
    pub windows: u64,
}

/// The chaos family: one scenario per fault family, all on the NX+Orin
/// YOLO pair (the `hetero-yolo-pair` target, with the budget tightened
/// from 6 400 to 6 100 mW: the fleet-mean budget must sit below what a
/// lone all-max survivor can draw — the Orin at max pulls ≈ 6 250 mW —
/// or a dropout that removes the hungrier board hands the static
/// baseline a free "recovery" through survivor aggregation, and the
/// bench's static-leg assertion stops holding).
pub const CHAOS_SCENARIOS: [ChaosScenario; 4] = [
    ChaosScenario {
        name: "chaos-dropout-pair",
        family: ChaosFamily::Dropout,
        model: ModelKind::Yolo,
        devices: PAIR,
        target_fps: 40.0,
        budget_mw: 6_100.0,
        min_budget_mw: 5_800.0,
        windows: 120,
    },
    ChaosScenario {
        name: "chaos-thermal-pair",
        family: ChaosFamily::Thermal,
        model: ModelKind::Yolo,
        devices: PAIR,
        target_fps: 40.0,
        budget_mw: 6_100.0,
        min_budget_mw: 5_800.0,
        windows: 120,
    },
    ChaosScenario {
        name: "chaos-glitch-pair",
        family: ChaosFamily::Glitch,
        model: ModelKind::Yolo,
        devices: PAIR,
        target_fps: 40.0,
        budget_mw: 6_100.0,
        min_budget_mw: 5_800.0,
        windows: 120,
    },
    ChaosScenario {
        name: "chaos-combined-pair",
        family: ChaosFamily::Combined,
        model: ModelKind::Yolo,
        devices: PAIR,
        target_fps: 40.0,
        budget_mw: 6_100.0,
        min_budget_mw: 5_800.0,
        windows: 120,
    },
];

impl ChaosScenario {
    /// Find a scenario by name.
    pub fn by_name(name: &str) -> Option<&'static ChaosScenario> {
        CHAOS_SCENARIOS.iter().find(|s| s.name == name)
    }

    /// Fleet-mean constraints the run starts under.
    pub fn constraints(&self) -> Constraints {
        Constraints::dual(self.target_fps, self.budget_mw)
    }

    /// The thermal model chaos events enable: milder heating/faster
    /// cooling than [`ThermalModel::default`], chosen so the fleet's
    /// *working* power (≈6 W) equilibrates near 53 °C — safely under
    /// the 70 °C throttle knee — while a scheduled heat soak still
    /// pushes past full throttle transiently. (The default model
    /// equilibrates a sustained 6 W draw at 80 °C, a *permanent* ~14%
    /// derate that would leave the scenario targets infeasible forever
    /// — recovery must be possible for recovery accounting to mean
    /// anything.)
    pub fn thermal_model() -> ThermalModel {
        ThermalModel { heat_per_ws: 0.3, cool_rate: 0.1, ..ThermalModel::default() }
    }

    /// The deterministic fault schedule: same seed, same events at the
    /// same windows. Event windows are jittered a little per seed so
    /// different seeds exercise different phase alignments against the
    /// search/hold cycle, but the family shape is fixed.
    pub fn schedule(&self, seed: u64) -> ChaosSchedule {
        let mut rng = Rng::new(seed ^ 0xC4A0_5EED);
        let n = self.devices.len();
        // Jitter a nominal window by 0..5 (drawn before the event's own
        // randomness, so the stream layout is fixed per family).
        fn jit(rng: &mut Rng, w: u64) -> u64 {
            w + rng.below(5) as u64
        }
        match self.family {
            ChaosFamily::Dropout => ChaosSchedule::new()
                .at(jit(&mut rng, 18), ChaosEvent::Dropout { member: rng.below(n), down_windows: 4 })
                .at(jit(&mut rng, 55), ChaosEvent::Dropout { member: rng.below(n), down_windows: 4 })
                .at(jit(&mut rng, 88), ChaosEvent::Dropout { member: rng.below(n), down_windows: 6 }),
            ChaosFamily::Thermal => ChaosSchedule::new()
                .at(jit(&mut rng, 12), ChaosEvent::ThermalEnable { model: Self::thermal_model() })
                .at(jit(&mut rng, 40), ChaosEvent::HeatSoak { power_mw: 30_000.0, soak_s: 60.0 })
                .at(jit(&mut rng, 80), ChaosEvent::AmbientShift { delta_c: 12.0 }),
            ChaosFamily::Glitch => ChaosSchedule::new()
                .at(jit(&mut rng, 20), ChaosEvent::GlitchBurst { windows: 3, kind: GlitchKind::NonFinite })
                .at(jit(&mut rng, 55), ChaosEvent::GlitchBurst { windows: 4, kind: GlitchKind::StuckAt })
                .at(jit(&mut rng, 90), ChaosEvent::GlitchBurst { windows: 3, kind: GlitchKind::NonFinite }),
            ChaosFamily::Combined => ChaosSchedule::new()
                .at(jit(&mut rng, 8), ChaosEvent::ThermalEnable { model: Self::thermal_model() })
                .at(jit(&mut rng, 25), ChaosEvent::Dropout { member: rng.below(n), down_windows: 4 })
                .at(jit(&mut rng, 50), ChaosEvent::GlitchBurst { windows: 3, kind: GlitchKind::NonFinite })
                .at(jit(&mut rng, 72), ChaosEvent::BudgetStep { budget_mw: self.min_budget_mw })
                .at(jit(&mut rng, 95), ChaosEvent::HeatSoak { power_mw: 30_000.0, soak_s: 60.0 }),
        }
    }

    /// The mixed fleet over fresh simulated boards (member `i` seeded
    /// `base_seed + i`) — same construction as the hetero scenarios.
    pub fn fleet(&self, base_seed: u64) -> FleetEnv {
        FleetEnv::mixed(self.devices, self.model, base_seed)
    }

    /// The fleet wrapped in the chaos decorator with this scenario's
    /// schedule (schedule stream forked off `base_seed` so boards and
    /// faults draw independent randomness).
    pub fn chaos(&self, base_seed: u64) -> ChaosEnv<FleetEnv> {
        ChaosEnv::new(
            self.fleet(base_seed),
            self.schedule(base_seed ^ 0x0DD5_EED5),
            self.constraints(),
        )
    }
}

/// One fleet-scale sweep point: `members` simulated boards, alternating
/// NX/Orin, measured as one [`FleetEnv`] observation per proposal.
///
/// The family exists to prove the persistent [`crate::control::FleetPool`]
/// scaling story (O(1) per-member dispatch, zero thread spawns per
/// proposal, hierarchical aggregation): `coral fleetscale` and
/// `bench_fleet_scale` sweep it 10 → 10,000 members (EXPERIMENTS.md
/// §Fleet-scale sweeps). Constraints are `hetero-yolo-pair`'s fleet-mean
/// numbers: every member count here is even and the kinds alternate, so
/// the fleet-mean surface matches the pair's at any size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetScaleScenario {
    pub name: &'static str,
    /// Fleet size (even; kinds alternate NX/Orin).
    pub members: usize,
    pub model: ModelKind,
    /// Fleet-mean throughput target (fps).
    pub target_fps: f64,
    /// Fleet-mean power budget (mW).
    pub budget_mw: f64,
}

/// The fleet-scale family: 10 → 10,000 mixed boards, one decade apart.
pub const FLEET_SCALE_SCENARIOS: [FleetScaleScenario; 4] = [
    FleetScaleScenario {
        name: "fleet-10",
        members: 10,
        model: ModelKind::Yolo,
        target_fps: 40.0,
        budget_mw: 6_400.0,
    },
    FleetScaleScenario {
        name: "fleet-100",
        members: 100,
        model: ModelKind::Yolo,
        target_fps: 40.0,
        budget_mw: 6_400.0,
    },
    FleetScaleScenario {
        name: "fleet-1k",
        members: 1_000,
        model: ModelKind::Yolo,
        target_fps: 40.0,
        budget_mw: 6_400.0,
    },
    FleetScaleScenario {
        name: "fleet-10k",
        members: 10_000,
        model: ModelKind::Yolo,
        target_fps: 40.0,
        budget_mw: 6_400.0,
    },
];

impl FleetScaleScenario {
    /// Find a scenario by name.
    pub fn by_name(name: &str) -> Option<&'static FleetScaleScenario> {
        FLEET_SCALE_SCENARIOS.iter().find(|s| s.name == name)
    }

    /// Fleet-mean constraints governing the shared search.
    pub fn constraints(&self) -> Constraints {
        Constraints::dual(self.target_fps, self.budget_mw)
    }

    /// Member device kinds: NX/Orin alternating, in fleet order.
    pub fn kinds(&self) -> Vec<DeviceKind> {
        (0..self.members).map(|i| PAIR[i % PAIR.len()]).collect()
    }

    /// The mixed fleet over fresh simulated boards (member `i` seeded
    /// `base_seed + i`); heterogeneous by construction, so it searches
    /// the normalized grid like the hetero scenarios.
    pub fn fleet(&self, base_seed: u64) -> FleetEnv {
        FleetEnv::mixed(&self.kinds(), self.model, base_seed)
    }
}

/// Open-loop load scenario: one (device, model) pair serving
/// arrival-driven traffic under a p99 latency SLO and a power budget
/// (`coral load`, the `open_loop` example, `bench_load`).
///
/// Unlike the closed-loop duals, the throughput clause here is the
/// offered load itself — a feasible config must serve *everything that
/// arrives* (no shedding), inside the power envelope, with the queueing
/// tail under the SLO. Ramping the offered rate therefore shrinks the
/// feasible region from both sides (capacity and tail) until it
/// vanishes: the **shed point** of a policy is the highest offered rate
/// it still sustains.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadScenario {
    pub name: &'static str,
    pub device: DeviceKind,
    pub model: ModelKind,
    /// Arrival shape name (`workload::ArrivalProfile::by_name`).
    pub profile: &'static str,
    /// Base offered load the profile modulates (fps).
    pub base_rate_fps: f64,
    /// p99 latency SLO (ms).
    pub latency_slo_ms: f64,
    /// Power budget (mW) — the member's paper dual budget.
    pub budget_mw: f64,
}

/// The open-loop load family: a steady YOLO feed on each board plus a
/// diurnal swing and a flash crowd. Base rates sit well under the
/// boards' best closed-loop capacity (the dual targets), so the regions
/// start nonempty and the ramps have room to climb before they shed.
pub const LOAD_SCENARIOS: [LoadScenario; 4] = [
    LoadScenario {
        name: "load-nx-yolo-steady",
        device: DeviceKind::XavierNx,
        model: ModelKind::Yolo,
        profile: "steady",
        base_rate_fps: 20.0,
        latency_slo_ms: 350.0,
        budget_mw: 6_500.0,
    },
    LoadScenario {
        name: "load-orin-yolo-diurnal",
        device: DeviceKind::OrinNano,
        model: ModelKind::Yolo,
        profile: "diurnal",
        base_rate_fps: 30.0,
        latency_slo_ms: 150.0,
        budget_mw: 5_600.0,
    },
    LoadScenario {
        name: "load-nx-frcnn-flash",
        device: DeviceKind::XavierNx,
        model: ModelKind::Frcnn,
        profile: "flash-crowd",
        base_rate_fps: 4.0,
        latency_slo_ms: 900.0,
        budget_mw: 6_000.0,
    },
    LoadScenario {
        name: "load-orin-retinanet-steady",
        device: DeviceKind::OrinNano,
        model: ModelKind::RetinaNet,
        profile: "steady",
        base_rate_fps: 5.0,
        latency_slo_ms: 1_100.0,
        budget_mw: 4_600.0,
    },
];

impl LoadScenario {
    /// Find a scenario by name.
    pub fn by_name(name: &str) -> Option<&'static LoadScenario> {
        LOAD_SCENARIOS.iter().find(|s| s.name == name)
    }

    /// The scenario's arrival profile (Poisson draws seeded `seed`).
    pub fn arrival(&self, seed: u64) -> crate::workload::ArrivalProfile {
        crate::workload::ArrivalProfile::by_name(self.profile, self.base_rate_fps, seed)
            .expect("LOAD_SCENARIOS use registered profile names")
    }

    /// Constraints at an offered rate: serve the whole load, under the
    /// budget, with the p99 tail inside the SLO.
    pub fn constraints_at(&self, offered_fps: f64) -> Constraints {
        Constraints::dual(offered_fps, self.budget_mw).with_latency_slo(self.latency_slo_ms)
    }

    /// Constraints at the scenario's base rate.
    pub fn constraints(&self) -> Constraints {
        self.constraints_at(self.base_rate_fps)
    }

    /// The batch axis the load family searches. Powers of two, capped
    /// at 4: on the heavy detectors (frcnn, retinanet) batch 8 inflates
    /// the activation footprint past the boards' memory budget at
    /// *every* concurrency, so opening it would only add a fully-OOM
    /// plane that costs the searched policy iterations without widening
    /// any scenario's feasible region.
    pub const BATCH_CAPS: &'static [u32] = &[1, 2, 4];

    /// The environment the scenario measures: a simulated board with
    /// the batch axis opened ([`LoadScenario::BATCH_CAPS`]) whose every
    /// window queues against the scenario's offered load.
    pub fn env(&self, seed: u64) -> SimEnv {
        let dev = Device::new(self.device, self.model, seed)
            .with_batch_caps(Self::BATCH_CAPS.to_vec());
        SimEnv::new(dev).under_load(self.arrival(seed))
    }

    /// Noise-free feasibility of one config at a steady offered rate:
    /// the true surfaces pushed through the deterministic queueing
    /// transform, judged by [`LoadScenario::constraints_at`].
    pub fn config_feasible_at(&self, cfg: &crate::device::HwConfig, offered_fps: f64) -> bool {
        use crate::device::{failure, perf, power, sim, Measured};
        if failure::check(self.device, self.model, cfg).is_some() {
            return false;
        }
        let pf = perf::evaluate(self.device, self.model, cfg);
        let pw = power::evaluate(self.device, cfg, &pf).total_mw();
        let m = Measured {
            config: *cfg,
            throughput_fps: pf.throughput_fps,
            power_mw: pw,
            latency_ms: pf.latency_ms,
            p99_latency_ms: pf.latency_ms,
            gpu_util: pf.gpu_util,
            cpu_util: pf.cpu_util,
            mem_util: pf.mem_util,
            accuracy: self.model.map(),
            failed: None,
        };
        let loaded =
            sim::under_offered_load(m, offered_fps, self.device.model_params().static_mw);
        self.constraints_at(offered_fps)
            .satisfied(loaded.throughput_fps, loaded.power_mw, loaded.p99_latency_ms, loaded.accuracy)
    }

    /// Shed point of a candidate set: ramp the steady offered rate from
    /// the base in `step_fps` increments and return the highest rate at
    /// which *some* candidate still satisfies the SLO+power pair
    /// (0.0 if none does even at the base). Every config's capacity is
    /// finite, so the ramp always terminates — shed points are finite
    /// by construction.
    pub fn shed_point_fps(&self, candidates: &[crate::device::HwConfig], step_fps: f64) -> f64 {
        assert!(step_fps > 0.0 && step_fps.is_finite());
        let mut highest = 0.0;
        let mut rate = self.base_rate_fps;
        while candidates.iter().any(|c| self.config_feasible_at(c, rate)) {
            highest = rate;
            rate += step_fps;
        }
        highest
    }

    /// The scenario's oracle shed point: the ramp over *every* valid
    /// config — the ceiling no policy, searched or fixed, can beat.
    pub fn oracle_shed_point_fps(&self, step_fps: f64) -> f64 {
        let valid = crate::device::failure::valid_configs(self.device, self.model);
        self.shed_point_fps(&valid, step_fps)
    }
}

/// Constraints of the dual scenario for (device, model).
pub fn dual_constraints(device: DeviceKind, model: ModelKind) -> Constraints {
    let s = DUAL_SCENARIOS
        .iter()
        .find(|s| s.device == device && s.model == model)
        .expect("scenario exists for every (device, model)");
    Constraints::dual(s.target_fps, s.budget_mw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::Environment;
    use crate::device::{failure, perf, power, Device};
    use crate::optimizer::CoralOptimizer;

    #[test]
    fn window_family_spans_three_orders_of_magnitude() {
        assert!(WINDOW_SCENARIOS.windows(2).all(|w| w[0].window < w[1].window));
        assert!(WINDOW_SCENARIOS.iter().all(|s| s.iters > s.window));
        assert_eq!(WINDOW_SCENARIOS[0].window, 10, "paper default first");
        assert_eq!(WINDOW_SCENARIOS.last().unwrap().window, 10_000);
        for s in WINDOW_SCENARIOS {
            assert_eq!(s.coral_config().window, s.window);
            assert_eq!(s.sampler().window_capacity(), s.window);
        }
    }

    #[test]
    fn fleet_w100_scenario_drives_coral_end_to_end() {
        // The first fleet-scale window: W exceeds the dCor fast-path
        // threshold, the stress run wraps the window, and the search
        // keeps functioning end to end through the canonical ControlLoop.
        let s = WINDOW_SCENARIOS[1];
        let device = DeviceKind::OrinNano;
        let model = ModelKind::Yolo;
        let cons = dual_constraints(device, model);
        let dev = Device::new(device, model, 27);
        let opt = CoralOptimizer::with_config(dev.space().clone(), cons, s.coral_config(), 27);
        let mut cl = crate::control::ControlLoop::with_budget(
            crate::control::SimEnv::new(dev),
            opt,
            cons,
            s.iters,
        );
        let out = cl.run();
        assert_eq!(out.iters, s.iters);
        assert!(cl.opt().window_len() <= s.window);
        assert!(
            cl.opt().window_len() > crate::stats::dcov::FAST_PATH_MIN_N,
            "window {} should engage the fast path",
            cl.opt().window_len()
        );
        assert!(out.best.is_some());
    }

    #[test]
    fn tenant_demand_shares_keep_every_feasible_region_nonempty() {
        // Each tenant's demand-weighted sub-budget must sit at or above
        // its single-tenant paper budget: the dual-constraint feasible
        // region is nonempty there (asserted below for DUAL_SCENARIOS),
        // and it only grows with budget — so every tenant of every
        // scenario has something to converge to.
        for s in MULTI_TENANT_SCENARIOS {
            let total: f64 = s.tenants.iter().map(|t| t.weight).sum();
            for t in s.tenants {
                let share = s.global_budget_mw * t.weight / total;
                let paper = DUAL_SCENARIOS
                    .iter()
                    .find(|d| d.device == s.device && d.model == t.model)
                    .expect("tenant mixes draw from the dual scenarios");
                assert!(
                    share >= paper.budget_mw,
                    "{}/{}: demand share {share:.0} below paper budget {}",
                    s.name,
                    t.name,
                    paper.budget_mw
                );
                assert_eq!(t.target_fps, paper.target_fps, "targets match the paper's");
            }
            // The global envelope is real: it is well under the sum of
            // what three unarbitrated max-power tenants could draw, and
            // under N× its own tightest member would allow.
            assert!(s.global_budget_mw < s.tenants.len() as f64 * 8_000.0);
        }
    }

    #[test]
    fn tenant_scenarios_lookup_and_static_shares() {
        assert!(TenantScenario::by_name("nx-triple").is_some());
        assert!(TenantScenario::by_name("bogus").is_none());
        for s in MULTI_TENANT_SCENARIOS {
            let shares = s.static_shares();
            assert_eq!(shares.len(), s.tenants.len());
            assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            let arb = s.arbiter(crate::control::BudgetPolicy::DemandWeighted, 9);
            assert_eq!(arb.len(), s.tenants.len());
            assert_eq!(arb.global_budget_mw(), s.global_budget_mw);
            let ind = s.independent(9);
            assert_eq!(ind.sub_budgets(), vec![s.global_budget_mw; s.tenants.len()]);
        }
    }

    #[test]
    fn cached_arbiter_wraps_every_tenant_and_hits_across_rounds() {
        let s = TenantScenario::by_name("nx-pair").unwrap();
        let mut arb = s.arbiter_cached(crate::control::BudgetPolicy::DemandWeighted, 9);
        assert!(
            arb.tenant_cache_stats().iter().all(|st| st.is_some()),
            "every tenant board sits behind a CachedEnv"
        );
        // An uncached arbiter reports no cache stats at all.
        assert!(s
            .arbiter(crate::control::BudgetPolicy::DemandWeighted, 9)
            .tenant_cache_stats()
            .iter()
            .all(|st| st.is_none()));
        arb.run_round();
        arb.run_round();
        let merged = arb
            .tenant_cache_stats()
            .into_iter()
            .flatten()
            .reduce(|a, b| a.merged(&b))
            .unwrap();
        assert!(merged.misses > 0, "first proposals are real windows");
        assert!(
            merged.hits > 0,
            "bootstrap presets / repeat proposals replay across rounds: {merged:?}"
        );
    }

    #[test]
    fn every_pair_covered() {
        for d in DeviceKind::ALL {
            for m in ModelKind::ALL {
                let _ = dual_constraints(d, m); // must not panic
            }
        }
    }

    #[test]
    fn hetero_constraints_derive_from_member_means() {
        // target ≤ the mean of member targets (a fleet SLO cannot demand
        // more than the members' own scenarios) yet within 25% of it (a
        // real target, not a relaxation to triviality); budget within
        // [0.95, 1.10] × the member-mean budget.
        for s in &HETERO_SCENARIOS {
            let n = s.devices.len() as f64;
            let papers: Vec<&DualScenario> = s
                .devices
                .iter()
                .map(|&d| {
                    DUAL_SCENARIOS
                        .iter()
                        .find(|p| p.device == d && p.model == s.model)
                        .expect("member scenario exists")
                })
                .collect();
            let mean_t: f64 = papers.iter().map(|p| p.target_fps).sum::<f64>() / n;
            let mean_b: f64 = papers.iter().map(|p| p.budget_mw).sum::<f64>() / n;
            assert!(s.target_fps <= mean_t, "{}: target above member mean", s.name);
            assert!(s.target_fps >= 0.75 * mean_t, "{}: target trivial", s.name);
            assert!(s.budget_mw <= 1.10 * mean_b, "{}: budget too loose", s.name);
            assert!(s.budget_mw >= 0.95 * mean_b, "{}: budget below member mean", s.name);
            // Both fleet shapes mix the two boards.
            assert!(s.devices.contains(&DeviceKind::XavierNx));
            assert!(s.devices.contains(&DeviceKind::OrinNano));
        }
    }

    #[test]
    fn hetero_fleet_mean_regions_are_thin_but_nonempty() {
        // Noise-free grid scan of every hetero scenario: decode each
        // normalized grid point per member, evaluate the true surfaces,
        // and check that the fleet-mean constraint slice is reachable
        // yet far from trivial — the premise that makes a single shared
        // CORAL worth running on a mixed fleet.
        use crate::device::NormSpace;
        for s in &HETERO_SCENARIOS {
            let ns = NormSpace::new(s.devices.iter().map(|d| d.space()).collect());
            let n = s.devices.len() as f64;
            let mut feasible = 0usize;
            let mut total = 0usize;
            for p in ns.grid().enumerate() {
                total += 1;
                let mut tput = 0.0;
                let mut power_mw = 0.0;
                let mut crashed = false;
                for (i, &d) in s.devices.iter().enumerate() {
                    let native = ns.decode_for(i, &p);
                    assert!(ns.members()[i].contains(&native));
                    if failure::check(d, s.model, &native).is_some() {
                        crashed = true;
                        break;
                    }
                    let pf = perf::evaluate(d, s.model, &native);
                    power_mw += power::evaluate(d, &native, &pf).total_mw();
                    tput += pf.throughput_fps;
                }
                if crashed {
                    continue;
                }
                if tput / n >= s.target_fps && power_mw / n <= s.budget_mw {
                    feasible += 1;
                }
            }
            let frac = feasible as f64 / total as f64;
            assert!(feasible > 0, "{}: empty fleet-mean feasible region", s.name);
            // A minority slice of the grid: real constraints, not a
            // relaxation to triviality. (The single-device paper slices
            // are a few percent; fleet means smooth the surface, so the
            // bound here is looser.)
            assert!(
                frac < 0.50,
                "{}: feasible region too wide ({:.1}%)",
                s.name,
                frac * 100.0
            );
        }
    }

    #[test]
    fn hetero_scenarios_lookup_fleets_and_member_constraints() {
        assert!(HeteroScenario::by_name("hetero-yolo-pair").is_some());
        assert!(HeteroScenario::by_name("bogus").is_none());
        for s in &HETERO_SCENARIOS {
            let fleet = s.fleet(3);
            assert_eq!(fleet.len(), s.devices.len());
            assert!(fleet.is_normalized(), "{}: mixed kinds → normalized", s.name);
            assert!(fleet.space().is_normalized());
            assert_eq!(s.constraints().throughput_target_fps, Some(s.target_fps));
            // The scaled per-member constraints aggregate back to the
            // scenario's fleet means — the independent baseline faces
            // the same common envelope.
            let n = s.devices.len() as f64;
            let sum_t: f64 = (0..s.devices.len())
                .map(|i| s.member_constraints(i).throughput_target_fps.unwrap())
                .sum();
            let sum_b: f64 = (0..s.devices.len())
                .map(|i| s.member_constraints(i).power_budget_mw.unwrap())
                .sum();
            assert!((sum_t / n - s.target_fps).abs() < 1e-9, "{}", s.name);
            assert!((sum_b / n - s.budget_mw).abs() < 1e-9, "{}", s.name);
        }
    }

    #[test]
    fn fleet_scale_family_spans_three_decades_of_even_mixed_fleets() {
        assert!(FLEET_SCALE_SCENARIOS.windows(2).all(|w| w[0].members * 10 == w[1].members));
        assert_eq!(FLEET_SCALE_SCENARIOS[0].members, 10);
        assert_eq!(FLEET_SCALE_SCENARIOS[3].members, 10_000);
        assert!(FleetScaleScenario::by_name("fleet-1k").is_some());
        assert!(FleetScaleScenario::by_name("bogus").is_none());
        let pair = HeteroScenario::by_name("hetero-yolo-pair").unwrap();
        for s in &FLEET_SCALE_SCENARIOS {
            // Even, alternating kinds: the fleet-mean surface is the
            // yolo pair's at every size, so its constraints carry over.
            assert_eq!(s.members % 2, 0, "{}", s.name);
            let kinds = s.kinds();
            assert_eq!(kinds.len(), s.members);
            assert_eq!(&kinds[..2], PAIR);
            assert_eq!(s.target_fps, pair.target_fps);
            assert_eq!(s.budget_mw, pair.budget_mw);
            assert_eq!(s.constraints().power_budget_mw, Some(s.budget_mw));
        }
    }

    #[test]
    fn fleet_scale_smallest_fleet_measures_on_the_normalized_grid() {
        let s = FleetScaleScenario::by_name("fleet-10").unwrap();
        let mut fleet = s.fleet(77);
        assert_eq!(fleet.len(), 10);
        assert!(fleet.is_normalized(), "mixed kinds → normalized grid");
        let cfg = fleet.space().midpoint();
        let m = fleet.measure(cfg);
        assert_eq!(m.config, cfg);
        assert!(m.throughput_fps > 0.0);
        assert!(m.power_mw > 0.0);
    }

    #[test]
    fn load_family_lookup_profiles_and_constraints() {
        assert!(LoadScenario::by_name("load-nx-yolo-steady").is_some());
        assert!(LoadScenario::by_name("bogus").is_none());
        for s in &LOAD_SCENARIOS {
            let p = s.arrival(7);
            assert_eq!(p.base_rate_fps, s.base_rate_fps, "{}", s.name);
            let cons = s.constraints();
            assert_eq!(cons.throughput_target_fps, Some(s.base_rate_fps));
            assert_eq!(cons.power_budget_mw, Some(s.budget_mw));
            assert_eq!(cons.latency_slo_ms, Some(s.latency_slo_ms));
            // The ramped clause tracks the offered rate.
            let up = s.constraints_at(s.base_rate_fps * 2.0);
            assert_eq!(up.throughput_target_fps, Some(s.base_rate_fps * 2.0));
            // The environment folds the load into its cache identity.
            assert_ne!(
                crate::control::Environment::fingerprint(&s.env(3)),
                crate::control::Environment::fingerprint(&SimEnv::new(Device::new(
                    s.device, s.model, 3
                ))),
                "{}: loaded and unloaded surfaces must not share a cache",
                s.name
            );
        }
    }

    #[test]
    fn load_regions_start_nonempty_and_shed_points_are_finite_and_ordered() {
        // The family's premise: at the base rate some valid config
        // serves the whole load inside SLO+power (the search has a
        // target), the ramp always sheds eventually (finite shed
        // points), and no fixed preset outlasts the full-space oracle.
        for s in &LOAD_SCENARIOS {
            let valid = failure::valid_configs(s.device, s.model);
            let at_base =
                valid.iter().filter(|c| s.config_feasible_at(c, s.base_rate_fps)).count();
            assert!(at_base > 0, "{}: empty region at the base rate", s.name);
            let step = s.base_rate_fps * 0.25;
            let oracle = s.oracle_shed_point_fps(step);
            assert!(
                oracle >= s.base_rate_fps && oracle.is_finite(),
                "{}: oracle shed point {oracle}",
                s.name
            );
            for (label, cfg) in [
                ("max-power", s.device.preset_max_power()),
                ("default", s.device.preset_default()),
            ] {
                let preset = s.shed_point_fps(&[cfg], step);
                assert!(preset.is_finite(), "{}/{label}", s.name);
                assert!(
                    preset <= oracle,
                    "{}/{label}: preset shed {preset} above oracle {oracle}",
                    s.name
                );
            }
            // The ramp genuinely vanishes: nothing survives far beyond
            // the oracle's shed point.
            assert!(valid
                .iter()
                .all(|c| !s.config_feasible_at(c, oracle + 10.0 * step)));
        }
    }

    #[test]
    fn chaos_scenarios_lookup_families_and_schedules() {
        use std::collections::BTreeSet;
        assert!(ChaosScenario::by_name("chaos-dropout-pair").is_some());
        assert!(ChaosScenario::by_name("bogus").is_none());
        assert_eq!(CHAOS_SCENARIOS.len(), 4);
        let families: BTreeSet<&str> = CHAOS_SCENARIOS
            .iter()
            .map(|s| match s.family {
                ChaosFamily::Dropout => "dropout",
                ChaosFamily::Thermal => "thermal",
                ChaosFamily::Glitch => "glitch",
                ChaosFamily::Combined => "combined",
            })
            .collect();
        assert_eq!(families.len(), 4, "one scenario per fault family");
        for s in &CHAOS_SCENARIOS {
            assert_eq!(s.devices, PAIR, "{}: chaos runs on the NX+Orin pair", s.name);
            assert!(s.fleet(3).is_normalized(), "{}", s.name);
            assert_eq!(s.constraints().throughput_target_fps, Some(s.target_fps));
            assert_eq!(s.constraints().power_budget_mw, Some(s.budget_mw));
            assert!(s.min_budget_mw < s.budget_mw, "{}: step must tighten", s.name);
            // Seeded schedules are deterministic: same seed, same bytes.
            let a = s.schedule(11);
            let b = s.schedule(11);
            assert_eq!(a.fingerprint(), b.fingerprint(), "{}", s.name);
            assert!(!a.is_empty(), "{}: a chaos scenario must inject faults", s.name);
            // Events stay inside the driven horizon (jitter included).
            assert!(
                a.events().iter().all(|(w, _)| *w < s.windows),
                "{}: event past the horizon",
                s.name
            );
        }
    }

    #[test]
    fn chaos_region_survives_the_budget_step_but_not_at_max_power() {
        // Two premises the chaos acceptance run leans on, checked on the
        // noise-free surfaces: (a) even at the stepped-down budget the
        // fleet-mean feasible region is nonempty, so CORAL has somewhere
        // to re-converge to after a BudgetStep; (b) the all-max static
        // baseline sits above the *original* budget, so it never becomes
        // feasible again on its own.
        use crate::device::NormSpace;
        for s in &CHAOS_SCENARIOS {
            let ns = NormSpace::new(s.devices.iter().map(|d| d.space()).collect());
            let n = s.devices.len() as f64;
            let mut feasible_at_min = 0usize;
            for p in ns.grid().enumerate() {
                let mut tput = 0.0;
                let mut power_mw = 0.0;
                let mut crashed = false;
                for (i, &d) in s.devices.iter().enumerate() {
                    let native = ns.decode_for(i, &p);
                    if failure::check(d, s.model, &native).is_some() {
                        crashed = true;
                        break;
                    }
                    let pf = perf::evaluate(d, s.model, &native);
                    power_mw += power::evaluate(d, &native, &pf).total_mw();
                    tput += pf.throughput_fps;
                }
                if crashed {
                    continue;
                }
                if tput / n >= s.target_fps && power_mw / n <= s.min_budget_mw {
                    feasible_at_min += 1;
                }
            }
            assert!(
                feasible_at_min > 0,
                "{}: nothing feasible at the stepped-down budget",
                s.name
            );
            // The all-max static baseline is never feasible: it either
            // crashes a member outright or blows the generous budget.
            let max = ns.grid().max_config();
            let mut max_power = 0.0;
            let mut max_crashes = false;
            for (i, &d) in s.devices.iter().enumerate() {
                let native = ns.decode_for(i, &max);
                max_crashes |= failure::check(d, s.model, &native).is_some();
                let pf = perf::evaluate(d, s.model, &native);
                max_power += power::evaluate(d, &native, &pf).total_mw();
            }
            assert!(
                max_crashes || max_power / n > s.budget_mw,
                "{}: all-max fleet mean {:.0} mW fits the budget {:.0} mW",
                s.name,
                max_power / n,
                s.budget_mw
            );
        }
    }

    #[test]
    fn accuracy_regions_open_only_below_full_accuracy() {
        // The family's premise, re-derived by noise-free grid scan per
        // scenario: (a) the dual region is EMPTY at the full-accuracy
        // baseline variant; (b) some degraded variant clearing the mAP
        // floor opens it; (c) the floor is binding — the ladder's
        // cheapest rung sits below it, so "degrade forever" is not an
        // answer the constraints accept.
        for s in &ACCURACY_SCENARIOS {
            let manifest = s.manifest();
            assert!(
                manifest.variants().last().unwrap().accuracy < s.min_accuracy,
                "{}: floor excludes no variant — it never binds",
                s.name
            );
            assert!(
                manifest.get(0).accuracy >= s.min_accuracy,
                "{}: the baseline itself must clear the floor",
                s.name
            );
            let space = s.device.space().with_variant_axis(manifest.len());
            let mut per_variant = vec![0usize; manifest.len()];
            for cfg in space.enumerate() {
                if s.config_feasible(&cfg) {
                    per_variant[cfg.variant as usize] += 1;
                }
            }
            assert_eq!(
                per_variant[0], 0,
                "{}: the full-accuracy region must be empty",
                s.name
            );
            let opened: usize = per_variant.iter().skip(1).sum();
            assert!(opened > 0, "{}: no degraded variant opens the region", s.name);
            // Every populated rung clears the floor (config_feasible
            // applies it, so a populated below-floor rung would mean
            // the clause is broken, not the calibration).
            for (i, &n) in per_variant.iter().enumerate() {
                if n > 0 {
                    assert!(
                        manifest.get(i as u32).accuracy >= s.min_accuracy,
                        "{}: below-floor variant {i} counted as feasible",
                        s.name
                    );
                }
            }
        }
    }

    /// Noise-free, lottery-free minimum power at which some valid
    /// config of `v` sustains `target` fps (None if none does).
    fn min_power_at_target(
        dev: DeviceKind,
        model: ModelKind,
        v: &crate::models::ModelVariant,
        target: f64,
    ) -> Option<f64> {
        dev.space()
            .enumerate()
            .into_iter()
            .filter(|c| failure::check_variant(dev, model, v, c).is_none())
            .filter_map(|c| {
                let pf = perf::evaluate_variant(dev, model, v, &c);
                (pf.throughput_fps >= target)
                    .then(|| power::evaluate_variant(dev, v, &c, &pf).total_mw())
            })
            .min_by(|a, b| a.total_cmp(b))
    }

    #[test]
    fn accuracy_tenant_scenario_premises_hold_on_the_noise_free_surface() {
        // The arbitration story's three premises: under demand-weighted
        // shares the YOLO tenant cannot reach its target at full
        // accuracy (share < min power), it can within its mAP floor at
        // a degraded variant (with margin for noise + lottery), and the
        // FRCNN tenant is covered at full accuracy either way.
        let s = &ACCURACY_TENANT_SCENARIO;
        let total: f64 = s.tenants.iter().map(|t| t.weight).sum();
        let share =
            |t: &Tenant| s.global_budget_mw * t.weight / total;
        let yolo = &s.tenants[0];
        let frcnn = &s.tenants[1];
        assert_eq!(yolo.model, ModelKind::Yolo);
        let manifest = yolo.model.standard_variants();
        let floor = yolo.min_accuracy.expect("the degrading tenant has a floor");
        let full = min_power_at_target(s.device, yolo.model, manifest.get(0), yolo.target_fps)
            .expect("full-accuracy target reachable at SOME power");
        assert!(
            full > share(yolo) * 1.05,
            "cam-yolo full-accuracy min power {full:.0} mW must clearly exceed its share {:.0} mW",
            share(yolo)
        );
        let degraded = manifest
            .variants()
            .iter()
            .filter(|v| v.accuracy >= floor && !v.is_identity())
            .filter_map(|v| min_power_at_target(s.device, yolo.model, v, yolo.target_fps))
            .fold(f64::INFINITY, f64::min);
        assert!(
            degraded < share(yolo) * 0.9,
            "cam-yolo needs a within-floor variant feasible with margin: {degraded:.0} mW vs share {:.0} mW",
            share(yolo)
        );
        let frcnn_manifest = frcnn.model.standard_variants();
        let frcnn_full = min_power_at_target(
            s.device,
            frcnn.model,
            frcnn_manifest.get(0),
            frcnn.target_fps,
        )
        .expect("lidar-frcnn reachable at full accuracy");
        assert!(
            frcnn_full < share(frcnn) * 0.97,
            "lidar-frcnn full-accuracy min power {frcnn_full:.0} mW must fit its share {:.0} mW",
            share(frcnn)
        );
    }

    #[test]
    fn singleton_variant_manifests_leave_every_trajectory_byte_identical() {
        // The compatibility contract of the seventh dimension: a
        // device whose manifest is the singleton identity
        // (`VariantManifest::full`, also the `Device::new` default)
        // produces the same bytes as the legacy construction on every
        // driving path — ControlLoop, TenantArbiter, cached fleet
        // sweeps. Singleton axes consume no RNG, identity variants
        // skip every multiplier, and `hw_key` never includes the
        // variant, so the trajectories cannot diverge.
        use crate::control::{fleet_sweep, fleet_sweep_cached, CacheStore, FleetRunner};
        use crate::models::VariantManifest;

        // ControlLoop leg.
        let device = DeviceKind::XavierNx;
        let model = ModelKind::Yolo;
        let cons = dual_constraints(device, model);
        let drive = |explicit: bool| {
            let mut dev = Device::new(device, model, 11);
            if explicit {
                dev = dev.with_variants(VariantManifest::full(model));
            }
            let opt = CoralOptimizer::new(dev.space().clone(), cons, 5);
            let mut cl =
                crate::control::ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 12);
            let out = cl.run();
            (out.best, out.iters, cl.env().cost_s(), Environment::fingerprint(cl.env()))
        };
        assert_eq!(drive(false), drive(true), "ControlLoop trajectories must match bit-for-bit");

        // TenantArbiter leg: the nx-pair scenario registered plainly vs
        // with explicit singleton manifests, two rounds each.
        let s = TenantScenario::by_name("nx-pair").unwrap();
        let mut plain = s.arbiter(crate::control::BudgetPolicy::DemandWeighted, 9);
        let mut explicit = {
            let mut arb = TenantArbiter::new(
                s.global_budget_mw,
                crate::control::BudgetPolicy::DemandWeighted,
            );
            for (i, t) in s.tenants.iter().enumerate() {
                let dev = Device::new(s.device, t.model, 9 + i as u64)
                    .with_variants(VariantManifest::full(t.model));
                arb.add_tenant(*t, Box::new(SimEnv::new(dev)), 9 + 100 + i as u64);
            }
            arb
        };
        for _ in 0..2 {
            let a = plain.run_round();
            let ac = a.combined;
            let ap = a.aggregate_power_mw;
            let at: Vec<crate::device::Measured> =
                a.tenants.iter().map(|t| t.chosen).collect();
            let b = explicit.run_round();
            assert_eq!(ac, b.combined, "combined window must match bit-for-bit");
            assert_eq!(ap, b.aggregate_power_mw);
            let bt: Vec<crate::device::Measured> = b.tenants.iter().map(|t| t.chosen).collect();
            assert_eq!(at, bt, "per-tenant held windows must match bit-for-bit");
        }

        // Cached fleet-sweep leg: the sweep's envs now all carry
        // singleton manifests; the sweep stays deterministic, replayed
        // passes are byte-identical, and the replay really happened
        // (no new misses on the second pass).
        let runner = FleetRunner::new(2);
        let scenarios = &DUAL_SCENARIOS[..2];
        let plain_sweep = fleet_sweep(scenarios, 2, &runner);
        let store = CacheStore::new();
        let first = fleet_sweep_cached(scenarios, 2, &runner, &store);
        let misses_after_first = store.stats().misses;
        let second = fleet_sweep_cached(scenarios, 2, &runner, &store);
        assert_eq!(
            store.stats().misses,
            misses_after_first,
            "second pass must replay entirely from the store"
        );
        for (a, b) in plain_sweep.iter().zip(&first) {
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.feasible, b.feasible, "{}: cached != plain", a.scenario.figures);
            assert!(
                (a.mean_first_feasible == b.mean_first_feasible)
                    || (a.mean_first_feasible.is_nan() && b.mean_first_feasible.is_nan())
            );
        }
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.feasible, b.feasible);
            assert!(
                (a.mean_first_feasible == b.mean_first_feasible)
                    || (a.mean_first_feasible.is_nan() && b.mean_first_feasible.is_nan())
            );
        }
    }

    #[test]
    fn feasible_regions_are_narrow_but_nonempty() {
        // The paper's premise: the dual-constraint region is a thin slice
        // of the valid space (hence random search fails) yet reachable
        // (hence CORAL/ORACLE succeed).
        for s in DUAL_SCENARIOS {
            let valid = failure::valid_configs(s.device, s.model);
            let feasible = valid
                .iter()
                .filter(|c| {
                    let pf = perf::evaluate(s.device, s.model, c);
                    let pw = power::evaluate(s.device, c, &pf).total_mw();
                    pf.throughput_fps >= s.target_fps && pw <= s.budget_mw
                })
                .count();
            let frac = feasible as f64 / valid.len() as f64;
            assert!(feasible > 0, "{:?}: empty feasible region", s);
            assert!(
                frac < 0.12,
                "{}/{}: feasible region too wide ({:.1}%)",
                s.device,
                s.model,
                frac * 100.0
            );
        }
    }
}
