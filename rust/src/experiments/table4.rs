//! Table 4: evaluated (valid) configuration counts per model and device.

use std::path::Path;

use anyhow::Result;

use crate::device::{failure, DeviceKind};
use crate::models::ModelKind;
use crate::util::csv::Csv;
use crate::util::table;

/// Paper Table 4 values for side-by-side reporting.
pub fn paper_value(dev: DeviceKind, model: ModelKind) -> usize {
    match (dev, model) {
        (DeviceKind::XavierNx, ModelKind::Yolo) => 2067,
        (DeviceKind::XavierNx, ModelKind::Frcnn) => 1813,
        (DeviceKind::XavierNx, ModelKind::RetinaNet) => 1491,
        (DeviceKind::OrinNano, ModelKind::Yolo) => 1522,
        (DeviceKind::OrinNano, ModelKind::Frcnn) => 1371,
        (DeviceKind::OrinNano, ModelKind::RetinaNet) => 1223,
    }
}

/// Regenerate Table 4 into `<out>/table4.csv` and print it.
pub fn run(out_dir: &Path) -> Result<()> {
    let mut csv = Csv::new(&["model", "device", "raw", "valid", "paper", "delta_pct"]);
    let mut rows = Vec::new();
    for model in ModelKind::ALL {
        for dev in DeviceKind::ALL {
            let raw = dev.space().raw_size();
            let valid = failure::valid_count(dev, model);
            let paper = paper_value(dev, model);
            let delta = (valid as f64 / paper as f64 - 1.0) * 100.0;
            csv.push(vec![
                model.name().into(),
                dev.name().into(),
                raw.to_string(),
                valid.to_string(),
                paper.to_string(),
                format!("{delta:+.1}"),
            ]);
            rows.push(vec![
                model.name().to_string(),
                dev.name().to_string(),
                valid.to_string(),
                paper.to_string(),
                format!("{delta:+.1}%"),
            ]);
        }
    }
    csv.save(&out_dir.join("table4.csv"))?;
    println!("Table 4 — evaluated configuration space (valid configs)");
    print!("{}", table::render(&["model", "device", "ours", "paper", "delta"], &rows));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_writes_csv(){
        let dir = std::env::temp_dir().join("coral_table4_test");
        let _ = std::fs::remove_dir_all(&dir);
        run(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("table4.csv")).unwrap();
        let csv = Csv::parse(&text).unwrap();
        assert_eq!(csv.rows.len(), 6);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
