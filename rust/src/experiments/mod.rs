//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§IV) on the simulated testbed. See DESIGN.md §4 for the
//! experiment-id ↔ module ↔ bench mapping, and EXPERIMENTS.md for the
//! recorded paper-vs-measured comparison.

pub mod ablation;
pub mod convergence;
pub mod dual;
pub mod fig1;
pub mod robustness;
pub mod runner;
pub mod scenarios;
pub mod single;
pub mod table4;

pub use runner::{run_method, MethodKind, MethodOutcome};
pub use scenarios::{
    dual_constraints, AccuracyScenario, ChaosFamily, ChaosScenario, DualScenario, HeteroScenario,
    ACCURACY_SCENARIOS, ACCURACY_TENANT_SCENARIO, CHAOS_SCENARIOS, DUAL_SCENARIOS,
    HETERO_SCENARIOS,
};

use std::path::Path;

/// Run the full suite into `out_dir` (CSV files + printed tables).
pub fn run_all(out_dir: &Path, seeds: u64) -> anyhow::Result<()> {
    fig1::run(out_dir)?;
    table4::run(out_dir)?;
    single::run(out_dir, seeds)?;
    dual::run_all(out_dir, seeds)?;
    ablation::run(out_dir, seeds)?;
    convergence::run(out_dir, seeds)?;
    robustness::run(out_dir, seeds)?;
    Ok(())
}
