//! Figure 1: power–throughput trade-off scatter of YOLO on both devices
//! (the paper's motivation: ~2× power spread at iso-throughput on
//! XAVIER-NX; 40–75 fps at iso-power on ORIN-NANO).

use std::path::Path;

use anyhow::Result;

use crate::device::{failure, Device, DeviceKind};
use crate::models::ModelKind;
use crate::util::csv::Csv;

/// Scatter data of one device.
pub struct Scatter {
    pub device: DeviceKind,
    pub points: Vec<(f64, f64)>, // (fps, mW)
    pub csv: Csv,
}

/// Measure every valid YOLO configuration on `device` (one window each —
/// the paper's exhaustive profiling pass).
pub fn sweep(device: DeviceKind, seed: u64) -> Scatter {
    let mut dev = Device::new(device, ModelKind::Yolo, seed);
    let mut csv = Csv::new(&[
        "device", "cpu_freq_mhz", "cpu_cores", "gpu_freq_mhz", "mem_freq_mhz",
        "concurrency", "throughput_fps", "power_mw",
    ]);
    let mut points = Vec::new();
    for cfg in failure::valid_configs(device, ModelKind::Yolo) {
        let m = dev.run(cfg);
        debug_assert!(m.failed.is_none());
        points.push((m.throughput_fps, m.power_mw));
        csv.push(vec![
            device.name().into(),
            cfg.cpu_freq_mhz.to_string(),
            cfg.cpu_cores.to_string(),
            cfg.gpu_freq_mhz.to_string(),
            cfg.mem_freq_mhz.to_string(),
            cfg.concurrency.to_string(),
            format!("{:.2}", m.throughput_fps),
            format!("{:.0}", m.power_mw),
        ]);
    }
    Scatter { device, points, csv }
}

/// The paper's headline spreads, computed from a scatter.
pub struct Fig1Stats {
    /// NX box: power spread (max/min) among configs within ±10 % of 30 fps.
    pub iso_tput_power_ratio: f64,
    /// Orin box: fps spread (max − min) among configs within ±5 % of 6 W.
    pub iso_power_fps_span: (f64, f64),
}

pub fn stats(nx: &Scatter, orin: &Scatter) -> Fig1Stats {
    let band: Vec<f64> = nx
        .points
        .iter()
        .filter(|(f, _)| (*f - 30.0).abs() <= 3.0)
        .map(|(_, p)| *p)
        .collect();
    let iso_tput_power_ratio = if band.is_empty() {
        f64::NAN
    } else {
        band.iter().cloned().fold(0.0, f64::max)
            / band.iter().cloned().fold(f64::INFINITY, f64::min)
    };
    let fps_at_6w: Vec<f64> = orin
        .points
        .iter()
        .filter(|(_, p)| (*p - 6000.0).abs() <= 300.0)
        .map(|(f, _)| *f)
        .collect();
    let iso_power_fps_span = if fps_at_6w.is_empty() {
        (f64::NAN, f64::NAN)
    } else {
        (
            fps_at_6w.iter().cloned().fold(f64::INFINITY, f64::min),
            fps_at_6w.iter().cloned().fold(0.0, f64::max),
        )
    };
    Fig1Stats { iso_tput_power_ratio, iso_power_fps_span }
}

/// Regenerate Figure 1 into `<out>/fig1_<device>.csv` + printed summary.
pub fn run(out_dir: &Path) -> Result<()> {
    let nx = sweep(DeviceKind::XavierNx, 0xF161);
    let orin = sweep(DeviceKind::OrinNano, 0xF161);
    nx.csv.save(&out_dir.join("fig1_xavier_nx.csv"))?;
    orin.csv.save(&out_dir.join("fig1_orin_nano.csv"))?;
    let s = stats(&nx, &orin);
    println!("Fig 1 — power-throughput trade-off (YOLO)");
    println!(
        "  XAVIER-NX: power spread at ~30 fps = {:.2}x (paper: ~2x, 6-8 W box)",
        s.iso_tput_power_ratio
    );
    println!(
        "  ORIN-NANO: {:.0}-{:.0} fps at ~6 W (paper: 40-75 fps)",
        s.iso_power_fps_span.0, s.iso_power_fps_span.1
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_spreads_match_paper_shape() {
        let nx = sweep(DeviceKind::XavierNx, 1);
        let orin = sweep(DeviceKind::OrinNano, 1);
        let s = stats(&nx, &orin);
        // NX: ≥1.5× power spread at iso-throughput (paper shows ~2×).
        assert!(s.iso_tput_power_ratio > 1.5, "{}", s.iso_tput_power_ratio);
        // Orin: ≥25 fps span at iso-power (paper shows 40→75).
        let (lo, hi) = s.iso_power_fps_span;
        assert!(hi - lo > 25.0, "span {lo}..{hi}");
        assert!(hi > 65.0, "top of the band reaches ~75 fps: {hi}");
    }

    #[test]
    fn sweep_covers_valid_space() {
        let nx = sweep(DeviceKind::XavierNx, 2);
        assert_eq!(
            nx.points.len(),
            failure::valid_count(DeviceKind::XavierNx, ModelKind::Yolo)
        );
        assert_eq!(nx.csv.rows.len(), nx.points.len());
    }
}
