//! Method runner: drives any optimization method against a simulated
//! device with the paper's evaluation loop (Fig. 2) — one
//! [`ControlLoop`] over a [`SimEnv`] — and records the outcome + search
//! cost.

use crate::control::{ControlLoop, SimEnv, DEFAULT_BUDGET};
use crate::device::{Device, DeviceKind};
use crate::models::ModelKind;
use crate::optimizer::{
    AlertOnlineOptimizer, AlertOptimizer, Constraints, CoralConfig, CoralOptimizer,
    OracleOptimizer, Optimizer, PresetOptimizer, RandomOptimizer,
};

/// Paper §IV-A: the online iteration budget.
pub const ITER_BUDGET: usize = DEFAULT_BUDGET;

/// The §IV-A method lineup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    Coral,
    Oracle,
    Alert,
    AlertOnline,
    MaxPower,
    Default,
    Random,
}

impl MethodKind {
    pub const PAPER_LINEUP: [MethodKind; 6] = [
        MethodKind::Oracle,
        MethodKind::Coral,
        MethodKind::Alert,
        MethodKind::AlertOnline,
        MethodKind::MaxPower,
        MethodKind::Default,
    ];

    pub fn name(self) -> &'static str {
        match self {
            MethodKind::Coral => "coral",
            MethodKind::Oracle => "oracle",
            MethodKind::Alert => "alert",
            MethodKind::AlertOnline => "alert-online",
            MethodKind::MaxPower => "max-power",
            MethodKind::Default => "default",
            MethodKind::Random => "random",
        }
    }

    pub fn parse(s: &str) -> Option<MethodKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "coral" => MethodKind::Coral,
            "oracle" => MethodKind::Oracle,
            "alert" => MethodKind::Alert,
            "alert-online" | "alertonline" => MethodKind::AlertOnline,
            "max-power" | "maxpower" | "max" => MethodKind::MaxPower,
            "default" => MethodKind::Default,
            "random" => MethodKind::Random,
            _ => return None,
        })
    }
}

/// Result of one method on one scenario seed.
#[derive(Debug, Clone)]
pub struct MethodOutcome {
    pub method: &'static str,
    pub device: DeviceKind,
    pub model: ModelKind,
    pub seed: u64,
    pub throughput_fps: f64,
    pub power_mw: f64,
    pub feasible: bool,
    pub online_windows: u64,
    pub offline_windows: u64,
    /// Simulated seconds of measurement the *online* phase cost.
    pub online_cost_s: f64,
    pub config: String,
}

/// Build the optimizer for a method. ALERT's offline profile is taken on
/// a sibling device (`seed + PROFILE_SEED_OFFSET`): a different unit at a
/// different time, as in deployment.
fn build(
    kind: MethodKind,
    device: DeviceKind,
    model: ModelKind,
    cons: Constraints,
    seed: u64,
    coral_cfg: CoralConfig,
) -> (Box<dyn Optimizer>, u64) {
    const PROFILE_SEED_OFFSET: u64 = 0x5EED_0FF5;
    let space = device.space();
    match kind {
        MethodKind::Coral => (
            Box::new(CoralOptimizer::with_config(space, cons, coral_cfg, seed)),
            0,
        ),
        MethodKind::Oracle => (Box::new(OracleOptimizer::new(space, cons)), 0),
        MethodKind::Alert => {
            let mut prof_dev = Device::new(device, model, seed + PROFILE_SEED_OFFSET);
            let profile = AlertOptimizer::profile_device(&mut prof_dev);
            let windows = prof_dev.windows_run();
            (Box::new(AlertOptimizer::new(profile, cons, windows)), windows)
        }
        MethodKind::AlertOnline => {
            (Box::new(AlertOnlineOptimizer::new(space, cons, seed)), 0)
        }
        MethodKind::MaxPower => (Box::new(PresetOptimizer::max_power(device, cons)), 0),
        MethodKind::Default => (Box::new(PresetOptimizer::default_mode(device, cons)), 0),
        MethodKind::Random => (Box::new(RandomOptimizer::new(space, cons, seed)), 0),
    }
}

/// Run one method once. ORACLE gets a full sweep; everything else gets
/// the paper's 10-iteration budget.
pub fn run_method(
    kind: MethodKind,
    device: DeviceKind,
    model: ModelKind,
    cons: Constraints,
    seed: u64,
) -> MethodOutcome {
    run_method_with(kind, device, model, cons, seed, CoralConfig::default(), ITER_BUDGET)
}

/// Run one method with explicit CORAL tunables and iteration budget
/// (ablations).
pub fn run_method_with(
    kind: MethodKind,
    device: DeviceKind,
    model: ModelKind,
    cons: Constraints,
    seed: u64,
    coral_cfg: CoralConfig,
    budget: usize,
) -> MethodOutcome {
    let dev = Device::new(device, model, seed);
    let (opt, offline) = build(kind, device, model, cons, seed, coral_cfg);
    let iters = match kind {
        MethodKind::Oracle => device.space().raw_size(),
        _ => budget,
    };
    let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, iters);
    let out = cl.run();
    let best = out.best.expect("at least one observation");
    MethodOutcome {
        method: cl.opt().name(),
        device,
        model,
        seed,
        throughput_fps: best.throughput_fps,
        power_mw: best.power_mw,
        feasible: best.feasible,
        online_windows: out.iters as u64,
        offline_windows: offline,
        online_cost_s: out.cost_s,
        config: best.config.to_string(),
    }
}

/// Mean outcome over seeds (feasible = majority vote; fps/power averaged
/// over the per-seed chosen configs).
#[derive(Debug, Clone)]
pub struct Aggregate {
    pub method: &'static str,
    pub mean_fps: f64,
    pub mean_mw: f64,
    pub feasible_rate: f64,
    pub mean_online_windows: f64,
    pub offline_windows: u64,
}

/// Aggregate several per-seed outcomes of one method.
pub fn aggregate(outcomes: &[MethodOutcome]) -> Aggregate {
    assert!(!outcomes.is_empty());
    let n = outcomes.len() as f64;
    Aggregate {
        method: outcomes[0].method,
        mean_fps: outcomes.iter().map(|o| o.throughput_fps).sum::<f64>() / n,
        mean_mw: outcomes.iter().map(|o| o.power_mw).sum::<f64>() / n,
        feasible_rate: outcomes.iter().filter(|o| o.feasible).count() as f64 / n,
        mean_online_windows: outcomes.iter().map(|o| o.online_windows as f64).sum::<f64>() / n,
        offline_windows: outcomes[0].offline_windows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_round_trip() {
        for m in MethodKind::PAPER_LINEUP {
            assert_eq!(MethodKind::parse(m.name()), Some(m));
        }
        assert_eq!(MethodKind::parse("bogus"), None);
    }

    #[test]
    fn runner_produces_outcomes_for_all_fast_methods() {
        let cons = Constraints::dual(30.0, 6500.0);
        for kind in [MethodKind::Coral, MethodKind::AlertOnline, MethodKind::MaxPower,
                     MethodKind::Default, MethodKind::Random] {
            let o = run_method(kind, DeviceKind::XavierNx, ModelKind::Yolo, cons, 1);
            assert_eq!(o.online_windows, ITER_BUDGET as u64, "{}", o.method);
            assert!(o.throughput_fps >= 0.0);
        }
    }

    #[test]
    fn coral_search_cost_stays_far_below_oracle_sweep() {
        // Search-cost accounting is now uniform (Environment::cost_s):
        // CORAL's 10 windows must come in well under ORACLE's exhaustive
        // sweep, and every window must be accounted at the paper's
        // warm-up + sampling duration.
        let cons = Constraints::dual(30.0, 6500.0);
        let coral = run_method(MethodKind::Coral, DeviceKind::XavierNx, ModelKind::Yolo, cons, 3);
        let oracle =
            run_method(MethodKind::Oracle, DeviceKind::XavierNx, ModelKind::Yolo, cons, 3);
        let per_window =
            crate::device::sim::WARMUP_S + crate::device::sim::SAMPLES_PER_WINDOW as f64;
        assert!((coral.online_cost_s - coral.online_windows as f64 * per_window).abs() < 1e-9);
        assert!((oracle.online_cost_s - oracle.online_windows as f64 * per_window).abs() < 1e-9);
        assert!(
            coral.online_cost_s * 20.0 < oracle.online_cost_s,
            "coral {:.0}s vs oracle {:.0}s",
            coral.online_cost_s,
            oracle.online_cost_s
        );
    }

    #[test]
    fn aggregate_means() {
        let cons = Constraints::dual(30.0, 6500.0);
        let outs: Vec<_> = (0..3)
            .map(|s| run_method(MethodKind::Default, DeviceKind::XavierNx, ModelKind::Yolo, cons, s))
            .collect();
        let agg = aggregate(&outs);
        assert_eq!(agg.method, "default");
        assert!(agg.mean_fps > 0.0);
        assert_eq!(agg.feasible_rate, 0.0, "default preset misses the target");
    }
}
