//! Extension experiments beyond the paper's evaluation:
//!
//! * **Noise robustness** — the paper's method consumes noisy 1 Hz
//!   telemetry but never quantifies sensitivity; we sweep the measurement
//!   noise from oracle-clean to 10× tegrastats-class and track CORAL's
//!   dual-constraint success rate.
//! * **Thermal drift** — §II positions CORAL for continuous adaptation
//!   (SHEEO-style); we run a long session with the thermal-throttle
//!   extension enabled: the device derates under sustained load and a
//!   periodically re-triggered CORAL must re-converge.

use std::path::Path;

use anyhow::Result;

use crate::control::{ControlLoop, ControlLoopConfig, DriftConfig, SimEnv, DEFAULT_BUDGET};
use crate::device::thermal::ThermalModel;
use crate::device::{Device, DeviceKind};
use crate::models::ModelKind;
use crate::optimizer::CoralOptimizer;
use crate::util::csv::Csv;
use crate::util::table;

use super::scenarios::dual_constraints;

/// Dual-constraint success rate of CORAL at one noise scale.
pub fn noise_success_rate(
    device: DeviceKind,
    model: ModelKind,
    noise_scale: f64,
    seeds: u64,
) -> f64 {
    let cons = dual_constraints(device, model);
    let mut hits = 0;
    for seed in 0..seeds {
        let dev = Device::new(device, model, 0x2015E + seed).with_noise_scale(noise_scale);
        let opt = CoralOptimizer::new(dev.space().clone(), cons, seed);
        let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, DEFAULT_BUDGET);
        if cl.run().best.map(|b| b.feasible).unwrap_or(false) {
            hits += 1;
        }
    }
    hits as f64 / seeds as f64
}

/// One epoch of the drift experiment.
#[derive(Debug, Clone)]
pub struct DriftEpoch {
    pub epoch: usize,
    pub temperature_c: f64,
    pub feasible: bool,
    pub throughput_fps: f64,
    pub power_mw: f64,
}

/// Long-running session: sustained load heats the device; each epoch is
/// one [`ControlLoop`] search round followed by a hold phase whose
/// windowed-throughput drift detector hands control back early once
/// throttling pulls the served rate off the level the configuration was
/// chosen at — the re-trigger the paper's §II positions CORAL for.
pub fn drift_session(seeds: u64, epochs: usize) -> Vec<Vec<DriftEpoch>> {
    // Orin/YOLO: the feasible region keeps non-zero headroom even at the
    // full derate (75 fps · 0.88 > 60 fps target), so "adapt under
    // throttling" is a meaningful ask — on NX the region vanishes
    // entirely once hot, which tests the impossible.
    let device = DeviceKind::OrinNano;
    let model = ModelKind::Yolo;
    let cons = dual_constraints(device, model);
    let throttle = ThermalModel { max_derate: 0.12, ..ThermalModel::default() };
    let space = device.space();
    let loop_cfg = ControlLoopConfig {
        budget: DEFAULT_BUDGET,
        // Hold-phase drift monitor: at the Orin power budget the thermal
        // equilibrium derate is a few percent, inside this threshold, so
        // epochs here normally re-search on schedule (full holds) and the
        // monitor guards against *larger* shifts — workload changes, a
        // hotter enclosure — ending the hold early when they happen.
        drift: Some(DriftConfig { window: 5, rel_threshold: 0.08 }),
        search_drift: None,
    };
    let mut sessions = Vec::new();
    for seed in 0..seeds {
        let dev = Device::new(device, model, 0xD41F7 + seed).with_thermal(throttle.clone());
        let mut cl = ControlLoop::new(
            SimEnv::new(dev),
            CoralOptimizer::new(space.clone(), cons, seed * 100),
            cons,
            loop_cfg,
        );
        let mut rows = Vec::new();
        for epoch in 0..epochs {
            if epoch > 0 {
                // Drift (or a completed hold) hands control back; a fresh
                // search round re-converges on the derated surface.
                cl.restart(CoralOptimizer::new(space.clone(), cons, seed * 100 + epoch as u64));
            }
            let out = cl.run();
            let b = out.best.expect("search observed windows");
            // Sustained load between searches: hold the chosen config for
            // up to ~5 simulated minutes (heats the chip); the drift
            // monitor may end the hold early.
            cl.hold(40);
            rows.push(DriftEpoch {
                epoch,
                temperature_c: thermal_temp(cl.env().device()),
                feasible: b.feasible,
                throughput_fps: b.throughput_fps,
                power_mw: b.power_mw,
            });
        }
        sessions.push(rows);
    }
    sessions
}

fn thermal_temp(dev: &Device) -> f64 {
    // The thermal model is private to the device; approximate via a probe
    // of true_point derate? Instead expose through config — simplest:
    // re-derive from throughput drop is noisy, so we read the derate via
    // a known config comparison.
    let cfg = dev.space().midpoint();
    let (pf, _) = dev.true_point(&cfg);
    // Derate factor = current / cold throughput for the same config.
    let cold = crate::device::perf::evaluate(dev.kind(), dev.model(), &cfg).throughput_fps;
    // Map derate to an indicative temperature on the default curve.
    let derate = (pf.throughput_fps / cold).clamp(0.0, 1.0);
    let t = ThermalModel { max_derate: 0.12, ..ThermalModel::default() };
    if derate >= 1.0 {
        t.throttle_start_c
    } else {
        t.throttle_start_c
            + (1.0 - derate) / t.max_derate * (t.throttle_full_c - t.throttle_start_c)
    }
}

/// Regenerate both extension experiments into `<out>/robustness.csv` +
/// `<out>/drift.csv`.
pub fn run(out_dir: &Path, seeds: u64) -> Result<()> {
    // Noise sweep.
    let mut csv = Csv::new(&["device", "model", "noise_scale", "success_rate"]);
    let mut rows = Vec::new();
    println!("Extension — noise robustness (dual constraints, {seeds} seeds)");
    for (device, model) in [
        (DeviceKind::XavierNx, ModelKind::Yolo),
        (DeviceKind::OrinNano, ModelKind::RetinaNet),
    ] {
        for scale in [0.0, 1.0, 3.0, 10.0] {
            let rate = noise_success_rate(device, model, scale, seeds);
            csv.push(vec![
                device.name().into(),
                model.name().into(),
                format!("{scale}"),
                format!("{rate:.2}"),
            ]);
            rows.push(vec![
                device.name().to_string(),
                model.name().to_string(),
                format!("{scale}x"),
                format!("{:.0}%", rate * 100.0),
            ]);
        }
    }
    print!(
        "{}",
        table::render(&["device", "model", "noise", "success"], &rows)
    );
    csv.save(&out_dir.join("robustness.csv"))?;

    // Thermal drift.
    println!("Extension — thermal drift re-convergence (Orin/YOLO)");
    let sessions = drift_session(seeds.min(5), 4);
    let mut csv = Csv::new(&["seed", "epoch", "temp_c", "feasible", "fps", "power_mw"]);
    let mut feas_by_epoch = vec![0u64; 4];
    for (seed, rows) in sessions.iter().enumerate() {
        for e in rows {
            csv.push(vec![
                seed.to_string(),
                e.epoch.to_string(),
                format!("{:.1}", e.temperature_c),
                (e.feasible as u8).to_string(),
                format!("{:.1}", e.throughput_fps),
                format!("{:.0}", e.power_mw),
            ]);
            if e.feasible {
                feas_by_epoch[e.epoch] += 1;
            }
        }
    }
    let n = sessions.len() as f64;
    for (epoch, hits) in feas_by_epoch.iter().enumerate() {
        println!(
            "  epoch {epoch}: re-converged feasible in {:.0}% of sessions",
            *hits as f64 / n * 100.0
        );
    }
    csv.save(&out_dir.join("drift.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moderate_noise_tolerated() {
        let clean = noise_success_rate(DeviceKind::XavierNx, ModelKind::Yolo, 0.0, 8);
        let noisy = noise_success_rate(DeviceKind::XavierNx, ModelKind::Yolo, 3.0, 8);
        assert!(clean >= 0.85, "clean {clean}");
        assert!(noisy >= clean - 0.4, "3x noise collapse: {noisy} vs {clean}");
    }

    #[test]
    fn drift_sessions_keep_adapting() {
        let sessions = drift_session(3, 3);
        // Every session's later epochs still find feasible configs at
        // least once (re-convergence, not one-shot luck).
        for rows in &sessions {
            assert!(
                rows.iter().skip(1).any(|e| e.feasible),
                "no re-convergence: {rows:?}"
            );
        }
    }
}
