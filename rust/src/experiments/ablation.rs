//! Ablations of CORAL's design choices (DESIGN.md §7): dCor weighting,
//! window size, heuristic variant, anchor interpretation, iteration
//! budget. Not in the paper's evaluation — they justify the design the
//! paper asserts.

use std::path::Path;

use anyhow::Result;

use crate::device::DeviceKind;
use crate::models::ModelKind;
use crate::optimizer::coral::{Anchor, CoralConfig, Heuristic};
use crate::optimizer::Constraints;
use crate::util::csv::Csv;
use crate::util::table;

use super::runner::{run_method_with, MethodKind};
use super::scenarios::dual_constraints;

/// One ablation variant.
pub struct Variant {
    pub name: &'static str,
    pub cfg: CoralConfig,
    pub budget: usize,
}

/// The ablation lineup.
pub fn variants() -> Vec<Variant> {
    let base = CoralConfig::default();
    vec![
        Variant { name: "coral (default)", cfg: base, budget: 10 },
        Variant {
            name: "no-dcor (gamma=1)",
            cfg: CoralConfig { use_dcor: false, ..base },
            budget: 10,
        },
        Variant {
            name: "heuristic off",
            cfg: CoralConfig { heuristic: Heuristic::Off, ..base },
            budget: 10,
        },
        Variant {
            name: "heuristic freq-min",
            cfg: CoralConfig { heuristic: Heuristic::FreqMin, ..base },
            budget: 10,
        },
        Variant {
            name: "heuristic cores-min",
            cfg: CoralConfig { heuristic: Heuristic::CoresMin, ..base },
            budget: 10,
        },
        Variant {
            name: "anchor best/second",
            cfg: CoralConfig { anchor: Anchor::BestSecond, ..base },
            budget: 10,
        },
        Variant {
            name: "revisits allowed",
            cfg: CoralConfig { avoid_revisits: false, ..base },
            budget: 10,
        },
        Variant { name: "window W=3", cfg: CoralConfig { window: 3, ..base }, budget: 10 },
        Variant { name: "window W=5", cfg: CoralConfig { window: 5, ..base }, budget: 10 },
        Variant { name: "budget 5", cfg: base, budget: 5 },
        Variant { name: "budget 20", cfg: base, budget: 20 },
        Variant { name: "budget 40", cfg: base, budget: 40 },
    ]
}

/// Feasibility rate + mean efficiency of one variant on one scenario.
pub fn run_variant(
    v: &Variant,
    device: DeviceKind,
    model: ModelKind,
    cons: Constraints,
    seeds: u64,
) -> (f64, f64) {
    let mut feasible = 0u64;
    let mut eff_sum = 0.0;
    for s in 0..seeds {
        let o = run_method_with(
            MethodKind::Coral,
            device,
            model,
            cons,
            0xAB1A + s,
            v.cfg,
            v.budget,
        );
        if o.feasible {
            feasible += 1;
            eff_sum += o.throughput_fps / o.power_mw * 1000.0;
        }
    }
    let rate = feasible as f64 / seeds as f64;
    let eff = if feasible > 0 { eff_sum / feasible as f64 } else { f64::NAN };
    (rate, eff)
}

/// Regenerate the ablation table into `<out>/ablation.csv`.
pub fn run(out_dir: &Path, seeds: u64) -> Result<()> {
    let device = DeviceKind::XavierNx;
    let model = ModelKind::Yolo;
    let cons = dual_constraints(device, model);
    let mut csv = Csv::new(&["variant", "budget", "feasible_rate", "mean_fps_per_w"]);
    let mut rows = Vec::new();
    println!("Ablations — CORAL variants on {device}/{model} dual constraints");
    for v in variants() {
        let (rate, eff) = run_variant(&v, device, model, cons, seeds);
        csv.push(vec![
            v.name.into(),
            v.budget.to_string(),
            format!("{rate:.2}"),
            format!("{eff:.2}"),
        ]);
        rows.push(vec![
            v.name.to_string(),
            v.budget.to_string(),
            format!("{:.0}%", rate * 100.0),
            format!("{eff:.2}"),
        ]);
    }
    print!(
        "{}",
        table::render(&["variant", "budget", "feasible", "fps/W"], &rows)
    );
    csv.save(&out_dir.join("ablation.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn larger_budget_never_hurts_much() {
        let device = DeviceKind::XavierNx;
        let model = ModelKind::Yolo;
        let cons = dual_constraints(device, model);
        let base = CoralConfig::default();
        let small = run_variant(
            &Variant { name: "b5", cfg: base, budget: 5 },
            device, model, cons, 8,
        );
        let large = run_variant(
            &Variant { name: "b20", cfg: base, budget: 20 },
            device, model, cons, 8,
        );
        assert!(large.0 >= small.0, "budget 20 ({}) >= budget 5 ({})", large.0, small.0);
        assert!(large.0 >= 0.8, "20 iterations should converge: {}", large.0);
    }
}
