//! Figures 3–4: single-constraint (throughput-maximization) comparison
//! of CORAL vs the baselines on YOLO, both devices.

use std::path::Path;

use anyhow::Result;

use crate::device::{failure, DeviceKind};
use crate::models::ModelKind;
use crate::optimizer::Constraints;
use crate::util::csv::Csv;
use crate::util::table;

use super::runner::{aggregate, run_method, MethodKind};

/// One device's comparison row set.
pub struct SingleResult {
    pub device: DeviceKind,
    /// (method, mean fps, mean mW, % of oracle fps).
    pub rows: Vec<(&'static str, f64, f64, f64)>,
    pub oracle_fps: f64,
}

/// Run the single-constraint scenario on one device, `seeds` repeats.
pub fn run_device(device: DeviceKind, seeds: u64) -> SingleResult {
    let cons = Constraints::max_throughput();
    let mut rows = Vec::new();
    let mut oracle_fps = f64::NAN;
    for kind in MethodKind::PAPER_LINEUP {
        // ORACLE's exhaustive sweep is deterministic modulo noise — one
        // seed is enough and keeps the harness fast.
        let n = if kind == MethodKind::Oracle { 1 } else { seeds };
        let outs: Vec<_> = (0..n)
            .map(|s| run_method(kind, device, ModelKind::Yolo, cons, 0xF344 + s))
            .collect();
        let agg = aggregate(&outs);
        if kind == MethodKind::Oracle {
            oracle_fps = agg.mean_fps;
        }
        rows.push((agg.method, agg.mean_fps, agg.mean_mw, f64::NAN));
    }
    for row in rows.iter_mut() {
        row.3 = row.1 / oracle_fps * 100.0;
    }
    SingleResult { device, rows, oracle_fps }
}

/// Regenerate Figs 3–4 into `<out>/fig3_4_single.csv` + printed tables.
pub fn run(out_dir: &Path, seeds: u64) -> Result<()> {
    let mut csv = Csv::new(&["device", "method", "fps", "power_mw", "pct_of_oracle"]);
    println!("Figs 3-4 — single-constraint (throughput) scenario, YOLO");
    for device in DeviceKind::ALL {
        let res = run_device(device, seeds);
        let mut rows = Vec::new();
        for (method, fps, mw, pct) in &res.rows {
            csv.push(vec![
                device.name().into(),
                (*method).into(),
                format!("{fps:.1}"),
                format!("{mw:.0}"),
                format!("{pct:.1}"),
            ]);
            rows.push(vec![
                method.to_string(),
                format!("{fps:.1}"),
                format!("{:.2}", mw / 1000.0),
                format!("{pct:.0}%"),
            ]);
        }
        println!("{device}:");
        print!("{}", table::render(&["method", "fps", "W", "% of oracle"], &rows));
        let _ = failure::valid_count(device, ModelKind::Yolo);
    }
    csv.save(&out_dir.join("fig3_4_single.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coral_hits_96_to_100_pct_presets_lag() {
        // Paper §IV-B: CORAL 96–100 % of ORACLE; presets 33–60 %
        // (our calibrated presets span ~33–80 %, same story).
        for device in DeviceKind::ALL {
            let res = run_device(device, 5);
            let pct = |m: &str| {
                res.rows.iter().find(|r| r.0 == m).map(|r| r.3).unwrap()
            };
            assert!(pct("coral") >= 93.0, "{device}: coral {:.1}%", pct("coral"));
            assert!(pct("default") <= 65.0, "{device}: default {:.1}%", pct("default"));
            assert!(pct("alert") >= 90.0, "{device}: alert {:.1}%", pct("alert"));
            // Presets can't tune concurrency, so they trail CORAL.
            assert!(pct("coral") > pct("max-power"), "{device}");
        }
    }
}
