//! Figures 5–10: dual-constraint scenarios (power budget + throughput
//! target) — YOLO (5–6), FRCNN (7–8), RETINANET (9–10) on both devices.

use std::path::Path;

use anyhow::Result;

use crate::models::ModelKind;
use crate::optimizer::Constraints;
use crate::util::csv::Csv;
use crate::util::table;

use super::runner::{aggregate, run_method, Aggregate, MethodKind};
use super::scenarios::{DualScenario, DUAL_SCENARIOS};

/// Aggregated lineup of one dual scenario.
pub struct DualResult {
    pub scenario: DualScenario,
    pub rows: Vec<Aggregate>,
}

/// Run one dual scenario across the full method lineup.
pub fn run_scenario(s: DualScenario, seeds: u64) -> DualResult {
    let cons = Constraints::dual(s.target_fps, s.budget_mw);
    let mut rows = Vec::new();
    for kind in MethodKind::PAPER_LINEUP {
        let n = if kind == MethodKind::Oracle { 1 } else { seeds };
        let outs: Vec<_> = (0..n)
            .map(|i| run_method(kind, s.device, s.model, cons, 0xD0A1 + i))
            .collect();
        rows.push(aggregate(&outs));
    }
    DualResult { scenario: s, rows }
}

/// Regenerate one model's dual figures into CSV + printed tables.
pub fn run_model(out_dir: &Path, model: ModelKind, seeds: u64) -> Result<()> {
    let scenarios: Vec<DualScenario> = DUAL_SCENARIOS
        .iter()
        .copied()
        .filter(|s| s.model == model)
        .collect();
    let figures = scenarios[0].figures;
    let mut csv = Csv::new(&[
        "device", "model", "target_fps", "budget_mw", "method", "fps", "power_mw",
        "feasible_rate", "online_windows", "offline_windows",
    ]);
    println!(
        "{figures} — dual-constraint scenario, {} ({}x size)",
        model.name(),
        model.params_m()
    );
    for s in scenarios {
        let res = run_scenario(s, seeds);
        let mut rows = Vec::new();
        for a in &res.rows {
            csv.push(vec![
                s.device.name().into(),
                model.name().into(),
                format!("{}", s.target_fps),
                format!("{}", s.budget_mw),
                a.method.into(),
                format!("{:.1}", a.mean_fps),
                format!("{:.0}", a.mean_mw),
                format!("{:.2}", a.feasible_rate),
                format!("{:.0}", a.mean_online_windows),
                a.offline_windows.to_string(),
            ]);
            rows.push(vec![
                a.method.to_string(),
                format!("{:.1}", a.mean_fps),
                format!("{:.2}", a.mean_mw / 1000.0),
                if a.feasible_rate >= 0.5 { "yes".into() } else { "NO".into() },
                format!("{:.0}+{}", a.mean_online_windows, a.offline_windows),
            ]);
        }
        println!(
            "{} (target {} fps, budget {:.1} W):",
            s.device,
            s.target_fps,
            s.budget_mw / 1000.0
        );
        print!(
            "{}",
            table::render(&["method", "fps", "W", "meets both", "windows"], &rows)
        );
    }
    let name = format!("{}_dual_{}.csv", figures.replace(',', "_"), model.name());
    csv.save(&out_dir.join(name))?;
    Ok(())
}

/// All dual figures (5–10).
pub fn run_all(out_dir: &Path, seeds: u64) -> Result<()> {
    for model in ModelKind::ALL {
        run_model(out_dir, model, seeds)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceKind;

    fn row<'a>(res: &'a DualResult, m: &str) -> &'a Aggregate {
        res.rows.iter().find(|a| a.method == m).unwrap()
    }

    #[test]
    fn yolo_dual_matches_paper_story() {
        // Paper §IV-B (Figs 5-6): CORAL + ORACLE feasible; ALERT over
        // budget; ALERT-Online mostly fails; presets fail on both devices.
        for s in DUAL_SCENARIOS.iter().filter(|s| s.model == ModelKind::Yolo) {
            let res = run_scenario(*s, 6);
            assert_eq!(row(&res, "oracle").feasible_rate, 1.0, "{}", s.device);
            assert!(
                row(&res, "coral").feasible_rate >= 0.8,
                "{}: coral rate {}",
                s.device,
                row(&res, "coral").feasible_rate
            );
            assert!(row(&res, "alert-online").feasible_rate <= 0.5, "{}", s.device);
            assert_eq!(row(&res, "max-power").feasible_rate, 0.0, "{}", s.device);
            assert_eq!(row(&res, "default").feasible_rate, 0.0, "{}", s.device);
            // ALERT meets throughput but not the budget, except where the
            // budget is loose; on NX it clearly overshoots (paper: 8.5 W).
            if s.device == DeviceKind::XavierNx {
                let alert = row(&res, "alert");
                assert!(alert.mean_mw > s.budget_mw, "alert {} mW", alert.mean_mw);
                assert!(alert.feasible_rate == 0.0);
            }
        }
    }

    #[test]
    fn gap_grows_with_model_size() {
        // Paper §IV-C: as models grow, baselines fail while CORAL keeps
        // finding the narrow region.
        for s in DUAL_SCENARIOS.iter().filter(|s| s.model == ModelKind::RetinaNet) {
            let res = run_scenario(*s, 6);
            assert_eq!(row(&res, "oracle").feasible_rate, 1.0, "{}", s.device);
            assert!(
                row(&res, "coral").feasible_rate >= 0.6,
                "{}: coral {}",
                s.device,
                row(&res, "coral").feasible_rate
            );
            for m in ["alert", "alert-online", "max-power", "default"] {
                assert!(
                    row(&res, m).feasible_rate <= 0.3,
                    "{}: {m} unexpectedly feasible",
                    s.device
                );
            }
        }
    }
}
