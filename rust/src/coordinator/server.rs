//! The serving event loop: batcher → worker pool → metrics, with
//! runtime-adjustable concurrency (the knob CORAL tunes live).
//!
//! The pump is **event-driven**: [`Server::run_closed_loop`] blocks on
//! the pool's completion signal, bounded by the batcher's next release
//! deadline ([`Batcher::next_deadline`]) — it never sleep-polls. On an
//! edge board a busy-wait is itself a power consumer, polluting exactly
//! the throughput/power signal the optimizer correlates, so the
//! measurement path must cost nothing while idle. Every wake is
//! accounted in [`ServeReport::pump_iterations`] /
//! [`ServeReport::deadline_fires`], which is what makes "no busy-wait"
//! an assertable property rather than a comment.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig, PendingRequest};
use super::metrics::{finite_rate, ServerMetrics};
use super::worker::{BatchJob, InferenceEngine, PoolEvent, ShareableRuntime, WorkerPool};
use crate::runtime::{Detections, ModelRuntime};
use crate::workload::VideoSource;

/// Server construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Inference workers (the paper's concurrency level).
    pub concurrency: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { concurrency: 2, batcher: BatcherConfig::default() }
    }
}

/// How long [`Server::set_concurrency`] waits for in-flight batches
/// before giving up on the old pool. The wait is event-driven (a
/// completion or a pool death ends it early); the timeout only bounds a
/// silently hung worker.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

/// Safety net for pump waits no batcher deadline bounds (pool at its
/// backpressure budget, or the queue is empty): a completion or a worker
/// death wakes the pump immediately, so this only bounds how long a
/// silently hung worker can block one loop iteration.
const PUMP_STALL_WAIT: Duration = Duration::from_secs(5);

/// Steady-state report of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: u64,
    pub failed: u64,
    /// NaN/inf-free: clamped via [`finite_rate`], so a trivially fast
    /// window feeds telemetry (and from there dCor) finite numbers.
    pub throughput_fps: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub mean_batch: f64,
    pub mean_exec_ms: f64,
    pub concurrency: usize,
    pub wall_s: f64,
    /// Pump loop iterations (wakeups) this run. Event-driven bound:
    /// proportional to completions + deadline fires, never wall-clock.
    pub pump_iterations: u64,
    /// Pump wakes caused by the batcher's release deadline firing
    /// (partial batches whose oldest request hit `max_wait`).
    pub deadline_fires: u64,
    /// Open-loop only: requests completed within the per-request
    /// latency deadline ([`Server::run_open_loop`]'s `deadline_ms`).
    /// Closed-loop runs have no deadlines — both counters stay 0.
    pub deadline_hits: u64,
    /// Open-loop only: requests that completed late or failed.
    pub deadline_misses: u64,
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} reqs in {:.2}s: {:.1} fps, p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms, \
             batch {:.2}, exec {:.1} ms, c={}",
            self.requests,
            self.wall_s,
            self.throughput_fps,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_p99_ms,
            self.mean_batch,
            self.mean_exec_ms,
            self.concurrency
        )
    }
}

/// Single-model serving stack.
pub struct Server {
    engine: Arc<dyn InferenceEngine>,
    pool: WorkerPool,
    batcher: Batcher,
    metrics: ServerMetrics,
    start: Instant,
    /// Batches handed to the pool and not yet absorbed.
    inflight_batches: usize,
    /// Exact requests inside those batches (a deadline-released partial
    /// batch counts its real size, not `max_batch`).
    inflight_requests: usize,
    total_submitted: u64,
}

impl Server {
    pub fn new(runtime: ModelRuntime, cfg: ServerConfig) -> Server {
        Server::with_engine(Arc::new(ShareableRuntime(runtime)), cfg)
    }

    /// Build a server over any [`InferenceEngine`] — the PJRT runtime in
    /// production, a stub in tests and benches, so the coordinator logic
    /// is fully exercisable without AOT artifacts.
    pub fn with_engine(engine: Arc<dyn InferenceEngine>, cfg: ServerConfig) -> Server {
        let pool = WorkerPool::new(Arc::clone(&engine), cfg.concurrency);
        Server {
            engine,
            pool,
            batcher: Batcher::new(cfg.batcher),
            metrics: ServerMetrics::new(),
            start: Instant::now(),
            inflight_batches: 0,
            inflight_requests: 0,
            total_submitted: 0,
        }
    }

    /// Elapsed logical time.
    pub fn now(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// Start a fresh measurement window: subsequent percentile, batch,
    /// and throughput-gauge reports describe only traffic served from
    /// now on. Lifetime counters (completed/failed) are unaffected.
    pub fn reset_window_metrics(&mut self) {
        self.metrics.reset_distributions();
    }

    pub fn concurrency(&self) -> usize {
        self.pool.size()
    }

    /// Requests queued or in flight (admission-control signal). Exact:
    /// an in-flight partial batch contributes its real request count,
    /// not `max_batch`, so deadline-released partial batches don't
    /// inflate the backpressure seen by the router.
    pub fn backlog(&self) -> usize {
        self.batcher.queued() + self.inflight_requests
    }

    /// Batches handed to the pool and not yet absorbed (the unit
    /// `tick()`'s `pool.size() * 2` backpressure budget is charged in).
    pub fn inflight_batches(&self) -> usize {
        self.inflight_batches
    }

    /// Exact request count inside the in-flight batches.
    pub fn inflight_requests(&self) -> usize {
        self.inflight_requests
    }

    /// Model input side (square pixels).
    pub fn input_side(&self) -> usize {
        self.engine.input_side()
    }

    /// Change the live concurrency level: drains in-flight work, swaps
    /// the worker pool (what `nvpmodel`-style reconfiguration does to the
    /// app layer; the measurement warm-up after this is the optimizer's
    /// problem, as on real hardware).
    ///
    /// The drain blocks on the pool's completion signal — it wakes on
    /// every result and the instant the pool dies — instead of polling
    /// with a fixed-slice `recv_timeout`. Whatever the old pool's
    /// `shutdown()` returns (including synthesized failures for jobs no
    /// worker ever ran) is absorbed, and the in-flight counters are
    /// reconciled against it, so a drain timeout can never leave
    /// `inflight_batches` pinned above zero and permanently shrink the
    /// backpressure budget. A pool whose live workers produced nothing
    /// for the whole drain window is detached (dropped without joining
    /// the hung threads) rather than joined, so reconfiguration always
    /// completes.
    pub fn set_concurrency(&mut self, c: usize) {
        // Same-size reconfiguration is a no-op only while every worker
        // is still alive: a pool with dead workers is rebuilt even at
        // unchanged concurrency, so reapplying the current level heals
        // a (partially) dead server instead of keeping it dead forever.
        if c == self.pool.size() && self.pool.alive() == self.pool.size() {
            return;
        }
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while self.inflight_batches > 0 {
            while let Some(r) = self.pool.try_recv() {
                self.absorb(r);
            }
            if self.inflight_batches == 0 {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.pool.wait_event(deadline - now) {
                PoolEvent::ResultReady => continue,
                PoolEvent::Dead | PoolEvent::TimedOut => break,
            }
        }
        // Final sweep: a result that landed just as the drain gave up
        // is still absorbed rather than discarded with the old pool.
        while let Some(r) = self.pool.try_recv() {
            self.absorb(r);
        }
        let old = std::mem::replace(
            &mut self.pool,
            WorkerPool::new(Arc::clone(&self.engine), c),
        );
        if self.inflight_batches > 0 && old.alive() > 0 {
            // Live workers that produced nothing for DRAIN_TIMEOUT are
            // hung mid-inference; `shutdown()` would join them and block
            // forever. Detach instead: the pool's Drop closes the job
            // queue without joining, and the stuck work is reconciled
            // as failed below.
            log::warn!(
                "drain timed out with {} batch(es) stuck on hung worker(s); detaching old pool",
                self.inflight_batches
            );
            drop(old);
        } else {
            for r in old.shutdown() {
                self.absorb(r);
            }
        }
        self.reconcile_lost_inflight();
    }

    /// Enqueue one frame.
    pub fn submit(&mut self, id: u64, pixels: Vec<f32>) {
        let req = PendingRequest { id, pixels, arrived: self.now() };
        self.batcher.push(req);
        self.total_submitted += 1;
    }

    fn absorb(&mut self, r: super::worker::BatchResult) -> Vec<(u64, Detections)> {
        if self.inflight_batches == 0 {
            // Late synthesized result for a batch already reconciled as
            // lost (the pool died with the job stranded in its queue):
            // its failure was counted by `reconcile_lost_inflight` —
            // drop it instead of double-counting. A real completion
            // cannot arrive here: reconciliation only happens once no
            // worker is left to complete anything.
            return Vec::new();
        }
        self.inflight_batches -= 1;
        self.inflight_requests = self.inflight_requests.saturating_sub(r.ids.len());
        let now = self.now();
        let lats: Vec<Duration> =
            r.arrived.iter().map(|&a| now.saturating_sub(a)).collect();
        self.metrics
            .record_batch(r.ids.len(), r.exec_time, &lats, now, r.error.is_some());
        if let Some(e) = &r.error {
            log::warn!("batch failed on worker {}: {e}", r.worker);
            return Vec::new();
        }
        r.ids.into_iter().zip(r.detections).collect()
    }

    /// Batches the pool can never return (every worker died mid-flight)
    /// are counted failed and the in-flight counters zeroed, so the
    /// backpressure budget — and any closed loop waiting on them —
    /// recovers instead of wedging.
    fn reconcile_lost_inflight(&mut self) {
        if self.inflight_batches == 0 {
            return;
        }
        let lost = self.inflight_requests;
        log::warn!(
            "{} in-flight batch(es) / {lost} request(s) lost to dead workers; counted failed",
            self.inflight_batches
        );
        let now = self.now();
        self.metrics.record_batch(lost, Duration::ZERO, &[], now, true);
        self.inflight_batches = 0;
        self.inflight_requests = 0;
    }

    /// A dead pool executes nothing: release every queued request
    /// immediately as failed (batching deadlines are moot without
    /// workers), so closed loops terminate instead of waiting on
    /// batches that will never form.
    fn fail_queued_requests(&mut self) {
        let queued = self.batcher.drain_all();
        if queued.is_empty() {
            return;
        }
        log::warn!("failing {} queued request(s): worker pool dead", queued.len());
        let now = self.now();
        self.metrics.record_batch(queued.len(), Duration::ZERO, &[], now, true);
    }

    /// Pump the loop: release due batches to the pool, collect finished
    /// ones. Returns completed `(id, detections)` pairs. Non-blocking —
    /// the closed loop blocks between ticks on the completion signal.
    pub fn tick(&mut self) -> Vec<(u64, Detections)> {
        let now = self.now();
        // Keep the pool fed, but do not queue unboundedly: at most 2
        // batches in flight per worker (backpressure).
        while self.inflight_batches < self.pool.size() * 2 {
            match self.batcher.pop_ready(now) {
                Some(batch) => {
                    let mut ids = Vec::with_capacity(batch.len());
                    let mut arrived = Vec::with_capacity(batch.len());
                    let mut pixels = Vec::new();
                    for r in batch {
                        ids.push(r.id);
                        arrived.push(r.arrived);
                        pixels.extend_from_slice(&r.pixels);
                    }
                    let requests = ids.len();
                    self.pool.submit(BatchJob { ids, arrived, pixels });
                    self.inflight_batches += 1;
                    self.inflight_requests += requests;
                }
                None => break,
            }
        }
        let mut done = Vec::new();
        while let Some(r) = self.pool.try_recv() {
            done.extend(self.absorb(r));
        }
        done
    }

    /// Drive a closed loop: `inflight` outstanding frames from `video`,
    /// `total` terminated requests (completions + failures). Returns the
    /// steady-state report.
    ///
    /// Event-driven: when a tick makes no progress the loop blocks on
    /// the pool's completion signal, with the timeout bounded by the
    /// batcher's next release deadline — each wake is a completion, a
    /// deadline fire, or a pool death. There is no sleep-polling, so an
    /// idle pump costs zero CPU (and zero power on an edge board).
    pub fn run_closed_loop(
        &mut self,
        video: &mut VideoSource,
        total: u64,
        inflight: usize,
    ) -> Result<ServeReport> {
        assert_eq!(video.side(), self.input_side(), "video must match model input");
        let t0 = self.now();
        let failed_at_start = self.metrics.failed();
        let mut next_id = 0u64;
        let mut outstanding = 0usize;
        let mut completed = 0u64;
        let mut failed_seen = 0u64;
        let mut pump_iterations = 0u64;
        let mut deadline_fires = 0u64;
        while completed + failed_seen < total {
            pump_iterations += 1;
            while outstanding < inflight && next_id < total {
                self.submit(next_id, video.next_frame());
                next_id += 1;
                outstanding += 1;
            }
            let done = self.tick();
            completed += done.len() as u64;
            outstanding -= done.len();
            // Failed batches produce no completions; count their
            // requests as terminated so a worker error can never pin
            // `outstanding` at `inflight` and hang the loop.
            let failed_now = self.metrics.failed() - failed_at_start;
            let newly_failed = failed_now - failed_seen;
            if newly_failed > 0 {
                failed_seen = failed_now;
                outstanding = outstanding.saturating_sub(newly_failed as usize);
            }
            if done.is_empty() && newly_failed == 0 {
                // No progress this tick: block until something real
                // happens. A pending batcher deadline bounds the wait
                // only while the backpressure budget could actually
                // dispatch the released batch.
                let now = self.now();
                let budget_free = self.inflight_batches < self.pool.size() * 2;
                let (timeout, deadline_bounded) = match self.batcher.next_deadline(now) {
                    Some(d) if budget_free => (d.saturating_sub(now), true),
                    _ => (PUMP_STALL_WAIT, false),
                };
                match self.pool.wait_event(timeout) {
                    PoolEvent::ResultReady => {}
                    PoolEvent::TimedOut => {
                        if deadline_bounded {
                            deadline_fires += 1;
                        }
                    }
                    PoolEvent::Dead => {
                        // Every worker is gone and no result is pending:
                        // in-flight and queued work can never complete.
                        // Count it failed so the loop terminates (new
                        // submissions flow through `submit` on the dead
                        // pool, which synthesizes failed results).
                        self.reconcile_lost_inflight();
                        self.fail_queued_requests();
                    }
                }
            }
        }
        let wall = (self.now() - t0).as_secs_f64();
        Ok(ServeReport {
            requests: completed,
            failed: failed_seen,
            throughput_fps: finite_rate(completed as f64, wall),
            latency_p50_ms: self.metrics.latency_ms(50.0),
            latency_p95_ms: self.metrics.latency_ms(95.0),
            latency_p99_ms: self.metrics.latency_ms(99.0),
            mean_batch: self.metrics.mean_batch_size(),
            mean_exec_ms: self.metrics.mean_exec_ms(),
            concurrency: self.pool.size(),
            wall_s: wall,
            pump_iterations,
            deadline_fires,
            deadline_hits: 0,
            deadline_misses: 0,
        })
    }

    /// Drive an open loop: requests arrive on `gen`'s schedule whether or
    /// not the server keeps up, until `total` requests have terminated
    /// (completions + failures). Each completion is scored against the
    /// per-request latency `deadline_ms` —
    /// [`ServeReport::deadline_hits`] / [`ServeReport::deadline_misses`]
    /// record the outcome (failures count as misses).
    ///
    /// Event-driven like [`Server::run_closed_loop`]: a no-progress tick
    /// blocks on the pool's completion signal, with the timeout bounded
    /// by whichever comes first of the next scheduled arrival and the
    /// batcher's release deadline. A backlogged server therefore keeps
    /// absorbing arrivals into the batcher queue — the queueing delay
    /// this builds up is exactly what the deadline accounting measures.
    pub fn run_open_loop(
        &mut self,
        video: &mut VideoSource,
        gen: &mut crate::workload::OpenLoopGen,
        total: u64,
        deadline_ms: f64,
    ) -> Result<ServeReport> {
        assert_eq!(video.side(), self.input_side(), "video must match model input");
        assert!(deadline_ms > 0.0, "deadline must be positive");
        let t0 = self.now();
        let failed_at_start = self.metrics.failed();
        let deadline = Duration::from_secs_f64(deadline_ms / 1000.0);
        let mut submitted_at: std::collections::HashMap<u64, Duration> =
            std::collections::HashMap::new();
        let mut issued = 0u64;
        let mut completed = 0u64;
        let mut failed_seen = 0u64;
        let mut deadline_hits = 0u64;
        let mut deadline_misses = 0u64;
        let mut pump_iterations = 0u64;
        let mut deadline_fires = 0u64;
        while completed + failed_seen < total {
            pump_iterations += 1;
            // Admit every arrival that is due. Arrival timestamps are
            // measured from t0 so the schedule is independent of what
            // ran on this server before.
            let now = self.now();
            if issued < total {
                for r in gen.poll(now - t0) {
                    if issued >= total {
                        break; // poll can overshoot the request budget
                    }
                    self.submit(r.id, video.frame(r.frame_index));
                    submitted_at.insert(r.id, now);
                    issued += 1;
                }
            }
            let done = self.tick();
            let done_at = self.now();
            for (id, _) in &done {
                match submitted_at.remove(id) {
                    Some(at) if done_at - at <= deadline => deadline_hits += 1,
                    _ => deadline_misses += 1,
                }
            }
            completed += done.len() as u64;
            let failed_now = self.metrics.failed() - failed_at_start;
            let newly_failed = failed_now - failed_seen;
            if newly_failed > 0 {
                failed_seen = failed_now;
                deadline_misses += newly_failed;
            }
            if done.is_empty() && newly_failed == 0 {
                let now = self.now();
                let mut timeout = PUMP_STALL_WAIT;
                let mut deadline_bounded = false;
                if issued < total {
                    let due = t0 + gen.due(); // schedule is relative to t0
                    if due <= now {
                        continue; // an arrival is already due: re-poll
                    }
                    timeout = timeout.min(due - now);
                }
                let budget_free = self.inflight_batches < self.pool.size() * 2;
                if let Some(d) = self.batcher.next_deadline(now) {
                    if budget_free {
                        let wait = d.saturating_sub(now);
                        if wait < timeout {
                            timeout = wait;
                            deadline_bounded = true;
                        }
                    }
                }
                match self.pool.wait_event(timeout) {
                    PoolEvent::ResultReady => {}
                    PoolEvent::TimedOut => {
                        if deadline_bounded {
                            deadline_fires += 1;
                        }
                    }
                    PoolEvent::Dead => {
                        self.reconcile_lost_inflight();
                        self.fail_queued_requests();
                    }
                }
            }
        }
        let wall = (self.now() - t0).as_secs_f64();
        Ok(ServeReport {
            requests: completed,
            failed: failed_seen,
            throughput_fps: finite_rate(completed as f64, wall),
            latency_p50_ms: self.metrics.latency_ms(50.0),
            latency_p95_ms: self.metrics.latency_ms(95.0),
            latency_p99_ms: self.metrics.latency_ms(99.0),
            mean_batch: self.metrics.mean_batch_size(),
            mean_exec_ms: self.metrics.mean_exec_ms(),
            concurrency: self.pool.size(),
            wall_s: wall,
            pump_iterations,
            deadline_fires,
            deadline_hits,
            deadline_misses,
        })
    }

    /// Shut down, returning total completed count.
    pub fn shutdown(self) -> u64 {
        let done = self.metrics.completed();
        self.pool.shutdown();
        done
    }
}

// PJRT-free pump/accounting regression tests live in
// rust/tests/coordinator_pump.rs (stub engines); integration tests with
// real PJRT + artifacts in rust/tests/runtime_integration.rs.
