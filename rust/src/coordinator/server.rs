//! The serving event loop: batcher → worker pool → metrics, with
//! runtime-adjustable concurrency (the knob CORAL tunes live).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig, PendingRequest};
use super::metrics::ServerMetrics;
use super::worker::{BatchJob, ShareableRuntime, WorkerPool};
use crate::runtime::{Detections, ModelRuntime};
use crate::workload::VideoSource;

/// Server construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Inference workers (the paper's concurrency level).
    pub concurrency: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { concurrency: 2, batcher: BatcherConfig::default() }
    }
}

/// Steady-state report of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: u64,
    pub failed: u64,
    pub throughput_fps: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub mean_batch: f64,
    pub mean_exec_ms: f64,
    pub concurrency: usize,
    pub wall_s: f64,
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} reqs in {:.2}s: {:.1} fps, p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms, \
             batch {:.2}, exec {:.1} ms, c={}",
            self.requests,
            self.wall_s,
            self.throughput_fps,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_p99_ms,
            self.mean_batch,
            self.mean_exec_ms,
            self.concurrency
        )
    }
}

/// Single-model serving stack.
pub struct Server {
    runtime: Arc<ShareableRuntime>,
    pool: WorkerPool,
    batcher: Batcher,
    metrics: ServerMetrics,
    start: Instant,
    inflight_batches: usize,
    total_submitted: u64,
}

impl Server {
    pub fn new(runtime: ModelRuntime, cfg: ServerConfig) -> Server {
        let runtime = Arc::new(ShareableRuntime(runtime));
        let pool = WorkerPool::new(Arc::clone(&runtime), cfg.concurrency);
        Server {
            runtime,
            pool,
            batcher: Batcher::new(cfg.batcher),
            metrics: ServerMetrics::new(),
            start: Instant::now(),
            inflight_batches: 0,
            total_submitted: 0,
        }
    }

    /// Elapsed logical time.
    pub fn now(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// Start a fresh measurement window: subsequent percentile/batch
    /// reports describe only traffic served from now on. Lifetime
    /// counters (completed/failed) are unaffected.
    pub fn reset_window_metrics(&mut self) {
        self.metrics.reset_distributions();
    }

    pub fn concurrency(&self) -> usize {
        self.pool.size()
    }

    /// Requests queued or in flight (admission-control signal).
    pub fn backlog(&self) -> usize {
        self.batcher.queued() + self.inflight_batches * self.batcher.config().max_batch
    }

    /// Model input side (square pixels).
    pub fn input_side(&self) -> usize {
        self.runtime.0.input_side()
    }

    /// Change the live concurrency level: drains in-flight work, swaps
    /// the worker pool (what `nvpmodel`-style reconfiguration does to the
    /// app layer; the measurement warm-up after this is the optimizer's
    /// problem, as on real hardware).
    pub fn set_concurrency(&mut self, c: usize) {
        if c == self.pool.size() {
            return;
        }
        // Drain in-flight batches so no request is lost.
        while self.inflight_batches > 0 {
            if let Some(r) = self.pool.recv_timeout(Duration::from_secs(30)) {
                self.absorb(r);
            } else {
                break;
            }
        }
        let old = std::mem::replace(
            &mut self.pool,
            WorkerPool::new(Arc::clone(&self.runtime), c),
        );
        for r in old.shutdown() {
            self.absorb(r);
        }
    }

    /// Enqueue one frame.
    pub fn submit(&mut self, id: u64, pixels: Vec<f32>) {
        let req = PendingRequest { id, pixels, arrived: self.now() };
        self.batcher.push(req);
        self.total_submitted += 1;
    }

    fn absorb(&mut self, r: super::worker::BatchResult) -> Vec<(u64, Detections)> {
        self.inflight_batches -= 1;
        let now = self.now();
        let lats: Vec<Duration> =
            r.arrived.iter().map(|&a| now.saturating_sub(a)).collect();
        self.metrics
            .record_batch(r.ids.len(), r.exec_time, &lats, now, r.error.is_some());
        if let Some(e) = &r.error {
            log::warn!("batch failed on worker {}: {e}", r.worker);
            return Vec::new();
        }
        r.ids.into_iter().zip(r.detections).collect()
    }

    /// Pump the loop: release due batches to the pool, collect finished
    /// ones. Returns completed `(id, detections)` pairs.
    pub fn tick(&mut self) -> Vec<(u64, Detections)> {
        let now = self.now();
        // Keep the pool fed, but do not queue unboundedly: at most 2
        // batches in flight per worker (backpressure).
        while self.inflight_batches < self.pool.size() * 2 {
            match self.batcher.pop_ready(now) {
                Some(batch) => {
                    let mut ids = Vec::with_capacity(batch.len());
                    let mut arrived = Vec::with_capacity(batch.len());
                    let mut pixels = Vec::new();
                    for r in batch {
                        ids.push(r.id);
                        arrived.push(r.arrived);
                        pixels.extend_from_slice(&r.pixels);
                    }
                    self.pool.submit(BatchJob { ids, arrived, pixels });
                    self.inflight_batches += 1;
                }
                None => break,
            }
        }
        let mut done = Vec::new();
        while let Some(r) = self.pool.try_recv() {
            done.extend(self.absorb(r));
        }
        done
    }

    /// Drive a closed loop: `inflight` outstanding frames from `video`,
    /// `total` terminated requests (completions + failures). Returns the
    /// steady-state report.
    pub fn run_closed_loop(
        &mut self,
        video: &mut VideoSource,
        total: u64,
        inflight: usize,
    ) -> Result<ServeReport> {
        assert_eq!(video.side(), self.input_side(), "video must match model input");
        let t0 = self.now();
        let failed_at_start = self.metrics.failed();
        let mut next_id = 0u64;
        let mut outstanding = 0usize;
        let mut completed = 0u64;
        let mut failed_seen = 0u64;
        while completed + failed_seen < total {
            while outstanding < inflight && next_id < total {
                self.submit(next_id, video.next_frame());
                next_id += 1;
                outstanding += 1;
            }
            let done = self.tick();
            completed += done.len() as u64;
            outstanding -= done.len();
            // Failed batches produce no completions; count their
            // requests as terminated so a worker error can never pin
            // `outstanding` at `inflight` and hang the loop.
            let failed_now = self.metrics.failed() - failed_at_start;
            let newly_failed = failed_now - failed_seen;
            if newly_failed > 0 {
                failed_seen = failed_now;
                outstanding = outstanding.saturating_sub(newly_failed as usize);
            }
            if done.is_empty() && newly_failed == 0 {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        let wall = (self.now() - t0).as_secs_f64();
        Ok(ServeReport {
            requests: completed,
            failed: failed_seen,
            throughput_fps: completed as f64 / wall,
            latency_p50_ms: self.metrics.latency_ms(50.0),
            latency_p95_ms: self.metrics.latency_ms(95.0),
            latency_p99_ms: self.metrics.latency_ms(99.0),
            mean_batch: self.metrics.mean_batch_size(),
            mean_exec_ms: self.metrics.mean_exec_ms(),
            concurrency: self.pool.size(),
            wall_s: wall,
        })
    }

    /// Shut down, returning total completed count.
    pub fn shutdown(self) -> u64 {
        let done = self.metrics.completed();
        self.pool.shutdown();
        done
    }
}

// Integration tests (real PJRT + artifacts) in rust/tests/.
