//! Worker pool: `c` inference workers (the paper's concurrency level)
//! pulling batch jobs from a shared queue and executing them on the
//! compiled PJRT executables.
//!
//! The pool is **event-driven**: completions land on a condvar-backed
//! queue and every push wakes the serving pump ([`WorkerPool::wait_event`]),
//! so the coordinator never sleep-polls for results. Worker liveness is
//! tracked the same way — a worker that dies (its inference panicked)
//! decrements the live count and wakes any waiter immediately, so a dead
//! pool is observed as [`PoolEvent::Dead`] instead of after a timeout,
//! and the batch it was holding is surfaced as a failed [`BatchResult`]
//! rather than silently lost.
//!
//! Safety: the `xla` crate's handles wrap raw PJRT pointers and are not
//! marked `Send`/`Sync`, but the PJRT C API guarantees thread-safe,
//! concurrent `Execute` calls on one loaded executable (each call owns
//! its own input/output buffers). [`ShareableRuntime`] asserts that
//! contract once, in one place.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::{Detections, ModelRuntime};

/// What the pool needs from an inference backend: the PJRT-compiled
/// [`ModelRuntime`] in production (via [`ShareableRuntime`]), anything
/// deterministic in tests and benches — the whole coordinator is
/// exercisable without AOT artifacts.
pub trait InferenceEngine: Send + Sync + 'static {
    /// Run `n` images (flattened NHWC, `n`·H·W·C floats); returns exactly
    /// `n` detections, or an error that the pool surfaces as a failed
    /// batch.
    fn infer(&self, pixels: &[f32], n: usize) -> anyhow::Result<Vec<Detections>>;

    /// Input image side (square pixels).
    fn input_side(&self) -> usize;
}

/// Wrapper asserting PJRT's documented thread-safety for execution.
pub struct ShareableRuntime(pub ModelRuntime);
// SAFETY: PJRT loaded executables are immutable after compilation and the
// PJRT C API specifies Execute is thread-safe; the CPU plugin serializes
// internally where needed. No interior mutation happens on our side.
unsafe impl Send for ShareableRuntime {}
unsafe impl Sync for ShareableRuntime {}

impl InferenceEngine for ShareableRuntime {
    fn infer(&self, pixels: &[f32], n: usize) -> anyhow::Result<Vec<Detections>> {
        self.0.infer(pixels, n)
    }

    fn input_side(&self) -> usize {
        self.0.input_side()
    }
}

/// One batch of work for a worker.
pub struct BatchJob {
    /// Request ids, one per image.
    pub ids: Vec<u64>,
    /// Submission times of each request (for end-to-end latency).
    pub arrived: Vec<Duration>,
    /// Flattened NHWC pixels, `ids.len()` images.
    pub pixels: Vec<f32>,
}

/// Completed batch.
pub struct BatchResult {
    pub ids: Vec<u64>,
    pub arrived: Vec<Duration>,
    pub detections: Vec<Detections>,
    /// Worker-side execution time.
    pub exec_time: Duration,
    /// Which worker ran it ([`NO_WORKER`] for results the pool
    /// synthesized: jobs a dead or shut-down pool never executed).
    pub worker: usize,
    /// Error message if the execution failed.
    pub error: Option<String>,
}

/// Sentinel worker index for synthesized failure results.
pub const NO_WORKER: usize = usize::MAX;

/// Outcome of a blocking wait on the completion signal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolEvent {
    /// At least one completed batch is ready for [`WorkerPool::try_recv`].
    ResultReady,
    /// The timeout elapsed with no completion (the caller's deadline —
    /// typically the batcher's next release — has fired).
    TimedOut,
    /// Every worker has died and no result is pending: in-flight work
    /// can never complete.
    Dead,
}

#[derive(Default)]
struct JobQueue {
    queue: VecDeque<BatchJob>,
    closed: bool,
}

struct DoneQueue {
    results: VecDeque<BatchResult>,
    /// Workers still running; decremented on every thread exit,
    /// including panics.
    alive: usize,
}

/// Both condvar-backed queues the workers and the pump share.
struct Shared {
    jobs: Mutex<JobQueue>,
    jobs_cv: Condvar,
    done: Mutex<DoneQueue>,
    done_cv: Condvar,
}

/// Poison-tolerant lock: a worker panics *outside* its critical
/// sections, but the queues must stay usable even if one ever unwinds
/// while holding a guard.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Shared {
    fn push_result(&self, r: BatchResult) {
        lock(&self.done).results.push_back(r);
        self.done_cv.notify_all();
    }
}

fn synthesized_failure(
    ids: Vec<u64>,
    arrived: Vec<Duration>,
    error: &str,
) -> BatchResult {
    BatchResult {
        ids,
        arrived,
        detections: Vec::new(),
        exec_time: Duration::ZERO,
        worker: NO_WORKER,
        error: Some(error.to_string()),
    }
}

fn worker_loop(shared: Arc<Shared>, engine: Arc<dyn InferenceEngine>, w: usize) {
    /// Runs on every exit path — including a panic unwinding out of
    /// `infer` — so the live-worker count stays exact and anyone blocked
    /// on the completion signal learns of the death immediately.
    struct AliveGuard {
        shared: Arc<Shared>,
    }
    impl Drop for AliveGuard {
        fn drop(&mut self) {
            lock(&self.shared.done).alive -= 1;
            self.shared.done_cv.notify_all();
        }
    }

    /// Armed while `infer` runs: if the engine panics, the in-hand batch
    /// is surfaced as a failed result instead of vanishing with the
    /// thread.
    struct JobGuard {
        shared: Arc<Shared>,
        job: Option<(Vec<u64>, Vec<Duration>)>,
        worker: usize,
    }
    impl Drop for JobGuard {
        fn drop(&mut self) {
            if let Some((ids, arrived)) = self.job.take() {
                let mut r =
                    synthesized_failure(ids, arrived, "worker panicked during inference");
                r.worker = self.worker;
                self.shared.push_result(r);
            }
        }
    }

    let _alive = AliveGuard { shared: Arc::clone(&shared) };
    loop {
        // Competitive pull: idle workers block on the job condvar and
        // race for the next job; a closed, empty queue shuts them down.
        let job = {
            let mut q = lock(&shared.jobs);
            loop {
                if let Some(job) = q.queue.pop_front() {
                    break job;
                }
                if q.closed {
                    return;
                }
                q = shared
                    .jobs_cv
                    .wait(q)
                    .unwrap_or_else(|p| p.into_inner());
            }
        };
        let BatchJob { ids, arrived, pixels } = job;
        let n = ids.len();
        let mut guard = JobGuard {
            shared: Arc::clone(&shared),
            job: Some((ids, arrived)),
            worker: w,
        };
        let t0 = Instant::now();
        let out = engine.infer(&pixels, n);
        let exec_time = t0.elapsed();
        let (ids, arrived) = guard.job.take().expect("guard armed above");
        let result = match out {
            Ok(detections) => BatchResult {
                ids,
                arrived,
                detections,
                exec_time,
                worker: w,
                error: None,
            },
            Err(e) => BatchResult {
                ids,
                arrived,
                detections: Vec::new(),
                exec_time,
                worker: w,
                error: Some(e.to_string()),
            },
        };
        shared.push_result(result);
    }
}

/// Fixed-size pool of inference workers over a shared job queue.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    /// Spawn `concurrency` workers sharing `engine`.
    pub fn new(engine: Arc<dyn InferenceEngine>, concurrency: usize) -> WorkerPool {
        assert!(concurrency >= 1, "pool needs at least one worker");
        let shared = Arc::new(Shared {
            jobs: Mutex::new(JobQueue::default()),
            jobs_cv: Condvar::new(),
            done: Mutex::new(DoneQueue { results: VecDeque::new(), alive: concurrency }),
            done_cv: Condvar::new(),
        });
        let handles = (0..concurrency)
            .map(|w| {
                let shared = Arc::clone(&shared);
                let engine = Arc::clone(&engine);
                std::thread::spawn(move || worker_loop(shared, engine, w))
            })
            .collect();
        WorkerPool { shared, handles, size: concurrency }
    }

    /// Number of workers (the live concurrency level).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Workers still running (a panicked worker's thread has exited).
    pub fn alive(&self) -> usize {
        lock(&self.shared.done).alive
    }

    /// Submit a batch. A dead pool (every worker panicked) surfaces the
    /// job as a failed result on the completion queue — the caller's
    /// normal absorption path accounts it — instead of panicking.
    pub fn submit(&self, job: BatchJob) {
        if self.alive() == 0 {
            self.shared.push_result(synthesized_failure(
                job.ids,
                job.arrived,
                "worker pool dead: every worker has exited",
            ));
            return;
        }
        lock(&self.shared.jobs).queue.push_back(job);
        self.shared.jobs_cv.notify_one();
    }

    /// Non-blocking poll for a finished batch.
    pub fn try_recv(&self) -> Option<BatchResult> {
        lock(&self.shared.done).results.pop_front()
    }

    /// Blocking wait (with timeout) for a finished batch. Returns `None`
    /// on timeout, or at once when every worker has died with no result
    /// pending.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<BatchResult> {
        match self.wait_event(timeout) {
            PoolEvent::ResultReady | PoolEvent::TimedOut => self.try_recv(),
            PoolEvent::Dead => None,
        }
    }

    /// Block until a completed batch is available (left on the queue for
    /// [`WorkerPool::try_recv`]), the timeout elapses, or the pool dies.
    /// This is the pump's wakeup primitive: no sleep-polling, every wake
    /// is a real event.
    pub fn wait_event(&self, timeout: Duration) -> PoolEvent {
        let deadline = Instant::now() + timeout;
        let mut d = lock(&self.shared.done);
        loop {
            if !d.results.is_empty() {
                return PoolEvent::ResultReady;
            }
            if d.alive == 0 {
                return PoolEvent::Dead;
            }
            let now = Instant::now();
            if now >= deadline {
                return PoolEvent::TimedOut;
            }
            let (guard, _wait) = self
                .shared
                .done_cv
                .wait_timeout(d, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            d = guard;
        }
    }

    /// Close the queue and join the workers. Returns every outstanding
    /// result — including synthesized failures for jobs no worker ever
    /// picked up — so callers can reconcile their in-flight accounting
    /// exactly (nothing is silently lost).
    pub fn shutdown(mut self) -> Vec<BatchResult> {
        {
            let mut q = lock(&self.shared.jobs);
            q.closed = true;
        }
        self.shared.jobs_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let mut rest: Vec<BatchResult> = lock(&self.shared.done).results.drain(..).collect();
        let mut q = lock(&self.shared.jobs);
        while let Some(job) = q.queue.pop_front() {
            rest.push(synthesized_failure(
                job.ids,
                job.arrived,
                "worker pool shut down before execution",
            ));
        }
        rest
    }
}

impl Drop for WorkerPool {
    /// The old mpsc design woke workers when the channel `Sender`
    /// dropped; the condvar design must do the same explicitly. Without
    /// this, dropping a pool that was never `shutdown()` (a panicking
    /// test, a detached hung pool) would leak every worker parked on
    /// the job condvar forever — each pinning the engine `Arc`.
    /// Threads are *not* joined here (a hung worker must not block the
    /// dropper); they exit on their own once they observe the closed
    /// queue. Runs after `shutdown()` too, where it is a no-op.
    fn drop(&mut self) {
        lock(&self.shared.jobs).closed = true;
        self.shared.jobs_cv.notify_all();
    }
}

// Pure channel/condvar plumbing is exercised PJRT-free through the stub
// engines in rust/tests/coordinator_pump.rs; integration tests with real
// PJRT artifacts live in rust/tests/runtime_integration.rs.
