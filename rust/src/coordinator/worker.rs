//! Worker pool: `c` inference workers (the paper's concurrency level)
//! pulling batch jobs from a shared queue and executing them on the
//! compiled PJRT executables.
//!
//! Safety: the `xla` crate's handles wrap raw PJRT pointers and are not
//! marked `Send`/`Sync`, but the PJRT C API guarantees thread-safe,
//! concurrent `Execute` calls on one loaded executable (each call owns
//! its own input/output buffers). [`ShareableRuntime`] asserts that
//! contract once, in one place.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::{Detections, ModelRuntime};

/// Wrapper asserting PJRT's documented thread-safety for execution.
pub struct ShareableRuntime(pub ModelRuntime);
// SAFETY: PJRT loaded executables are immutable after compilation and the
// PJRT C API specifies Execute is thread-safe; the CPU plugin serializes
// internally where needed. No interior mutation happens on our side.
unsafe impl Send for ShareableRuntime {}
unsafe impl Sync for ShareableRuntime {}

/// One batch of work for a worker.
pub struct BatchJob {
    /// Request ids, one per image.
    pub ids: Vec<u64>,
    /// Submission times of each request (for end-to-end latency).
    pub arrived: Vec<Duration>,
    /// Flattened NHWC pixels, `ids.len()` images.
    pub pixels: Vec<f32>,
}

/// Completed batch.
pub struct BatchResult {
    pub ids: Vec<u64>,
    pub arrived: Vec<Duration>,
    pub detections: Vec<Detections>,
    /// Worker-side execution time.
    pub exec_time: Duration,
    /// Which worker ran it.
    pub worker: usize,
    /// Error message if the execution failed.
    pub error: Option<String>,
}

/// Fixed-size pool of inference workers over a shared job queue.
pub struct WorkerPool {
    job_tx: Option<Sender<BatchJob>>,
    result_rx: Receiver<BatchResult>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    /// Spawn `concurrency` workers sharing `runtime`.
    pub fn new(runtime: Arc<ShareableRuntime>, concurrency: usize) -> WorkerPool {
        assert!(concurrency >= 1, "pool needs at least one worker");
        let (job_tx, job_rx) = channel::<BatchJob>();
        let (result_tx, result_rx) = channel::<BatchResult>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut handles = Vec::new();
        for w in 0..concurrency {
            let job_rx = Arc::clone(&job_rx);
            let result_tx = result_tx.clone();
            let runtime = Arc::clone(&runtime);
            handles.push(std::thread::spawn(move || loop {
                // Competitive pull: idle workers race for the next job.
                let job = match job_rx.lock().unwrap().recv() {
                    Ok(j) => j,
                    Err(_) => break, // queue closed: shut down
                };
                let n = job.ids.len();
                let t0 = Instant::now();
                let out = runtime.0.infer(&job.pixels, n);
                let exec_time = t0.elapsed();
                let result = match out {
                    Ok(detections) => BatchResult {
                        ids: job.ids,
                        arrived: job.arrived,
                        detections,
                        exec_time,
                        worker: w,
                        error: None,
                    },
                    Err(e) => BatchResult {
                        ids: job.ids,
                        arrived: job.arrived,
                        detections: Vec::new(),
                        exec_time,
                        worker: w,
                        error: Some(e.to_string()),
                    },
                };
                if result_tx.send(result).is_err() {
                    break;
                }
            }));
        }
        WorkerPool { job_tx: Some(job_tx), result_rx, handles, size: concurrency }
    }

    /// Number of workers (the live concurrency level).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a batch.
    pub fn submit(&self, job: BatchJob) {
        self.job_tx
            .as_ref()
            .expect("pool closed")
            .send(job)
            .expect("workers gone");
    }

    /// Non-blocking poll for a finished batch.
    pub fn try_recv(&self) -> Option<BatchResult> {
        self.result_rx.try_recv().ok()
    }

    /// Blocking wait (with timeout) for a finished batch.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<BatchResult> {
        self.result_rx.recv_timeout(timeout).ok()
    }

    /// Close the queue and join the workers, returning any stragglers.
    pub fn shutdown(mut self) -> Vec<BatchResult> {
        drop(self.job_tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let mut rest = Vec::new();
        while let Ok(r) = self.result_rx.try_recv() {
            rest.push(r);
        }
        rest
    }
}

// Integration tests (real PJRT) live in rust/tests/; unit tests of the
// channel plumbing use a trivially-failing runtime path instead and are
// exercised through Server tests.
