//! L3 serving coordinator.
//!
//! The system CORAL tunes: a request router feeding per-model dynamic
//! batchers, a worker pool whose size is the paper's **concurrency
//! level** (the application-level knob presets ignore, §II-A1), and
//! serving metrics. Threads + condvar-backed queues (std) own the
//! event loop; the PJRT executables run real inference on the hot path
//! (behind the [`InferenceEngine`] seam, so the coordinator is fully
//! testable without artifacts).
//!
//! ```text
//! clients → Router → Batcher (size/deadline) → WorkerPool (c workers)
//!                                                  └→ InferenceEngine (PJRT)
//!               completions → ServerMetrics (fps, latency percentiles)
//! ```
//!
//! The serving pump is **event-driven**: workers signal every
//! completion (and their own death) through a condvar the pump blocks
//! on, bounded by [`Batcher::next_deadline`] — no sleep-polling
//! anywhere on the serving or measurement path, so an idle pump costs
//! zero CPU and zero power.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod worker;

pub use batcher::{Batcher, BatcherConfig, PendingRequest};
pub use metrics::{finite_rate, ServerMetrics, MIN_RATE_WINDOW_S};
pub use router::{ModelServer, Router};
pub use server::{Server, ServerConfig, ServeReport};
pub use worker::{
    BatchJob, BatchResult, InferenceEngine, PoolEvent, ShareableRuntime, WorkerPool, NO_WORKER,
};
