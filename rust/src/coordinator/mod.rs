//! L3 serving coordinator.
//!
//! The system CORAL tunes: a request router feeding per-model dynamic
//! batchers, a worker pool whose size is the paper's **concurrency
//! level** (the application-level knob presets ignore, §II-A1), and
//! serving metrics. Threads + channels (std) own the event loop; the
//! PJRT executables run real inference on the hot path.
//!
//! ```text
//! clients → Router → Batcher (size/deadline) → WorkerPool (c workers)
//!                                                  └→ PJRT executables
//!               completions → ServerMetrics (fps, latency percentiles)
//! ```

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod worker;

pub use batcher::{Batcher, BatcherConfig, PendingRequest};
pub use metrics::ServerMetrics;
pub use router::{ModelServer, Router};
pub use server::{Server, ServerConfig, ServeReport};
pub use worker::{BatchJob, BatchResult, WorkerPool};
