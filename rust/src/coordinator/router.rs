//! Request router: front door mapping each request's model to its
//! serving stack. The paper evaluates one model at a time; the router
//! generalizes the coordinator to multi-model edge boxes (the fleet
//! example) with per-model queues and a shared admission policy.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::server::Server;
use crate::models::ModelKind;
use crate::runtime::Detections;

/// Multi-model front door.
pub struct Router {
    servers: BTreeMap<ModelKind, Server>,
    /// Reject new work once a model's batcher backlog exceeds this.
    pub admission_limit: usize,
    rejected: u64,
}

impl Router {
    pub fn new() -> Router {
        Router { servers: BTreeMap::new(), admission_limit: 256, rejected: 0 }
    }

    /// Register a model's serving stack.
    pub fn register(&mut self, model: ModelKind, server: Server) {
        self.servers.insert(model, server);
    }

    pub fn models(&self) -> Vec<ModelKind> {
        self.servers.keys().copied().collect()
    }

    pub fn server(&self, model: ModelKind) -> Option<&Server> {
        self.servers.get(&model)
    }

    pub fn server_mut(&mut self, model: ModelKind) -> Option<&mut Server> {
        self.servers.get_mut(&model)
    }

    /// Requests rejected by admission control.
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Route one request. Errors on unknown models; sheds load (returns
    /// Ok(false)) when the target queue is saturated.
    pub fn route(&mut self, model: ModelKind, id: u64, pixels: Vec<f32>) -> Result<bool> {
        let limit = self.admission_limit;
        let server = match self.servers.get_mut(&model) {
            Some(s) => s,
            None => bail!("no server registered for model {model}"),
        };
        if server.backlog() >= limit {
            self.rejected += 1;
            return Ok(false);
        }
        server.submit(id, pixels);
        Ok(true)
    }

    /// Pump every server; returns completions as (model, id, detections).
    pub fn tick(&mut self) -> Vec<(ModelKind, u64, Detections)> {
        let mut out = Vec::new();
        for (&model, server) in self.servers.iter_mut() {
            for (id, det) in server.tick() {
                out.push((model, id, det));
            }
        }
        out
    }

    /// Shut everything down; returns per-model completion counts.
    pub fn shutdown(self) -> Vec<(ModelKind, u64)> {
        self.servers
            .into_iter()
            .map(|(m, s)| (m, s.shutdown()))
            .collect()
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_is_an_error() {
        let mut r = Router::new();
        assert!(r.route(ModelKind::Yolo, 0, vec![0.0]).is_err());
        assert!(r.models().is_empty());
        assert_eq!(r.rejected(), 0);
    }
}
