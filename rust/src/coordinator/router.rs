//! Request router: front door mapping each request's model to its
//! serving stack. The paper evaluates one model at a time; the router
//! generalizes the coordinator to multi-model edge boxes (the fleet
//! example) with per-model queues and a shared admission policy.
//!
//! The router is generic over [`ModelServer`] — the real PJRT-backed
//! [`Server`] in production, anything queue-shaped in tests — so the
//! admission policy is testable without artifacts.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::server::Server;
use crate::models::ModelKind;
use crate::runtime::Detections;

/// What the router needs from a per-model serving stack.
pub trait ModelServer {
    /// Enqueue one frame.
    fn submit(&mut self, id: u64, pixels: Vec<f32>);

    /// Requests queued or in flight (the admission-control signal).
    fn backlog(&self) -> usize;

    /// Pump the stack; returns completed `(id, detections)` pairs.
    fn tick(&mut self) -> Vec<(u64, Detections)>;

    /// Apply a new concurrency level (a tenant reconfiguration — the
    /// multi-tenant arbiter pushes each round's arbitrated level through
    /// the shared router). Default: no-op for stacks without a worker
    /// pool. Reconfiguring one model's stack must never disturb the
    /// router's shared admission state (`Router::rejected`).
    fn set_concurrency(&mut self, _concurrency: usize) {}

    /// Shut down; returns total completed count.
    fn shutdown(self) -> u64;
}

impl ModelServer for Server {
    fn submit(&mut self, id: u64, pixels: Vec<f32>) {
        Server::submit(self, id, pixels)
    }

    fn backlog(&self) -> usize {
        Server::backlog(self)
    }

    fn tick(&mut self) -> Vec<(u64, Detections)> {
        Server::tick(self)
    }

    fn set_concurrency(&mut self, concurrency: usize) {
        Server::set_concurrency(self, concurrency)
    }

    fn shutdown(self) -> u64 {
        Server::shutdown(self)
    }
}

/// Multi-model front door.
pub struct Router<S: ModelServer = Server> {
    servers: BTreeMap<ModelKind, S>,
    /// Reject new work once a model's batcher backlog exceeds this.
    pub admission_limit: usize,
    rejected: u64,
}

impl<S: ModelServer> Router<S> {
    pub fn new() -> Router<S> {
        Router { servers: BTreeMap::new(), admission_limit: 256, rejected: 0 }
    }

    /// Register a model's serving stack.
    pub fn register(&mut self, model: ModelKind, server: S) {
        self.servers.insert(model, server);
    }

    pub fn models(&self) -> Vec<ModelKind> {
        self.servers.keys().copied().collect()
    }

    pub fn server(&self, model: ModelKind) -> Option<&S> {
        self.servers.get(&model)
    }

    pub fn server_mut(&mut self, model: ModelKind) -> Option<&mut S> {
        self.servers.get_mut(&model)
    }

    /// Requests rejected by admission control, across all models, over
    /// the router's lifetime.
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Route one request. Errors on unknown models; sheds load (returns
    /// Ok(false)) when the target queue is saturated.
    pub fn route(&mut self, model: ModelKind, id: u64, pixels: Vec<f32>) -> Result<bool> {
        let limit = self.admission_limit;
        let server = match self.servers.get_mut(&model) {
            Some(s) => s,
            None => bail!("no server registered for model {model}"),
        };
        if server.backlog() >= limit {
            self.rejected += 1;
            return Ok(false);
        }
        server.submit(id, pixels);
        Ok(true)
    }

    /// Pump every server; returns completions as (model, id, detections).
    pub fn tick(&mut self) -> Vec<(ModelKind, u64, Detections)> {
        let mut out = Vec::new();
        for (&model, server) in self.servers.iter_mut() {
            for (id, det) in server.tick() {
                out.push((model, id, det));
            }
        }
        out
    }

    /// Shut everything down; returns per-model completion counts.
    pub fn shutdown(self) -> Vec<(ModelKind, u64)> {
        self.servers
            .into_iter()
            .map(|(m, s)| (m, s.shutdown()))
            .collect()
    }
}

impl<S: ModelServer> Default for Router<S> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::testkit::QueueServer;

    #[test]
    fn unknown_model_is_an_error() {
        let mut r: Router = Router::new();
        assert!(r.route(ModelKind::Yolo, 0, vec![0.0]).is_err());
        assert!(r.models().is_empty());
        assert_eq!(r.rejected(), 0);
    }

    #[test]
    fn default_router_matches_new() {
        let r: Router<QueueServer> = Router::default();
        assert_eq!(r.admission_limit, 256);
        assert_eq!(r.rejected(), 0);
        assert!(r.models().is_empty());
    }

    #[test]
    fn requests_beyond_admission_limit_are_rejected_and_counted() {
        let mut r: Router<QueueServer> = Router::new();
        r.admission_limit = 2;
        r.register(ModelKind::Yolo, QueueServer::default());
        assert!(r.route(ModelKind::Yolo, 0, Vec::new()).unwrap());
        assert!(r.route(ModelKind::Yolo, 1, Vec::new()).unwrap());
        assert!(
            !r.route(ModelKind::Yolo, 2, Vec::new()).unwrap(),
            "third request exceeds the backlog limit"
        );
        assert!(!r.route(ModelKind::Yolo, 3, Vec::new()).unwrap());
        assert_eq!(r.rejected(), 2);
        assert_eq!(r.server(ModelKind::Yolo).unwrap().backlog(), 2);
        // Draining the queue reopens admission.
        assert_eq!(r.tick().len(), 1);
        assert!(r.route(ModelKind::Yolo, 4, Vec::new()).unwrap());
        assert_eq!(r.rejected(), 2, "admitted request adds no rejection");
    }

    #[test]
    fn rejected_count_survives_across_models() {
        let mut r: Router<QueueServer> = Router::new();
        r.admission_limit = 1;
        r.register(ModelKind::Yolo, QueueServer::default());
        r.register(ModelKind::Frcnn, QueueServer::default());
        assert!(r.route(ModelKind::Yolo, 0, Vec::new()).unwrap());
        assert!(!r.route(ModelKind::Yolo, 1, Vec::new()).unwrap());
        assert_eq!(r.rejected(), 1);
        // A different model's saturation adds to the same shared counter;
        // per-model queues stay independent.
        assert!(r.route(ModelKind::Frcnn, 2, Vec::new()).unwrap());
        assert!(!r.route(ModelKind::Frcnn, 3, Vec::new()).unwrap());
        assert_eq!(r.rejected(), 2, "counter survives across models");
        // Completions flow out tagged per model; shutdown totals match.
        let done = r.tick();
        assert_eq!(done.len(), 2);
        assert!(done.iter().any(|(m, id, _)| *m == ModelKind::Yolo && *id == 0));
        assert!(done.iter().any(|(m, id, _)| *m == ModelKind::Frcnn && *id == 2));
        let totals = r.shutdown();
        assert_eq!(totals.iter().map(|(_, c)| *c).sum::<u64>(), 2);
    }
}
