//! Serving metrics: completed/failed counts, end-to-end latency
//! distribution, batch-size histogram, throughput gauge.
//!
//! Gauges are **per measurement window**: `reset_distributions` starts
//! a fresh window (distributions *and* the completion span), while the
//! completed/failed counters span the server's lifetime. Rates are
//! NaN/inf-free via [`finite_rate`] so degenerate windows can never
//! leak `inf` into telemetry and from there into dCor.

use std::time::Duration;

use crate::stats::summary;

/// Shortest window over which a rate is computed (seconds). Trivially
/// fast runs — stub engines, sub-microsecond walls — clamp here so rate
/// gauges stay finite instead of dividing by (near-)zero.
pub const MIN_RATE_WINDOW_S: f64 = 1e-6;

/// `count / seconds` with a NaN/inf-free contract: a zero (or
/// non-finite) count reports 0.0 regardless of the window, and the
/// window is clamped to [`MIN_RATE_WINDOW_S`]. Used by every
/// throughput gauge on the serving path; the telemetry window and the
/// correlation engine downstream assume finite inputs.
pub fn finite_rate(count: f64, seconds: f64) -> f64 {
    if count <= 0.0 || !count.is_finite() {
        return 0.0;
    }
    count / seconds.max(MIN_RATE_WINDOW_S)
}

/// Accumulated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    completed: u64,
    failed: u64,
    /// Completions inside the current window (tracks the span below, so
    /// the throughput gauge never mixes lifetime counts with a window
    /// span).
    window_completed: u64,
    latencies_ms: Vec<f64>,
    exec_ms: Vec<f64>,
    batch_sizes: Vec<usize>,
    first_completion: Option<Duration>,
    last_completion: Option<Duration>,
}

impl ServerMetrics {
    pub fn new() -> ServerMetrics {
        ServerMetrics::default()
    }

    /// Record a completed batch.
    pub fn record_batch(
        &mut self,
        batch_size: usize,
        exec_time: Duration,
        request_latencies: &[Duration],
        now: Duration,
        failed: bool,
    ) {
        if failed {
            self.failed += batch_size as u64;
            return;
        }
        self.completed += batch_size as u64;
        self.window_completed += batch_size as u64;
        self.batch_sizes.push(batch_size);
        self.exec_ms.push(exec_time.as_secs_f64() * 1000.0);
        for l in request_latencies {
            self.latencies_ms.push(l.as_secs_f64() * 1000.0);
        }
        if self.first_completion.is_none() {
            self.first_completion = Some(now);
        }
        self.last_completion = Some(now);
    }

    /// Start a fresh measurement window: clear the distribution buffers
    /// (latency/exec/batch) *and* the completion span feeding the
    /// throughput gauge, keeping only the lifetime completed/failed
    /// counters. Called at window boundaries so percentile and
    /// throughput reports describe one window, not the server's whole
    /// life.
    pub fn reset_distributions(&mut self) {
        self.latencies_ms.clear();
        self.exec_ms.clear();
        self.batch_sizes.clear();
        self.window_completed = 0;
        self.first_completion = None;
        self.last_completion = None;
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn failed(&self) -> u64 {
        self.failed
    }

    /// Requests per second over the current window's completion span
    /// (NaN until the window holds two completions at distinct times).
    pub fn throughput_fps(&self) -> f64 {
        match (self.first_completion, self.last_completion) {
            (Some(a), Some(b)) if b > a && self.window_completed > 1 => {
                (self.window_completed - 1) as f64 / (b - a).as_secs_f64()
            }
            _ => f64::NAN,
        }
    }

    /// End-to-end latency percentile (ms).
    pub fn latency_ms(&self, pct: f64) -> f64 {
        summary::percentile(&self.latencies_ms, pct)
    }

    /// Mean executor time per batch (ms).
    pub fn mean_exec_ms(&self) -> f64 {
        summary::mean(&self.exec_ms)
    }

    /// Mean released batch size (batching efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return f64::NAN;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn accumulates_batches() {
        let mut m = ServerMetrics::new();
        m.record_batch(2, ms(10), &[ms(15), ms(20)], ms(100), false);
        m.record_batch(1, ms(12), &[ms(30)], ms(200), false);
        assert_eq!(m.completed(), 3);
        assert_eq!(m.failed(), 0);
        assert!((m.mean_batch_size() - 1.5).abs() < 1e-12);
        assert_eq!(m.latency_ms(100.0), 30.0);
        assert!((m.mean_exec_ms() - 11.0).abs() < 1e-12);
    }

    #[test]
    fn reset_distributions_keeps_lifetime_counters() {
        let mut m = ServerMetrics::new();
        m.record_batch(2, ms(10), &[ms(15), ms(20)], ms(100), false);
        m.reset_distributions();
        assert_eq!(m.completed(), 2, "lifetime counter survives");
        assert!(m.latency_ms(50.0).is_nan(), "distributions cleared");
        assert!(m.mean_batch_size().is_nan());
        m.record_batch(1, ms(12), &[ms(30)], ms(200), false);
        assert_eq!(m.latency_ms(100.0), 30.0, "new window only");
        assert_eq!(m.completed(), 3);
    }

    #[test]
    fn reset_distributions_resets_completion_span() {
        // Regression: the throughput gauge must describe the current
        // window, not the server's lifetime. Before the fix, the span
        // (first/last completion) survived `reset_distributions`, so a
        // post-reset gauge still divided lifetime counts by a lifetime
        // span, contradicting the documented per-window contract.
        let mut m = ServerMetrics::new();
        m.record_batch(1, ms(1), &[ms(1)], ms(0), false);
        m.record_batch(1, ms(1), &[ms(1)], ms(1000), false);
        assert!((m.throughput_fps() - 1.0).abs() < 1e-9);
        m.reset_distributions();
        assert!(m.throughput_fps().is_nan(), "fresh window has no span yet");
        assert_eq!(m.completed(), 2, "lifetime counter survives the reset");
        // The new window's gauge spans only its own completions: two
        // completions 100 ms apart = 10 fps, regardless of the 5-second
        // lifetime span.
        m.record_batch(1, ms(1), &[ms(1)], ms(5000), false);
        m.record_batch(1, ms(1), &[ms(1)], ms(5100), false);
        assert!((m.throughput_fps() - 10.0).abs() < 1e-9, "{}", m.throughput_fps());
    }

    #[test]
    fn throughput_over_span() {
        let mut m = ServerMetrics::new();
        m.record_batch(1, ms(1), &[ms(1)], ms(0), false);
        m.record_batch(1, ms(1), &[ms(1)], ms(500), false);
        m.record_batch(1, ms(1), &[ms(1)], ms(1000), false);
        assert!((m.throughput_fps() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn failures_counted_separately() {
        let mut m = ServerMetrics::new();
        m.record_batch(3, ms(1), &[], ms(10), true);
        assert_eq!(m.failed(), 3);
        assert_eq!(m.completed(), 0);
        assert!(m.throughput_fps().is_nan());
    }

    #[test]
    fn zero_wall_rate_is_finite() {
        // Regression: `completed / 0.0` used to feed `inf` into the
        // telemetry window (and from there into dCor). The clamp keeps
        // trivially fast windows finite and a zero count exactly 0.
        assert_eq!(finite_rate(0.0, 0.0), 0.0);
        assert_eq!(finite_rate(0.0, 10.0), 0.0);
        assert!((finite_rate(30.0, 2.0) - 15.0).abs() < 1e-12);
        let clamped = finite_rate(5.0, 0.0);
        assert!(clamped.is_finite(), "zero wall must not produce inf");
        assert!((clamped - 5.0 / MIN_RATE_WINDOW_S).abs() < 1e-6);
        assert!(finite_rate(5.0, f64::NAN).is_finite());
        assert_eq!(finite_rate(f64::NAN, 1.0), 0.0);
        assert_eq!(finite_rate(f64::INFINITY, 1.0), 0.0);
        assert_eq!(finite_rate(-3.0, 1.0), 0.0);
    }
}
