//! Serving metrics: completed/failed counts, end-to-end latency
//! distribution, batch-size histogram, throughput gauge.

use std::time::Duration;

use crate::stats::summary;

/// Accumulated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    completed: u64,
    failed: u64,
    latencies_ms: Vec<f64>,
    exec_ms: Vec<f64>,
    batch_sizes: Vec<usize>,
    first_completion: Option<Duration>,
    last_completion: Option<Duration>,
}

impl ServerMetrics {
    pub fn new() -> ServerMetrics {
        ServerMetrics::default()
    }

    /// Record a completed batch.
    pub fn record_batch(
        &mut self,
        batch_size: usize,
        exec_time: Duration,
        request_latencies: &[Duration],
        now: Duration,
        failed: bool,
    ) {
        if failed {
            self.failed += batch_size as u64;
            return;
        }
        self.completed += batch_size as u64;
        self.batch_sizes.push(batch_size);
        self.exec_ms.push(exec_time.as_secs_f64() * 1000.0);
        for l in request_latencies {
            self.latencies_ms.push(l.as_secs_f64() * 1000.0);
        }
        if self.first_completion.is_none() {
            self.first_completion = Some(now);
        }
        self.last_completion = Some(now);
    }

    /// Clear the distribution buffers (latency/exec/batch) while keeping
    /// the lifetime counters and completion span. Called at
    /// measurement-window boundaries so percentile reports describe one
    /// window, not the server's whole life.
    pub fn reset_distributions(&mut self) {
        self.latencies_ms.clear();
        self.exec_ms.clear();
        self.batch_sizes.clear();
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn failed(&self) -> u64 {
        self.failed
    }

    /// Requests per second over the completion span.
    pub fn throughput_fps(&self) -> f64 {
        match (self.first_completion, self.last_completion) {
            (Some(a), Some(b)) if b > a && self.completed > 1 => {
                (self.completed - 1) as f64 / (b - a).as_secs_f64()
            }
            _ => f64::NAN,
        }
    }

    /// End-to-end latency percentile (ms).
    pub fn latency_ms(&self, pct: f64) -> f64 {
        summary::percentile(&self.latencies_ms, pct)
    }

    /// Mean executor time per batch (ms).
    pub fn mean_exec_ms(&self) -> f64 {
        summary::mean(&self.exec_ms)
    }

    /// Mean released batch size (batching efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return f64::NAN;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn accumulates_batches() {
        let mut m = ServerMetrics::new();
        m.record_batch(2, ms(10), &[ms(15), ms(20)], ms(100), false);
        m.record_batch(1, ms(12), &[ms(30)], ms(200), false);
        assert_eq!(m.completed(), 3);
        assert_eq!(m.failed(), 0);
        assert!((m.mean_batch_size() - 1.5).abs() < 1e-12);
        assert_eq!(m.latency_ms(100.0), 30.0);
        assert!((m.mean_exec_ms() - 11.0).abs() < 1e-12);
    }

    #[test]
    fn reset_distributions_keeps_lifetime_counters() {
        let mut m = ServerMetrics::new();
        m.record_batch(2, ms(10), &[ms(15), ms(20)], ms(100), false);
        m.reset_distributions();
        assert_eq!(m.completed(), 2, "lifetime counter survives");
        assert!(m.latency_ms(50.0).is_nan(), "distributions cleared");
        assert!(m.mean_batch_size().is_nan());
        m.record_batch(1, ms(12), &[ms(30)], ms(200), false);
        assert_eq!(m.latency_ms(100.0), 30.0, "new window only");
        assert_eq!(m.completed(), 3);
    }

    #[test]
    fn throughput_over_span() {
        let mut m = ServerMetrics::new();
        m.record_batch(1, ms(1), &[ms(1)], ms(0), false);
        m.record_batch(1, ms(1), &[ms(1)], ms(500), false);
        m.record_batch(1, ms(1), &[ms(1)], ms(1000), false);
        assert!((m.throughput_fps() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn failures_counted_separately() {
        let mut m = ServerMetrics::new();
        m.record_batch(3, ms(1), &[], ms(10), true);
        assert_eq!(m.failed(), 3);
        assert_eq!(m.completed(), 0);
        assert!(m.throughput_fps().is_nan());
    }
}
