//! Dynamic batcher: accumulates requests and releases a batch when it
//! reaches the target size or the oldest request hits its deadline —
//! the standard size-or-timeout policy (vLLM-style), kept as pure logic
//! (logical clock in, batches out) so it is exhaustively testable.
//!
//! [`Batcher::next_deadline`] is the same policy read forward in time:
//! it tells the event-driven pump the earliest instant `pop_ready`
//! would release, so the pump can block exactly that long instead of
//! sleep-polling.

use std::time::Duration;

/// A queued request (frame already rendered to pixels).
#[derive(Debug, Clone)]
pub struct PendingRequest {
    pub id: u64,
    /// Flattened HWC f32 pixels.
    pub pixels: Vec<f32>,
    /// Arrival time (logical).
    pub arrived: Duration,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Release as soon as this many requests are queued.
    pub max_batch: usize,
    /// Release a partial batch once the oldest request has waited this
    /// long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(10) }
    }
}

/// Size-or-deadline dynamic batcher.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    queue: Vec<PendingRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        Batcher { cfg, queue: Vec::new() }
    }

    pub fn config(&self) -> BatcherConfig {
        self.cfg
    }

    /// Change the target batch size at runtime (the optimizer may tune
    /// it alongside concurrency).
    pub fn set_max_batch(&mut self, n: usize) {
        assert!(n >= 1);
        self.cfg.max_batch = n;
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue a request.
    pub fn push(&mut self, req: PendingRequest) {
        self.queue.push(req);
    }

    /// Release the next batch if the policy says so.
    pub fn pop_ready(&mut self, now: Duration) -> Option<Vec<PendingRequest>> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.cfg.max_batch;
        let expired = now.saturating_sub(self.queue[0].arrived) >= self.cfg.max_wait;
        if !(full || expired) {
            return None;
        }
        let n = self.queue.len().min(self.cfg.max_batch);
        Some(self.queue.drain(..n).collect())
    }

    /// When [`Batcher::pop_ready`] would next release a batch, assuming
    /// no further pushes: `None` on an empty queue, otherwise the
    /// earliest `t >= now` at which `pop_ready(t)` returns a batch —
    /// `now` itself when one is already due (full batch, expired head,
    /// or zero `max_wait`), the head's `arrived + max_wait` deadline
    /// otherwise. Pure logic: the event-driven serving pump uses it to
    /// bound its blocking wait instead of sleep-polling, and the
    /// agreement with `pop_ready` is property-tested below.
    pub fn next_deadline(&self, now: Duration) -> Option<Duration> {
        let head = self.queue.first()?;
        if self.queue.len() >= self.cfg.max_batch || self.cfg.max_wait.is_zero() {
            return Some(now);
        }
        Some((head.arrived + self.cfg.max_wait).max(now))
    }

    /// Drain everything immediately (shutdown).
    pub fn drain_all(&mut self) -> Vec<PendingRequest> {
        std::mem::take(&mut self.queue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn req(id: u64, at_ms: u64) -> PendingRequest {
        PendingRequest { id, pixels: vec![0.0; 4], arrived: Duration::from_millis(at_ms) }
    }

    fn cfg(max_batch: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn releases_on_size() {
        let mut b = Batcher::new(cfg(2, 1000));
        b.push(req(0, 0));
        assert!(b.pop_ready(Duration::from_millis(1)).is_none());
        b.push(req(1, 1));
        let batch = b.pop_ready(Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(cfg(8, 10));
        b.push(req(0, 0));
        assert!(b.pop_ready(Duration::from_millis(9)).is_none());
        let batch = b.pop_ready(Duration::from_millis(10)).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn oversize_queue_releases_max_batch_only() {
        let mut b = Batcher::new(cfg(2, 1000));
        for i in 0..5 {
            b.push(req(i, 0));
        }
        assert_eq!(b.pop_ready(Duration::ZERO).unwrap().len(), 2);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn oversize_release_rearms_deadline_on_new_head() {
        // Deadline-triggered release of an over-full queue must release
        // only max_batch, and the *new* head's arrival time re-arms the
        // deadline — the remainder does not ride the old head's timer.
        let mut b = Batcher::new(cfg(2, 10));
        b.push(req(0, 0));
        b.push(req(1, 8));
        b.push(req(2, 9));
        let first = b.pop_ready(Duration::from_millis(10)).unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        // New head arrived at t=9: at t=10 it has waited only 1 ms, and
        // the queue (1 request) is below max_batch — nothing releases.
        assert!(b.pop_ready(Duration::from_millis(10)).is_none());
        assert!(b.pop_ready(Duration::from_millis(18)).is_none());
        let second = b.pop_ready(Duration::from_millis(19)).unwrap();
        assert_eq!(second[0].id, 2);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn set_max_batch_shrinking_below_queue_len() {
        // A queue longer than the (newly shrunk) max_batch drains in
        // max_batch-sized chunks, preserving FIFO order.
        let mut b = Batcher::new(cfg(8, 1000));
        for i in 0..6 {
            b.push(req(i, 0));
        }
        assert!(b.pop_ready(Duration::ZERO).is_none(), "not full, not expired");
        b.set_max_batch(2);
        let a = b.pop_ready(Duration::ZERO).unwrap();
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.queued(), 4);
        let c = b.pop_ready(Duration::ZERO).unwrap();
        assert_eq!(c.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        // Two left: below the restored size-trigger and not yet expired.
        b.set_max_batch(5);
        assert_eq!(b.queued(), 2);
        assert!(b.pop_ready(Duration::ZERO).is_none());
        // They still drain on deadline.
        assert_eq!(b.pop_ready(Duration::from_millis(1000)).unwrap().len(), 2);
    }

    #[test]
    #[should_panic(expected = "n >= 1")]
    fn set_max_batch_zero_rejected() {
        let mut b = Batcher::new(cfg(4, 10));
        b.set_max_batch(0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(cfg(3, 0));
        for i in 0..3 {
            b.push(req(i, 0));
        }
        let ids: Vec<u64> = b.pop_ready(Duration::ZERO).unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn drain_all_empties() {
        let mut b = Batcher::new(cfg(4, 1000));
        b.push(req(0, 0));
        b.push(req(1, 0));
        assert_eq!(b.drain_all().len(), 2);
        assert_eq!(b.queued(), 0);
        assert!(b.pop_ready(Duration::from_secs(10)).is_none());
    }

    #[test]
    fn next_deadline_empty_queue_is_none() {
        let b = Batcher::new(cfg(4, 10));
        assert!(b.next_deadline(Duration::ZERO).is_none());
        assert!(b.next_deadline(Duration::from_secs(100)).is_none());
    }

    #[test]
    fn next_deadline_full_batch_is_due_now() {
        let mut b = Batcher::new(cfg(2, 1000));
        b.push(req(0, 0));
        b.push(req(1, 1));
        let now = Duration::from_millis(1);
        assert_eq!(b.next_deadline(now), Some(now));
    }

    #[test]
    fn next_deadline_partial_batch_is_head_deadline() {
        let mut b = Batcher::new(cfg(8, 10));
        b.push(req(0, 3));
        b.push(req(1, 7));
        // Head arrived at t=3 with a 10 ms wait: fires at t=13
        // regardless of later arrivals.
        assert_eq!(
            b.next_deadline(Duration::from_millis(5)),
            Some(Duration::from_millis(13))
        );
        // An already-expired head is due now, never in the past.
        let late = Duration::from_millis(20);
        assert_eq!(b.next_deadline(late), Some(late));
    }

    #[test]
    fn next_deadline_zero_wait_is_always_due() {
        let mut b = Batcher::new(cfg(8, 0));
        b.push(req(0, 4));
        let now = Duration::from_millis(4);
        assert_eq!(b.next_deadline(now), Some(now));
    }

    #[test]
    fn prop_next_deadline_agrees_with_pop_ready() {
        // The pump's contract: for any reachable queue state and any
        // probe time t >= now, pop_ready(t) releases a batch exactly
        // when t has reached next_deadline(now).
        prop::check("next_deadline/pop_ready agreement", 300, |g| {
            let policy =
                cfg(g.rng.range_usize(1, 6), g.rng.range_usize(0, 15) as u64);
            let mut b = Batcher::new(policy);
            let mut t = 0u64;
            for id in 0..g.rng.range_usize(0, 12) as u64 {
                t += g.rng.range_usize(0, 6) as u64;
                b.push(req(id, t));
                // Occasionally pop so partial/post-release states are
                // covered too.
                if g.rng.chance(0.3) {
                    b.pop_ready(Duration::from_millis(t));
                }
            }
            let now = Duration::from_millis(t);
            let probe = Duration::from_millis(t + g.rng.range_usize(0, 30) as u64);
            match b.next_deadline(now) {
                None => {
                    prop::assert_true(b.queued() == 0, "None only when empty")?;
                    prop::assert_true(
                        b.pop_ready(probe).is_none(),
                        "empty queue never releases",
                    )
                }
                Some(d) => {
                    prop::assert_true(d >= now, "deadline never in the past")?;
                    let fires = b.pop_ready(probe).is_some();
                    prop::assert_eq_dbg(&fires, &(probe >= d))
                }
            }
        });
    }

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        prop::check("batcher conservation", 100, |g| {
            let mut b = Batcher::new(cfg(g.rng.range_usize(1, 6), g.rng.range_usize(0, 20) as u64));
            let n = g.rng.range_usize(1, 40);
            let mut seen = Vec::new();
            let mut t = 0u64;
            for id in 0..n as u64 {
                t += g.rng.range_usize(0, 5) as u64;
                b.push(req(id, t));
                if g.rng.chance(0.5) {
                    if let Some(batch) = b.pop_ready(Duration::from_millis(t)) {
                        seen.extend(batch.iter().map(|r| r.id));
                    }
                }
            }
            seen.extend(b.drain_all().iter().map(|r| r.id));
            let want: Vec<u64> = (0..n as u64).collect();
            prop::assert_eq_dbg(&seen, &want)
        });
    }
}
