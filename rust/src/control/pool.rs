//! The persistent work-stealing pool behind every fleet-parallel path.
//!
//! [`FleetRunner`](super::FleetRunner) sweeps, [`FleetEnv`](super::FleetEnv)
//! member fan-out and [`TenantArbiter`](super::TenantArbiter) rounds used
//! to spawn fresh `std::thread`s per call — fine for the paper's 2-board
//! experiments, fatal at production fleet sizes where spawn cost dominates
//! the microsecond-scale simulated windows. [`FleetPool`] spawns its
//! workers **once** and dispatches every later batch over them:
//!
//! * **Injector + per-worker deques.** A batch of `n` index jobs is cut
//!   into contiguous ranges, one deque per worker. Owners pop single
//!   indices off the *front* of their own deque; an idle worker steals
//!   the back *half* of the first non-empty victim deque (classic deque
//!   discipline, mutex-backed — the offline mirror has no lock-free
//!   Chase–Lev to lean on, and jobs here are coarse enough that a
//!   sub-microsecond mutex pop is noise).
//! * **Determinism by construction.** Jobs carry their index and write
//!   into index slots; each job owns its state (seed, member, device).
//!   The steal schedule decides only *which thread* runs a job, never
//!   what the job computes or where its result lands, so results are
//!   byte-identical to sequential for every worker count and every steal
//!   schedule. Property-tested under an adversarial scripted scheduler
//!   (seeded per-job delays that force steals) in this module and in
//!   `tests/fleet_pool.rs`.
//! * **The submitter helps.** [`BatchTicket::join`] claims and runs jobs
//!   like any worker, so completion never depends on pool workers being
//!   free — nested `run` calls from inside a job cannot deadlock, and a
//!   ticket outliving a dropped pool still finishes its batch.
//! * **Teardown.** Dropping the pool mirrors the coordinator
//!   `WorkerPool` contract: close the injector, wake parked workers,
//!   never join (a worker stuck inside a job must not block the
//!   dropper). Workers finish the batch they are helping, observe the
//!   closed injector, and exit on their own.
//!
//! `bench_fleet_scale` tracks the two numbers this module exists for:
//! thread spawns after construction (must be zero, even at 10,000
//! members) and per-round wall time vs fleet size (must grow
//! sub-linearly). EXPERIMENTS.md §Fleet-scale sweeps has the curves.

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Poison-tolerant lock: a panicked job must not wedge the pool (same
/// helper the coordinator's `WorkerPool` uses).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Parse a `CORAL_FLEET_WORKERS`-style override. Any parseable value is
/// honored but clamped ≥ 1; unset or unparseable means "no override".
fn worker_override(raw: Option<&str>) -> Option<usize> {
    raw.and_then(|v| v.trim().parse::<usize>().ok())
        .map(|w| w.max(1))
}

/// Worker count for [`FleetPool::auto`] (and `FleetRunner::auto`): the
/// `CORAL_FLEET_WORKERS` env var when set (clamped ≥ 1, so CI and
/// benches pin worker counts reproducibly — EXPERIMENTS.md §Fleet-scale
/// sweeps), else one per available CPU, at least 2.
pub fn auto_workers() -> usize {
    let env = std::env::var("CORAL_FLEET_WORKERS").ok();
    if let Some(w) = worker_override(env.as_deref()) {
        return w;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .max(2)
}

/// Completion accounting of one batch, behind one mutex so the final
/// `complete` and the joiner's wakeup cannot miss each other.
struct BatchDone {
    completed: usize,
    poisoned: bool,
}

/// One submitted batch: `total` index jobs behind per-worker deques of
/// half-open index ranges.
struct Batch {
    /// Runs job `i`. Captures the caller's shared state (jobs in, index
    /// slots out) — the pool itself never sees job payloads or results.
    task: Box<dyn Fn(usize) + Send + Sync>,
    /// Per-worker deques. Owners pop indices off the front range;
    /// thieves split the back range (see module docs).
    queues: Vec<Mutex<VecDeque<(usize, usize)>>>,
    total: usize,
    done: Mutex<BatchDone>,
    done_cv: Condvar,
}

impl Batch {
    fn new(total: usize, queues: usize, task: Box<dyn Fn(usize) + Send + Sync>) -> Batch {
        let queues = queues.min(total.max(1)).max(1);
        let deques = (0..queues)
            .map(|q| {
                // Contiguous near-even split; stealing rebalances from
                // there, so initial placement only has to be fair.
                let lo = q * total / queues;
                let hi = (q + 1) * total / queues;
                let mut dq = VecDeque::new();
                if lo < hi {
                    dq.push_back((lo, hi));
                }
                Mutex::new(dq)
            })
            .collect();
        Batch {
            task,
            queues: deques,
            total,
            done: Mutex::new(BatchDone { completed: 0, poisoned: false }),
            done_cv: Condvar::new(),
        }
    }

    /// Pop one index off the front of deque `q` (owner side).
    fn pop_front(&self, q: usize) -> Option<usize> {
        let mut dq = lock(&self.queues[q]);
        let &(s, e) = dq.front()?;
        if s + 1 == e {
            dq.pop_front();
        } else {
            dq.front_mut().expect("nonempty deque").0 = s + 1;
        }
        Some(s)
    }

    /// Steal from the back of deque `victim`: the whole back range if it
    /// is a single index, else its back half (the victim keeps the
    /// front half — steal-half amortizes steals at scale).
    fn steal_back(&self, victim: usize) -> Option<(usize, usize)> {
        let mut dq = lock(&self.queues[victim]);
        let &(s, e) = dq.back()?;
        if e - s <= 1 {
            dq.pop_back();
            return Some((s, e));
        }
        let mid = s + (e - s) / 2;
        dq.back_mut().expect("nonempty deque").1 = mid;
        Some((mid, e))
    }

    /// Claim one index: own deque first, then scan victims in ring
    /// order. A stolen multi-index range parks its remainder on the
    /// claimant's own deque (where it can be stolen from in turn).
    fn claim(&self, home: usize, steals: &AtomicU64) -> Option<usize> {
        let k = self.queues.len();
        let home = home % k;
        if let Some(i) = self.pop_front(home) {
            return Some(i);
        }
        for off in 1..k {
            if let Some((s, e)) = self.steal_back((home + off) % k) {
                steals.fetch_add(1, Ordering::Relaxed);
                if e - s > 1 {
                    lock(&self.queues[home]).push_back((s + 1, e));
                }
                return Some(s);
            }
        }
        None
    }

    /// No unclaimed indices left (claimed-but-running jobs may remain;
    /// completion is what `done` tracks).
    fn drained(&self) -> bool {
        self.queues.iter().all(|q| lock(q).is_empty())
    }

    /// Run claimed job `i`, containing panics: a poisoned batch still
    /// completes (so joiners wake) and the worker thread survives to
    /// serve later batches.
    fn run_one(&self, i: usize) {
        let ok = panic::catch_unwind(AssertUnwindSafe(|| (self.task)(i))).is_ok();
        let mut d = lock(&self.done);
        d.completed += 1;
        d.poisoned |= !ok;
        if d.completed == self.total {
            self.done_cv.notify_all();
        }
    }
}

/// Claim-and-run until the batch has no unclaimed jobs. Used identically
/// by pool workers and by joining submitter threads.
fn help(batch: &Batch, home: usize, steals: &AtomicU64) {
    while let Some(i) = batch.claim(home, steals) {
        batch.run_one(i);
    }
}

/// The injector: submitted batches awaiting workers, plus the closed
/// flag that tears the pool down.
struct Injector {
    batches: VecDeque<Arc<Batch>>,
    closed: bool,
}

struct PoolShared {
    injector: Mutex<Injector>,
    work_cv: Condvar,
    /// Threads ever spawned — exactly the worker count for the pool's
    /// whole lifetime (`bench_fleet_scale` asserts it never moves after
    /// construction).
    spawned: AtomicU64,
    /// Workers currently running their loop; drops to 0 after teardown
    /// (the Drop regression test watches this through [`PoolWatcher`]).
    alive: AtomicUsize,
    /// Successful steals across all batches (diagnostics only — steals
    /// can never affect results, only wall-clock).
    steals: AtomicU64,
}

fn worker_loop(shared: &Arc<PoolShared>, home: usize) {
    loop {
        let batch = {
            let mut inj = lock(&shared.injector);
            loop {
                // Retire drained batches off the front so parked workers
                // never spin on exhausted work.
                while inj.batches.front().is_some_and(|b| b.drained()) {
                    inj.batches.pop_front();
                }
                if let Some(b) = inj.batches.front() {
                    break Arc::clone(b);
                }
                if inj.closed {
                    return;
                }
                inj = match shared.work_cv.wait(inj) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
        };
        help(&batch, home, &shared.steals);
    }
}

/// A persistent work-stealing pool of OS threads (see module docs).
///
/// Construction spawns the workers; every later [`FleetPool::run`] /
/// [`FleetPool::map`] dispatches over them with zero thread spawns and
/// O(1) per-job dispatch (an index pop), for any batch size.
pub struct FleetPool {
    shared: Arc<PoolShared>,
    workers: usize,
}

impl FleetPool {
    pub fn new(workers: usize) -> FleetPool {
        assert!(workers >= 1, "need at least one worker");
        let shared = Arc::new(PoolShared {
            injector: Mutex::new(Injector { batches: VecDeque::new(), closed: false }),
            work_cv: Condvar::new(),
            spawned: AtomicU64::new(0),
            alive: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
        });
        for home in 0..workers {
            shared.spawned.fetch_add(1, Ordering::Relaxed);
            shared.alive.fetch_add(1, Ordering::Relaxed);
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("fleet-pool-{home}"))
                .spawn(move || {
                    // Decrement on every exit path, including a panic
                    // unwinding out of the loop itself.
                    struct Alive(Arc<PoolShared>);
                    impl Drop for Alive {
                        fn drop(&mut self) {
                            self.0.alive.fetch_sub(1, Ordering::Release);
                        }
                    }
                    let _alive = Alive(Arc::clone(&sh));
                    worker_loop(&sh, home);
                })
                .expect("spawn fleet pool worker");
        }
        FleetPool { shared, workers }
    }

    /// A pool sized by [`auto_workers`] (`CORAL_FLEET_WORKERS` override,
    /// else available parallelism).
    pub fn auto() -> FleetPool {
        FleetPool::new(auto_workers())
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Threads this pool has ever spawned — equals [`FleetPool::workers`]
    /// forever; the fleet-scale bench asserts exactly that.
    pub fn spawned_threads(&self) -> u64 {
        self.shared.spawned.load(Ordering::Relaxed)
    }

    /// Successful steals so far (diagnostics; cannot affect results).
    pub fn steals(&self) -> u64 {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// A counters-only view that may outlive the pool (the teardown
    /// regression test asserts `alive_workers` reaches 0 after drop).
    pub fn watcher(&self) -> PoolWatcher {
        PoolWatcher { shared: Arc::clone(&self.shared) }
    }

    /// Submit `total` index jobs without blocking; `task(i)` runs exactly
    /// once for every `i < total`, on any worker or on the thread that
    /// joins the ticket.
    pub fn submit(
        &self,
        total: usize,
        task: impl Fn(usize) + Send + Sync + 'static,
    ) -> BatchTicket {
        let queues = self.workers.min(total.max(1));
        let batch = Arc::new(Batch::new(total, queues, Box::new(task)));
        {
            let mut inj = lock(&self.shared.injector);
            assert!(!inj.closed, "submit on a closed FleetPool");
            if total > 0 {
                inj.batches.push_back(Arc::clone(&batch));
            }
        }
        self.shared.work_cv.notify_all();
        BatchTicket { batch, shared: Arc::clone(&self.shared) }
    }

    /// Run `total` index jobs to completion. The calling thread helps
    /// (claims and runs jobs like any worker), so progress never depends
    /// on workers being free — including nested `run` calls from inside
    /// a job.
    pub fn run(&self, total: usize, task: impl Fn(usize) + Send + Sync + 'static) {
        self.submit(total, task).join();
    }

    /// Run `total` index jobs to completion, containing panics: every
    /// job runs exactly once, panicked jobs simply leave whatever
    /// side-effect slot they owned unfilled, and the batch — and the
    /// pool — stay usable. Returns `true` if any job panicked.
    pub fn run_contained(
        &self,
        total: usize,
        task: impl Fn(usize) + Send + Sync + 'static,
    ) -> bool {
        self.submit(total, task).join_quiet()
    }

    /// Parallel map preserving job order. Results land by index, so the
    /// output is byte-identical for every worker count and every steal
    /// schedule; panicking jobs propagate as a panic after the batch
    /// completes.
    pub fn map<J, R, F>(&self, jobs: Vec<J>, f: F) -> Vec<R>
    where
        J: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, J) -> R + Send + Sync + 'static,
    {
        let n = jobs.len();
        let jobs: Arc<Mutex<Vec<Option<J>>>> =
            Arc::new(Mutex::new(jobs.into_iter().map(Some).collect()));
        let slots: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let out = Arc::clone(&slots);
        self.run(n, move |i| {
            let job = lock(&jobs)[i].take().expect("each job claimed once");
            let r = f(i, job);
            lock(&slots)[i] = Some(r);
        });
        std::mem::take(&mut *lock(&out))
            .into_iter()
            .map(|r| r.expect("every job produced a result"))
            .collect()
    }
}

impl Drop for FleetPool {
    /// Close the injector and wake every parked worker; never join (the
    /// coordinator `WorkerPool` contract — a worker stuck inside a job
    /// must not block the dropper). Workers finish the batch they are
    /// helping, then observe the closed injector and exit on their own;
    /// queued batches are abandoned unless an outstanding
    /// [`BatchTicket::join`] claims their jobs itself.
    fn drop(&mut self) {
        lock(&self.shared.injector).closed = true;
        self.shared.work_cv.notify_all();
    }
}

/// Handle to one submitted batch (see [`FleetPool::submit`]).
pub struct BatchTicket {
    batch: Arc<Batch>,
    shared: Arc<PoolShared>,
}

impl BatchTicket {
    /// Help run the batch to completion, then wait for stragglers
    /// claimed by workers. Valid even after the pool is dropped: the
    /// joiner claims everything the workers abandoned. Panics if any
    /// job panicked.
    pub fn join(self) {
        if self.join_quiet() {
            panic!("fleet pool job panicked");
        }
    }

    /// Like [`BatchTicket::join`], but a panicked job is *reported*
    /// (returns `true`) rather than re-raised — the containment entry
    /// point for fault-tolerant callers (`FleetEnv` survivor
    /// aggregation), which read their per-job result slots and treat
    /// unfilled ones as dropped members instead of aborting the round.
    pub fn join_quiet(self) -> bool {
        help(&self.batch, 0, &self.shared.steals);
        let mut d = lock(&self.batch.done);
        while d.completed < self.batch.total {
            d = match self.batch.done_cv.wait(d) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        let poisoned = d.poisoned;
        drop(d);
        // Retire the (fully drained) batch now rather than at the next
        // worker wakeup, so its captured state is freed promptly.
        let mut inj = lock(&self.shared.injector);
        if let Some(pos) = inj.batches.iter().position(|b| Arc::ptr_eq(b, &self.batch)) {
            inj.batches.remove(pos);
        }
        drop(inj);
        poisoned
    }
}

/// Counters-only view of a pool's worker accounting; may outlive the
/// pool itself.
pub struct PoolWatcher {
    shared: Arc<PoolShared>,
}

impl PoolWatcher {
    pub fn alive_workers(&self) -> usize {
        self.shared.alive.load(Ordering::Acquire)
    }

    pub fn spawned_threads(&self) -> u64 {
        self.shared.spawned.load(Ordering::Relaxed)
    }

    pub fn steals(&self) -> u64 {
        self.shared.steals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn worker_override_parses_and_clamps() {
        assert_eq!(worker_override(None), None);
        assert_eq!(worker_override(Some("")), None);
        assert_eq!(worker_override(Some("not a number")), None);
        assert_eq!(worker_override(Some("0")), Some(1), "clamped ≥ 1");
        assert_eq!(worker_override(Some("1")), Some(1));
        assert_eq!(worker_override(Some(" 12 ")), Some(12));
        assert!(auto_workers() >= 1);
    }

    #[test]
    fn map_is_index_slotted_for_any_worker_count() {
        let jobs: Vec<u64> = (0..57).collect();
        let expect: Vec<u64> = jobs.iter().map(|j| j * 3 + 1).collect();
        for workers in [1, 2, 3, 7, 16] {
            let pool = FleetPool::new(workers);
            let got = pool.map(jobs.clone(), |_, j| j * 3 + 1);
            assert_eq!(got, expect, "{workers} workers");
            assert_eq!(pool.spawned_threads(), workers as u64);
        }
    }

    #[test]
    fn empty_and_single_job_batches_complete() {
        let pool = FleetPool::new(3);
        assert_eq!(pool.map(Vec::<u64>::new(), |_, j| j), Vec::<u64>::new());
        assert_eq!(pool.map(vec![41u64], |i, j| j + i as u64 + 1), vec![42]);
    }

    /// The adversarial scripted scheduler: seeded per-job delays skew
    /// which deques drain first, forcing different steal schedules case
    /// by case — under all of which results must be byte-identical to
    /// sequential. Steals must actually occur across the run for the
    /// property to mean anything.
    #[test]
    fn scripted_steal_schedules_never_change_results() {
        let mut total_steals = 0u64;
        prop::check("scripted steal schedules", 60, |g| {
            let n = g.rng.range_usize(2, 32);
            let workers = g.rng.range_usize(2, 5);
            // The script: each job sleeps its own seeded delay before
            // computing, so deque drain order varies adversarially.
            let delays: Vec<u64> = (0..n).map(|_| g.rng.below(120) as u64).collect();
            let salt = g.rng.next_u64();
            let expect: Vec<u64> = (0..n as u64).map(|j| j.wrapping_mul(salt) ^ j).collect();
            let pool = FleetPool::new(workers);
            let got = pool.map((0..n as u64).collect(), move |i, j| {
                std::thread::sleep(Duration::from_micros(delays[i]));
                j.wrapping_mul(salt) ^ j
            });
            total_steals += pool.steals();
            prop::assert_true(got == expect, "steal schedule changed results")
        });
        assert!(total_steals > 0, "no case ever stole — scheduler not adversarial");
    }

    #[test]
    fn nested_runs_on_the_same_pool_complete() {
        let pool = Arc::new(FleetPool::new(2));
        let inner_pool = Arc::clone(&pool);
        let total = Arc::new(AtomicUsize::new(0));
        let outer_total = Arc::clone(&total);
        pool.run(4, move |_| {
            let inner_total = Arc::clone(&outer_total);
            inner_pool.run(8, move |_| {
                inner_total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn panicking_job_poisons_the_batch_but_not_the_pool() {
        let pool = FleetPool::new(2);
        let poisoned = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map((0..8u64).collect(), |_, j| {
                assert!(j != 5, "scripted job failure");
                j
            })
        }));
        assert!(poisoned.is_err(), "poisoned batch must propagate the panic");
        // Workers survived the contained panic; the pool still serves.
        let ok = pool.map((0..8u64).collect(), |_, j| j + 1);
        assert_eq!(ok, (1..9u64).collect::<Vec<u64>>());
        assert_eq!(pool.spawned_threads(), 2, "no respawn after a poisoned batch");
    }

    #[test]
    fn run_contained_reports_the_panic_and_runs_every_other_job() {
        // The fault-tolerant entry point: a scripted job panic must not
        // propagate, every *other* job still runs exactly once (its slot
        // fills), and the caller learns the batch was poisoned.
        let pool = FleetPool::new(2);
        let slots: Arc<Mutex<Vec<Option<u64>>>> =
            Arc::new(Mutex::new((0..8).map(|_| None).collect()));
        let write = Arc::clone(&slots);
        let poisoned = pool.run_contained(8, move |i| {
            assert!(i != 3, "scripted member failure");
            lock(&write)[i] = Some(i as u64 * 10);
        });
        assert!(poisoned, "the panic must be reported");
        let got = lock(&slots).clone();
        for (i, slot) in got.iter().enumerate() {
            if i == 3 {
                assert!(slot.is_none(), "panicked job leaves its slot unfilled");
            } else {
                assert_eq!(*slot, Some(i as u64 * 10));
            }
        }
        // A fault-free batch on the same pool reports clean.
        assert!(!pool.run_contained(4, |_| {}));
        assert_eq!(pool.spawned_threads(), 2, "no respawn after containment");
    }
}
