//! Scripted test environments, shared everywhere a test needs a
//! deterministic measurement surface.
//!
//! Before this module existed every test file hand-rolled its own
//! `StepEnv`; the copies drifted apart and new tests kept forking them.
//! The scripted pieces now live here once, compiled only for test
//! builds: the module is gated on `#[cfg(any(test, feature = "testkit"))]`
//! and the crate dev-depends on itself with the `testkit` feature, so
//! unit tests, integration tests (`rust/tests/common/mod.rs` re-exports
//! this module), and benches all see the same definitions while release
//! builds ship none of it.
//!
//! * [`StepEnv`] — constant metrics that step to a second level after a
//!   scripted number of windows: the minimal drifting surface (a
//!   workload/thermal shift in miniature). With
//!   [`StepEnv::with_space`], scripted members of different native
//!   grids compose into heterogeneous [`super::FleetEnv`]s
//!   (`rust/tests/hetero_fleet.rs`).
//! * [`QueueServer`] — a queue-shaped [`ModelServer`]: the admission
//!   policy's test double (no PJRT, no threads), recording applied
//!   concurrency levels so reconfiguration paths are observable.

use crate::coordinator::ModelServer;
use crate::device::{ConfigSpace, DeviceKind, HwConfig, Measured};
use crate::runtime::Detections;

use super::env::Environment;

/// Scripted environment: constant throughput/power that steps to a
/// second level after `step_after` windows, regardless of the applied
/// configuration. Defaults reproduce the historical inline test env:
/// 30 → 15 fps at a constant 5000 mW, 7 s of cost per window, on the
/// Xavier NX configuration space.
#[derive(Debug, Clone)]
pub struct StepEnv {
    space: ConfigSpace,
    windows: u64,
    step_after: u64,
    cost_per_window_s: f64,
    fps_before: f64,
    fps_after: f64,
    power_mw: f64,
}

impl StepEnv {
    /// Steps from 30 fps down to 15 fps after `step_after` windows.
    pub fn new(step_after: u64) -> StepEnv {
        StepEnv {
            space: DeviceKind::XavierNx.space(),
            windows: 0,
            step_after,
            cost_per_window_s: 7.0,
            fps_before: 30.0,
            fps_after: 15.0,
            power_mw: 5000.0,
        }
    }

    /// A surface that never shifts (constant `fps_before` forever).
    pub fn constant() -> StepEnv {
        StepEnv::new(u64::MAX)
    }

    /// Override the configuration space — heterogeneous-fleet tests
    /// build scripted members with different native grids (e.g. one NX
    /// and one Orin member under a single normalized `FleetEnv`).
    pub fn with_space(mut self, space: ConfigSpace) -> StepEnv {
        self.space = space;
        self
    }

    /// Override the two throughput levels.
    pub fn with_levels(mut self, fps_before: f64, fps_after: f64) -> StepEnv {
        self.fps_before = fps_before;
        self.fps_after = fps_after;
        self
    }

    /// Override the constant measured power.
    pub fn with_power(mut self, power_mw: f64) -> StepEnv {
        self.power_mw = power_mw;
        self
    }

    /// Windows measured so far.
    pub fn windows(&self) -> u64 {
        self.windows
    }
}

impl Environment for StepEnv {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        self.windows += 1;
        let fps = if self.windows > self.step_after {
            self.fps_after
        } else {
            self.fps_before
        };
        Measured {
            config: cfg,
            throughput_fps: fps,
            power_mw: self.power_mw,
            latency_ms: 10.0,
            p99_latency_ms: 10.0,
            gpu_util: 0.5,
            cpu_util: 0.5,
            mem_util: 0.5,
            accuracy: 30.0,
            failed: None,
        }
    }

    fn space(&self) -> &ConfigSpace {
        &self.space
    }

    fn cost_s(&self) -> f64 {
        self.windows as f64 * self.cost_per_window_s
    }

    /// The full script: two same-space `StepEnv`s with different
    /// levels, power, cost or step schedule are different surfaces and
    /// must never share cache entries.
    fn fingerprint(&self) -> u64 {
        super::cache::stable_hash(&[
            super::cache::space_fingerprint(&self.space),
            self.step_after,
            self.cost_per_window_s.to_bits(),
            self.fps_before.to_bits(),
            self.fps_after.to_bits(),
            self.power_mw.to_bits(),
        ])
    }
}

/// Queue-shaped [`ModelServer`] stand-in: `tick` completes one request
/// per call, `set_concurrency` is recorded rather than resizing any
/// worker pool — so admission and reconfiguration behavior is testable
/// without artifacts.
#[derive(Debug, Default)]
pub struct QueueServer {
    queued: Vec<u64>,
    completed: u64,
    /// Last concurrency level applied via [`ModelServer::set_concurrency`].
    pub concurrency: usize,
    /// Number of reconfigurations applied (the arbiter's audit trail).
    pub reconfigs: u64,
}

impl ModelServer for QueueServer {
    fn submit(&mut self, id: u64, _pixels: Vec<f32>) {
        self.queued.push(id);
    }

    fn backlog(&self) -> usize {
        self.queued.len()
    }

    fn tick(&mut self) -> Vec<(u64, Detections)> {
        if self.queued.is_empty() {
            return Vec::new();
        }
        let id = self.queued.remove(0);
        self.completed += 1;
        vec![(id, Detections { boxes: Vec::new(), scores: Vec::new() })]
    }

    fn set_concurrency(&mut self, c: usize) {
        self.concurrency = c;
        self.reconfigs += 1;
    }

    fn shutdown(self) -> u64 {
        self.completed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_env_shifts_on_schedule_and_accounts_cost() {
        let mut env = StepEnv::new(2).with_levels(40.0, 20.0).with_power(4000.0);
        let cfg = env.space().midpoint();
        assert_eq!(env.measure(cfg).throughput_fps, 40.0);
        assert_eq!(env.measure(cfg).throughput_fps, 40.0);
        let m = env.measure(cfg);
        assert_eq!(m.throughput_fps, 20.0, "third window is past the step");
        assert_eq!(m.power_mw, 4000.0);
        assert_eq!(env.windows(), 3);
        assert!((env.cost_s() - 3.0 * 7.0).abs() < 1e-12);
        let mut flat = StepEnv::constant();
        for _ in 0..50 {
            assert_eq!(flat.measure(cfg).throughput_fps, 30.0);
        }
    }

    #[test]
    fn with_space_overrides_the_native_grid() {
        let env = StepEnv::constant().with_space(DeviceKind::OrinNano.space());
        assert_eq!(env.space().device(), DeviceKind::OrinNano);
        assert_eq!(env.space(), &DeviceKind::OrinNano.space());
    }

    #[test]
    fn queue_server_records_reconfigurations() {
        let mut s = QueueServer::default();
        s.submit(1, Vec::new());
        s.submit(2, Vec::new());
        assert_eq!(s.backlog(), 2);
        s.set_concurrency(3);
        assert_eq!((s.concurrency, s.reconfigs), (3, 1));
        assert_eq!(s.tick().len(), 1, "one completion per tick");
        assert_eq!(s.backlog(), 1);
        assert_eq!(s.tick()[0].0, 2);
        assert_eq!(s.shutdown(), 2);
    }
}
