//! Fleet-parallel control: run many independent control loops on a
//! persistent worker pool without changing a single number.
//!
//! Every job owns its RNG seed and results land by job index, so the
//! parallel schedule — including work stealing on the underlying
//! [`FleetPool`](super::FleetPool) — affects wall-clock only:
//! `fleet_sweep` over any worker count is asserted byte-identical to
//! the sequential run.
//! (This is the *many independent searches* axis; one search observing
//! many boards per window is [`super::FleetEnv`]. EXPERIMENTS.md
//! §Closed-loop serving covers both.)
//!
//! [`fleet_sweep_cached`] is the same sweep through the measurement
//! cache: every job's board is wrapped in a [`CachedEnv`] over one
//! shared [`CacheStore`], so re-running the sweep replays every window
//! from the store (EXPERIMENTS.md §Measurement cache, `bench_cache`).

use std::sync::OnceLock;

use crate::device::Device;
use crate::experiments::scenarios::DualScenario;
use crate::optimizer::{Constraints, CoralOptimizer};

use super::cache::{CacheStore, CachedEnv};
use super::engine::{ControlLoop, DEFAULT_BUDGET};
use super::env::{Environment, SimEnv};
use super::pool::{auto_workers, FleetPool};

/// A deterministic parallel job runner over a persistent [`FleetPool`].
///
/// The pool is built lazily on the first parallel [`FleetRunner::map`]
/// and reused for every later call — zero further thread spawns for the
/// runner's whole lifetime, which is what lets `fleet_sweep` and
/// `TenantArbiter` rounds scale past the paper's 2-board experiments.
pub struct FleetRunner {
    workers: usize,
    pool: OnceLock<FleetPool>,
}

impl FleetRunner {
    pub fn new(workers: usize) -> FleetRunner {
        assert!(workers >= 1, "need at least one worker");
        FleetRunner { workers, pool: OnceLock::new() }
    }

    /// One worker per available CPU (at least 2); the
    /// `CORAL_FLEET_WORKERS` env var overrides, clamped ≥ 1, so CI and
    /// benches pin worker counts reproducibly (EXPERIMENTS.md
    /// §Fleet-scale sweeps).
    pub fn auto() -> FleetRunner {
        FleetRunner::new(auto_workers())
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Threads this runner's pool has ever spawned: 0 until the first
    /// parallel `map`, then exactly [`FleetRunner::workers`] forever.
    pub fn spawned_threads(&self) -> u64 {
        self.pool.get().map_or(0, FleetPool::spawned_threads)
    }

    fn pool(&self) -> &FleetPool {
        self.pool.get_or_init(|| FleetPool::new(self.workers))
    }

    /// Parallel map preserving job order. Results are byte-identical for
    /// any worker count and any steal schedule: each job is
    /// self-contained (own seed, own device state) and lands in its slot
    /// by index, so thread timing cannot reorder or perturb anything
    /// (the [`super::pool`] determinism contract).
    pub fn map<J, R, F>(&self, jobs: Vec<J>, f: F) -> Vec<R>
    where
        J: Send + 'static,
        R: Send + 'static,
        F: Fn(J) -> R + Send + Sync + 'static,
    {
        if self.workers == 1 || jobs.len() <= 1 {
            return jobs.into_iter().map(f).collect();
        }
        self.pool().map(jobs, move |_, job| f(job))
    }
}

/// Per-scenario aggregate of a fleet sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetStats {
    pub scenario: DualScenario,
    pub seeds: u64,
    /// Seeds whose chosen configuration met both constraints.
    pub feasible: u64,
    /// Mean 1-based iteration of the first feasible measurement (NaN
    /// when no seed ever measured one).
    pub mean_first_feasible: f64,
    /// Mean per-seed search cost ([`super::Environment::cost_s`]).
    pub mean_cost_s: f64,
}

/// Per-seed outcome of one sweep job.
#[derive(Debug, Clone, Copy)]
struct SweepResult {
    feasible: bool,
    first_feasible_iter: Option<usize>,
    cost_s: f64,
}

/// The fresh simulated board of one (scenario, seed) sweep job.
fn sweep_device(s: DualScenario, seed: u64) -> Device {
    const DEVICE_SEED_BASE: u64 = 0xF1EE7;
    Device::new(s.device, s.model, DEVICE_SEED_BASE + seed)
}

/// One (scenario, seed) CORAL search — the paper's 10-iteration budget —
/// driving `env` (a plain [`SimEnv`], or the same board behind a
/// [`CachedEnv`] for the cached sweep).
fn sweep_job_in<E: Environment>(env: E, s: DualScenario, seed: u64) -> SweepResult {
    let cons = Constraints::dual(s.target_fps, s.budget_mw);
    let opt = CoralOptimizer::new(env.space().clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(env, opt, cons, DEFAULT_BUDGET);
    let out = cl.run();
    SweepResult {
        feasible: out.best.map(|b| b.feasible).unwrap_or(false),
        first_feasible_iter: out.first_feasible_iter,
        cost_s: out.cost_s,
    }
}

fn sweep_job(s: DualScenario, seed: u64) -> SweepResult {
    sweep_job_in(SimEnv::new(sweep_device(s, seed)), s, seed)
}

/// Fold per-job sweep results into per-scenario [`FleetStats`].
fn aggregate(scenarios: &[DualScenario], seeds: u64, results: &[SweepResult]) -> Vec<FleetStats> {
    let per = seeds as usize;
    scenarios
        .iter()
        .enumerate()
        .map(|(i, &scenario)| {
            let chunk = &results[i * per..(i + 1) * per];
            let feasible = chunk.iter().filter(|r| r.feasible).count() as u64;
            let firsts: Vec<f64> = chunk
                .iter()
                .filter_map(|r| r.first_feasible_iter.map(|it| it as f64))
                .collect();
            let mean_first_feasible = if firsts.is_empty() {
                f64::NAN
            } else {
                firsts.iter().sum::<f64>() / firsts.len() as f64
            };
            let mean_cost_s = chunk.iter().map(|r| r.cost_s).sum::<f64>() / per as f64;
            FleetStats {
                scenario,
                seeds,
                feasible,
                mean_first_feasible,
                mean_cost_s,
            }
        })
        .collect()
}

/// CORAL across `scenarios` × `seeds` on `runner`'s workers. The result
/// is identical for every worker count (see [`FleetRunner::map`]).
pub fn fleet_sweep(scenarios: &[DualScenario], seeds: u64, runner: &FleetRunner) -> Vec<FleetStats> {
    assert!(seeds >= 1, "need at least one seed");
    let jobs: Vec<(DualScenario, u64)> = scenarios
        .iter()
        .flat_map(|&s| (0..seeds).map(move |seed| (s, seed)))
        .collect();
    let results = runner.map(jobs, |(s, seed)| sweep_job(s, seed));
    aggregate(scenarios, seeds, &results)
}

/// [`fleet_sweep`] with every job's board wrapped in a [`CachedEnv`]
/// over the shared `store` — same scenarios, same per-job seeding, same
/// deterministic parallelism.
///
/// Jobs are salted per scenario ([`CachedEnv::with_store_salted`]), so
/// two scenarios probing the *same* (device, model, seed) board under
/// different constraints keep disjoint key spaces — concurrent
/// first-misses can never race on the board's stateful noise, and the
/// result stays byte-identical for any worker count. Within one job a
/// re-proposed configuration is answered from the store (that is the
/// cache's contract), so on noisy surfaces a first pass can differ from
/// the uncached [`fleet_sweep`]; re-running the sweep over the same
/// store replays every window as a hit — identical outcomes at zero
/// measurement cost. `bench_cache` quantifies both effects.
pub fn fleet_sweep_cached(
    scenarios: &[DualScenario],
    seeds: u64,
    runner: &FleetRunner,
    store: &CacheStore,
) -> Vec<FleetStats> {
    assert!(seeds >= 1, "need at least one seed");
    let jobs: Vec<(usize, DualScenario, u64)> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(i, &s)| (0..seeds).map(move |seed| (i, s, seed)))
        .collect();
    let store = store.clone();
    let results = runner.map(jobs, move |(i, s, seed)| {
        let env = CachedEnv::with_store_salted(
            SimEnv::new(sweep_device(s, seed)),
            store.clone(),
            i as u64,
        );
        sweep_job_in(env, s, seed)
    });
    aggregate(scenarios, seeds, &results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::scenarios::DUAL_SCENARIOS;

    #[test]
    fn map_preserves_order_at_any_worker_count() {
        let jobs: Vec<u64> = (0..23).collect();
        let seq = FleetRunner::new(1).map(jobs.clone(), |j| j * j + 1);
        for workers in [2, 3, 8, 40] {
            let par = FleetRunner::new(workers).map(jobs.clone(), |j| j * j + 1);
            assert_eq!(seq, par, "{workers} workers");
        }
        assert_eq!(seq[22], 22 * 22 + 1);
        assert!(FleetRunner::auto().workers() >= 1);
    }

    #[test]
    fn runner_reuses_one_pool_across_calls() {
        let runner = FleetRunner::new(3);
        assert_eq!(runner.spawned_threads(), 0, "pool is lazy");
        for pass in 0..5u64 {
            let got = runner.map((0..40u64).collect(), move |j| j + pass);
            assert_eq!(got[39], 39 + pass);
            assert_eq!(runner.spawned_threads(), 3, "pass {pass} spawned threads");
        }
        // The sequential fast path never builds a pool at all.
        let seq = FleetRunner::new(1);
        seq.map((0..10u64).collect(), |j| j);
        assert_eq!(seq.spawned_threads(), 0);
    }

    #[test]
    fn fleet_sweep_parallel_matches_sequential_byte_for_byte() {
        let scenarios = &DUAL_SCENARIOS[..2];
        let seq = fleet_sweep(scenarios, 4, &FleetRunner::new(1));
        let par = fleet_sweep(scenarios, 4, &FleetRunner::new(3));
        // NaN-tolerant exact comparison: the formatted stats must agree
        // to the last bit-visible digit.
        assert_eq!(format!("{seq:?}"), format!("{par:?}"));
        assert_eq!(seq.len(), 2);
        for st in &seq {
            assert_eq!(st.seeds, 4);
            assert!(st.mean_cost_s > 0.0);
        }
        // The paper's headline scenario: CORAL converges for most seeds.
        assert!(
            seq[0].feasible >= 3,
            "NX/YOLO should mostly converge: {:?}",
            seq[0]
        );
    }

    #[test]
    fn cached_fleet_sweep_is_schedule_independent() {
        let scenarios = &DUAL_SCENARIOS[..2];
        let s1 = CacheStore::new();
        let s2 = CacheStore::new();
        let seq = fleet_sweep_cached(scenarios, 3, &FleetRunner::new(1), &s1);
        let par = fleet_sweep_cached(scenarios, 3, &FleetRunner::new(4), &s2);
        assert_eq!(format!("{seq:?}"), format!("{par:?}"));
        assert_eq!(s1.stats().misses, s2.stats().misses);
        assert!(!s1.is_empty());
    }

    #[test]
    fn cached_fleet_sweep_replays_repeat_passes_from_the_store() {
        let scenarios = &DUAL_SCENARIOS[..2];
        let store = CacheStore::new();
        let p1 = fleet_sweep_cached(scenarios, 3, &FleetRunner::new(1), &store);
        let misses_p1 = store.stats().misses;
        let p2 = fleet_sweep_cached(scenarios, 3, &FleetRunner::new(3), &store);
        assert_eq!(store.stats().misses, misses_p1, "pass 2 runs zero real windows");
        assert!(store.stats().hits > 0);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.feasible, b.feasible, "replayed outcomes identical");
            assert_eq!(
                format!("{:?}", a.mean_first_feasible),
                format!("{:?}", b.mean_first_feasible)
            );
            assert!(a.mean_cost_s > 0.0);
            assert_eq!(b.mean_cost_s, 0.0, "every pass-2 window hit the store");
        }
    }
}
