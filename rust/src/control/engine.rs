//! The canonical closed drive loop.
//!
//! Every caller that used to hand-roll `propose()/observe()` —
//! experiments, CLI, examples — now drives through [`ControlLoop`]:
//! iteration budget, first-feasible tracking, per-search cost accounting
//! via [`Environment::cost_s`], a recorded [`Trace`], an event log, and
//! an optional hold phase whose windowed-throughput drift detector hands
//! control back for a fresh search round when the surface shifts
//! (thermal throttling, workload change).
//!
//! The loop is deliberately ignorant of what it is driving: the same
//! engine runs a single simulated board, the live serving stack, a
//! (possibly mixed-device) fleet, or a whole multi-tenant arbitration
//! round — see ARCHITECTURE.md for the composition diagram and
//! EXPERIMENTS.md (§Closed-loop serving, §Multi-tenant arbitration,
//! §Heterogeneous fleets) for the experiments each shape backs.

use std::collections::VecDeque;

use crate::device::{HwConfig, Measured};
use crate::optimizer::{BestConfig, Constraints, Optimizer};
use crate::workload::Trace;

use super::cache::CacheStats;
use super::env::Environment;

/// The paper's online iteration budget (§IV-A).
pub const DEFAULT_BUDGET: usize = 10;

/// Windowed-throughput drift detection tunables.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Hold-phase windows averaged before comparing to the reference.
    pub window: usize,
    /// Relative shift of the windowed mean that re-triggers search.
    pub rel_threshold: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { window: 5, rel_threshold: 0.1 }
    }
}

/// Detects sustained throughput shifts against a reference level.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    cfg: DriftConfig,
    reference_fps: f64,
    recent: VecDeque<f64>,
    /// Non-finite samples dropped instead of entering the window.
    glitches: u64,
}

impl DriftDetector {
    pub fn new(cfg: DriftConfig, reference_fps: f64) -> DriftDetector {
        assert!(cfg.window >= 1, "drift window must hold a sample");
        assert!(cfg.rel_threshold > 0.0, "drift threshold must be positive");
        DriftDetector { cfg, reference_fps, recent: VecDeque::new(), glitches: 0 }
    }

    pub fn reference_fps(&self) -> f64 {
        self.reference_fps
    }

    /// Non-finite samples dropped so far (sensor glitches).
    pub fn glitches(&self) -> u64 {
        self.glitches
    }

    /// Feed one throughput sample. Returns the windowed mean when it has
    /// drifted more than `rel_threshold` from the reference (a single
    /// noisy window cannot fire; the mean over `window` samples must
    /// shift).
    ///
    /// A non-finite sample is a sensor glitch, not a measurement: it is
    /// dropped — counted in [`DriftDetector::glitches`], never entering
    /// the window — so a NaN burst cannot masquerade as a throughput
    /// collapse and fire a spurious drift (epoch bump, cache purge,
    /// restart). A *real* collapse reports finite 0 fps windows and
    /// still fires.
    pub fn push(&mut self, throughput_fps: f64) -> Option<f64> {
        if !throughput_fps.is_finite() {
            self.glitches += 1;
            return None;
        }
        self.recent.push_back(throughput_fps);
        if self.recent.len() > self.cfg.window {
            self.recent.pop_front();
        }
        if self.recent.len() < self.cfg.window {
            return None;
        }
        let mean = self.recent.iter().sum::<f64>() / self.recent.len() as f64;
        let denom = self.reference_fps.abs().max(1e-12);
        if (mean - self.reference_fps).abs() / denom > self.cfg.rel_threshold {
            Some(mean)
        } else {
            None
        }
    }
}

/// Control-loop tunables.
#[derive(Debug, Clone, Copy)]
pub struct ControlLoopConfig {
    /// Online iterations per search round.
    pub budget: usize,
    /// Hold-phase drift detection (None = hold never ends early).
    pub drift: Option<DriftConfig>,
    /// Search-phase drift detection (None = off, the default). The
    /// monitor feeds on the optimizer's own sliding window
    /// ([`Optimizer::window_throughputs`]): once the window first holds
    /// `window` observations their mean becomes the reference level, and
    /// every later in-window observation is pushed into a
    /// [`DriftDetector`]. A mid-search surface shift restarts the round
    /// in place — [`Optimizer::reset_search`] drops the stale window and
    /// anchors while CORAL's prohibited list survives. Search proposals
    /// vary by design, so thresholds here should be materially wider
    /// than hold-phase ones; optimizers without a window (the presets,
    /// random search) never arm the monitor.
    pub search_drift: Option<DriftConfig>,
}

impl Default for ControlLoopConfig {
    fn default() -> Self {
        ControlLoopConfig { budget: DEFAULT_BUDGET, drift: None, search_drift: None }
    }
}

/// In-round search restarts are capped so a surface that never stops
/// shifting cannot keep a [`ControlLoop::run`] alive forever.
pub const MAX_SEARCH_RESTARTS: usize = 8;

/// Telemetry event log of a control loop's life.
#[derive(Debug, Clone, Copy)]
pub enum LoopEvent {
    /// A search round began (loop creation or [`ControlLoop::restart`]).
    SearchStarted { at_window: u64 },
    /// First measurement of the round satisfying the constraints.
    FirstFeasible { at_window: u64, config: HwConfig },
    /// A search round ran its full budget.
    SearchCompleted { at_window: u64, feasible: bool },
    /// Hold-phase windowed throughput shifted off the chosen config's
    /// measured level — the caller should re-search.
    DriftDetected { at_window: u64, reference_fps: f64, observed_fps: f64 },
    /// Mid-search windowed throughput shifted off the level the round's
    /// early observations established — the round restarted in place
    /// with the optimizer's prohibited list intact.
    SearchDriftDetected { at_window: u64, reference_fps: f64, observed_fps: f64 },
    /// A hold phase ran its full length without drifting.
    HoldCompleted { at_window: u64, windows: u64 },
    /// Cache accounting snapshot of a [`super::CachedEnv`]-wrapped
    /// environment — logged at round/hold boundaries and after every
    /// drift-induced epoch bump. Never emitted for uncached
    /// environments ([`Environment::cache_stats`] is None), so their
    /// event logs are unchanged by the cache layer's existence.
    Cache { at_window: u64, stats: CacheStats },
}

/// One executed propose → measure → observe iteration.
#[derive(Debug, Clone, Copy)]
pub struct Step {
    /// Global measurement-window counter (searches + holds).
    pub window: u64,
    /// 0-based iteration within the current search round.
    pub iter: usize,
    /// Proposed (pre-snap) configuration.
    pub config: HwConfig,
    /// The measured window (snapped config, metrics, failure).
    pub measured: Measured,
    /// Whether this measurement satisfied the constraints.
    pub feasible: bool,
    /// Best-so-far after observing this measurement (pre-restart when
    /// `search_drift` fired on this step).
    pub best: Option<BestConfig>,
    /// `(reference_fps, observed_windowed_fps)` when this step's
    /// observation fired the search-phase drift monitor and restarted
    /// the round.
    pub search_drift: Option<(f64, f64)>,
}

/// Result of one search round.
#[derive(Debug, Clone)]
pub struct LoopOutcome {
    /// The optimizer's chosen configuration (feasible preferred).
    pub best: Option<BestConfig>,
    /// Iterations actually run.
    pub iters: usize,
    /// 1-based iteration of the first feasible *measurement* (None when
    /// the round never measured a feasible window).
    pub first_feasible_iter: Option<usize>,
    /// `feasible_by_iter[i]` — was the best-so-far after iteration i
    /// feasible? (Convergence curves.)
    pub feasible_by_iter: Vec<bool>,
    /// Measurement cost this round's search iterations consumed, in
    /// [`Environment::cost_s`] units (hold-phase windows excluded —
    /// serving the chosen config is deployment, not search). Includes
    /// iterations spent before an in-round search-drift restart: their
    /// windows were really measured.
    pub cost_s: f64,
    /// In-round restarts the search-phase drift monitor triggered
    /// (0 when `search_drift` is off or the surface held still).
    pub search_restarts: usize,
    /// Every iteration of the round, replayable via
    /// [`crate::workload::TraceReplay`]. Spans the whole round including
    /// iterations before a search-drift restart, so `trace.len()` can
    /// exceed `iters` when `search_restarts > 0`.
    pub trace: Trace,
    /// Cache accounting when the environment carries a
    /// [`super::CachedEnv`] layer (None for plain environments). Note
    /// the counters are environment-lifetime — under a shared
    /// [`super::CacheStore`] they span every wrapper on that store —
    /// not per-round.
    pub cache: Option<CacheStats>,
}

/// Result of a hold phase.
#[derive(Debug, Clone, Copy)]
pub struct HoldOutcome {
    /// Windows measured (≤ requested when drift ended the hold early).
    pub windows: u64,
    /// `(reference_fps, observed_windowed_fps)` when drift fired.
    pub drift: Option<(f64, f64)>,
}

/// The closed loop: one optimizer driving one environment.
///
/// ```text
/// let mut cl = ControlLoop::with_budget(env, opt, cons, 10);
/// let outcome = cl.run();            // or: while !cl.done() { cl.step() }
/// cl.hold(40);                       // serve the chosen config, watch drift
/// cl.restart(fresh_opt); cl.run();   // re-search after drift
/// ```
pub struct ControlLoop<E: Environment, O: Optimizer> {
    env: E,
    opt: O,
    cons: Constraints,
    cfg: ControlLoopConfig,
    window: u64,
    iter: usize,
    first_feasible: Option<usize>,
    feasible_by_iter: Vec<bool>,
    trace: Trace,
    events: Vec<LoopEvent>,
    /// Cost consumed by this round's search steps (holds excluded).
    search_cost_s: f64,
    /// Armed search-phase drift monitor (None until the optimizer's
    /// window first fills, and between restarts).
    search_detector: Option<DriftDetector>,
    /// Optimizer-window length at the previous arming check — a stalled
    /// length below the configured drift window means the optimizer's
    /// window saturated (its capacity is smaller), so the monitor arms
    /// on what is retained instead of staying silently inert.
    search_window_len: usize,
    /// In-round restarts the search-phase monitor triggered.
    search_restarts: usize,
}

impl<E: Environment, O: Optimizer> ControlLoop<E, O> {
    pub fn new(env: E, opt: O, cons: Constraints, cfg: ControlLoopConfig) -> Self {
        ControlLoop {
            env,
            opt,
            cons,
            cfg,
            window: 0,
            iter: 0,
            first_feasible: None,
            feasible_by_iter: Vec::new(),
            trace: Trace::new(),
            events: vec![LoopEvent::SearchStarted { at_window: 0 }],
            search_cost_s: 0.0,
            search_detector: None,
            search_window_len: 0,
            search_restarts: 0,
        }
    }

    /// Default config with an explicit iteration budget.
    pub fn with_budget(env: E, opt: O, cons: Constraints, budget: usize) -> Self {
        ControlLoop::new(env, opt, cons, ControlLoopConfig {
            budget,
            ..ControlLoopConfig::default()
        })
    }

    /// Has the current search round exhausted its budget?
    pub fn done(&self) -> bool {
        self.iter >= self.cfg.budget
    }

    /// Run one propose → measure → observe iteration.
    pub fn step(&mut self) -> Step {
        assert!(!self.done(), "budget exhausted; restart() begins a new round");
        let config = self.opt.propose();
        let cost_before = self.env.cost_s();
        let m = self.env.measure(config);
        self.search_cost_s += self.env.cost_s() - cost_before;
        self.opt.observe(config, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
        self.trace.record(config, m.throughput_fps, m.power_mw);
        self.window += 1;
        self.iter += 1;
        let this_iter = self.iter - 1;
        // `satisfied` adds the p99 SLO and accuracy-floor clauses;
        // without an SLO or floor it is exactly the historical Eq. 6
        // check.
        let feasible =
            self.cons.satisfied(m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
        if feasible && self.first_feasible.is_none() {
            self.first_feasible = Some(self.iter);
            self.events
                .push(LoopEvent::FirstFeasible { at_window: self.window, config });
        }
        let best = self.opt.best();
        self.feasible_by_iter
            .push(best.map(|b| b.feasible).unwrap_or(false));
        let search_drift = self.search_drift_check(&m);
        if let Some((reference, observed)) = search_drift {
            // The surface shifted under the search: everything measured
            // so far describes a level that no longer exists. Restart
            // the round in place — the optimizer keeps what survives a
            // shift (CORAL's prohibited list) and drops the stale window
            // and anchors; the fresh round re-references off the new
            // surface before the monitor can arm again.
            self.events.push(LoopEvent::SearchDriftDetected {
                at_window: self.window,
                reference_fps: reference,
                observed_fps: observed,
            });
            // The entries cached off the old surface are stale with it.
            self.env.bump_epoch();
            self.opt.reset_search();
            self.iter = 0;
            self.first_feasible = None;
            self.feasible_by_iter.clear();
            self.search_detector = None;
            self.search_window_len = 0;
            self.search_restarts += 1;
            self.events
                .push(LoopEvent::SearchStarted { at_window: self.window });
            self.log_cache_stats();
        } else if self.done() {
            // Emitted here — not from run() — so manually-stepped loops
            // log round completion too, exactly once per round.
            self.events.push(LoopEvent::SearchCompleted {
                at_window: self.window,
                feasible: best.map(|b| b.feasible).unwrap_or(false),
            });
            self.log_cache_stats();
        }
        Step {
            window: self.window,
            iter: this_iter,
            config,
            measured: m,
            feasible,
            best,
            search_drift,
        }
    }

    /// Feed the search-phase drift monitor with this step's observation.
    /// Returns `(reference, observed)` when the windowed mean has
    /// shifted off the round's reference level.
    fn search_drift_check(&mut self, m: &Measured) -> Option<(f64, f64)> {
        let dcfg = self.cfg.search_drift?;
        if self.search_restarts >= MAX_SEARCH_RESTARTS {
            return None; // runaway-shift backstop: finish on the budget
        }
        // Crashed windows carry no surface signal (the optimizer's
        // window skips them too).
        if m.throughput_fps <= 0.0 {
            return None;
        }
        if self.search_detector.is_none() {
            let w = self.opt.window_throughputs();
            // Every call reaching this point pushed a sample into the
            // optimizer's window, so a stalled length below the drift
            // window means the window is evicting — its capacity is
            // smaller than `dcfg.window` — and waiting longer would
            // leave the monitor silently inert. Arm on what is retained.
            let saturated = !w.is_empty() && w.len() == self.search_window_len;
            self.search_window_len = w.len();
            if w.len() >= dcfg.window || saturated {
                // The window's first fill sets the reference level; this
                // step's observation is part of it, not a pushed sample.
                let mean = w.iter().sum::<f64>() / w.len() as f64;
                self.search_detector = Some(DriftDetector::new(dcfg, mean));
            }
            return None;
        }
        let det = self.search_detector.as_mut().expect("armed above");
        det.push(m.throughput_fps)
            .map(|observed| (det.reference_fps(), observed))
    }

    /// Drive the remaining budget and return the round's outcome.
    pub fn run(&mut self) -> LoopOutcome {
        self.run_observed(|_, _| {})
    }

    /// Like [`ControlLoop::run`], calling `observe` after every step
    /// (per-iteration reporting with typed optimizer access).
    pub fn run_observed(&mut self, mut observe: impl FnMut(&Step, &O)) -> LoopOutcome {
        while !self.done() {
            let step = self.step();
            observe(&step, &self.opt);
        }
        self.outcome()
    }

    /// Snapshot of the current round's outcome.
    pub fn outcome(&self) -> LoopOutcome {
        LoopOutcome {
            best: self.opt.best(),
            iters: self.iter,
            first_feasible_iter: self.first_feasible,
            feasible_by_iter: self.feasible_by_iter.clone(),
            cost_s: self.search_cost_s,
            search_restarts: self.search_restarts,
            trace: self.trace.clone(),
            cache: self.env.cache_stats(),
        }
    }

    /// Log a [`LoopEvent::Cache`] snapshot — only when a cache layer is
    /// actually present, so uncached loops' event logs are unchanged.
    fn log_cache_stats(&mut self) {
        if let Some(stats) = self.env.cache_stats() {
            self.events
                .push(LoopEvent::Cache { at_window: self.window, stats });
        }
    }

    /// Hold the chosen configuration for up to `windows` measurement
    /// windows (deployment between searches). With drift detection
    /// configured, the hold ends early — with a [`LoopEvent::DriftDetected`]
    /// event — once the windowed throughput shifts off the level the
    /// configuration was chosen at; the caller then [`ControlLoop::restart`]s.
    ///
    /// Hold windows measure through [`Environment::measure_fresh`]: the
    /// hold's entire purpose is watching the live surface for drift, so
    /// a [`super::CachedEnv`] layer must never answer them from its
    /// store (it refreshes the stored entry instead). A detected drift
    /// additionally bumps the environment's cache epoch — everything
    /// cached off the old surface is stale with it.
    pub fn hold(&mut self, windows: u64) -> HoldOutcome {
        let best = match self.opt.best() {
            Some(b) => b,
            None => return HoldOutcome { windows: 0, drift: None },
        };
        let mut detector = self
            .cfg
            .drift
            .map(|d| DriftDetector::new(d, best.throughput_fps));
        for w in 0..windows {
            let m = self.env.measure_fresh(best.config);
            self.window += 1;
            if let Some(det) = detector.as_mut() {
                if let Some(observed) = det.push(m.throughput_fps) {
                    self.events.push(LoopEvent::DriftDetected {
                        at_window: self.window,
                        reference_fps: best.throughput_fps,
                        observed_fps: observed,
                    });
                    self.env.bump_epoch();
                    self.log_cache_stats();
                    return HoldOutcome {
                        windows: w + 1,
                        drift: Some((best.throughput_fps, observed)),
                    };
                }
            }
        }
        self.events
            .push(LoopEvent::HoldCompleted { at_window: self.window, windows });
        self.log_cache_stats();
        HoldOutcome { windows, drift: None }
    }

    /// Begin a fresh search round with a new optimizer (drift response,
    /// periodic re-tune). The environment — including its accumulated
    /// state: thermal history, clocks, cost — the global window counter,
    /// and the event log all carry over; per-round trackers reset.
    pub fn restart(&mut self, opt: O) {
        self.opt = opt;
        self.iter = 0;
        self.first_feasible = None;
        self.feasible_by_iter.clear();
        self.trace = Trace::new();
        self.search_cost_s = 0.0;
        self.search_detector = None;
        self.search_window_len = 0;
        self.search_restarts = 0;
        self.events
            .push(LoopEvent::SearchStarted { at_window: self.window });
    }

    /// Replace the feasibility constraints for subsequent rounds. The
    /// multi-tenant arbiter re-budgets tenants between rounds; swap the
    /// optimizer too ([`ControlLoop::restart`]) when doing this — the
    /// running round's best-so-far was ranked under the old constraints.
    pub fn set_cons(&mut self, cons: Constraints) {
        self.cons = cons;
    }

    /// Total measurement windows across all rounds and holds.
    pub fn windows(&self) -> u64 {
        self.window
    }

    pub fn events(&self) -> &[LoopEvent] {
        &self.events
    }

    pub fn cons(&self) -> Constraints {
        self.cons
    }

    pub fn env(&self) -> &E {
        &self.env
    }

    pub fn env_mut(&mut self) -> &mut E {
        &mut self.env
    }

    pub fn opt(&self) -> &O {
        &self.opt
    }

    pub fn opt_mut(&mut self) -> &mut O {
        &mut self.opt
    }

    pub fn into_env(self) -> E {
        self.env
    }

    pub fn into_parts(self) -> (E, O) {
        (self.env, self.opt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::env::SimEnv;
    use crate::control::testkit::StepEnv;
    use crate::device::sim::{SAMPLES_PER_WINDOW, WARMUP_S};
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;
    use crate::optimizer::{CoralOptimizer, RandomOptimizer};

    fn coral_loop(seed: u64) -> ControlLoop<SimEnv, CoralOptimizer> {
        let dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, seed);
        let cons = Constraints::dual(30.0, 6500.0);
        let opt = CoralOptimizer::new(dev.space().clone(), cons, seed);
        ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 10)
    }

    fn trajectory(seed: u64) -> Vec<(HwConfig, f64, f64)> {
        coral_loop(seed)
            .run()
            .trace
            .steps
            .iter()
            .map(|s| (s.config, s.throughput_fps, s.power_mw))
            .collect()
    }

    #[test]
    fn same_seed_identical_trajectory_different_seed_diverges() {
        assert_eq!(trajectory(5), trajectory(5), "determinism across runs");
        assert_ne!(
            trajectory(5),
            trajectory(6),
            "seeds drive distinct measurement noise"
        );
    }

    #[test]
    fn cost_and_windows_account_every_iteration() {
        let mut cl = coral_loop(1);
        let out = cl.run();
        assert_eq!(out.iters, 10);
        assert_eq!(out.trace.len(), 10);
        assert_eq!(out.feasible_by_iter.len(), 10);
        assert_eq!(cl.windows(), 10);
        let per_window = WARMUP_S + SAMPLES_PER_WINDOW as f64;
        assert!((out.cost_s - 10.0 * per_window).abs() < 1e-9);
        // Best-so-far feasibility is monotone.
        assert!(out
            .feasible_by_iter
            .windows(2)
            .all(|w| w[1] as u8 >= w[0] as u8));
    }

    #[test]
    fn first_feasible_is_one_based_and_logged() {
        let mut hits = 0;
        for seed in 0..8 {
            let mut cl = coral_loop(seed);
            let out = cl.run();
            if let Some(first) = out.first_feasible_iter {
                hits += 1;
                assert!((1..=10).contains(&first), "1-based within budget");
                assert!(cl
                    .events()
                    .iter()
                    .any(|e| matches!(e, LoopEvent::FirstFeasible { .. })));
            }
            assert!(cl
                .events()
                .iter()
                .any(|e| matches!(e, LoopEvent::SearchCompleted { .. })));
        }
        assert!(hits >= 5, "coral reaches the region in most seeds: {hits}/8");
    }

    #[test]
    fn drift_retriggers_on_throughput_step_change() {
        // 3 search windows at 30 fps, then the environment steps down to
        // 15 fps: the hold's windowed mean shifts and drift must fire.
        let env = StepEnv::new(3);
        let cons = Constraints::none();
        let opt = RandomOptimizer::new(DeviceKind::XavierNx.space(), cons, 1);
        let cfg = ControlLoopConfig {
            budget: 3,
            drift: Some(DriftConfig { window: 4, rel_threshold: 0.2 }),
            search_drift: None,
        };
        let mut cl = ControlLoop::new(env, opt, cons, cfg);
        let out = cl.run();
        assert_eq!(out.best.unwrap().throughput_fps, 30.0);
        let hold = cl.hold(20);
        assert_eq!(hold.windows, 4, "fires as soon as the window fills");
        let (reference, observed) = hold.drift.expect("step change must be detected");
        assert_eq!(reference, 30.0);
        assert_eq!(observed, 15.0);
        assert!(cl
            .events()
            .iter()
            .any(|e| matches!(e, LoopEvent::DriftDetected { .. })));
        // A fresh round on the shifted surface re-converges to the new level.
        cl.restart(RandomOptimizer::new(DeviceKind::XavierNx.space(), cons, 2));
        let out2 = cl.run();
        assert_eq!(out2.iters, 3);
        assert_eq!(out2.best.unwrap().throughput_fps, 15.0);
    }

    #[test]
    fn steady_hold_runs_full_length_without_drift() {
        let env = StepEnv::new(u64::MAX); // never steps
        let cons = Constraints::none();
        let opt = RandomOptimizer::new(DeviceKind::XavierNx.space(), cons, 1);
        let cfg = ControlLoopConfig {
            budget: 2,
            drift: Some(DriftConfig::default()),
            search_drift: None,
        };
        let mut cl = ControlLoop::new(env, opt, cons, cfg);
        cl.run();
        let hold = cl.hold(12);
        assert_eq!(hold.windows, 12);
        assert!(hold.drift.is_none());
        // Hold windows are deployment, not search: round cost unchanged.
        assert!((cl.outcome().cost_s - 2.0 * 7.0).abs() < 1e-9);
        assert!(cl
            .events()
            .iter()
            .any(|e| matches!(e, LoopEvent::HoldCompleted { .. })));
        assert_eq!(cl.windows(), 2 + 12);
    }

    #[test]
    fn restart_resets_round_state_but_keeps_environment() {
        let mut cl = coral_loop(4);
        let out1 = cl.run();
        let cost1 = cl.env().cost_s();
        assert!(cost1 > 0.0);
        let dev_windows = cl.env().device().windows_run();
        cl.restart(CoralOptimizer::new(
            cl.env().space().clone(),
            cl.cons(),
            99,
        ));
        assert!(!cl.done());
        assert_eq!(cl.outcome().iters, 0);
        assert!(cl.outcome().trace.is_empty());
        let out2 = cl.run();
        assert_eq!(out2.iters, 10);
        // Per-round cost restarts; environment clock keeps running.
        assert!((out1.cost_s - out2.cost_s).abs() < 1e-9);
        assert_eq!(cl.env().device().windows_run(), dev_windows + 10);
    }

    #[test]
    fn search_drift_restarts_with_prohibited_list_intact() {
        // An unreachable target (40 fps on a 30-fps surface) makes every
        // pre-shift window infeasible, so CORAL's PS grows one config per
        // step. The surface steps to 15 fps mid-search (after env window
        // 6, inside the 12-iteration budget): the monitor — referenced
        // off the optimizer's sliding window at 30 fps — must fire,
        // restart the round in place, and keep every prohibited config
        // prohibited.
        let env = StepEnv::new(6);
        let cons = Constraints::dual(40.0, 6000.0);
        let opt = CoralOptimizer::new(DeviceKind::XavierNx.space(), cons, 3);
        let cfg = ControlLoopConfig {
            budget: 12,
            drift: None,
            search_drift: Some(DriftConfig { window: 4, rel_threshold: 0.2 }),
        };
        let mut cl = ControlLoop::new(env, opt, cons, cfg);
        let mut proposals = Vec::new();
        let mut drift_step = None;
        while !cl.done() {
            let step = cl.step();
            proposals.push(step.config);
            if let Some((reference, observed)) = step.search_drift {
                assert!(drift_step.is_none(), "one shift fires exactly once");
                // Reference = mean of the first 4 window entries (all
                // 30 fps); observed = mean over [30, 30, 15, 15].
                assert_eq!(reference, 30.0);
                assert_eq!(observed, 22.5);
                drift_step = Some((proposals.len(), cl.opt().prohibited_len()));
            }
        }
        let (steps_before, ps_at_drift) =
            drift_step.expect("mid-search shift must fire the monitor");
        // Detector arms at step 4 and fires on the second post-shift
        // sample: windows 7 and 8 measure 15 fps.
        assert_eq!(steps_before, 8);
        assert_eq!(ps_at_drift, 8, "every infeasible step entered the PS");

        let out = cl.outcome();
        assert_eq!(out.search_restarts, 1);
        assert_eq!(out.iters, 12, "the restarted round runs a full budget");
        assert_eq!(out.trace.len(), 8 + 12, "trace spans the whole round");
        assert_eq!(cl.windows(), 8 + 12);
        // All 20 windows were infeasible and the PS was never cleared:
        // distinct proposals throughout prove the restart respected it.
        assert_eq!(cl.opt().prohibited_len(), 20);
        let distinct: std::collections::HashSet<_> = proposals.iter().collect();
        assert_eq!(distinct.len(), proposals.len(), "prohibited config re-proposed");
        assert!(cl
            .events()
            .iter()
            .any(|e| matches!(e, LoopEvent::SearchDriftDetected { .. })));
        let starts = cl
            .events()
            .iter()
            .filter(|e| matches!(e, LoopEvent::SearchStarted { .. }))
            .count();
        assert_eq!(starts, 2, "round creation + in-place restart");
    }

    #[test]
    fn search_drift_arms_even_when_optimizer_window_is_smaller() {
        // A drift window larger than the optimizer's sliding-window
        // capacity (here W = 2 < 5) must not leave the monitor silently
        // inert: the stalled window length means saturation, and the
        // monitor arms on what the optimizer retains.
        let env = StepEnv::new(6);
        let cons = Constraints::dual(40.0, 6000.0);
        let opt = CoralOptimizer::with_config(
            DeviceKind::XavierNx.space(),
            cons,
            crate::optimizer::CoralConfig::with_window(2),
            3,
        );
        let cfg = ControlLoopConfig {
            budget: 12,
            drift: None,
            search_drift: Some(DriftConfig { window: 5, rel_threshold: 0.2 }),
        };
        let mut cl = ControlLoop::new(env, opt, cons, cfg);
        let out = cl.run();
        assert_eq!(out.search_restarts, 1, "saturated window still arms the monitor");
        assert_eq!(out.iters, 12);
        assert!(cl
            .events()
            .iter()
            .any(|e| matches!(e, LoopEvent::SearchDriftDetected { .. })));
    }

    #[test]
    fn search_drift_never_arms_for_windowless_optimizers() {
        // RandomOptimizer keeps no sliding window, so the monitor must
        // stay dormant even across a step change.
        let env = StepEnv::new(3);
        let cons = Constraints::none();
        let opt = RandomOptimizer::new(DeviceKind::XavierNx.space(), cons, 1);
        let cfg = ControlLoopConfig {
            budget: 10,
            drift: None,
            search_drift: Some(DriftConfig { window: 2, rel_threshold: 0.1 }),
        };
        let mut cl = ControlLoop::new(env, opt, cons, cfg);
        let out = cl.run();
        assert_eq!(out.search_restarts, 0);
        assert_eq!(out.iters, 10);
        assert!(!cl
            .events()
            .iter()
            .any(|e| matches!(e, LoopEvent::SearchDriftDetected { .. })));
    }

    #[test]
    fn cached_loop_replays_a_restarted_round_from_the_store() {
        let dev =
            Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 5).with_noise_scale(0.0);
        let cons = Constraints::dual(30.0, 6500.0);
        let opt = CoralOptimizer::new(dev.space().clone(), cons, 5);
        let env = crate::control::CachedEnv::new(SimEnv::new(dev));
        let mut cl = ControlLoop::with_budget(env, opt, cons, 10);
        let out1 = cl.run();
        assert!(out1.cache.is_some(), "cached env reports through the outcome");
        assert!(out1.cost_s > 0.0);
        assert!(cl.events().iter().any(|e| matches!(e, LoopEvent::Cache { .. })));
        // A same-seed optimizer replays the identical proposal sequence
        // (hits return byte-identical observations), so the whole second
        // round is answered from the store at zero cost.
        cl.restart(CoralOptimizer::new(cl.env().space().clone(), cons, 5));
        let out2 = cl.run();
        assert_eq!(out2.cost_s, 0.0, "replayed round fully answered from the store");
        assert_eq!(out1.best.unwrap().config, out2.best.unwrap().config);
        assert!(out2.cache.unwrap().hits >= 10);
    }

    #[test]
    fn hold_drift_bumps_the_cache_epoch_and_measures_fresh() {
        let env = crate::control::CachedEnv::new(StepEnv::new(3));
        let cons = Constraints::none();
        let opt = RandomOptimizer::new(DeviceKind::XavierNx.space(), cons, 1);
        let cfg = ControlLoopConfig {
            budget: 3,
            drift: Some(DriftConfig { window: 4, rel_threshold: 0.2 }),
            search_drift: None,
        };
        let mut cl = ControlLoop::new(env, opt, cons, cfg);
        cl.run();
        let hold = cl.hold(20);
        assert!(hold.drift.is_some(), "the cache must never blind the detector");
        assert_eq!(hold.windows, 4);
        assert_eq!(cl.env().epoch(), 1, "detected drift bumped the epoch");
        let last_cache = cl
            .events()
            .iter()
            .rev()
            .find_map(|e| match e {
                LoopEvent::Cache { stats, .. } => Some(*stats),
                _ => None,
            })
            .expect("drift logged a cache snapshot");
        assert_eq!(last_cache.epoch, 1);
        assert_eq!(last_cache.refreshes, 4, "every hold window measured fresh");
    }

    #[test]
    fn search_drift_bumps_the_cache_epoch() {
        // The cached twin of search_drift_restarts_with_prohibited_list_
        // intact: every proposal there is distinct, so the cache changes
        // nothing about the trajectory — but the in-place restart must
        // bump the epoch.
        let env = crate::control::CachedEnv::new(StepEnv::new(6));
        let cons = Constraints::dual(40.0, 6000.0);
        let opt = CoralOptimizer::new(DeviceKind::XavierNx.space(), cons, 3);
        let cfg = ControlLoopConfig {
            budget: 12,
            drift: None,
            search_drift: Some(DriftConfig { window: 4, rel_threshold: 0.2 }),
        };
        let mut cl = ControlLoop::new(env, opt, cons, cfg);
        let out = cl.run();
        assert_eq!(out.search_restarts, 1);
        assert_eq!(cl.env().epoch(), 1, "mid-search drift bumped the epoch");
        assert_eq!(out.cache.unwrap().epoch, 1);
    }

    #[test]
    fn glitch_burst_fires_no_drift_but_real_collapse_does() {
        // A 3-sample NaN burst on a steady board is a sensor glitch:
        // dropped, counted, no drift — the historical sanitize-to-0.0
        // read it as a collapse and fired (epoch bump, cache purge,
        // restart) on a perfectly healthy surface.
        let cfg = DriftConfig { window: 3, rel_threshold: 0.1 };
        let mut det = DriftDetector::new(cfg, 100.0);
        for _ in 0..3 {
            det.push(100.0);
        }
        for _ in 0..3 {
            assert!(det.push(f64::NAN).is_none(), "glitch burst must not fire");
        }
        assert_eq!(det.glitches(), 3);
        assert!(det.push(101.0).is_none(), "healthy window after the burst");

        // A real collapse reports finite 0 fps windows and still fires.
        let mut det = DriftDetector::new(cfg, 100.0);
        det.push(0.0);
        det.push(0.0);
        let fired = det.push(0.0).expect("sustained 0 fps collapse must fire");
        assert_eq!(fired, 0.0);
        assert_eq!(det.glitches(), 0);
    }

    #[test]
    fn drift_detector_ignores_noise_within_threshold() {
        let mut det = DriftDetector::new(
            DriftConfig { window: 3, rel_threshold: 0.1 },
            100.0,
        );
        assert!(det.push(103.0).is_none(), "window not full yet");
        assert!(det.push(97.0).is_none());
        assert!(det.push(101.0).is_none(), "mean within 10%");
        assert!(det.push(104.0).is_none());
        assert_eq!(det.reference_fps(), 100.0);
        // Sustained sag pushes the windowed mean past the threshold.
        for fps in [85.0, 85.0] {
            det.push(fps);
        }
        assert!(det.push(85.0).is_some(), "mean 85 vs reference 100");
    }
}
