//! Fault injection: a chaos decorator over any [`Environment`].
//!
//! The paper evaluates CORAL on healthy boards over short windows; a
//! deployed optimizer meets member dropout, sustained thermal
//! throttling, flaky sensors and operator-driven budget changes.
//! [`ChaosEnv`] wraps any environment with a **deterministic, seeded
//! fault schedule** — the same seed replays the same fault sequence at
//! the same windows, so chaos runs are as reproducible as clean ones —
//! and keeps **per-event recovery accounting**: for every scheduled
//! event, the number of measurement windows until the first window that
//! again satisfies the (possibly stepped) constraints.
//!
//! Fault delivery is the [`Environment::inject_fault`] hook: the
//! decorator stays fully generic while each layer handles its own fault
//! family — [`super::FleetEnv`] takes member dropout/rejoin (down
//! flags, survivor aggregation), device-backed environments take the
//! thermal family, and [`ChaosEnv`] itself owns what no inner layer
//! can see: sensor-glitch corruption of the *observation* and
//! power-budget steps (which change the constraints the caller should
//! optimize under, not the hardware).
//!
//! A `ChaosEnv` with an **empty schedule is a byte-identical
//! passthrough**: same-seed trajectories through the decorator equal
//! the undecorated environment's bit for bit (the acceptance tests pin
//! this), so measurements under chaos are directly comparable to clean
//! baselines.

use crate::device::thermal::ThermalModel;
use crate::device::{ConfigSpace, HwConfig, Measured};
use crate::optimizer::{Constraints, CoralOptimizer};

use super::{ControlLoop, ControlLoopConfig, DriftConfig, Environment, DEFAULT_BUDGET};

/// One fault as *delivered* to an environment layer via
/// [`Environment::inject_fault`]. Layers ignore families that are not
/// theirs: the fleet handles `Member*`, device-backed environments the
/// thermal trio, and decorators forward everything inward.
#[derive(Debug, Clone)]
pub enum ChaosFault {
    /// Fleet member `member` vanishes (modulo fleet size).
    MemberDown { member: usize },
    /// Fleet member `member` rejoins.
    MemberUp { member: usize },
    /// Switch the board's thermal extension on (or replace its model)
    /// mid-run — the surface becomes history-dependent from here on.
    ThermalEnable { model: ThermalModel },
    /// Externally-forced heating: advance the thermal model as if
    /// `power_mw` had been drawn for `dt_s` seconds (a blocked fan, a
    /// co-located burst). No-op on boards without a thermal model.
    HeatSoak { power_mw: f64, dt_s: f64 },
    /// Shift the thermal model's ambient temperature (enclosure heat
    /// wave). No-op on boards without a thermal model.
    AmbientShift { delta_c: f64 },
}

/// How a glitch burst corrupts the throughput reading.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlitchKind {
    /// The sensor reports NaN (a dead tegrastats line). Exercises the
    /// non-finite drops in `telemetry::Sampler` / `DriftDetector`.
    NonFinite,
    /// The sensor sticks at its last good reading — plausible-looking
    /// but frozen, the nastier failure mode.
    StuckAt,
}

/// One *scheduled* fault event. `Dropout` is the only compound one: it
/// expands into a `MemberDown` at its window and the matching
/// `MemberUp` `down_windows` later, so rejoin needs no separate entry.
#[derive(Debug, Clone)]
pub enum ChaosEvent {
    /// Member `member` drops for exactly `down_windows` windows, then
    /// rejoins (its RNG/clock/thermal state frozen while away).
    Dropout { member: usize, down_windows: u64 },
    /// Enable the thermal extension on every board underneath.
    ThermalEnable { model: ThermalModel },
    /// Force-heat every thermal board: `power_mw` for `soak_s` seconds.
    HeatSoak { power_mw: f64, soak_s: f64 },
    /// Ambient shift on every thermal board.
    AmbientShift { delta_c: f64 },
    /// Corrupt the next `windows` throughput observations.
    GlitchBurst { windows: u64, kind: GlitchKind },
    /// Step the power budget (operator/energy-price action): the
    /// decorator's [`ChaosEnv::current_constraints`] changes and the
    /// driving loop re-optimizes under the new envelope.
    BudgetStep { budget_mw: f64 },
}

impl ChaosEvent {
    /// Human-readable tag used in recovery tables.
    pub fn label(&self) -> String {
        match self {
            ChaosEvent::Dropout { member, down_windows } => {
                format!("dropout(m{member},{down_windows}w)")
            }
            ChaosEvent::ThermalEnable { .. } => "thermal-enable".to_string(),
            ChaosEvent::HeatSoak { power_mw, soak_s } => {
                format!("heat-soak({:.0}mW,{soak_s:.0}s)", power_mw)
            }
            ChaosEvent::AmbientShift { delta_c } => format!("ambient({delta_c:+.0}C)"),
            ChaosEvent::GlitchBurst { windows, kind } => {
                let k = match kind {
                    GlitchKind::NonFinite => "nan",
                    GlitchKind::StuckAt => "stuck",
                };
                format!("glitch({k},{windows}w)")
            }
            ChaosEvent::BudgetStep { budget_mw } => format!("budget({budget_mw:.0}mW)"),
        }
    }

    fn words(&self, out: &mut Vec<u64>) {
        match self {
            ChaosEvent::Dropout { member, down_windows } => {
                out.extend([1, *member as u64, *down_windows])
            }
            ChaosEvent::ThermalEnable { model } => out.extend([
                2,
                model.ambient_c.to_bits(),
                model.heat_per_ws.to_bits(),
                model.cool_rate.to_bits(),
                model.throttle_start_c.to_bits(),
                model.throttle_full_c.to_bits(),
                model.max_derate.to_bits(),
            ]),
            ChaosEvent::HeatSoak { power_mw, soak_s } => {
                out.extend([3, power_mw.to_bits(), soak_s.to_bits()])
            }
            ChaosEvent::AmbientShift { delta_c } => out.extend([4, delta_c.to_bits()]),
            ChaosEvent::GlitchBurst { windows, kind } => {
                out.extend([5, *windows, *kind as u64])
            }
            ChaosEvent::BudgetStep { budget_mw } => out.extend([6, budget_mw.to_bits()]),
        }
    }
}

/// A deterministic fault schedule: `(window, event)` pairs. Events fire
/// *before* the measurement of their window (an event at window 0
/// shapes the very first window). Multiple events may share a window;
/// they fire in insertion order.
#[derive(Debug, Clone, Default)]
pub struct ChaosSchedule {
    events: Vec<(u64, ChaosEvent)>,
}

impl ChaosSchedule {
    pub fn new() -> ChaosSchedule {
        ChaosSchedule { events: Vec::new() }
    }

    /// Schedule `event` to fire before window `window`'s measurement.
    pub fn at(mut self, window: u64, event: ChaosEvent) -> ChaosSchedule {
        self.events.push((window, event));
        self
    }

    /// The scheduled `(window, event)` pairs, in insertion order.
    pub fn events(&self) -> &[(u64, ChaosEvent)] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Keep only the first `n` scheduled events (insertion order) — the
    /// CI-reduction knob (`CORAL_BENCH_CHAOS_EVENTS`). Applied *before*
    /// expansion, so a kept `Dropout` keeps its rejoin.
    pub fn take(mut self, n: usize) -> ChaosSchedule {
        self.events.truncate(n);
        self
    }

    /// Stable identity of the schedule (cache keying through the
    /// decorator: two chaos runs share entries only for identical
    /// schedules).
    pub fn fingerprint(&self) -> u64 {
        let mut words = vec![self.events.len() as u64];
        for (w, ev) in &self.events {
            words.push(*w);
            ev.words(&mut words);
        }
        super::cache::stable_hash(&words)
    }
}

/// Per-event recovery accounting: the event's window, and the first
/// window at or after it whose measurement satisfied the (then-current)
/// constraints again with no failure. `recovered_at == at_window` means
/// the fleet absorbed the fault without ever going infeasible.
#[derive(Debug, Clone)]
pub struct RecoveryRecord {
    /// [`ChaosEvent::label`] of the event this record tracks.
    pub label: String,
    /// Window the event fired before.
    pub at_window: u64,
    /// First re-feasible window (None = never recovered so far).
    pub recovered_at: Option<u64>,
}

impl RecoveryRecord {
    /// Windows from event to recovery (None while unrecovered).
    pub fn windows(&self) -> Option<u64> {
        self.recovered_at.map(|r| r - self.at_window)
    }
}

/// What the decorator does when a timeline entry fires.
#[derive(Debug, Clone)]
enum Action {
    /// Deliver to the inner environment ([`Environment::inject_fault`]).
    Fault(ChaosFault),
    /// Start corrupting observations (handled by the decorator itself).
    Glitch { windows: u64, kind: GlitchKind },
    /// Step the constraints' power budget (decorator-owned too: budgets
    /// live in the caller's head, not in the hardware).
    Budget { budget_mw: f64 },
}

/// The chaos decorator. See the module docs for the contract; the
/// short version: wrap any environment, give it a [`ChaosSchedule`]
/// and the starting [`Constraints`], and every `measure`/`measure_fresh`
/// first fires the events due at the current window, then measures,
/// then corrupts the observation if a glitch burst is active, then
/// closes any open [`RecoveryRecord`]s if the window came back
/// feasible.
pub struct ChaosEnv<E: Environment> {
    inner: E,
    schedule: ChaosSchedule,
    /// Expanded timeline, sorted by window (stable: same-window entries
    /// keep schedule order). `Some(label)` opens a recovery record.
    timeline: Vec<(u64, Action, Option<String>)>,
    next: usize,
    window: u64,
    /// Constraints as of now — [`ChaosEvent::BudgetStep`] mutates the
    /// budget; recovery is judged against this.
    cons: Constraints,
    glitch_left: u64,
    glitch_kind: GlitchKind,
    /// Last good throughput reading (what a stuck sensor reports).
    stuck_fps: f64,
    recoveries: Vec<RecoveryRecord>,
}

impl<E: Environment> ChaosEnv<E> {
    pub fn new(inner: E, schedule: ChaosSchedule, cons: Constraints) -> ChaosEnv<E> {
        let mut timeline = Vec::with_capacity(schedule.events.len() + 4);
        for (w, ev) in &schedule.events {
            let label = Some(ev.label());
            match ev {
                ChaosEvent::Dropout { member, down_windows } => {
                    timeline.push((
                        *w,
                        Action::Fault(ChaosFault::MemberDown { member: *member }),
                        label,
                    ));
                    // The rejoin is part of the same event: no record.
                    timeline.push((
                        w + down_windows,
                        Action::Fault(ChaosFault::MemberUp { member: *member }),
                        None,
                    ));
                }
                ChaosEvent::ThermalEnable { model } => timeline.push((
                    *w,
                    Action::Fault(ChaosFault::ThermalEnable { model: model.clone() }),
                    label,
                )),
                ChaosEvent::HeatSoak { power_mw, soak_s } => timeline.push((
                    *w,
                    Action::Fault(ChaosFault::HeatSoak { power_mw: *power_mw, dt_s: *soak_s }),
                    label,
                )),
                ChaosEvent::AmbientShift { delta_c } => timeline.push((
                    *w,
                    Action::Fault(ChaosFault::AmbientShift { delta_c: *delta_c }),
                    label,
                )),
                ChaosEvent::GlitchBurst { windows, kind } => {
                    timeline.push((*w, Action::Glitch { windows: *windows, kind: *kind }, label))
                }
                ChaosEvent::BudgetStep { budget_mw } => {
                    timeline.push((*w, Action::Budget { budget_mw: *budget_mw }, label))
                }
            }
        }
        timeline.sort_by_key(|e| e.0);
        ChaosEnv {
            inner,
            schedule,
            timeline,
            next: 0,
            window: 0,
            cons,
            glitch_left: 0,
            glitch_kind: GlitchKind::NonFinite,
            stuck_fps: f64::NAN,
            recoveries: Vec::new(),
        }
    }

    /// Windows measured through the decorator so far.
    pub fn windows(&self) -> u64 {
        self.window
    }

    /// The schedule this decorator replays.
    pub fn schedule(&self) -> &ChaosSchedule {
        &self.schedule
    }

    /// Constraints as of the last fired event ([`ChaosEvent::BudgetStep`]
    /// moves the budget). Driving loops poll this and re-optimize when
    /// it shifts — the budget change is an *operator* action the
    /// optimizer must be told about, unlike the physical faults it can
    /// only observe.
    pub fn current_constraints(&self) -> Constraints {
        self.cons
    }

    /// Per-event recovery records, in firing order.
    pub fn recoveries(&self) -> &[RecoveryRecord] {
        &self.recoveries
    }

    /// Whether every fired event has seen a re-feasible window.
    pub fn all_recovered(&self) -> bool {
        self.recoveries.iter().all(|r| r.recovered_at.is_some())
    }

    /// Mean windows-to-recovery over fired events: infinite while any
    /// event is unrecovered, 0.0 with no events fired (a fleet that
    /// absorbs every fault without going infeasible reports 0).
    pub fn mean_recovery_windows(&self) -> f64 {
        if self.recoveries.is_empty() {
            return 0.0;
        }
        let mut sum = 0.0;
        for r in &self.recoveries {
            match r.windows() {
                Some(w) => sum += w as f64,
                None => return f64::INFINITY,
            }
        }
        sum / self.recoveries.len() as f64
    }

    /// Worst windows-to-recovery (None with no fired events; infinite
    /// while any is unrecovered).
    pub fn max_recovery_windows(&self) -> Option<f64> {
        self.recoveries
            .iter()
            .map(|r| r.windows().map_or(f64::INFINITY, |w| w as f64))
            .fold(None, |acc, w| Some(acc.map_or(w, |a: f64| a.max(w))))
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// The one measurement path (`fresh` picks the inner entry point).
    fn chaos_measure(&mut self, cfg: HwConfig, fresh: bool) -> Measured {
        let w = self.window;
        while self.next < self.timeline.len() && self.timeline[self.next].0 <= w {
            let (_, action, label) = self.timeline[self.next].clone();
            if let Some(label) = label {
                self.recoveries.push(RecoveryRecord {
                    label,
                    at_window: w,
                    recovered_at: None,
                });
            }
            match action {
                Action::Fault(fault) => self.inner.inject_fault(&fault),
                Action::Glitch { windows, kind } => {
                    self.glitch_left = windows;
                    self.glitch_kind = kind;
                }
                Action::Budget { budget_mw } => self.cons.power_budget_mw = Some(budget_mw),
            }
            self.next += 1;
        }
        let mut m = if fresh {
            self.inner.measure_fresh(cfg)
        } else {
            self.inner.measure(cfg)
        };
        if self.glitch_left > 0 {
            self.glitch_left -= 1;
            match self.glitch_kind {
                GlitchKind::NonFinite => m.throughput_fps = f64::NAN,
                // Stuck at the last good reading (NaN if the burst
                // started before any window — no reading to stick at).
                GlitchKind::StuckAt => m.throughput_fps = self.stuck_fps,
            }
        } else {
            self.stuck_fps = m.throughput_fps;
        }
        if m.failed.is_none()
            && self
                .cons
                .satisfied(m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy)
        {
            for r in self.recoveries.iter_mut() {
                if r.recovered_at.is_none() {
                    r.recovered_at = Some(w);
                }
            }
        }
        self.window += 1;
        m
    }
}

impl<E: Environment> Environment for ChaosEnv<E> {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        self.chaos_measure(cfg, false)
    }

    fn measure_fresh(&mut self, cfg: HwConfig) -> Measured {
        self.chaos_measure(cfg, true)
    }

    fn space(&self) -> &ConfigSpace {
        self.inner.space()
    }

    fn cost_s(&self) -> f64 {
        self.inner.cost_s()
    }

    /// Inner identity + the schedule: two chaos runs share cache
    /// entries only when both the surface and the fault sequence match.
    fn fingerprint(&self) -> u64 {
        super::cache::stable_hash(&[self.inner.fingerprint(), self.schedule.fingerprint()])
    }

    fn bump_epoch(&mut self) {
        self.inner.bump_epoch()
    }

    fn cache_stats(&self) -> Option<super::CacheStats> {
        self.inner.cache_stats()
    }

    /// Any non-empty schedule makes the surface history-dependent: what
    /// a window returns depends on which events have fired, which is a
    /// function of the window counter — pure replay would skip faults.
    fn history_dependent(&self) -> bool {
        !self.schedule.is_empty() || self.inner.history_dependent()
    }

    /// Nested chaos (or an outer driver injecting by hand): forward in.
    fn inject_fault(&mut self, fault: &ChaosFault) {
        self.inner.inject_fault(fault)
    }
}

/// Hold length of the chaos driver's serve phases: short enough that a
/// fault fired mid-hold is re-searched within a few windows, long
/// enough that the drift detector's window fills.
pub const CHAOS_HOLD_WINDOWS: u64 = 5;

/// Drive CORAL through a chaos run: search → hold (drift-watched) →
/// re-search, until `total_windows` windows have been measured. The
/// loop re-reads [`ChaosEnv::current_constraints`] at every phase
/// boundary, so [`ChaosEvent::BudgetStep`]s reach the optimizer as a
/// constraint change; every re-search gets a deterministically
/// re-seeded optimizer (`seed ^ k·golden`), so the whole run is a pure
/// function of `(env, cons, seed, total_windows)`. Returns the
/// decorator for recovery inspection ([`ChaosEnv::recoveries`]).
pub fn drive_coral<E: Environment>(
    env: ChaosEnv<E>,
    cons: Constraints,
    seed: u64,
    total_windows: u64,
) -> ChaosEnv<E> {
    let space = env.space().clone();
    let opt = CoralOptimizer::new(space.clone(), cons, seed);
    let cfg = ControlLoopConfig {
        budget: DEFAULT_BUDGET,
        drift: Some(DriftConfig::default()),
        search_drift: None,
    };
    let mut cl = ControlLoop::new(env, opt, cons, cfg);
    let mut restarts: u64 = 0;
    loop {
        cl.run();
        let live = cl.env().current_constraints();
        if live != cl.cons() {
            cl.set_cons(live);
        }
        cl.hold(CHAOS_HOLD_WINDOWS);
        let live = cl.env().current_constraints();
        if live != cl.cons() {
            cl.set_cons(live);
        }
        if cl.windows() >= total_windows {
            break;
        }
        // Always re-search after a hold: chaos surfaces move, and a
        // drift firing mid-hold lands here anyway. Deterministic
        // re-seed per restart keeps the run replayable.
        restarts += 1;
        let reseed = seed ^ restarts.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        cl.restart(CoralOptimizer::new(space.clone(), cl.cons(), reseed));
    }
    cl.into_env()
}

/// The unarbitrated baseline: serve one fixed configuration through the
/// whole chaos run, never adapting (a PolyThrottle-style static preset;
/// see PAPERS.md). Recovery accounting runs identically — which is the
/// point: the static preset's records simply never close once an event
/// pushes its one config out of feasibility.
pub fn drive_static<E: Environment>(
    mut env: ChaosEnv<E>,
    cfg: HwConfig,
    total_windows: u64,
) -> ChaosEnv<E> {
    while env.windows() < total_windows {
        env.measure(cfg);
    }
    env
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::testkit::StepEnv;
    use crate::control::SimEnv;
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;

    fn loose_cons() -> Constraints {
        Constraints::dual(20.0, 8000.0)
    }

    #[test]
    fn empty_schedule_is_a_byte_identical_passthrough() {
        let mk = |seed| Device::new(DeviceKind::XavierNx, ModelKind::Yolo, seed);
        let mut plain = SimEnv::new(mk(11));
        let mut chaos = ChaosEnv::new(SimEnv::new(mk(11)), ChaosSchedule::new(), loose_cons());
        let cfgs: Vec<HwConfig> = {
            let space = plain.space().clone();
            let mut rng = crate::util::rng::Rng::new(3);
            (0..12).map(|_| space.random(&mut rng)).collect()
        };
        for cfg in cfgs {
            let a = plain.measure(cfg);
            let b = chaos.measure(cfg);
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "trajectories diverged");
        }
        assert_eq!(plain.cost_s(), chaos.cost_s());
        assert!(chaos.recoveries().is_empty());
        assert!(!chaos.history_dependent());
    }

    #[test]
    fn glitch_burst_corrupts_observations_not_the_surface() {
        let schedule = ChaosSchedule::new()
            .at(2, ChaosEvent::GlitchBurst { windows: 2, kind: GlitchKind::NonFinite });
        let mut env = ChaosEnv::new(StepEnv::constant(), schedule, loose_cons());
        let cfg = env.space().midpoint();
        for w in 0..6 {
            let m = env.measure(cfg);
            if w == 2 || w == 3 {
                assert!(m.throughput_fps.is_nan(), "window {w} must be glitched");
            } else {
                assert_eq!(m.throughput_fps, 30.0, "window {w} clean");
            }
            assert!(m.failed.is_none(), "a glitch is not a failure");
        }
    }

    #[test]
    fn stuck_at_glitch_reports_the_last_good_reading() {
        // A 30 → 15 fps step hidden behind a stuck sensor: the glitched
        // windows keep reporting 30 even though the surface moved.
        let schedule = ChaosSchedule::new()
            .at(1, ChaosEvent::GlitchBurst { windows: 2, kind: GlitchKind::StuckAt });
        let mut env = ChaosEnv::new(StepEnv::new(1), schedule, loose_cons());
        let cfg = env.space().midpoint();
        assert_eq!(env.measure(cfg).throughput_fps, 30.0);
        assert_eq!(env.measure(cfg).throughput_fps, 30.0, "stuck at the old level");
        assert_eq!(env.measure(cfg).throughput_fps, 30.0, "still stuck");
        assert_eq!(env.measure(cfg).throughput_fps, 15.0, "sensor unstuck, truth visible");
    }

    #[test]
    fn budget_step_moves_constraints_and_recovery_closes_on_refeasibility() {
        // StepEnv serves 30 fps at 5000 mW forever. Stepping the budget
        // to 4000 makes it infeasible (record stays open); stepping
        // back to 6000 re-closes it on the next window.
        let schedule = ChaosSchedule::new()
            .at(2, ChaosEvent::BudgetStep { budget_mw: 4000.0 })
            .at(5, ChaosEvent::BudgetStep { budget_mw: 6000.0 });
        let mut env = ChaosEnv::new(StepEnv::constant(), schedule, Constraints::dual(20.0, 8000.0));
        let cfg = env.space().midpoint();
        for _ in 0..8 {
            env.measure(cfg);
        }
        assert_eq!(env.current_constraints().power_budget_mw, Some(6000.0));
        let rec = env.recoveries();
        assert_eq!(rec.len(), 2);
        assert_eq!(rec[0].label, "budget(4000mW)");
        assert_eq!(
            rec[0].recovered_at,
            Some(5),
            "first budget step recovers only when the second lifts it"
        );
        assert_eq!(rec[1].windows(), Some(0), "second step is feasible immediately");
        assert!(env.all_recovered());
        assert!((env.mean_recovery_windows() - 1.5).abs() < 1e-12);
        assert_eq!(env.max_recovery_windows(), Some(3.0));
    }

    #[test]
    fn unrecovered_events_report_infinite_mean() {
        let schedule =
            ChaosSchedule::new().at(1, ChaosEvent::BudgetStep { budget_mw: 1.0 });
        let mut env = ChaosEnv::new(StepEnv::constant(), schedule, loose_cons());
        let cfg = env.space().midpoint();
        for _ in 0..5 {
            env.measure(cfg);
        }
        assert!(!env.all_recovered());
        assert!(env.mean_recovery_windows().is_infinite());
        assert!(env.history_dependent(), "non-empty schedule is history-dependent");
    }

    #[test]
    fn schedule_take_preserves_dropout_rejoins_and_fingerprints_differ() {
        let full = ChaosSchedule::new()
            .at(3, ChaosEvent::Dropout { member: 1, down_windows: 4 })
            .at(9, ChaosEvent::BudgetStep { budget_mw: 6000.0 });
        let cut = full.clone().take(1);
        assert_eq!(cut.len(), 1);
        // The kept Dropout still expands to down + rejoin.
        let env = ChaosEnv::new(StepEnv::constant(), cut.clone(), loose_cons());
        assert_eq!(env.timeline.len(), 2, "down + rejoin both survive a take");
        assert_ne!(full.fingerprint(), cut.fingerprint());
        assert_ne!(full.fingerprint(), ChaosSchedule::new().fingerprint());
    }
}
