//! Content-addressed measurement cache as an [`Environment`] layer.
//!
//! CORAL's whole cost model is measurement windows, yet every layer of
//! the repo used to re-pay full price for configurations it had already
//! measured: tenant rounds re-measure held allocations, drift restarts
//! re-probe the bootstrap presets, `fleet_sweep` re-runs overlapping
//! trajectories. [`CachedEnv`] is the decorator that makes repeated
//! proposals free online — the systems-level expression of the paper's
//! "near-optimal *without exhaustive profiling*" claim (what separates
//! CORAL from offline-profiling baselines like PolyThrottle).
//!
//! **Keying.** An entry is addressed by
//! ([`Environment::fingerprint`], epoch, applied [`HwConfig`]): the
//! fingerprint is a stable hash of the measurement surface's identity —
//! device/space grids (normalized [`crate::device::NormSpace`] grids
//! included), workload descriptor, window parameters, noise-seed
//! lineage — and the configuration is snapped onto the space's grid
//! first, so every proposal that would *apply* identically shares one
//! entry. The value is the full [`Measured`] window plus the
//! measurement cost it took: a hit returns byte-identical results and
//! charges **zero** [`Environment::cost_s`], so same-seed determinism
//! is preserved exactly and search-cost accounting stays honest.
//!
//! **Invalidation is epoch-based.** Every
//! [`super::DriftDetector`] firing — hold-phase or search-phase — calls
//! [`Environment::bump_epoch`], which advances this wrapper's epoch and
//! prunes its stale entries: nothing cached before a detected surface
//! shift can ever be returned after it. Epochs are **per wrapper**, so
//! under [`super::TenantArbiter`] a drift-restarted tenant invalidates
//! only its own entries and never its neighbours'.
//!
//! **What stays uncached.** Hold phases watch the surface for drift, so
//! [`super::ControlLoop::hold`] measures through
//! [`Environment::measure_fresh`]: the wrapper bypasses lookup, runs a
//! real window, and *refreshes* the stored entry — the cache can never
//! blind the very detector that invalidates it. Stateful aggregate
//! environments whose `measure` is not a pure function of the applied
//! configuration (the [`super::TenantArbiter`], whose measure advances
//! an arbitration round) must not be wrapped; wrap their *member*
//! environments instead.
//!
//! See EXPERIMENTS.md §Measurement cache for key derivation,
//! invalidation rules, and how to read the hit/cost-saved statistics,
//! and `bench_cache` for the cached-vs-uncached comparison.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::device::{ConfigSpace, Dim, HwConfig, Measured};

use super::env::Environment;

/// Hit/miss/cost accounting of a cache layer, as reported through
/// [`Environment::cache_stats`] and logged by
/// [`super::LoopEvent::Cache`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CacheStats {
    /// Lookups answered from the store (each one a measurement window
    /// never run).
    pub hits: u64,
    /// Lookups that fell through to a real measurement.
    pub misses: u64,
    /// Fresh measurements that overwrote an entry
    /// ([`Environment::measure_fresh`] — hold-phase windows).
    pub refreshes: u64,
    /// Measurement cost the hits avoided, in [`Environment::cost_s`]
    /// units (the sum of each hit entry's recorded miss cost).
    pub cost_saved_s: f64,
    /// Current invalidation epoch of the reporting wrapper (0 until the
    /// first drift-induced bump).
    pub epoch: u64,
}

impl CacheStats {
    /// Measurement windows the cache saved — one per hit.
    pub fn windows_saved(&self) -> u64 {
        self.hits
    }

    /// Lookups through the cached `measure` path (hits + misses;
    /// refreshes bypass lookup by design).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// hits / lookups (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.lookups();
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }

    /// Combine two stats (fleet members each wrapping their own cache);
    /// counters add, the epoch reports the most-invalidated member.
    pub fn merged(&self, other: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            refreshes: self.refreshes + other.refreshes,
            cost_saved_s: self.cost_saved_s + other.cost_saved_s,
            epoch: self.epoch.max(other.epoch),
        }
    }
}

/// Address of one cached window: surface fingerprint × invalidation
/// epoch × the configuration as it would be **applied** (snapped onto
/// the space grid, so off-grid aliases of one applied config share an
/// entry).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct CacheKey {
    fp: u64,
    epoch: u64,
    cfg: HwConfig,
}

/// One stored window: the full measurement plus what it cost, so a hit
/// can report exactly the cost it avoided.
#[derive(Debug, Clone, Copy)]
struct CacheEntry {
    measured: Measured,
    cost_s: f64,
}

#[derive(Default)]
struct StoreInner {
    map: HashMap<CacheKey, CacheEntry>,
    hits: u64,
    misses: u64,
    refreshes: u64,
    cost_saved_s: f64,
}

/// Shared, thread-safe backing store of one or more [`CachedEnv`]s.
///
/// Cloning shares the underlying map and counters — pass clones of one
/// store to many wrappers (a cached `fleet_sweep`, fleet members) and
/// repeated work across them is paid once. Entries are fully keyed by
/// (fingerprint, epoch, config), so wrappers over *different* surfaces
/// never read each other's windows — provided their environments'
/// [`Environment::fingerprint`]s faithfully identify those surfaces
/// (the default fingerprint hashes the configuration space alone; an
/// environment whose surface depends on more must override it before
/// its wrappers may share a store).
#[derive(Clone, Default)]
pub struct CacheStore(Arc<Mutex<StoreInner>>);

impl CacheStore {
    pub fn new() -> CacheStore {
        CacheStore::default()
    }

    /// Entries currently stored (all fingerprints, live epochs only —
    /// bumps prune).
    pub fn len(&self) -> usize {
        self.0.lock().expect("cache store poisoned").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Store-wide counters (epoch 0 — the store spans wrappers, each
    /// with its own epoch; [`CachedEnv::stats`] fills in the wrapper's).
    pub fn stats(&self) -> CacheStats {
        let inner = self.0.lock().expect("cache store poisoned");
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            refreshes: inner.refreshes,
            cost_saved_s: inner.cost_saved_s,
            epoch: 0,
        }
    }

    /// Hit path: return the stored window and account the avoided cost.
    fn lookup(&self, key: &CacheKey) -> Option<Measured> {
        let mut inner = self.0.lock().expect("cache store poisoned");
        match inner.map.get(key).copied() {
            Some(e) => {
                inner.hits += 1;
                inner.cost_saved_s += e.cost_s;
                Some(e.measured)
            }
            None => None,
        }
    }

    /// Miss path: store the freshly measured window.
    fn insert(&self, key: CacheKey, measured: Measured, cost_s: f64) {
        let mut inner = self.0.lock().expect("cache store poisoned");
        inner.misses += 1;
        inner.map.insert(key, CacheEntry { measured, cost_s });
    }

    /// Refresh path: overwrite (or create) the entry with a window that
    /// was deliberately measured fresh.
    fn refresh(&self, key: CacheKey, measured: Measured, cost_s: f64) {
        let mut inner = self.0.lock().expect("cache store poisoned");
        inner.refreshes += 1;
        inner.map.insert(key, CacheEntry { measured, cost_s });
    }

    /// Drop every entry of `fp` older than `epoch`. Other fingerprints
    /// — other tenants, other boards sharing this store — are untouched.
    fn prune(&self, fp: u64, epoch: u64) {
        let mut inner = self.0.lock().expect("cache store poisoned");
        inner.map.retain(|k, _| k.fp != fp || k.epoch >= epoch);
    }
}

/// 64-bit FNV-1a over little-endian words — a *stable* hash (the std
/// `Hasher` is randomized per process, which would make fingerprints,
/// and therefore cross-run cache behavior, nondeterministic).
pub fn stable_hash(words: &[u64]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

/// Stable fingerprint of a configuration space: device tag, normalized
/// flag, and every grid value of every dimension — so two spaces that
/// could decode one proposal differently (different native grids,
/// different [`crate::device::NormSpace`] unions) never share cache
/// entries. This is the *space identity* part of an environment
/// fingerprint; [`Environment::fingerprint`] implementations fold in
/// their workload/seed/window identity on top.
pub fn space_fingerprint(space: &ConfigSpace) -> u64 {
    let mut words = vec![space.device().id(), space.is_normalized() as u64];
    for &d in &Dim::ALL {
        let vals = space.values(d);
        words.push(vals.len() as u64);
        words.extend(vals.iter().map(|&v| v as u64));
    }
    stable_hash(&words)
}

/// The content-addressed, epoch-invalidated measurement cache: wrap any
/// [`Environment`] and repeated proposals are answered from the store,
/// byte-identical and at zero cost. See the module docs for semantics.
///
/// ```text
/// ControlLoop ── measure ──▶ CachedEnv ── miss ──▶ inner Environment
///                               │ hit                    │
///                               ◀── stored Measured ◀────┘
/// ```
pub struct CachedEnv<E: Environment> {
    inner: E,
    store: CacheStore,
    fp: u64,
    epoch: u64,
}

impl<E: Environment> CachedEnv<E> {
    /// Wrap `inner` over a private store.
    pub fn new(inner: E) -> CachedEnv<E> {
        CachedEnv::with_store(inner, CacheStore::new())
    }

    /// Wrap `inner` over a shared store (cached sweeps, fleets). The
    /// fingerprint is taken once, here: mutating the inner environment
    /// afterwards in ways that change its surface (noise scale, space)
    /// is the caller's responsibility to avoid — or to follow with
    /// [`Environment::bump_epoch`].
    pub fn with_store(inner: E, store: CacheStore) -> CachedEnv<E> {
        let fp = inner.fingerprint();
        CachedEnv { inner, store, fp, epoch: 0 }
    }

    /// Like [`CachedEnv::with_store`], additionally folding `salt` into
    /// the fingerprint. Callers sharing one store across many jobs use
    /// this when two jobs' environments could legitimately collide —
    /// e.g. the same (device, seed, workload) driven under different
    /// constraints, where concurrent first-misses would otherwise race
    /// on stateful noise ([`super::fleet::fleet_sweep_cached`] salts per
    /// scenario). Same salt across repeated passes keeps the replay
    /// property.
    pub fn with_store_salted(inner: E, store: CacheStore, salt: u64) -> CachedEnv<E> {
        let fp = stable_hash(&[inner.fingerprint(), salt]);
        CachedEnv { inner, store, fp, epoch: 0 }
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Current invalidation epoch (0 until the first drift bump).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The surface fingerprint entries are keyed under.
    pub fn fp(&self) -> u64 {
        self.fp
    }

    /// The backing store (shared or private).
    pub fn store(&self) -> &CacheStore {
        &self.store
    }

    /// This wrapper's view of the statistics: the (possibly shared)
    /// store counters, stamped with this wrapper's epoch.
    pub fn stats(&self) -> CacheStats {
        CacheStats { epoch: self.epoch, ..self.store.stats() }
    }

    fn key_for(&self, cfg: HwConfig) -> CacheKey {
        // Key on the configuration as the environment would apply it:
        // off-grid proposals snap (exactly like `Device::apply`), so
        // every alias of one applied config shares one entry.
        let applied = self.inner.space().snap_config(cfg.as_vec());
        CacheKey { fp: self.fp, epoch: self.epoch, cfg: applied }
    }
}

impl<E: Environment> Environment for CachedEnv<E> {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        // A history-dependent surface (thermal board, arbiter round
        // state) must never be answered from the store: a window
        // measured cold is not the window a hot board produces, and a
        // zero-cost hit would skip stepping the very state that makes
        // the surface history-dependent — freezing the temperature
        // trajectory. Checked per call, not at construction: faults
        // (`ThermalEnable`) can make an inner surface history-dependent
        // mid-run.
        if self.inner.history_dependent() {
            return self.measure_fresh(cfg);
        }
        let key = self.key_for(cfg);
        if let Some(m) = self.store.lookup(&key) {
            return m; // inner cost_s untouched: the hit charges zero.
        }
        let cost_before = self.inner.cost_s();
        let m = self.inner.measure(cfg);
        self.store.insert(key, m, self.inner.cost_s() - cost_before);
        m
    }

    /// Bypass lookup, run a real window, and overwrite the entry —
    /// hold-phase drift detection must observe the live surface, never
    /// the store.
    fn measure_fresh(&mut self, cfg: HwConfig) -> Measured {
        let key = self.key_for(cfg);
        let cost_before = self.inner.cost_s();
        let m = self.inner.measure_fresh(cfg);
        self.store.refresh(key, m, self.inner.cost_s() - cost_before);
        m
    }

    fn space(&self) -> &ConfigSpace {
        self.inner.space()
    }

    /// The inner environment's cost: hits never advance it, so the
    /// control loop's per-step cost deltas charge 0 for a hit with no
    /// special-casing anywhere.
    fn cost_s(&self) -> f64 {
        self.inner.cost_s()
    }

    /// Transparent decorator: same surface identity as the inner
    /// environment (wrapping twice keys identically).
    fn fingerprint(&self) -> u64 {
        self.fp
    }

    /// Drift-induced invalidation: advance the epoch and prune this
    /// surface's stale entries — no pre-epoch window can ever be
    /// returned again. Forwards to the inner environment (nested
    /// caches, fleet members).
    fn bump_epoch(&mut self) {
        self.epoch += 1;
        self.store.prune(self.fp, self.epoch);
        self.inner.bump_epoch();
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        Some(self.stats())
    }

    /// Transparent: the wrapper is history-dependent exactly when the
    /// surface underneath is (which is also what routes `measure`
    /// through `measure_fresh` above).
    fn history_dependent(&self) -> bool {
        self.inner.history_dependent()
    }

    fn inject_fault(&mut self, fault: &crate::control::chaos::ChaosFault) {
        self.inner.inject_fault(fault)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::testkit::StepEnv;
    use crate::control::SimEnv;
    use crate::device::{Device, DeviceKind, NormSpace};
    use crate::models::ModelKind;

    fn nx_env() -> SimEnv {
        SimEnv::new(Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 7))
    }

    #[test]
    fn stable_hash_is_stable_and_word_sensitive() {
        let a = stable_hash(&[1, 2, 3]);
        assert_eq!(a, stable_hash(&[1, 2, 3]), "same words, same hash");
        assert_ne!(a, stable_hash(&[1, 2, 4]));
        assert_ne!(a, stable_hash(&[1, 2]));
        assert_ne!(stable_hash(&[0]), stable_hash(&[]), "zero word is not absence");
    }

    #[test]
    fn space_fingerprints_distinguish_devices_and_encodings() {
        let nx = DeviceKind::XavierNx.space();
        let orin = DeviceKind::OrinNano.space();
        let norm = NormSpace::new(vec![nx.clone(), orin.clone()]).grid().clone();
        let fps = [space_fingerprint(&nx), space_fingerprint(&orin), space_fingerprint(&norm)];
        assert_eq!(fps[0], space_fingerprint(&DeviceKind::XavierNx.space()));
        assert_ne!(fps[0], fps[1]);
        assert_ne!(fps[0], fps[2]);
        assert_ne!(fps[1], fps[2]);
    }

    #[test]
    fn environment_fingerprints_distinguish_surfaces_sharing_a_space() {
        // Same NX space, different scripts/seeds — the fingerprint must
        // split them or a shared store would serve one surface's
        // windows for the other.
        let a = StepEnv::constant();
        let b = StepEnv::constant().with_levels(40.0, 40.0);
        let c = StepEnv::new(3);
        assert_eq!(a.fingerprint(), StepEnv::constant().fingerprint());
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        let d1 = SimEnv::new(Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1));
        let d2 = SimEnv::new(Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 2));
        let d3 = SimEnv::new(Device::new(DeviceKind::XavierNx, ModelKind::Frcnn, 1));
        assert_ne!(d1.fingerprint(), d2.fingerprint(), "noise seed lineage");
        assert_ne!(d1.fingerprint(), d3.fingerprint(), "workload descriptor");
        assert_ne!(a.fingerprint(), d1.fingerprint());
    }

    #[test]
    fn variant_manifests_split_fingerprints_and_never_cross_hit() {
        use crate::models::VariantManifest;
        // The manifest is part of the measurement surface: a degraded
        // variant's window depends on its multipliers and mAP, so two
        // devices with different manifests must never answer each
        // other's windows — even when their spaces fingerprint alike.
        let plain = SimEnv::new(Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 7));
        let std_manifest = ModelKind::Yolo.standard_variants();
        let varied = SimEnv::new(
            Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 7)
                .with_variants(std_manifest.clone()),
        );
        assert_ne!(plain.fingerprint(), varied.fingerprint(), "singleton vs 4-variant axis");
        // Same axis length, different content: the spaces are
        // indistinguishable, so only the manifest words can split them.
        let mut variants = std_manifest.variants().to_vec();
        variants[3].accuracy -= 0.5;
        let tweaked = VariantManifest::new(
            ModelKind::Yolo,
            variants,
            std_manifest.min_runnable_depth(),
        )
        .expect("lowering the last variant's mAP keeps the manifest monotone");
        let varied2 = SimEnv::new(
            Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 7).with_variants(tweaked),
        );
        assert_eq!(
            space_fingerprint(varied.space()),
            space_fingerprint(varied2.space()),
            "premise: the spaces alone cannot tell these surfaces apart",
        );
        assert_ne!(varied.fingerprint(), varied2.fingerprint(), "manifest content keys the surface");
        // And through a shared store: the same config measured under
        // each manifest is a miss both times — no cross-replay.
        let store = CacheStore::new();
        let mut c1 = CachedEnv::with_store(varied, store.clone());
        let mut c2 = CachedEnv::with_store(varied2, store.clone());
        let cfg = c1.space().midpoint();
        c1.measure(cfg);
        c2.measure(cfg);
        let stats = store.stats();
        assert_eq!((stats.hits, stats.misses), (0, 2), "no hits across manifests");
        assert_eq!(store.len(), 2, "one entry per manifest fingerprint");
    }

    #[test]
    fn hit_returns_byte_identical_window_at_zero_cost() {
        let mut cached = CachedEnv::new(nx_env());
        let cfg = cached.space().midpoint();
        let first = cached.measure(cfg);
        let cost_after_miss = cached.cost_s();
        let second = cached.measure(cfg);
        assert_eq!(first, second, "hit must be byte-identical");
        assert_eq!(cached.cost_s(), cost_after_miss, "hit charges zero cost");
        assert_eq!(cached.inner().device().windows_run(), 1, "one real window");
        let stats = cached.stats();
        assert_eq!((stats.hits, stats.misses, stats.refreshes), (1, 1, 0));
        assert_eq!(stats.windows_saved(), 1);
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
        assert!(stats.cost_saved_s > 0.0);
        assert!((stats.cost_saved_s - cost_after_miss).abs() < 1e-9);
    }

    #[test]
    fn off_grid_aliases_share_the_applied_entry() {
        let mut cached = CachedEnv::new(nx_env());
        let on_grid = cached.space().midpoint();
        let mut alias = on_grid;
        alias.cpu_freq_mhz += 1; // snaps back onto the same grid point
        let a = cached.measure(on_grid);
        let b = cached.measure(alias);
        assert_eq!(a, b);
        assert_eq!(cached.stats().hits, 1, "alias hit the applied entry");
    }

    #[test]
    fn measure_fresh_bypasses_and_refreshes() {
        // A shifting surface: the cache would happily serve the stale
        // 30-fps window forever; measure_fresh must see 15 fps and
        // leave the refreshed value behind for subsequent hits.
        let mut cached = CachedEnv::new(StepEnv::new(1));
        let cfg = cached.space().midpoint();
        assert_eq!(cached.measure(cfg).throughput_fps, 30.0);
        let fresh = cached.measure_fresh(cfg);
        assert_eq!(fresh.throughput_fps, 15.0, "fresh window sees the shift");
        assert_eq!(cached.measure(cfg).throughput_fps, 15.0, "entry refreshed");
        let stats = cached.stats();
        assert_eq!((stats.hits, stats.misses, stats.refreshes), (1, 1, 1));
    }

    #[test]
    fn thermal_board_behind_a_cache_never_replays_a_stale_window() {
        // Regression: `device_fingerprint` folds only the has_thermal
        // *flag*, not the temperature, so a cached thermal board used
        // to replay cold windows as hits forever — and hits (cost 0)
        // never stepped the thermal model, freezing the trajectory.
        // History-dependent surfaces must route through measure_fresh.
        let dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 7)
            .with_thermal(crate::device::thermal::ThermalModel::default());
        let mut cached = CachedEnv::new(SimEnv::new(dev));
        assert!(cached.history_dependent());
        let cfg = cached.space().snap_config([1.0; crate::device::HwConfig::NDIMS]);
        let mut cost = cached.cost_s();
        let mut windows = Vec::new();
        for _ in 0..40 {
            windows.push(cached.measure(cfg).throughput_fps);
            let now = cached.cost_s();
            assert!(now > cost, "every window ran for real (no zero-cost hit)");
            cost = now;
        }
        let stats = cached.stats();
        assert_eq!(stats.hits, 0, "a stale-temperature window must never replay");
        assert_eq!(stats.refreshes, 40, "every repeat re-measured the live surface");
        // The trajectory actually moves: sustained max-power windows
        // heat the board past the throttle knee, so later windows are
        // slower than the cold first one — visible only because no hit
        // froze the model.
        let hot = windows.last().copied().unwrap();
        assert!(
            hot < windows[0],
            "throttling must show up through the cache: first {} vs hot {hot}",
            windows[0]
        );
        // A thermal-free twin of the same device still caches normally.
        let mut plain =
            CachedEnv::new(SimEnv::new(Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 7)));
        assert!(!plain.history_dependent());
        plain.measure(cfg);
        plain.measure(cfg);
        assert_eq!(plain.stats().hits, 1);
    }

    #[test]
    fn bump_epoch_prunes_this_surface_only() {
        let store = CacheStore::new();
        let mut a = CachedEnv::with_store(StepEnv::constant(), store.clone());
        let mut b =
            CachedEnv::with_store(StepEnv::constant().with_levels(40.0, 40.0), store.clone());
        let cfg = a.space().midpoint();
        a.measure(cfg);
        b.measure(cfg);
        assert_eq!(store.len(), 2);
        a.bump_epoch();
        assert_eq!(a.epoch(), 1);
        assert_eq!(b.epoch(), 0, "neighbour epoch untouched");
        assert_eq!(store.len(), 1, "only a's entry pruned");
        assert_eq!(b.measure(cfg).throughput_fps, 40.0);
        assert_eq!(store.stats().hits, 1, "b still hits after a's bump");
        // a re-measures under the new epoch: a miss, never the old entry.
        a.measure(cfg);
        assert_eq!(store.stats().misses, 3);
    }

    #[test]
    fn boxed_cached_env_forwards_through_the_trait_object() {
        let mut env: Box<dyn Environment + Send> = Box::new(CachedEnv::new(StepEnv::constant()));
        let cfg = env.space().midpoint();
        let a = env.measure(cfg);
        let b = env.measure(cfg);
        assert_eq!(a, b);
        let stats = env.cache_stats().expect("cache layer visible through the box");
        assert_eq!((stats.hits, stats.misses), (1, 1));
        env.bump_epoch();
        assert_eq!(env.cache_stats().expect("still cached").epoch, 1);
        assert!(StepEnv::constant().cache_stats().is_none(), "uncached env reports none");
    }

    #[test]
    fn shared_store_pays_repeated_work_once_across_wrappers() {
        // Two same-surface wrappers over one store (a repeat-heavy
        // sweep in miniature): the second pays nothing.
        let store = CacheStore::new();
        let mk = || SimEnv::new(Device::new(DeviceKind::OrinNano, ModelKind::Frcnn, 3));
        let mut first = CachedEnv::with_store(mk(), store.clone());
        let cfgs: Vec<HwConfig> = {
            let mut rng = crate::util::Rng::new(11);
            (0..6).map(|_| first.space().random(&mut rng)).collect()
        };
        let pass1: Vec<Measured> = cfgs.iter().map(|&c| first.measure(c)).collect();
        let mut second = CachedEnv::with_store(mk(), store.clone());
        let pass2: Vec<Measured> = cfgs.iter().map(|&c| second.measure(c)).collect();
        assert_eq!(pass1, pass2, "second wrapper replays the first byte-for-byte");
        assert_eq!(second.inner().device().windows_run(), 0, "no real window on pass 2");
        assert!(store.stats().hits >= 6);
    }
}
