//! Closed-loop control: the one canonical drive loop between any
//! [`Optimizer`](crate::optimizer::Optimizer) and any measurement
//! [`Environment`].
//!
//! The paper's whole point is *online* optimization of a live serving
//! stack; this module is where "online" actually lives:
//!
//! * [`Environment`] abstracts measurement — the simulated board
//!   ([`SimEnv`]), the real serving stack with sim-backed power
//!   ([`LiveEnv`]), or a whole fleet of boards per observation
//!   ([`FleetEnv`]).
//! * [`ControlLoop`] owns the drive loop every experiment, the CLI, and
//!   the examples used to hand-roll: budget, first-feasible tracking,
//!   uniform search-cost accounting, trace recording, an event log, and
//!   hold phases with windowed-throughput drift detection that
//!   re-trigger search.
//! * [`FleetRunner`] / [`fleet_sweep`] run many independent loops
//!   thread-parallel with deterministic per-job seeding — results are
//!   byte-identical to the sequential run, only faster.

pub mod engine;
pub mod env;
pub mod fleet;

pub use engine::{
    ControlLoop, ControlLoopConfig, DriftConfig, DriftDetector, HoldOutcome, LoopEvent,
    LoopOutcome, Step, DEFAULT_BUDGET,
};
pub use env::{Environment, FleetEnv, LiveEnv, SimEnv};
pub use fleet::{fleet_sweep, FleetRunner, FleetStats};
