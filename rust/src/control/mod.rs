//! Closed-loop control: the one canonical drive loop between any
//! [`Optimizer`](crate::optimizer::Optimizer) and any measurement
//! [`Environment`].
//!
//! The loop, in one line (ARCHITECTURE.md draws it in full):
//!
//! ```text
//! Environment ⇄ ControlLoop ⇄ Optimizer, composed by TenantArbiter,
//! fronted on the live path by coordinator::Router
//! ```
//!
//! The paper's whole point is *online* optimization of a live serving
//! stack; this module is where "online" actually lives:
//!
//! * [`Environment`] abstracts measurement — the simulated board
//!   ([`SimEnv`]), the real serving stack with sim-backed power
//!   ([`LiveEnv`]), or a whole fleet of boards per observation
//!   ([`FleetEnv`] — including mixed NX/Orin fleets searched through
//!   the normalized [`crate::device::NormSpace`] grid; EXPERIMENTS.md
//!   §Heterogeneous fleets).
//! * [`CachedEnv`] wraps any environment in the content-addressed
//!   measurement cache ([`cache`]): repeated proposals are answered
//!   byte-identically from the store at zero cost, and
//!   [`DriftDetector`] firings bump an epoch that invalidates stale
//!   entries (EXPERIMENTS.md §Measurement cache).
//! * [`ControlLoop`] owns the drive loop every experiment, the CLI, and
//!   the examples used to hand-roll: budget, first-feasible tracking,
//!   uniform search-cost accounting, trace recording, an event log, and
//!   hold phases with windowed-throughput drift detection that
//!   re-trigger search.
//! * [`FleetPool`] is the persistent work-stealing pool every parallel
//!   path above dispatches on — workers spawn once, every later batch
//!   is O(1)-dispatch index jobs, and results are byte-identical to
//!   sequential for every worker count and steal schedule
//!   (EXPERIMENTS.md §Fleet-scale sweeps).
//! * [`FleetRunner`] / [`fleet_sweep`] run many independent loops
//!   pool-parallel with deterministic per-job seeding — results are
//!   byte-identical to the sequential run, only faster.
//! * [`TenantArbiter`] arbitrates several loops sharing one power
//!   envelope: per-round budget splitting (static / demand-weighted /
//!   water-filling), one `ControlLoop` per tenant against its
//!   sub-budget, fleet-combined per-round observations.
//!
//! Test builds additionally expose `control::testkit` (scripted
//! environments shared by unit tests, integration tests, and benches;
//! gated behind `cfg(any(test, feature = "testkit"))`).

pub mod cache;
pub mod chaos;
pub mod engine;
pub mod env;
pub mod fleet;
pub mod pool;
pub mod tenant;
#[cfg(any(test, feature = "testkit"))]
pub mod testkit;

pub use cache::{CacheStats, CacheStore, CachedEnv};
pub use chaos::{
    drive_coral, drive_static, ChaosEnv, ChaosEvent, ChaosFault, ChaosSchedule, GlitchKind,
    RecoveryRecord, CHAOS_HOLD_WINDOWS,
};
pub use engine::{
    ControlLoop, ControlLoopConfig, DriftConfig, DriftDetector, HoldOutcome, LoopEvent,
    LoopOutcome, Step, DEFAULT_BUDGET, MAX_SEARCH_RESTARTS,
};
pub use env::{Environment, FleetEnv, LiveEnv, SimEnv};
pub use fleet::{fleet_sweep, fleet_sweep_cached, FleetRunner, FleetStats};
pub use pool::{auto_workers, BatchTicket, FleetPool, PoolWatcher};
pub use tenant::{
    BudgetPolicy, RoundReport, Tenant, TenantArbiter, TenantRound, MAX_DRIFT_RESTARTS,
    WATERFILL_HEADROOM,
};
