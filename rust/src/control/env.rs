//! Measurement environments: where an optimizer's proposals get turned
//! into observed (throughput, power) windows.
//!
//! The paper evaluates on physical Jetson boards; this repo historically
//! only ever measured the simulator, with the drive loop copy-pasted at
//! every call site. [`Environment`] makes the measurement side a trait,
//! so the one canonical [`super::ControlLoop`] drives:
//!
//! * [`SimEnv`] — the simulated Jetson ([`Device`]); cost is simulated
//!   seconds.
//! * [`LiveEnv`] — the real serving stack ([`Server`]): proposals apply
//!   their concurrency level to the live worker pool, throughput is
//!   sampled from served traffic through [`Sampler`] with the paper's
//!   warm-up discipline, power comes from the device model (a dev box
//!   has no INA3221 power rails), and the whole thing degrades
//!   gracefully to sim-backed windows when no PJRT artifacts exist.
//! * [`FleetEnv`] — many boards measured per proposal (one batch of
//!   member-index jobs on a persistent [`super::FleetPool`]), observing
//!   fleet-mean metrics. Members with different configuration spaces
//!   (mixed NX/Orin) make the fleet heterogeneous: it searches the
//!   normalized [`NormSpace`] grid and decodes each proposal per member
//!   (EXPERIMENTS.md §Heterogeneous fleets, §Fleet-scale sweeps).
//!
//! Any of these can additionally be wrapped in [`super::CachedEnv`] —
//! the content-addressed measurement cache ([`super::cache`]) — which
//! answers repeated proposals from its store at zero cost. The trait's
//! cache hooks ([`Environment::measure_fresh`],
//! [`Environment::fingerprint`], [`Environment::bump_epoch`],
//! [`Environment::cache_stats`]) all have pass-through defaults, so
//! plain environments are unaffected.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::{Server, ServerConfig, ServeReport};
use crate::device::failure::FailureKind;
use crate::device::sim::SAMPLES_PER_WINDOW;
use crate::device::{ConfigSpace, Device, DeviceKind, HwConfig, Measured, NormSpace};

use super::pool::{lock, FleetPool};
use crate::models::{artifacts_dir, Manifest, ModelKind};
use crate::runtime::PjrtRuntime;
use crate::telemetry::{Sample, Sampler};
use crate::workload::{ArrivalProfile, VideoSource};

/// A place where hardware configurations can be applied and measured.
///
/// One `measure` call is one of the paper's measurement windows: apply
/// the configuration, warm up, observe aggregated throughput and power.
pub trait Environment {
    /// Apply `cfg` and run one measurement window.
    fn measure(&mut self, cfg: HwConfig) -> Measured;

    /// The configuration space proposals must come from.
    fn space(&self) -> &ConfigSpace;

    /// Total measurement cost so far, in seconds. Simulated environments
    /// report simulated seconds; live ones report wall-clock spent
    /// serving. The control loop reports per-search deltas of this, so
    /// search cost is accounted uniformly (no more ad-hoc
    /// `sim_clock_s()` reads at call sites).
    fn cost_s(&self) -> f64;

    /// Measure without consulting any cache layer. For plain
    /// environments this *is* [`Environment::measure`]; a
    /// [`super::CachedEnv`] overrides it to bypass lookup, run a real
    /// window and refresh the stored entry. [`super::ControlLoop::hold`]
    /// measures through this, so hold-phase drift detection always
    /// observes the live surface.
    fn measure_fresh(&mut self, cfg: HwConfig) -> Measured {
        self.measure(cfg)
    }

    /// Stable identity of this measurement surface, used to key cache
    /// entries ([`super::cache`]). Two environments whose `measure`
    /// could answer the same configuration differently must report
    /// different fingerprints before their [`super::CachedEnv`]
    /// wrappers may share a [`super::CacheStore`].
    ///
    /// The default hashes the configuration space alone (device tag,
    /// normalized flag, every grid value) — correct only for
    /// environments fully determined by their space. [`SimEnv`],
    /// [`LiveEnv`], [`FleetEnv`] and the testkit's scripted
    /// environments all override it to fold in workload, seed lineage,
    /// window parameters and script state; custom environments sharing
    /// a store should do the same.
    fn fingerprint(&self) -> u64 {
        super::cache::space_fingerprint(self.space())
    }

    /// Advance the cache-invalidation epoch after a detected surface
    /// shift ([`super::DriftDetector`] firings). No-op for uncached
    /// environments; [`super::CachedEnv`] prunes its stale entries,
    /// aggregates ([`FleetEnv`], [`super::TenantArbiter`]) forward to
    /// their members.
    fn bump_epoch(&mut self) {}

    /// Cache accounting of this environment, when a cache layer is
    /// present anywhere in its composition (None otherwise — which is
    /// how the control loop knows not to log cache events for plain
    /// environments).
    fn cache_stats(&self) -> Option<super::CacheStats> {
        None
    }

    /// Whether this surface's answers depend on measurement *history*
    /// (a thermal board whose temperature integrates past windows, a
    /// multi-tenant arbiter whose round state evolves) rather than on
    /// the configuration alone. A [`super::CachedEnv`] must never
    /// replay a stored window for such a surface — a window measured
    /// cold is simply not the window a hot board would produce, and a
    /// zero-cost hit would freeze the very state (temperature) that
    /// makes the surface history-dependent. `CachedEnv` therefore
    /// routes these through [`Environment::measure_fresh`]
    /// unconditionally. Default: false (pure config→window surfaces).
    fn history_dependent(&self) -> bool {
        false
    }

    /// Deliver one injected fault ([`super::chaos::ChaosFault`]) to
    /// this surface. Environments ignore faults that don't apply to
    /// them (the default ignores everything): device-backed
    /// environments handle thermal faults, [`FleetEnv`] handles member
    /// dropout/rejoin and forwards the rest to every member,
    /// decorators forward to their inner environment. Called by the
    /// [`super::chaos::ChaosEnv`] decorator when its schedule fires.
    fn inject_fault(&mut self, _fault: &super::chaos::ChaosFault) {}
}

/// The simulated Jetson board as an [`Environment`].
#[derive(Debug, Clone)]
pub struct SimEnv {
    dev: Device,
    /// Open-loop offered load (None = the paper's closed-loop windows).
    arrival: Option<ArrivalProfile>,
}

impl SimEnv {
    pub fn new(dev: Device) -> SimEnv {
        SimEnv { dev, arrival: None }
    }

    /// Measure every window under an open-loop offered load: the rate
    /// the profile holds at the window's (simulated) start time queues
    /// against the config's capacity (`device::sim::under_offered_load`)
    /// — p99 latency becomes the load-dependent signal the SLO
    /// constraint reads.
    pub fn under_load(mut self, profile: ArrivalProfile) -> SimEnv {
        self.arrival = Some(profile);
        self
    }

    /// The active arrival profile, if any.
    pub fn arrival(&self) -> Option<&ArrivalProfile> {
        self.arrival.as_ref()
    }

    /// The underlying simulated device (thermal state, window counts).
    pub fn device(&self) -> &Device {
        &self.dev
    }

    pub fn device_mut(&mut self) -> &mut Device {
        &mut self.dev
    }

    pub fn into_device(self) -> Device {
        self.dev
    }
}

impl Environment for SimEnv {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        match &self.arrival {
            Some(p) => {
                // The window's offered rate is the profile's rate at the
                // moment the window starts (simulated clock = logical
                // arrival time), so diurnal/flash phases play out over a
                // long search exactly as they would against a wall clock.
                let rate = p.rate_at(self.dev.sim_clock_s());
                self.dev.run_under_load(cfg, rate)
            }
            None => self.dev.run(cfg),
        }
    }

    fn space(&self) -> &ConfigSpace {
        self.dev.space()
    }

    fn cost_s(&self) -> f64 {
        self.dev.sim_clock_s()
    }

    /// Space identity + workload + noise-seed lineage + window
    /// parameters — everything that shapes what a window can return.
    /// Thermal devices additionally fold in the flag so their
    /// history-dependent surface never shares entries with a
    /// thermal-free twin, and an offered-load profile folds in its
    /// full shape (rate, phase schedule, seed): windows measured under
    /// different traffic must never answer for each other.
    fn fingerprint(&self) -> u64 {
        let dev = device_fingerprint(&self.dev);
        match &self.arrival {
            Some(p) => super::cache::stable_hash(&[dev, p.fingerprint()]),
            None => dev,
        }
    }

    /// A thermal board's windows depend on its temperature trajectory.
    fn history_dependent(&self) -> bool {
        self.dev.has_thermal()
    }

    fn inject_fault(&mut self, fault: &super::chaos::ChaosFault) {
        apply_device_fault(&mut self.dev, fault);
    }
}

/// Apply a fault to a simulated device: the thermal family acts on its
/// [`crate::device::thermal::ThermalModel`] (shared by [`SimEnv`] and
/// [`LiveEnv`], whose power/DVFS side is this device); everything else
/// is someone else's fault to handle and is ignored.
fn apply_device_fault(dev: &mut Device, fault: &super::chaos::ChaosFault) {
    use super::chaos::ChaosFault;
    match fault {
        ChaosFault::ThermalEnable { model } => dev.enable_thermal(model.clone()),
        ChaosFault::HeatSoak { power_mw, dt_s } => {
            if let Some(t) = dev.thermal_mut() {
                t.step(*power_mw, *dt_s);
            }
        }
        ChaosFault::AmbientShift { delta_c } => {
            if let Some(t) = dev.thermal_mut() {
                t.ambient_c += delta_c;
            }
        }
        ChaosFault::MemberDown { .. } | ChaosFault::MemberUp { .. } => {}
    }
}

/// Cache identity of one simulated device (shared by [`SimEnv`] and
/// [`LiveEnv`], whose power/DVFS side is this device). The variant
/// manifest's full content is folded in: two devices whose spaces look
/// identical but whose manifests model different accuracy/cost surfaces
/// must never answer each other's windows from a shared store.
fn device_fingerprint(dev: &Device) -> u64 {
    let mut words = vec![
        super::cache::space_fingerprint(dev.space()),
        dev.kind().id(),
        dev.model().id(),
        dev.seed(),
        dev.noise_scale().to_bits(),
        dev.has_thermal() as u64,
        crate::device::sim::WARMUP_S.to_bits(),
        SAMPLES_PER_WINDOW as u64,
    ];
    words.extend(dev.manifest().content_words());
    super::cache::stable_hash(&words)
}

/// Boxed environments measure through the same trait like any concrete
/// environment — the multi-tenant arbiter drives a heterogeneous
/// sim/live mix as `Box<dyn Environment + Send>`.
impl<E: Environment + ?Sized> Environment for Box<E> {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        (**self).measure(cfg)
    }

    fn space(&self) -> &ConfigSpace {
        (**self).space()
    }

    fn cost_s(&self) -> f64 {
        (**self).cost_s()
    }

    fn measure_fresh(&mut self, cfg: HwConfig) -> Measured {
        (**self).measure_fresh(cfg)
    }

    fn fingerprint(&self) -> u64 {
        (**self).fingerprint()
    }

    fn bump_epoch(&mut self) {
        (**self).bump_epoch()
    }

    fn cache_stats(&self) -> Option<super::CacheStats> {
        (**self).cache_stats()
    }

    fn history_dependent(&self) -> bool {
        (**self).history_dependent()
    }

    fn inject_fault(&mut self, fault: &super::chaos::ChaosFault) {
        (**self).inject_fault(fault)
    }
}

/// The live serving stack behind [`LiveEnv`].
struct LiveBackend {
    server: Server,
    video: VideoSource,
}

/// The live serving stack as an [`Environment`].
///
/// Each `measure` applies the proposal's concurrency level to the real
/// worker pool, serves synthetic traffic video in sample-sized chunks,
/// and records per-chunk throughput into a [`Sampler`] (first two
/// chunks after a reconfiguration discarded — the paper's 2-sample
/// warm-up discipline). The serving pump underneath is event-driven
/// (`Server::run_closed_loop` blocks on worker completions bounded by
/// the batcher deadline), so a live measurement window costs **zero
/// busy-wait** — the pump cannot pollute the very throughput/power
/// signal being correlated. [`LiveEnv::pump_iterations`] exposes the
/// cumulative wakeup accounting.
/// Power always comes from the device model's DVFS state: a development
/// box has no module power rails, so the simulator is the wattmeter.
///
/// Without AOT artifacts / a PJRT backend there is no server to drive;
/// the environment then degrades to fully sim-backed windows so every
/// caller keeps working (see [`LiveEnv::auto`]).
pub struct LiveEnv {
    /// DVFS + power model; also the throughput fallback without PJRT.
    sim: Device,
    backend: Option<LiveBackend>,
    sampler: Sampler,
    frames_per_sample: u64,
    inflight: usize,
    serving_wall_s: f64,
    /// Cumulative serving-pump wakeups across all live windows.
    pump_iterations: u64,
    last_report: Option<ServeReport>,
    /// Open-loop offered load (None = closed-loop windows).
    arrival: Option<ArrivalProfile>,
    /// Logical seconds of offered-load exposure so far (drives the
    /// profile's phase schedule across successive windows).
    arrival_clock_s: f64,
}

impl LiveEnv {
    /// Degraded mode: every window is answered by the device simulator.
    pub fn sim_backed(sim: Device) -> LiveEnv {
        LiveEnv {
            sim,
            backend: None,
            // The paper's measurement discipline: 2 warm-up samples
            // discarded after every reconfiguration, then the retained
            // window (Sampler::paper_default's shape).
            sampler: Sampler::new(2, SAMPLES_PER_WINDOW),
            frames_per_sample: 12,
            inflight: 8,
            serving_wall_s: 0.0,
            pump_iterations: 0,
            last_report: None,
            arrival: None,
            arrival_clock_s: 0.0,
        }
    }

    /// Measure every window under an open-loop offered load (same
    /// contract as [`SimEnv::under_load`]): the closed-loop window
    /// establishes the config's service capacity, then the offered rate
    /// queues against it deterministically.
    pub fn under_load(mut self, profile: ArrivalProfile) -> LiveEnv {
        self.arrival = Some(profile);
        self
    }

    /// The active arrival profile, if any.
    pub fn arrival(&self) -> Option<&ArrivalProfile> {
        self.arrival.as_ref()
    }

    /// Live mode over an already-built server. `video` must match the
    /// server's model input side.
    pub fn with_server(sim: Device, server: Server, video: VideoSource) -> LiveEnv {
        assert_eq!(
            video.side(),
            server.input_side(),
            "video side must match the served model input"
        );
        let mut env = LiveEnv::sim_backed(sim);
        env.backend = Some(LiveBackend { server, video });
        env
    }

    /// Build the live stack when AOT artifacts + a PJRT backend exist,
    /// degrading to [`LiveEnv::sim_backed`] (with a logged reason)
    /// otherwise.
    pub fn auto(kind: DeviceKind, model: ModelKind, seed: u64, cfg: ServerConfig) -> LiveEnv {
        let sim = Device::new(kind, model, seed);
        match Self::try_backend(model, seed, cfg) {
            Ok(backend) => {
                let mut env = LiveEnv::sim_backed(sim);
                env.backend = Some(backend);
                env
            }
            Err(e) => {
                log::warn!("live serving unavailable ({e}); measuring sim-backed");
                LiveEnv::sim_backed(sim)
            }
        }
    }

    fn try_backend(model: ModelKind, seed: u64, cfg: ServerConfig) -> anyhow::Result<LiveBackend> {
        let manifest = Manifest::load(&artifacts_dir())?;
        let rt = PjrtRuntime::cpu()?;
        let model_rt = rt.load_model(&manifest, model)?;
        let side = model_rt.input_side();
        Ok(LiveBackend {
            server: Server::new(model_rt, cfg),
            video: VideoSource::new(side, 30, seed),
        })
    }

    /// Frames served per telemetry sample (per chunk of the closed loop).
    pub fn frames_per_sample(mut self, frames: u64) -> LiveEnv {
        self.frames_per_sample = frames.max(1);
        self
    }

    /// Outstanding frames kept in flight while serving.
    pub fn inflight(mut self, inflight: usize) -> LiveEnv {
        self.inflight = inflight.max(1);
        self
    }

    /// Whether a real serving stack answers measurements.
    pub fn is_live(&self) -> bool {
        self.backend.is_some()
    }

    /// The device model supplying DVFS state and power.
    pub fn device(&self) -> &Device {
        &self.sim
    }

    /// Serving report of the most recent live chunk.
    pub fn last_report(&self) -> Option<&ServeReport> {
        self.last_report.as_ref()
    }

    /// Cumulative serving-pump wakeups across all live windows. With
    /// the event-driven pump this is bounded by completions + batcher
    /// deadline fires — never wall-clock — which is what "a live window
    /// costs zero busy-wait" means operationally. Always 0 sim-backed.
    pub fn pump_iterations(&self) -> u64 {
        self.pump_iterations
    }

    /// Serve `frames` at `cfg` in steady state on the live stack.
    /// Returns `None` when sim-backed (or when serving fails).
    pub fn steady_state(&mut self, cfg: HwConfig, frames: u64) -> Option<ServeReport> {
        let applied = self.sim.apply(cfg);
        let b = self.backend.as_mut()?;
        b.server.set_concurrency(applied.concurrency as usize);
        b.server.reset_window_metrics();
        match b.server.run_closed_loop(&mut b.video, frames, self.inflight) {
            Ok(report) => {
                self.pump_iterations += report.pump_iterations;
                Some(report)
            }
            Err(e) => {
                log::warn!("steady-state serving failed: {e}");
                None
            }
        }
    }

    /// Shut the serving stack down; total frames served when live.
    pub fn shutdown(self) -> Option<u64> {
        self.backend.map(|b| b.server.shutdown())
    }

    /// Apply the offered-load transform (if any) to a finished window
    /// and advance the logical arrival clock by one window span, so the
    /// profile's phase schedule plays out across successive windows.
    fn finish_window(&mut self, m: Measured) -> Measured {
        let Some(p) = &self.arrival else { return m };
        let rate = p.rate_at(self.arrival_clock_s);
        self.arrival_clock_s += crate::device::sim::WARMUP_S + SAMPLES_PER_WINDOW as f64;
        crate::device::sim::under_offered_load(
            m,
            rate,
            self.sim.kind().model_params().static_mw,
        )
    }
}

impl Environment for LiveEnv {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        // The sim layer first: it applies/snaps the DVFS state, models
        // power, and catches config failures before they hit the server.
        let sim_m = self.sim.run(cfg);
        // A stale serving report must not outlive the window it belongs
        // to: windows answered without serving (sim-backed, vetoed
        // config) report no live stats.
        self.last_report = None;
        // Vetoed configs never reach the server: the device model
        // detects the failure instantly, so in live mode they genuinely
        // cost ~no wall-clock (on physical hardware the crash would
        // consume a window — the sim clock still records that view).
        if self.backend.is_none() || sim_m.failed.is_some() {
            return self.finish_window(sim_m);
        }
        let backend = self.backend.as_mut().expect("live mode checked above");

        backend.server.set_concurrency(sim_m.config.concurrency as usize);
        self.sampler.reset(); // reconfiguration restarts warm-up
        let t0 = Instant::now();
        let mut lat_ms_sum = 0.0;
        let mut p99_ms_sum = 0.0;
        let mut lat_chunks = 0u32;
        while self.sampler.len() < SAMPLES_PER_WINDOW {
            // Percentiles must describe this chunk, not the server's
            // lifetime — reset the distribution buffers per chunk.
            backend.server.reset_window_metrics();
            match backend.server.run_closed_loop(
                &mut backend.video,
                self.frames_per_sample,
                self.inflight,
            ) {
                Ok(report) => {
                    self.pump_iterations += report.pump_iterations;
                    let retained = self.sampler.record(Sample {
                        throughput_fps: report.throughput_fps,
                        power_mw: sim_m.power_mw,
                        gpu_util: sim_m.gpu_util,
                        cpu_util: sim_m.cpu_util,
                        mem_util: sim_m.mem_util,
                    });
                    if retained {
                        // Window latency aggregates the retained chunks,
                        // same discipline as throughput.
                        lat_ms_sum += report.latency_p50_ms;
                        p99_ms_sum += report.latency_p99_ms;
                        lat_chunks += 1;
                    }
                    self.last_report = Some(report);
                }
                Err(e) => {
                    log::warn!("live measurement failed ({e}); falling back to sim window");
                    self.serving_wall_s += t0.elapsed().as_secs_f64();
                    // The aborted window's partial chunks are not this
                    // window's stats: the returned measurement is
                    // sim-backed, so report no live stats for it.
                    self.last_report = None;
                    return self.finish_window(sim_m);
                }
            }
        }
        self.serving_wall_s += t0.elapsed().as_secs_f64();
        let w = self.sampler.window().expect("retained samples exist");
        let m = Measured {
            config: sim_m.config,
            throughput_fps: w.throughput_fps,
            power_mw: sim_m.power_mw,
            latency_ms: if lat_chunks > 0 {
                lat_ms_sum / lat_chunks as f64
            } else {
                sim_m.latency_ms
            },
            p99_latency_ms: if lat_chunks > 0 {
                p99_ms_sum / lat_chunks as f64
            } else {
                sim_m.p99_latency_ms
            },
            gpu_util: sim_m.gpu_util,
            cpu_util: sim_m.cpu_util,
            mem_util: sim_m.mem_util,
            accuracy: sim_m.accuracy,
            failed: None,
        };
        self.finish_window(m)
    }

    fn space(&self) -> &ConfigSpace {
        self.sim.space()
    }

    fn cost_s(&self) -> f64 {
        if self.backend.is_some() {
            self.serving_wall_s
        } else {
            self.sim.sim_clock_s()
        }
    }

    /// The sim device's identity plus the live serving knobs — and the
    /// live/degraded flag itself, since the two modes answer windows
    /// from different surfaces. An offered-load profile folds in its
    /// full shape: traffic changes every number a window reports.
    fn fingerprint(&self) -> u64 {
        super::cache::stable_hash(&[
            device_fingerprint(&self.sim),
            self.is_live() as u64,
            self.frames_per_sample,
            self.inflight as u64,
            self.arrival.as_ref().map_or(0, |p| p.fingerprint()),
        ])
    }

    /// The power/DVFS side is the sim device; thermal state there makes
    /// the whole live surface history-dependent.
    fn history_dependent(&self) -> bool {
        self.sim.has_thermal()
    }

    fn inject_fault(&mut self, fault: &super::chaos::ChaosFault) {
        apply_device_fault(&mut self.sim, fault);
    }
}

/// A fleet of boards measured together, as an [`Environment`].
///
/// One proposal is applied to every member; the observation the
/// optimizer sees is the fleet mean (a config that crashes any member is
/// prohibited fleet-wide). Members are measured as one index-slotted
/// batch on the fleet's persistent pool and aggregated by the pairwise
/// tree combine — sharded or flat, parallel or sequential, the numbers
/// are byte-identical; thread timing and steal schedules can change
/// wall-clock, never numbers.
///
/// **Heterogeneous fleets.** Members may carry *different*
/// [`ConfigSpace`]s (mixed NX/Orin boards, or scripted test members).
/// The fleet then exposes the shared [`NormSpace`] grid — per-dimension
/// rank fractions, the encoding that lets one distance-correlation
/// surface span heterogeneous hardware — and decodes every proposal per
/// member onto that member's native grid before measuring
/// ([`NormSpace::decode_for`]). Decoding is pure and aggregation is
/// unchanged, so parallel == sequential byte-identity is preserved.
///
/// Measurement runs on a persistent [`FleetPool`], built lazily at the
/// first parallel window and reused for the fleet's whole lifetime —
/// zero thread spawns per proposal, O(1) per-member dispatch (each pool
/// job is a member index; its native config decodes inside the job,
/// which is pure and therefore schedule-independent). That is what
/// makes 10,000-member fleets practical where thread-per-member was not
/// (`bench_fleet_scale`, EXPERIMENTS.md §Fleet-scale sweeps).
pub struct FleetEnv {
    /// Members behind per-member locks: pool jobs measure them in place
    /// (each batch index is claimed exactly once, so every lock is
    /// uncontended), and the `Arc` is what lets the pool's `'static`
    /// jobs borrow nothing from the fleet.
    members: Arc<Vec<Mutex<Box<dyn Environment + Send>>>>,
    /// The space proposals come from: the members' shared native grid
    /// for a homogeneous fleet, the normalized grid for a mixed one.
    space: ConfigSpace,
    /// Mixed-space decoding (None = homogeneous fleet; proposals pass
    /// through to members untouched).
    norm: Option<Arc<NormSpace>>,
    parallel: bool,
    /// Pinned pool size (None = [`FleetPool::auto`]'s choice).
    workers: Option<usize>,
    /// Lazily-built persistent pool; `spawned_threads` never moves once
    /// this exists.
    pool: Option<FleetPool>,
    /// Per-member dropout flags (chaos injection / operator action): a
    /// down member is not measured — its round observation is the
    /// synthetic [`dropped_window`] and the fleet aggregate is computed
    /// over the survivors. `Arc`'d alongside `members` so pool jobs can
    /// read the flags without borrowing the fleet.
    down: Arc<Vec<AtomicBool>>,
}

impl FleetEnv {
    /// A fleet from explicit member environments. Members sharing one
    /// configuration space get it verbatim; members with different
    /// spaces make the fleet heterogeneous — it then searches the
    /// normalized [`NormSpace`] grid and decodes per member.
    pub fn new(members: Vec<Box<dyn Environment + Send>>) -> FleetEnv {
        assert!(!members.is_empty(), "a fleet needs at least one member");
        let homogeneous = members.iter().all(|m| *m.space() == *members[0].space());
        let (space, norm) = if homogeneous {
            (members[0].space().clone(), None)
        } else {
            let ns = NormSpace::new(members.iter().map(|m| m.space().clone()).collect());
            (ns.grid().clone(), Some(Arc::new(ns)))
        };
        let n = members.len();
        FleetEnv {
            members: Arc::new(members.into_iter().map(Mutex::new).collect()),
            space,
            norm,
            parallel: true,
            workers: None,
            pool: None,
            down: Arc::new((0..n).map(|_| AtomicBool::new(false)).collect()),
        }
    }

    /// A fleet of simulated boards.
    pub fn of_boards(boards: Vec<Device>) -> FleetEnv {
        FleetEnv::new(
            boards
                .into_iter()
                .map(|d| Box::new(SimEnv::new(d)) as Box<dyn Environment + Send>)
                .collect(),
        )
    }

    /// `n` same-model replicas with per-member seeds (chip lottery +
    /// independent noise), seeded `base_seed..base_seed + n`.
    pub fn replicas(kind: DeviceKind, model: ModelKind, n: usize, base_seed: u64) -> FleetEnv {
        FleetEnv::of_boards(
            (0..n)
                .map(|i| Device::new(kind, model, base_seed + i as u64))
                .collect(),
        )
    }

    /// A mixed-device fleet serving one model: member `i` runs
    /// `kinds[i]`, seeded `base_seed + i`. With more than one distinct
    /// kind the fleet is heterogeneous (normalized search grid).
    pub fn mixed(kinds: &[DeviceKind], model: ModelKind, base_seed: u64) -> FleetEnv {
        FleetEnv::of_boards(
            kinds
                .iter()
                .enumerate()
                .map(|(i, &k)| Device::new(k, model, base_seed + i as u64))
                .collect(),
        )
    }

    /// Measure members sequentially on the caller's thread (identical
    /// results; used to assert the parallel path byte-for-byte).
    pub fn sequential(mut self) -> FleetEnv {
        self.parallel = false;
        self.pool = None;
        self
    }

    /// Pin the fleet's pool to `workers` threads (benches pin this for
    /// reproducible scaling curves; the default is [`FleetPool::auto`]'s
    /// choice). Takes effect at the next parallel window — any
    /// already-built pool is dropped and rebuilt lazily.
    pub fn with_workers(mut self, workers: usize) -> FleetEnv {
        assert!(workers >= 1, "a fleet pool needs at least one worker");
        self.workers = Some(workers);
        self.pool = None;
        self
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Run `f` against member `i` (members live behind per-member locks
    /// so the pool's `'static` jobs can measure them in place).
    pub fn with_member<R>(&self, i: usize, f: impl FnOnce(&dyn Environment) -> R) -> R {
        f(&**lock(&self.members[i]))
    }

    /// Mark member `i` down (true) or rejoined (false). A down member
    /// is skipped by every window — its observation is the synthetic
    /// dropped window ([`FailureKind::Dropout`]) and the fleet
    /// aggregate is the survivor mean. The member itself is untouched
    /// while away: its RNG, simulated clock and thermal state freeze,
    /// so a rejoin resumes exactly where the dropout left it.
    pub fn set_member_down(&self, i: usize, down: bool) {
        self.down[i].store(down, Ordering::Relaxed);
    }

    /// Whether member `i` is currently marked down.
    pub fn member_down(&self, i: usize) -> bool {
        self.down[i].load(Ordering::Relaxed)
    }

    /// Members currently up (fleet size minus down-flagged members).
    pub fn live_members(&self) -> usize {
        self.down.iter().filter(|d| !d.load(Ordering::Relaxed)).count()
    }

    /// Threads spawned by the fleet's persistent pool — 0 until the
    /// first parallel window, constant forever after
    /// (`bench_fleet_scale` asserts it never moves once measuring
    /// starts).
    pub fn spawned_threads(&self) -> u64 {
        self.pool.as_ref().map_or(0, FleetPool::spawned_threads)
    }

    /// Jobs claimed off another worker's deque so far (work-stealing
    /// traffic; diagnostics only — steals can never affect results).
    pub fn pool_steals(&self) -> u64 {
        self.pool.as_ref().map_or(0, FleetPool::steals)
    }

    /// Worker count of the built pool (0 before the first parallel
    /// window).
    pub fn pool_workers(&self) -> usize {
        self.pool.as_ref().map_or(0, FleetPool::workers)
    }

    /// Whether proposals go through the normalized encoding (mixed
    /// member spaces).
    pub fn is_normalized(&self) -> bool {
        self.norm.is_some()
    }

    /// The normalized encoding of a mixed fleet (None when homogeneous).
    pub fn norm(&self) -> Option<&NormSpace> {
        self.norm.as_deref()
    }

    /// The native configuration each member would run for proposal
    /// `cfg`, in member order (the identity for homogeneous fleets).
    /// Every returned configuration is on that member's native grid.
    pub fn decoded(&self, cfg: HwConfig) -> Vec<HwConfig> {
        match &self.norm {
            Some(ns) => (0..self.members.len())
                .map(|i| ns.decode_for(i, &cfg))
                .collect(),
            None => vec![cfg; self.members.len()],
        }
    }

    /// Aggregate windows measured together, in member order: the mean of
    /// every metric, with one crashed member prohibiting the config for
    /// the whole group. This is both the fleet's per-proposal
    /// aggregation and the multi-tenant arbiter's per-round observation
    /// (`control::tenant`).
    ///
    /// **Dropout is not a crash.** A window carrying
    /// [`FailureKind::Dropout`] (a member that vanished mid-round) does
    /// not veto the config: it contributes nothing and the means are
    /// taken over the *survivors* (quorum-weighted). Only when every
    /// member dropped does the aggregate itself report `Dropout`.
    /// Fault-free groups divide by the same count as before, so their
    /// aggregates stay byte-identical.
    ///
    /// Internally a pairwise tree reduction over fixed midpoints (see
    /// [`partial_over`]): the summation tree depends only on `results.
    /// len()`, so [`FleetEnv::combine_sharded`] — which cuts the same
    /// tree at interior nodes to aggregate shard-parallel — is
    /// byte-identical to this flat form for every shard count.
    pub fn combine(results: &[Measured]) -> Measured {
        assert!(!results.is_empty(), "combine needs at least one window");
        finish(partial_over(results, 0, results.len()))
    }

    /// Hierarchical aggregation: per-shard partials first, then the
    /// cross-shard merge — byte-identical to [`FleetEnv::combine`] by
    /// construction, because shard boundaries ([`shard_bounds`]) land
    /// only on interior nodes of the flat combine's summation tree and
    /// [`merge_partials`] mirrors that tree's shape. `shards` is clamped
    /// to `1..=results.len()`. This is what lets the fleet mean itself
    /// parallelize at 10,000 members ([`FleetEnv::measure`] shards
    /// across the pool above [`HIER_COMBINE_MIN`]).
    pub fn combine_sharded(results: &[Measured], shards: usize) -> Measured {
        assert!(!results.is_empty(), "combine needs at least one window");
        let shards = shards.clamp(1, results.len());
        let mut bounds = Vec::with_capacity(shards);
        shard_bounds(0, results.len(), shards, &mut bounds);
        let parts: Vec<Partial> = bounds
            .iter()
            .map(|&(lo, hi)| partial_over(results, lo, hi))
            .collect();
        finish(merge_partials(&parts))
    }
}

/// Fleets at or above this many members aggregate shard-parallel on the
/// pool ([`FleetEnv::combine_sharded`]); smaller fleets combine flat on
/// the measuring thread, where sharding overhead would dominate.
const HIER_COMBINE_MIN: usize = 512;

/// Running sums over one contiguous member range — the unit of
/// hierarchical aggregation. Merging two adjacent partials is one
/// interior node of the combine tree, so any cut of that tree into
/// partials re-merges to the identical result.
#[derive(Debug, Clone, Copy)]
struct Partial {
    /// Config of the range's first member (fleet order), echoed into the
    /// combined observation like the old left-fold did.
    config: HwConfig,
    n: usize,
    /// Members in this range that actually produced a window (dropped
    /// members contribute identity elements and `live: 0`); the means
    /// divide by this. Fault-free ranges have `live == n`, so every
    /// historical aggregate divides by the same count bit-for-bit.
    live: usize,
    throughput_fps: f64,
    power_mw: f64,
    latency_ms: f64,
    p99_latency_ms: f64,
    gpu_util: f64,
    cpu_util: f64,
    mem_util: f64,
    /// Modeled accuracy sum over live members (mean in `finish`): the
    /// fleet serves at the accuracy of its *average* member — for the
    /// common one-manifest fleet every member serves the same variant,
    /// so the mean is exactly that variant's mAP.
    accuracy: f64,
    /// First *config* failure in fleet order (left-priority merge),
    /// regardless of which thread measured it. Dropout never lands
    /// here — a vanished member is a missing observation, not a verdict
    /// on the configuration.
    failed: Option<FailureKind>,
}

impl Partial {
    fn leaf(m: &Measured) -> Partial {
        if m.failed == Some(FailureKind::Dropout) {
            // A dropped member contributes the sums' identity elements
            // (0.0 adds, NEG_INFINITY max) so the merge arithmetic of
            // every *other* member is untouched, and live: 0 so the
            // final means divide by survivors only.
            return Partial {
                config: m.config,
                n: 1,
                live: 0,
                throughput_fps: 0.0,
                power_mw: 0.0,
                latency_ms: 0.0,
                p99_latency_ms: f64::NEG_INFINITY,
                gpu_util: 0.0,
                cpu_util: 0.0,
                mem_util: 0.0,
                accuracy: 0.0,
                failed: None,
            };
        }
        Partial {
            config: m.config,
            n: 1,
            live: 1,
            throughput_fps: m.throughput_fps,
            power_mw: m.power_mw,
            latency_ms: m.latency_ms,
            p99_latency_ms: m.p99_latency_ms,
            gpu_util: m.gpu_util,
            cpu_util: m.cpu_util,
            mem_util: m.mem_util,
            accuracy: m.accuracy,
            failed: m.failed,
        }
    }

    /// One interior tree node: `left` covers the members immediately
    /// before `right` in fleet order.
    fn merge(left: Partial, right: Partial) -> Partial {
        Partial {
            config: left.config,
            n: left.n + right.n,
            live: left.live + right.live,
            throughput_fps: left.throughput_fps + right.throughput_fps,
            power_mw: left.power_mw + right.power_mw,
            latency_ms: left.latency_ms + right.latency_ms,
            // The fleet's tail is the *worst* member tail, not a mean:
            // an SLO is violated if any member violates it. Max merges
            // associatively, so sharded == flat still holds.
            p99_latency_ms: left.p99_latency_ms.max(right.p99_latency_ms),
            gpu_util: left.gpu_util + right.gpu_util,
            cpu_util: left.cpu_util + right.cpu_util,
            mem_util: left.mem_util + right.mem_util,
            accuracy: left.accuracy + right.accuracy,
            failed: left.failed.or(right.failed),
        }
    }
}

/// The combine tree over `results[lo..hi]`: split at the fixed ceiling
/// midpoint (left half takes the odd element) and merge the halves.
/// The tree shape is a pure function of the range, never of threads —
/// that is where sharded == flat byte-identity comes from. The ceiling
/// split makes n ≤ 3 associate exactly like a left fold, `(a + b) + c`,
/// which keeps historical small-group aggregates (pairs, 3-tenant
/// rounds) bit-identical to the pre-tree implementation.
fn partial_over(results: &[Measured], lo: usize, hi: usize) -> Partial {
    debug_assert!(lo < hi && hi <= results.len());
    if hi - lo == 1 {
        return Partial::leaf(&results[lo]);
    }
    let mid = lo + (hi - lo + 1) / 2;
    Partial::merge(partial_over(results, lo, mid), partial_over(results, mid, hi))
}

/// Cut `results[lo..hi]` into exactly `shards` contiguous ranges whose
/// boundaries are interior nodes of [`partial_over`]'s tree: recurse
/// down the same ceiling midpoints, sending `ceil(shards / 2)` shards
/// left. Both sides stay feasible (`1 ≤ shards ≤ elements`) because the
/// left half holds `ceil(n / 2) ≥ ceil(shards / 2)` elements and the
/// right half `floor(n / 2) ≥ floor(shards / 2)`.
fn shard_bounds(lo: usize, hi: usize, shards: usize, out: &mut Vec<(usize, usize)>) {
    debug_assert!(shards >= 1 && shards <= hi - lo);
    if shards == 1 {
        out.push((lo, hi));
        return;
    }
    let mid = lo + (hi - lo + 1) / 2;
    let left = (shards + 1) / 2;
    shard_bounds(lo, mid, left, out);
    shard_bounds(mid, hi, shards - left, out);
}

/// Merge per-shard partials by mirroring [`shard_bounds`]'s recursion:
/// the first `ceil(k / 2)` partials are exactly the left half's shards,
/// so this rebuilds the flat tree's interior nodes bottom-up.
fn merge_partials(parts: &[Partial]) -> Partial {
    debug_assert!(!parts.is_empty());
    if parts.len() == 1 {
        return parts[0];
    }
    let left = parts.len().div_ceil(2);
    Partial::merge(merge_partials(&parts[..left]), merge_partials(&parts[left..]))
}

/// Turn a full-fleet partial into the observation the optimizer sees:
/// metric means over the *live* members, with one crashed member
/// prohibiting the config fleet-wide (the surviving boards still draw
/// power). Dropped members are excluded from every mean (`live < n`);
/// a fully-dropped group is itself a [`FailureKind::Dropout`] window.
/// Fault-free groups have `live == n`, so their divisions — and hence
/// their aggregates — are byte-identical to the historical form.
fn finish(p: Partial) -> Measured {
    if let Some(failed) = p.failed {
        // A config crash vetoes the group; its power mean is still the
        // survivors' (the live boards keep drawing power).
        let n = p.live.max(1) as f64;
        return Measured {
            config: p.config,
            throughput_fps: 0.0,
            power_mw: p.power_mw / n,
            latency_ms: f64::INFINITY,
            p99_latency_ms: f64::INFINITY,
            gpu_util: 0.0,
            cpu_util: 0.0,
            mem_util: 0.0,
            accuracy: 0.0,
            failed: Some(failed),
        };
    }
    if p.live == 0 {
        // Every member dropped: no observation exists this round.
        return dropped_window(p.config);
    }
    let n = p.live as f64;
    Measured {
        config: p.config,
        throughput_fps: p.throughput_fps / n,
        power_mw: p.power_mw / n,
        latency_ms: p.latency_ms / n,
        // Already the worst *live* member tail (max-merged, not
        // summed; dropped leaves contribute NEG_INFINITY).
        p99_latency_ms: p.p99_latency_ms,
        gpu_util: p.gpu_util / n,
        cpu_util: p.cpu_util / n,
        mem_util: p.mem_util / n,
        accuracy: p.accuracy / n,
        failed: None,
    }
}

/// The synthetic observation of a member that vanished mid-round (down
/// flag, panicked measurement job): zero throughput and power — a
/// vanished board serves nothing and its rail reads nothing — infinite
/// latency, and the [`FailureKind::Dropout`] marker the aggregation
/// treats as "exclude from the survivor means" rather than as a config
/// veto.
fn dropped_window(native: HwConfig) -> Measured {
    Measured {
        config: native,
        throughput_fps: 0.0,
        power_mw: 0.0,
        latency_ms: f64::INFINITY,
        p99_latency_ms: f64::INFINITY,
        gpu_util: 0.0,
        cpu_util: 0.0,
        mem_util: 0.0,
        accuracy: 0.0,
        failed: Some(FailureKind::Dropout),
    }
}

impl FleetEnv {
    /// The one measurement path: `fresh` selects whether members
    /// measure through their cache layers (`measure`) or past them
    /// (`measure_fresh`) — both hold-phase and search-phase windows
    /// share every other line of this.
    ///
    /// Parallel fleets dispatch one index batch over the persistent
    /// pool: zero thread spawns per proposal and O(1) per-member
    /// dispatch — the only per-proposal allocation proportional to
    /// fleet size is the results vec itself. Each job decodes its own
    /// member's native config *inside* the job
    /// ([`NormSpace::decode_for`] is pure, so the steal schedule cannot
    /// influence what a member measures), measures the member behind
    /// its lock (each index is claimed exactly once — every lock is
    /// uncontended), and stores the window into its index slot.
    fn measure_members(&mut self, cfg: HwConfig, fresh: bool) -> Measured {
        let n = self.members.len();
        let results: Vec<Measured> = if self.parallel && n > 1 {
            let workers = self.workers;
            let pool = self.pool.get_or_insert_with(|| match workers {
                Some(w) => FleetPool::new(w),
                None => FleetPool::auto(),
            });
            let members = Arc::clone(&self.members);
            let down = Arc::clone(&self.down);
            let norm = self.norm.clone();
            let slots: Arc<Mutex<Vec<Option<Measured>>>> = Arc::new(Mutex::new(vec![None; n]));
            let out = Arc::clone(&slots);
            // `run_contained`, not `run`: one panicking member must not
            // abort the fleet round. The pool contains the panic, the
            // dead job's slot stays unfilled, and the collection below
            // turns it into a dropped observation.
            pool.run_contained(n, move |i| {
                let native = match &norm {
                    Some(ns) => ns.decode_for(i, &cfg),
                    None => cfg,
                };
                if down[i].load(Ordering::Relaxed) {
                    // Skip the member entirely: no lock, no RNG draw,
                    // no clock advance — a down board is frozen, not
                    // measured-at-zero.
                    lock(&out)[i] = Some(dropped_window(native));
                    return;
                }
                let mut env = lock(&members[i]);
                let m = if fresh {
                    env.measure_fresh(native)
                } else {
                    env.measure(native)
                };
                lock(&out)[i] = Some(m);
            });
            std::mem::take(&mut *lock(&slots))
                .into_iter()
                .enumerate()
                .map(|(i, m)| {
                    m.unwrap_or_else(|| {
                        // The member's job panicked mid-window (slot
                        // never filled): this round, that member is
                        // simply gone.
                        let native = match &self.norm {
                            Some(ns) => ns.decode_for(i, &cfg),
                            None => cfg,
                        };
                        dropped_window(native)
                    })
                })
                .collect()
        } else {
            self.members
                .iter()
                .enumerate()
                .map(|(i, member)| {
                    let native = match &self.norm {
                        Some(ns) => ns.decode_for(i, &cfg),
                        None => cfg,
                    };
                    if self.down[i].load(Ordering::Relaxed) {
                        return dropped_window(native);
                    }
                    // Same containment as the pool path: a panicking
                    // member yields a dropped window, not an aborted
                    // round (`lock` is poison-tolerant, so the member
                    // stays reachable next round).
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        let mut env = lock(member);
                        if fresh {
                            env.measure_fresh(native)
                        } else {
                            env.measure(native)
                        }
                    }))
                    .unwrap_or_else(|_| dropped_window(native))
                })
                .collect()
        };
        let mut m = self.combine_results(results);
        if self.norm.is_some() {
            // Per-member windows carry per-member *native* configs; the
            // observation the optimizer sees must echo its normalized
            // proposal (snapped onto the grid, like any environment).
            m.config = self.space.snap_config(cfg.as_vec());
        }
        m
    }

    /// Aggregate one proposal's member windows. Small fleets combine
    /// flat on this thread; at [`HIER_COMBINE_MIN`] members and above a
    /// parallel fleet computes per-shard partials on the pool (one
    /// shard per worker) and merges across shards — byte-identical to
    /// flat by the [`shard_bounds`] construction.
    fn combine_results(&self, results: Vec<Measured>) -> Measured {
        let n = results.len();
        let pool = match &self.pool {
            Some(pool) if self.parallel && n >= HIER_COMBINE_MIN => pool,
            _ => return FleetEnv::combine(&results),
        };
        let shards = pool.workers().clamp(1, n);
        let mut bounds = Vec::with_capacity(shards);
        shard_bounds(0, n, shards, &mut bounds);
        let results = Arc::new(results);
        let parts: Vec<Partial> = pool.map(bounds, {
            let results = Arc::clone(&results);
            move |_, (lo, hi)| partial_over(&results, lo, hi)
        });
        finish(merge_partials(&parts))
    }
}

impl Environment for FleetEnv {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        self.measure_members(cfg, false)
    }

    fn measure_fresh(&mut self, cfg: HwConfig) -> Measured {
        self.measure_members(cfg, true)
    }

    fn space(&self) -> &ConfigSpace {
        &self.space
    }

    /// Fleet members measure concurrently, so wall-clock cost is the
    /// slowest member, not the sum.
    fn cost_s(&self) -> f64 {
        self.members.iter().map(|m| lock(m).cost_s()).fold(0.0, f64::max)
    }

    /// The ordered member fingerprints plus the encoding flag: two
    /// fleets share entries only when every member (device, seed,
    /// workload) and the proposal encoding match.
    fn fingerprint(&self) -> u64 {
        let mut words = vec![self.members.len() as u64, self.norm.is_some() as u64];
        words.extend(self.members.iter().map(|m| lock(m).fingerprint()));
        super::cache::stable_hash(&words)
    }

    /// Forwarded to every member: fleet-level drift invalidates each
    /// member's cache layer (if any).
    fn bump_epoch(&mut self) {
        for m in self.members.iter() {
            lock(m).bump_epoch();
        }
    }

    /// Merged member stats — Some as soon as any member carries a cache
    /// layer.
    fn cache_stats(&self) -> Option<super::CacheStats> {
        self.members
            .iter()
            .filter_map(|m| lock(m).cache_stats())
            .reduce(|a, b| a.merged(&b))
    }

    /// History-dependent as soon as any member is (one thermal board
    /// makes the whole fleet mean trajectory-dependent).
    fn history_dependent(&self) -> bool {
        self.members.iter().any(|m| lock(m).history_dependent())
    }

    /// Member dropout/rejoin is the fleet's own fault family (the down
    /// flags); everything else is forwarded to every member.
    fn inject_fault(&mut self, fault: &super::chaos::ChaosFault) {
        use super::chaos::ChaosFault;
        match fault {
            ChaosFault::MemberDown { member } => {
                self.set_member_down(member % self.len(), true)
            }
            ChaosFault::MemberUp { member } => {
                self.set_member_down(member % self.len(), false)
            }
            other => {
                for m in self.members.iter() {
                    lock(m).inject_fault(other);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::sim::WARMUP_S;

    #[test]
    fn sim_env_measures_and_accounts_cost() {
        let mut env = SimEnv::new(Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1));
        let cfg = env.space().midpoint();
        let m = env.measure(cfg);
        assert!(m.throughput_fps > 0.0);
        let per_window = WARMUP_S + SAMPLES_PER_WINDOW as f64;
        assert!((env.cost_s() - per_window).abs() < 1e-9);
        assert_eq!(env.device().windows_run(), 1);
    }

    #[test]
    fn live_env_degrades_to_sim_without_artifacts() {
        // In the offline container PJRT construction fails, so `auto`
        // must fall back to sim-backed windows and keep measuring.
        let mut env = LiveEnv::auto(
            DeviceKind::XavierNx,
            ModelKind::Yolo,
            1,
            ServerConfig::default(),
        );
        let cfg = env.space().midpoint();
        let m = env.measure(cfg);
        assert!(m.throughput_fps > 0.0);
        assert!(m.power_mw > 0.0);
        assert!(env.cost_s() > 0.0);
        if !env.is_live() {
            assert!(env.last_report().is_none());
        }
        assert!(env.steady_state(cfg, 10).is_some() == env.is_live());
    }

    #[test]
    fn live_env_sim_backed_matches_plain_device() {
        let mut dev = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 9);
        let mut env = LiveEnv::sim_backed(Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 9));
        let cfg = dev.space().midpoint();
        assert_eq!(env.measure(cfg), dev.run(cfg));
        assert_eq!(env.cost_s(), dev.sim_clock_s());
    }

    #[test]
    fn fleet_parallel_matches_sequential_byte_for_byte() {
        let mut par = FleetEnv::replicas(DeviceKind::OrinNano, ModelKind::Yolo, 4, 0x99);
        let mut seq =
            FleetEnv::replicas(DeviceKind::OrinNano, ModelKind::Yolo, 4, 0x99).sequential();
        assert_eq!(par.len(), 4);
        let space = par.space().clone();
        let cfgs = [
            space.midpoint(),
            DeviceKind::OrinNano.preset_default(),
            DeviceKind::OrinNano.preset_max_power(),
        ];
        for cfg in cfgs {
            let a = par.measure(cfg);
            let b = seq.measure(cfg);
            assert_eq!(a, b, "parallel fleet must be bit-identical");
        }
        assert_eq!(par.cost_s(), seq.cost_s());
        assert!(par.cost_s() > 0.0);
    }

    #[test]
    fn fleet_mean_smooths_member_noise() {
        let mut one = FleetEnv::replicas(DeviceKind::XavierNx, ModelKind::Yolo, 1, 7);
        let mut many = FleetEnv::replicas(DeviceKind::XavierNx, ModelKind::Yolo, 8, 7);
        let cfg = one.space().midpoint();
        let a = one.measure(cfg);
        let b = many.measure(cfg);
        // Same surface, different aggregation width: both near truth.
        let rel = (a.throughput_fps - b.throughput_fps).abs() / a.throughput_fps;
        assert!(rel < 0.1, "fleet mean wildly off: {rel}");
    }

    #[test]
    fn fleet_prohibits_configs_that_crash_any_member() {
        // RetinaNet at max concurrency exceeds the NX memory budget.
        let mut fleet = FleetEnv::replicas(DeviceKind::XavierNx, ModelKind::RetinaNet, 3, 5);
        let mut cfg = fleet.space().midpoint();
        cfg.concurrency = 3;
        let m = fleet.measure(cfg);
        assert!(m.failed.is_some());
        assert_eq!(m.throughput_fps, 0.0);
        assert!(m.power_mw > 0.0, "surviving boards still draw power");
    }

    #[test]
    fn homogeneous_fleet_keeps_the_native_space_and_identity_decode() {
        let fleet = FleetEnv::replicas(DeviceKind::XavierNx, ModelKind::Yolo, 2, 1);
        assert!(!fleet.is_normalized());
        assert!(fleet.norm().is_none());
        assert!(!fleet.space().is_normalized());
        assert_eq!(fleet.space().device(), DeviceKind::XavierNx);
        let cfg = fleet.space().midpoint();
        assert_eq!(fleet.decoded(cfg), vec![cfg, cfg]);
        assert_eq!(fleet.len(), 2);
    }

    #[test]
    fn mixed_fleet_searches_the_normalized_grid_and_decodes_per_member() {
        let mut fleet = FleetEnv::mixed(
            &[DeviceKind::XavierNx, DeviceKind::OrinNano],
            ModelKind::Yolo,
            0x7E7,
        );
        assert!(fleet.is_normalized());
        let space = fleet.space().clone();
        assert!(space.is_normalized());
        let cfg = space.midpoint();
        let natives = fleet.decoded(cfg);
        assert_eq!(natives.len(), 2);
        let ns = fleet.norm().expect("mixed fleet has an encoding").clone();
        for (i, native) in natives.iter().enumerate() {
            assert!(ns.members()[i].contains(native), "member {i} off its native grid");
        }
        assert_ne!(natives[0], natives[1], "same fraction, different native units");
        let m = fleet.measure(cfg);
        assert_eq!(m.config, cfg, "observation echoes the normalized proposal");
        assert!(m.throughput_fps > 0.0);
        assert!(m.power_mw > 0.0);
        assert!(fleet.cost_s() > 0.0);
    }

    #[test]
    fn fleet_of_cached_members_hits_and_invalidates_through_the_fleet() {
        let mk = || {
            FleetEnv::new(
                (0..3u64)
                    .map(|i| {
                        let dev = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 40 + i);
                        Box::new(super::super::CachedEnv::new(SimEnv::new(dev)))
                            as Box<dyn Environment + Send>
                    })
                    .collect(),
            )
        };
        let mut fleet = mk();
        assert_eq!(fleet.fingerprint(), mk().fingerprint(), "fleet fingerprint stable");
        let cfg = fleet.space().midpoint();
        let a = fleet.measure(cfg);
        let cost_after_miss = fleet.cost_s();
        let b = fleet.measure(cfg);
        assert_eq!(a, b, "fleet hit is byte-identical");
        assert_eq!(fleet.cost_s(), cost_after_miss, "fleet hit charges zero");
        let stats = fleet.cache_stats().expect("cached members visible");
        assert_eq!((stats.hits, stats.misses), (3, 3));
        fleet.bump_epoch();
        assert_eq!(fleet.cache_stats().expect("still cached").epoch, 1);
        fleet.measure(cfg);
        assert_eq!(fleet.cache_stats().unwrap().misses, 6, "post-bump windows re-measure");
        for i in 0..fleet.len() {
            let epoch_bumped =
                fleet.with_member(i, |m| m.cache_stats().is_some_and(|s| s.epoch == 1));
            assert!(epoch_bumped, "member {i} cache layer missed the epoch bump");
        }
    }

    #[test]
    fn mixed_fleet_parallel_matches_sequential_byte_for_byte() {
        let mk = |sequential: bool| {
            let f = FleetEnv::mixed(
                &[DeviceKind::XavierNx, DeviceKind::OrinNano, DeviceKind::OrinNano],
                ModelKind::Yolo,
                5,
            );
            if sequential {
                f.sequential()
            } else {
                f
            }
        };
        let mut par = mk(false);
        let mut seq = mk(true);
        let space = par.space().clone();
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..6 {
            let cfg = space.random(&mut rng);
            assert_eq!(par.measure(cfg), seq.measure(cfg), "{cfg:?}");
        }
        assert_eq!(par.cost_s(), seq.cost_s());
    }

    #[test]
    fn fleet_builds_one_pool_lazily_and_reuses_it() {
        let mut fleet =
            FleetEnv::replicas(DeviceKind::OrinNano, ModelKind::Yolo, 6, 11).with_workers(2);
        assert_eq!(fleet.spawned_threads(), 0, "pool is lazy");
        assert_eq!(fleet.pool_workers(), 0);
        let cfg = fleet.space().midpoint();
        for _ in 0..5 {
            fleet.measure(cfg);
            assert_eq!(fleet.spawned_threads(), 2, "one pool, built once");
            assert_eq!(fleet.pool_workers(), 2);
        }
        // Sequential fleets never build a pool at all.
        let mut seq = FleetEnv::replicas(DeviceKind::OrinNano, ModelKind::Yolo, 6, 11).sequential();
        seq.measure(cfg);
        assert_eq!(seq.spawned_threads(), 0);
    }

    /// The hierarchical-aggregation contract: cutting the combine tree
    /// into any number of shards and re-merging is byte-identical to
    /// the flat combine — including failure propagation (first failure
    /// in member order wins, survivors' power still averages in).
    #[test]
    fn sharded_combine_is_byte_identical_to_flat_for_every_shard_count() {
        use crate::util::prop;
        let cfg = DeviceKind::OrinNano.preset_default();
        prop::check("sharded combine matches flat", 120, |g| {
            let n = g.rng.range_usize(1, 40);
            let results: Vec<Measured> = (0..n)
                .map(|_| Measured {
                    config: cfg,
                    throughput_fps: g.rng.range_f64(0.1, 90.0),
                    power_mw: g.rng.range_f64(800.0, 16_000.0),
                    latency_ms: g.rng.range_f64(2.0, 220.0),
                    p99_latency_ms: g.rng.range_f64(2.0, 900.0),
                    gpu_util: g.rng.f64(),
                    cpu_util: g.rng.f64(),
                    mem_util: g.rng.f64(),
                    accuracy: g.rng.range_f64(20.0, 45.0),
                    failed: if g.rng.chance(0.1) {
                        Some(FailureKind::OutOfMemory)
                    } else {
                        None
                    },
                })
                .collect();
            let flat = FleetEnv::combine(&results);
            for shards in 1..=n + 2 {
                let sharded = FleetEnv::combine_sharded(&results, shards);
                prop::assert_true(
                    format!("{flat:?}") == format!("{sharded:?}"),
                    "sharded combine diverged from flat",
                )?;
            }
            Ok(())
        });
    }

    /// At `HIER_COMBINE_MIN` members and beyond, the parallel fleet
    /// measures *and aggregates* on the pool — and must still be
    /// byte-identical to the plain sequential fleet.
    #[test]
    fn large_fleet_hierarchical_path_matches_sequential_byte_for_byte() {
        const PAIR: [DeviceKind; 2] = [DeviceKind::XavierNx, DeviceKind::OrinNano];
        let n = HIER_COMBINE_MIN + 88;
        let kinds: Vec<DeviceKind> = (0..n).map(|i| PAIR[i % 2]).collect();
        let mut par = FleetEnv::mixed(&kinds, ModelKind::Yolo, 0xF1EE7).with_workers(3);
        let mut seq = FleetEnv::mixed(&kinds, ModelKind::Yolo, 0xF1EE7).sequential();
        let cfg = par.space().midpoint();
        for _ in 0..2 {
            assert_eq!(par.measure(cfg), seq.measure(cfg), "hierarchical path diverged");
            assert_eq!(par.spawned_threads(), 3, "zero spawns after pool construction");
        }
        assert_eq!(par.cost_s(), seq.cost_s());
    }
}
