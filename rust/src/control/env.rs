//! Measurement environments: where an optimizer's proposals get turned
//! into observed (throughput, power) windows.
//!
//! The paper evaluates on physical Jetson boards; this repo historically
//! only ever measured the simulator, with the drive loop copy-pasted at
//! every call site. [`Environment`] makes the measurement side a trait,
//! so the one canonical [`super::ControlLoop`] drives:
//!
//! * [`SimEnv`] — the simulated Jetson ([`Device`]); cost is simulated
//!   seconds.
//! * [`LiveEnv`] — the real serving stack ([`Server`]): proposals apply
//!   their concurrency level to the live worker pool, throughput is
//!   sampled from served traffic through [`Sampler`] with the paper's
//!   warm-up discipline, power comes from the device model (a dev box
//!   has no INA3221 power rails), and the whole thing degrades
//!   gracefully to sim-backed windows when no PJRT artifacts exist.
//! * [`FleetEnv`] — many boards measured per proposal (one thread per
//!   member), observing fleet-mean metrics. Members with different
//!   configuration spaces (mixed NX/Orin) make the fleet heterogeneous:
//!   it searches the normalized [`NormSpace`] grid and decodes each
//!   proposal per member (EXPERIMENTS.md §Heterogeneous fleets).
//!
//! Any of these can additionally be wrapped in [`super::CachedEnv`] —
//! the content-addressed measurement cache ([`super::cache`]) — which
//! answers repeated proposals from its store at zero cost. The trait's
//! cache hooks ([`Environment::measure_fresh`],
//! [`Environment::fingerprint`], [`Environment::bump_epoch`],
//! [`Environment::cache_stats`]) all have pass-through defaults, so
//! plain environments are unaffected.

use std::time::Instant;

use crate::coordinator::{Server, ServerConfig, ServeReport};
use crate::device::sim::SAMPLES_PER_WINDOW;
use crate::device::{ConfigSpace, Device, DeviceKind, HwConfig, Measured, NormSpace};
use crate::models::{artifacts_dir, Manifest, ModelKind};
use crate::runtime::PjrtRuntime;
use crate::telemetry::{Sample, Sampler};
use crate::workload::VideoSource;

/// A place where hardware configurations can be applied and measured.
///
/// One `measure` call is one of the paper's measurement windows: apply
/// the configuration, warm up, observe aggregated throughput and power.
pub trait Environment {
    /// Apply `cfg` and run one measurement window.
    fn measure(&mut self, cfg: HwConfig) -> Measured;

    /// The configuration space proposals must come from.
    fn space(&self) -> &ConfigSpace;

    /// Total measurement cost so far, in seconds. Simulated environments
    /// report simulated seconds; live ones report wall-clock spent
    /// serving. The control loop reports per-search deltas of this, so
    /// search cost is accounted uniformly (no more ad-hoc
    /// `sim_clock_s()` reads at call sites).
    fn cost_s(&self) -> f64;

    /// Measure without consulting any cache layer. For plain
    /// environments this *is* [`Environment::measure`]; a
    /// [`super::CachedEnv`] overrides it to bypass lookup, run a real
    /// window and refresh the stored entry. [`super::ControlLoop::hold`]
    /// measures through this, so hold-phase drift detection always
    /// observes the live surface.
    fn measure_fresh(&mut self, cfg: HwConfig) -> Measured {
        self.measure(cfg)
    }

    /// Stable identity of this measurement surface, used to key cache
    /// entries ([`super::cache`]). Two environments whose `measure`
    /// could answer the same configuration differently must report
    /// different fingerprints before their [`super::CachedEnv`]
    /// wrappers may share a [`super::CacheStore`].
    ///
    /// The default hashes the configuration space alone (device tag,
    /// normalized flag, every grid value) — correct only for
    /// environments fully determined by their space. [`SimEnv`],
    /// [`LiveEnv`], [`FleetEnv`] and the testkit's scripted
    /// environments all override it to fold in workload, seed lineage,
    /// window parameters and script state; custom environments sharing
    /// a store should do the same.
    fn fingerprint(&self) -> u64 {
        super::cache::space_fingerprint(self.space())
    }

    /// Advance the cache-invalidation epoch after a detected surface
    /// shift ([`super::DriftDetector`] firings). No-op for uncached
    /// environments; [`super::CachedEnv`] prunes its stale entries,
    /// aggregates ([`FleetEnv`], [`super::TenantArbiter`]) forward to
    /// their members.
    fn bump_epoch(&mut self) {}

    /// Cache accounting of this environment, when a cache layer is
    /// present anywhere in its composition (None otherwise — which is
    /// how the control loop knows not to log cache events for plain
    /// environments).
    fn cache_stats(&self) -> Option<super::CacheStats> {
        None
    }
}

/// The simulated Jetson board as an [`Environment`].
#[derive(Debug, Clone)]
pub struct SimEnv {
    dev: Device,
}

impl SimEnv {
    pub fn new(dev: Device) -> SimEnv {
        SimEnv { dev }
    }

    /// The underlying simulated device (thermal state, window counts).
    pub fn device(&self) -> &Device {
        &self.dev
    }

    pub fn device_mut(&mut self) -> &mut Device {
        &mut self.dev
    }

    pub fn into_device(self) -> Device {
        self.dev
    }
}

impl Environment for SimEnv {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        self.dev.run(cfg)
    }

    fn space(&self) -> &ConfigSpace {
        self.dev.space()
    }

    fn cost_s(&self) -> f64 {
        self.dev.sim_clock_s()
    }

    /// Space identity + workload + noise-seed lineage + window
    /// parameters — everything that shapes what a window can return.
    /// Thermal devices additionally fold in the flag so their
    /// history-dependent surface never shares entries with a
    /// thermal-free twin.
    fn fingerprint(&self) -> u64 {
        device_fingerprint(&self.dev)
    }
}

/// Cache identity of one simulated device (shared by [`SimEnv`] and
/// [`LiveEnv`], whose power/DVFS side is this device).
fn device_fingerprint(dev: &Device) -> u64 {
    super::cache::stable_hash(&[
        super::cache::space_fingerprint(dev.space()),
        dev.kind().id(),
        dev.model().id(),
        dev.seed(),
        dev.noise_scale().to_bits(),
        dev.has_thermal() as u64,
        crate::device::sim::WARMUP_S.to_bits(),
        SAMPLES_PER_WINDOW as u64,
    ])
}

/// Boxed environments measure through the same trait like any concrete
/// environment — the multi-tenant arbiter drives a heterogeneous
/// sim/live mix as `Box<dyn Environment + Send>`.
impl<E: Environment + ?Sized> Environment for Box<E> {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        (**self).measure(cfg)
    }

    fn space(&self) -> &ConfigSpace {
        (**self).space()
    }

    fn cost_s(&self) -> f64 {
        (**self).cost_s()
    }

    fn measure_fresh(&mut self, cfg: HwConfig) -> Measured {
        (**self).measure_fresh(cfg)
    }

    fn fingerprint(&self) -> u64 {
        (**self).fingerprint()
    }

    fn bump_epoch(&mut self) {
        (**self).bump_epoch()
    }

    fn cache_stats(&self) -> Option<super::CacheStats> {
        (**self).cache_stats()
    }
}

/// The live serving stack behind [`LiveEnv`].
struct LiveBackend {
    server: Server,
    video: VideoSource,
}

/// The live serving stack as an [`Environment`].
///
/// Each `measure` applies the proposal's concurrency level to the real
/// worker pool, serves synthetic traffic video in sample-sized chunks,
/// and records per-chunk throughput into a [`Sampler`] (first two
/// chunks after a reconfiguration discarded — the paper's 2-sample
/// warm-up discipline). The serving pump underneath is event-driven
/// (`Server::run_closed_loop` blocks on worker completions bounded by
/// the batcher deadline), so a live measurement window costs **zero
/// busy-wait** — the pump cannot pollute the very throughput/power
/// signal being correlated. [`LiveEnv::pump_iterations`] exposes the
/// cumulative wakeup accounting.
/// Power always comes from the device model's DVFS state: a development
/// box has no module power rails, so the simulator is the wattmeter.
///
/// Without AOT artifacts / a PJRT backend there is no server to drive;
/// the environment then degrades to fully sim-backed windows so every
/// caller keeps working (see [`LiveEnv::auto`]).
pub struct LiveEnv {
    /// DVFS + power model; also the throughput fallback without PJRT.
    sim: Device,
    backend: Option<LiveBackend>,
    sampler: Sampler,
    frames_per_sample: u64,
    inflight: usize,
    serving_wall_s: f64,
    /// Cumulative serving-pump wakeups across all live windows.
    pump_iterations: u64,
    last_report: Option<ServeReport>,
}

impl LiveEnv {
    /// Degraded mode: every window is answered by the device simulator.
    pub fn sim_backed(sim: Device) -> LiveEnv {
        LiveEnv {
            sim,
            backend: None,
            // The paper's measurement discipline: 2 warm-up samples
            // discarded after every reconfiguration, then the retained
            // window (Sampler::paper_default's shape).
            sampler: Sampler::new(2, SAMPLES_PER_WINDOW),
            frames_per_sample: 12,
            inflight: 8,
            serving_wall_s: 0.0,
            pump_iterations: 0,
            last_report: None,
        }
    }

    /// Live mode over an already-built server. `video` must match the
    /// server's model input side.
    pub fn with_server(sim: Device, server: Server, video: VideoSource) -> LiveEnv {
        assert_eq!(
            video.side(),
            server.input_side(),
            "video side must match the served model input"
        );
        let mut env = LiveEnv::sim_backed(sim);
        env.backend = Some(LiveBackend { server, video });
        env
    }

    /// Build the live stack when AOT artifacts + a PJRT backend exist,
    /// degrading to [`LiveEnv::sim_backed`] (with a logged reason)
    /// otherwise.
    pub fn auto(kind: DeviceKind, model: ModelKind, seed: u64, cfg: ServerConfig) -> LiveEnv {
        let sim = Device::new(kind, model, seed);
        match Self::try_backend(model, seed, cfg) {
            Ok(backend) => {
                let mut env = LiveEnv::sim_backed(sim);
                env.backend = Some(backend);
                env
            }
            Err(e) => {
                log::warn!("live serving unavailable ({e}); measuring sim-backed");
                LiveEnv::sim_backed(sim)
            }
        }
    }

    fn try_backend(model: ModelKind, seed: u64, cfg: ServerConfig) -> anyhow::Result<LiveBackend> {
        let manifest = Manifest::load(&artifacts_dir())?;
        let rt = PjrtRuntime::cpu()?;
        let model_rt = rt.load_model(&manifest, model)?;
        let side = model_rt.input_side();
        Ok(LiveBackend {
            server: Server::new(model_rt, cfg),
            video: VideoSource::new(side, 30, seed),
        })
    }

    /// Frames served per telemetry sample (per chunk of the closed loop).
    pub fn frames_per_sample(mut self, frames: u64) -> LiveEnv {
        self.frames_per_sample = frames.max(1);
        self
    }

    /// Outstanding frames kept in flight while serving.
    pub fn inflight(mut self, inflight: usize) -> LiveEnv {
        self.inflight = inflight.max(1);
        self
    }

    /// Whether a real serving stack answers measurements.
    pub fn is_live(&self) -> bool {
        self.backend.is_some()
    }

    /// The device model supplying DVFS state and power.
    pub fn device(&self) -> &Device {
        &self.sim
    }

    /// Serving report of the most recent live chunk.
    pub fn last_report(&self) -> Option<&ServeReport> {
        self.last_report.as_ref()
    }

    /// Cumulative serving-pump wakeups across all live windows. With
    /// the event-driven pump this is bounded by completions + batcher
    /// deadline fires — never wall-clock — which is what "a live window
    /// costs zero busy-wait" means operationally. Always 0 sim-backed.
    pub fn pump_iterations(&self) -> u64 {
        self.pump_iterations
    }

    /// Serve `frames` at `cfg` in steady state on the live stack.
    /// Returns `None` when sim-backed (or when serving fails).
    pub fn steady_state(&mut self, cfg: HwConfig, frames: u64) -> Option<ServeReport> {
        let applied = self.sim.apply(cfg);
        let b = self.backend.as_mut()?;
        b.server.set_concurrency(applied.concurrency as usize);
        b.server.reset_window_metrics();
        match b.server.run_closed_loop(&mut b.video, frames, self.inflight) {
            Ok(report) => {
                self.pump_iterations += report.pump_iterations;
                Some(report)
            }
            Err(e) => {
                log::warn!("steady-state serving failed: {e}");
                None
            }
        }
    }

    /// Shut the serving stack down; total frames served when live.
    pub fn shutdown(self) -> Option<u64> {
        self.backend.map(|b| b.server.shutdown())
    }
}

impl Environment for LiveEnv {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        // The sim layer first: it applies/snaps the DVFS state, models
        // power, and catches config failures before they hit the server.
        let sim_m = self.sim.run(cfg);
        // A stale serving report must not outlive the window it belongs
        // to: windows answered without serving (sim-backed, vetoed
        // config) report no live stats.
        self.last_report = None;
        // Vetoed configs never reach the server: the device model
        // detects the failure instantly, so in live mode they genuinely
        // cost ~no wall-clock (on physical hardware the crash would
        // consume a window — the sim clock still records that view).
        if self.backend.is_none() || sim_m.failed.is_some() {
            return sim_m;
        }
        let backend = self.backend.as_mut().expect("live mode checked above");

        backend.server.set_concurrency(sim_m.config.concurrency as usize);
        self.sampler.reset(); // reconfiguration restarts warm-up
        let t0 = Instant::now();
        let mut lat_ms_sum = 0.0;
        let mut lat_chunks = 0u32;
        while self.sampler.len() < SAMPLES_PER_WINDOW {
            // Percentiles must describe this chunk, not the server's
            // lifetime — reset the distribution buffers per chunk.
            backend.server.reset_window_metrics();
            match backend.server.run_closed_loop(
                &mut backend.video,
                self.frames_per_sample,
                self.inflight,
            ) {
                Ok(report) => {
                    self.pump_iterations += report.pump_iterations;
                    let retained = self.sampler.record(Sample {
                        throughput_fps: report.throughput_fps,
                        power_mw: sim_m.power_mw,
                        gpu_util: sim_m.gpu_util,
                        cpu_util: sim_m.cpu_util,
                        mem_util: sim_m.mem_util,
                    });
                    if retained {
                        // Window latency aggregates the retained chunks,
                        // same discipline as throughput.
                        lat_ms_sum += report.latency_p50_ms;
                        lat_chunks += 1;
                    }
                    self.last_report = Some(report);
                }
                Err(e) => {
                    log::warn!("live measurement failed ({e}); falling back to sim window");
                    self.serving_wall_s += t0.elapsed().as_secs_f64();
                    // The aborted window's partial chunks are not this
                    // window's stats: the returned measurement is
                    // sim-backed, so report no live stats for it.
                    self.last_report = None;
                    return sim_m;
                }
            }
        }
        self.serving_wall_s += t0.elapsed().as_secs_f64();
        let w = self.sampler.window().expect("retained samples exist");
        Measured {
            config: sim_m.config,
            throughput_fps: w.throughput_fps,
            power_mw: sim_m.power_mw,
            latency_ms: if lat_chunks > 0 {
                lat_ms_sum / lat_chunks as f64
            } else {
                sim_m.latency_ms
            },
            gpu_util: sim_m.gpu_util,
            cpu_util: sim_m.cpu_util,
            mem_util: sim_m.mem_util,
            failed: None,
        }
    }

    fn space(&self) -> &ConfigSpace {
        self.sim.space()
    }

    fn cost_s(&self) -> f64 {
        if self.backend.is_some() {
            self.serving_wall_s
        } else {
            self.sim.sim_clock_s()
        }
    }

    /// The sim device's identity plus the live serving knobs — and the
    /// live/degraded flag itself, since the two modes answer windows
    /// from different surfaces.
    fn fingerprint(&self) -> u64 {
        super::cache::stable_hash(&[
            device_fingerprint(&self.sim),
            self.is_live() as u64,
            self.frames_per_sample,
            self.inflight as u64,
        ])
    }
}

/// A fleet of boards measured together, as an [`Environment`].
///
/// One proposal is applied to every member; the observation the
/// optimizer sees is the fleet mean (a config that crashes any member is
/// prohibited fleet-wide). Members are measured on one thread each;
/// results are aggregated in member order, so the parallel measurement
/// is byte-identical to the sequential one — thread timing can change
/// wall-clock, never numbers.
///
/// **Heterogeneous fleets.** Members may carry *different*
/// [`ConfigSpace`]s (mixed NX/Orin boards, or scripted test members).
/// The fleet then exposes the shared [`NormSpace`] grid — per-dimension
/// rank fractions, the encoding that lets one distance-correlation
/// surface span heterogeneous hardware — and decodes every proposal per
/// member onto that member's native grid before measuring
/// ([`NormSpace::decode_for`]). Decoding is pure and aggregation is
/// unchanged, so parallel == sequential byte-identity is preserved.
///
/// The thread-per-member fan-out models real fleet measurement, where a
/// window costs seconds per board; for the microsecond-scale simulated
/// `Device::run` the spawn overhead exceeds the work, so sim-only
/// benchmarking should use [`FleetEnv::sequential`] (a persistent
/// worker pool is a ROADMAP open item).
pub struct FleetEnv {
    members: Vec<Box<dyn Environment + Send>>,
    /// The space proposals come from: the members' shared native grid
    /// for a homogeneous fleet, the normalized grid for a mixed one.
    space: ConfigSpace,
    /// Mixed-space decoding (None = homogeneous fleet; proposals pass
    /// through to members untouched).
    norm: Option<NormSpace>,
    parallel: bool,
}

impl FleetEnv {
    /// A fleet from explicit member environments. Members sharing one
    /// configuration space get it verbatim; members with different
    /// spaces make the fleet heterogeneous — it then searches the
    /// normalized [`NormSpace`] grid and decodes per member.
    pub fn new(members: Vec<Box<dyn Environment + Send>>) -> FleetEnv {
        assert!(!members.is_empty(), "a fleet needs at least one member");
        let homogeneous = members.iter().all(|m| *m.space() == *members[0].space());
        let (space, norm) = if homogeneous {
            (members[0].space().clone(), None)
        } else {
            let ns = NormSpace::new(members.iter().map(|m| m.space().clone()).collect());
            (ns.grid().clone(), Some(ns))
        };
        FleetEnv { members, space, norm, parallel: true }
    }

    /// A fleet of simulated boards.
    pub fn of_boards(boards: Vec<Device>) -> FleetEnv {
        FleetEnv::new(
            boards
                .into_iter()
                .map(|d| Box::new(SimEnv::new(d)) as Box<dyn Environment + Send>)
                .collect(),
        )
    }

    /// `n` same-model replicas with per-member seeds (chip lottery +
    /// independent noise), seeded `base_seed..base_seed + n`.
    pub fn replicas(kind: DeviceKind, model: ModelKind, n: usize, base_seed: u64) -> FleetEnv {
        FleetEnv::of_boards(
            (0..n)
                .map(|i| Device::new(kind, model, base_seed + i as u64))
                .collect(),
        )
    }

    /// A mixed-device fleet serving one model: member `i` runs
    /// `kinds[i]`, seeded `base_seed + i`. With more than one distinct
    /// kind the fleet is heterogeneous (normalized search grid).
    pub fn mixed(kinds: &[DeviceKind], model: ModelKind, base_seed: u64) -> FleetEnv {
        FleetEnv::of_boards(
            kinds
                .iter()
                .enumerate()
                .map(|(i, &k)| Device::new(k, model, base_seed + i as u64))
                .collect(),
        )
    }

    /// Measure members sequentially on the caller's thread (identical
    /// results; used to assert the parallel path byte-for-byte).
    pub fn sequential(mut self) -> FleetEnv {
        self.parallel = false;
        self
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Member environments, in fleet order.
    pub fn members(&self) -> &[Box<dyn Environment + Send>] {
        &self.members
    }

    /// Whether proposals go through the normalized encoding (mixed
    /// member spaces).
    pub fn is_normalized(&self) -> bool {
        self.norm.is_some()
    }

    /// The normalized encoding of a mixed fleet (None when homogeneous).
    pub fn norm(&self) -> Option<&NormSpace> {
        self.norm.as_ref()
    }

    /// The native configuration each member would run for proposal
    /// `cfg`, in member order (the identity for homogeneous fleets).
    /// Every returned configuration is on that member's native grid.
    pub fn decoded(&self, cfg: HwConfig) -> Vec<HwConfig> {
        match &self.norm {
            Some(ns) => (0..self.members.len())
                .map(|i| ns.decode_for(i, &cfg))
                .collect(),
            None => vec![cfg; self.members.len()],
        }
    }

    /// Aggregate windows measured together, in member order: the mean of
    /// every metric, with one crashed member prohibiting the config for
    /// the whole group. This is both the fleet's per-proposal
    /// aggregation and the multi-tenant arbiter's per-round observation
    /// (`control::tenant`).
    pub fn combine(results: &[Measured]) -> Measured {
        assert!(!results.is_empty(), "combine needs at least one window");
        let n = results.len() as f64;
        let mean = |f: fn(&Measured) -> f64| results.iter().map(f).sum::<f64>() / n;
        if let Some(failed) = results.iter().find(|m| m.failed.is_some()) {
            // One crashed member prohibits the config fleet-wide; the
            // surviving boards still draw power.
            return Measured {
                config: results[0].config,
                throughput_fps: 0.0,
                power_mw: mean(|m| m.power_mw),
                latency_ms: f64::INFINITY,
                gpu_util: 0.0,
                cpu_util: 0.0,
                mem_util: 0.0,
                failed: failed.failed,
            };
        }
        Measured {
            config: results[0].config,
            throughput_fps: mean(|m| m.throughput_fps),
            power_mw: mean(|m| m.power_mw),
            latency_ms: mean(|m| m.latency_ms),
            gpu_util: mean(|m| m.gpu_util),
            cpu_util: mean(|m| m.cpu_util),
            mem_util: mean(|m| m.mem_util),
            failed: None,
        }
    }
}

impl FleetEnv {
    /// The one measurement path: `fresh` selects whether members
    /// measure through their cache layers (`measure`) or past them
    /// (`measure_fresh`) — both hold-phase and search-phase windows
    /// share every other line of this.
    fn measure_members(&mut self, cfg: HwConfig, fresh: bool) -> Measured {
        // Pure per-member decode (identity for homogeneous fleets)
        // happens before any thread is spawned, so the parallel schedule
        // cannot influence which native config a member measures.
        let natives = self.decoded(cfg);
        let results: Vec<Measured> = if self.parallel && self.members.len() > 1 {
            // One thread per member; members are moved out and rejoined
            // in order, so aggregation order never depends on timing.
            let handles: Vec<_> = self
                .members
                .drain(..)
                .zip(natives)
                .map(|(mut env, native)| {
                    std::thread::spawn(move || {
                        let m = if fresh {
                            env.measure_fresh(native)
                        } else {
                            env.measure(native)
                        };
                        (env, m)
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(handles.len());
            for h in handles {
                let (env, m) = h.join().expect("fleet member panicked");
                self.members.push(env);
                out.push(m);
            }
            out
        } else {
            self.members
                .iter_mut()
                .zip(&natives)
                .map(|(env, native)| {
                    if fresh {
                        env.measure_fresh(*native)
                    } else {
                        env.measure(*native)
                    }
                })
                .collect()
        };
        let mut m = FleetEnv::combine(&results);
        if self.norm.is_some() {
            // Per-member windows carry per-member *native* configs; the
            // observation the optimizer sees must echo its normalized
            // proposal (snapped onto the grid, like any environment).
            m.config = self.space.snap_config(cfg.as_vec());
        }
        m
    }
}

impl Environment for FleetEnv {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        self.measure_members(cfg, false)
    }

    fn measure_fresh(&mut self, cfg: HwConfig) -> Measured {
        self.measure_members(cfg, true)
    }

    fn space(&self) -> &ConfigSpace {
        &self.space
    }

    /// Fleet members measure concurrently, so wall-clock cost is the
    /// slowest member, not the sum.
    fn cost_s(&self) -> f64 {
        self.members.iter().map(|m| m.cost_s()).fold(0.0, f64::max)
    }

    /// The ordered member fingerprints plus the encoding flag: two
    /// fleets share entries only when every member (device, seed,
    /// workload) and the proposal encoding match.
    fn fingerprint(&self) -> u64 {
        let mut words = vec![self.members.len() as u64, self.norm.is_some() as u64];
        words.extend(self.members.iter().map(|m| m.fingerprint()));
        super::cache::stable_hash(&words)
    }

    /// Forwarded to every member: fleet-level drift invalidates each
    /// member's cache layer (if any).
    fn bump_epoch(&mut self) {
        for m in &mut self.members {
            m.bump_epoch();
        }
    }

    /// Merged member stats — Some as soon as any member carries a cache
    /// layer.
    fn cache_stats(&self) -> Option<super::CacheStats> {
        self.members
            .iter()
            .filter_map(|m| m.cache_stats())
            .reduce(|a, b| a.merged(&b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::sim::WARMUP_S;

    #[test]
    fn sim_env_measures_and_accounts_cost() {
        let mut env = SimEnv::new(Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1));
        let cfg = env.space().midpoint();
        let m = env.measure(cfg);
        assert!(m.throughput_fps > 0.0);
        let per_window = WARMUP_S + SAMPLES_PER_WINDOW as f64;
        assert!((env.cost_s() - per_window).abs() < 1e-9);
        assert_eq!(env.device().windows_run(), 1);
    }

    #[test]
    fn live_env_degrades_to_sim_without_artifacts() {
        // In the offline container PJRT construction fails, so `auto`
        // must fall back to sim-backed windows and keep measuring.
        let mut env = LiveEnv::auto(
            DeviceKind::XavierNx,
            ModelKind::Yolo,
            1,
            ServerConfig::default(),
        );
        let cfg = env.space().midpoint();
        let m = env.measure(cfg);
        assert!(m.throughput_fps > 0.0);
        assert!(m.power_mw > 0.0);
        assert!(env.cost_s() > 0.0);
        if !env.is_live() {
            assert!(env.last_report().is_none());
        }
        assert!(env.steady_state(cfg, 10).is_some() == env.is_live());
    }

    #[test]
    fn live_env_sim_backed_matches_plain_device() {
        let mut dev = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 9);
        let mut env = LiveEnv::sim_backed(Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 9));
        let cfg = dev.space().midpoint();
        assert_eq!(env.measure(cfg), dev.run(cfg));
        assert_eq!(env.cost_s(), dev.sim_clock_s());
    }

    #[test]
    fn fleet_parallel_matches_sequential_byte_for_byte() {
        let mut par = FleetEnv::replicas(DeviceKind::OrinNano, ModelKind::Yolo, 4, 0x99);
        let mut seq =
            FleetEnv::replicas(DeviceKind::OrinNano, ModelKind::Yolo, 4, 0x99).sequential();
        assert_eq!(par.len(), 4);
        let space = par.space().clone();
        let cfgs = [
            space.midpoint(),
            DeviceKind::OrinNano.preset_default(),
            DeviceKind::OrinNano.preset_max_power(),
        ];
        for cfg in cfgs {
            let a = par.measure(cfg);
            let b = seq.measure(cfg);
            assert_eq!(a, b, "parallel fleet must be bit-identical");
        }
        assert_eq!(par.cost_s(), seq.cost_s());
        assert!(par.cost_s() > 0.0);
    }

    #[test]
    fn fleet_mean_smooths_member_noise() {
        let mut one = FleetEnv::replicas(DeviceKind::XavierNx, ModelKind::Yolo, 1, 7);
        let mut many = FleetEnv::replicas(DeviceKind::XavierNx, ModelKind::Yolo, 8, 7);
        let cfg = one.space().midpoint();
        let a = one.measure(cfg);
        let b = many.measure(cfg);
        // Same surface, different aggregation width: both near truth.
        let rel = (a.throughput_fps - b.throughput_fps).abs() / a.throughput_fps;
        assert!(rel < 0.1, "fleet mean wildly off: {rel}");
    }

    #[test]
    fn fleet_prohibits_configs_that_crash_any_member() {
        // RetinaNet at max concurrency exceeds the NX memory budget.
        let mut fleet = FleetEnv::replicas(DeviceKind::XavierNx, ModelKind::RetinaNet, 3, 5);
        let mut cfg = fleet.space().midpoint();
        cfg.concurrency = 3;
        let m = fleet.measure(cfg);
        assert!(m.failed.is_some());
        assert_eq!(m.throughput_fps, 0.0);
        assert!(m.power_mw > 0.0, "surviving boards still draw power");
    }

    #[test]
    fn homogeneous_fleet_keeps_the_native_space_and_identity_decode() {
        let fleet = FleetEnv::replicas(DeviceKind::XavierNx, ModelKind::Yolo, 2, 1);
        assert!(!fleet.is_normalized());
        assert!(fleet.norm().is_none());
        assert!(!fleet.space().is_normalized());
        assert_eq!(fleet.space().device(), DeviceKind::XavierNx);
        let cfg = fleet.space().midpoint();
        assert_eq!(fleet.decoded(cfg), vec![cfg, cfg]);
        assert_eq!(fleet.members().len(), 2);
    }

    #[test]
    fn mixed_fleet_searches_the_normalized_grid_and_decodes_per_member() {
        let mut fleet = FleetEnv::mixed(
            &[DeviceKind::XavierNx, DeviceKind::OrinNano],
            ModelKind::Yolo,
            0x7E7,
        );
        assert!(fleet.is_normalized());
        let space = fleet.space().clone();
        assert!(space.is_normalized());
        let cfg = space.midpoint();
        let natives = fleet.decoded(cfg);
        assert_eq!(natives.len(), 2);
        let ns = fleet.norm().expect("mixed fleet has an encoding").clone();
        for (i, native) in natives.iter().enumerate() {
            assert!(ns.members()[i].contains(native), "member {i} off its native grid");
        }
        assert_ne!(natives[0], natives[1], "same fraction, different native units");
        let m = fleet.measure(cfg);
        assert_eq!(m.config, cfg, "observation echoes the normalized proposal");
        assert!(m.throughput_fps > 0.0);
        assert!(m.power_mw > 0.0);
        assert!(fleet.cost_s() > 0.0);
    }

    #[test]
    fn fleet_of_cached_members_hits_and_invalidates_through_the_fleet() {
        let mk = || {
            FleetEnv::new(
                (0..3u64)
                    .map(|i| {
                        let dev = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 40 + i);
                        Box::new(super::super::CachedEnv::new(SimEnv::new(dev)))
                            as Box<dyn Environment + Send>
                    })
                    .collect(),
            )
        };
        let mut fleet = mk();
        assert_eq!(fleet.fingerprint(), mk().fingerprint(), "fleet fingerprint stable");
        let cfg = fleet.space().midpoint();
        let a = fleet.measure(cfg);
        let cost_after_miss = fleet.cost_s();
        let b = fleet.measure(cfg);
        assert_eq!(a, b, "fleet hit is byte-identical");
        assert_eq!(fleet.cost_s(), cost_after_miss, "fleet hit charges zero");
        let stats = fleet.cache_stats().expect("cached members visible");
        assert_eq!((stats.hits, stats.misses), (3, 3));
        fleet.bump_epoch();
        assert_eq!(fleet.cache_stats().expect("still cached").epoch, 1);
        fleet.measure(cfg);
        assert_eq!(fleet.cache_stats().unwrap().misses, 6, "post-bump windows re-measure");
        assert!(fleet
            .members()
            .iter()
            .all(|m| m.cache_stats().map_or(false, |s| s.epoch == 1)));
    }

    #[test]
    fn mixed_fleet_parallel_matches_sequential_byte_for_byte() {
        let mk = |sequential: bool| {
            let f = FleetEnv::mixed(
                &[DeviceKind::XavierNx, DeviceKind::OrinNano, DeviceKind::OrinNano],
                ModelKind::Yolo,
                5,
            );
            if sequential {
                f.sequential()
            } else {
                f
            }
        };
        let mut par = mk(false);
        let mut seq = mk(true);
        let space = par.space().clone();
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..6 {
            let cfg = space.random(&mut rng);
            assert_eq!(par.measure(cfg), seq.measure(cfg), "{cfg:?}");
        }
        assert_eq!(par.cost_s(), seq.cost_s());
    }
}
