//! Multi-tenant power-budget arbitration: several CORAL instances on
//! one box, sharing one power envelope.
//!
//! The paper tunes one model per board; the production regime the
//! ROADMAP targets puts several models on the same box, where
//! per-model tuning breaks down — each controller honestly meets *its
//! own* budget while the box blows the shared one (the PolyThrottle
//! failure mode; Fulcrum draws the same conclusion for concurrent
//! workloads on one edge accelerator: a shared constraint needs an
//! explicit arbiter, not independent controllers).
//!
//! [`TenantArbiter`] is that arbiter. It wraps N per-tenant
//! [`Environment`]s (any sim/live mix, boxed) and each round:
//!
//! 1. **splits** the global power budget into per-tenant sub-budgets
//!    under a [`BudgetPolicy`] — static shares, demand-weighted shares,
//!    or water-filling rebalance of the slack donated by tenants already
//!    holding a feasible configuration. Every policy guarantees the
//!    safety invariant **Σ sub-budgets ≤ global budget, every round**
//!    (property-tested; the deliberate exception is the
//!    [`TenantArbiter::independent`] baseline, which models the
//!    unarbitrated regime for comparison);
//! 2. **searches**: one [`ControlLoop`] per tenant runs a fresh CORAL
//!    round against its sub-budget, then holds its choice with the
//!    windowed drift monitor — a drifted hold restarts that tenant's
//!    loop (bounded, deterministically seeded);
//! 3. **measures the allocation**: each tenant's held configuration gets
//!    one fresh window (a tenant whose search found nothing feasible is
//!    parked on the space-minimum floor configuration instead of an
//!    infeasible best), and the per-tenant windows are aggregated with
//!    [`FleetEnv::combine`] — so the arbiter itself presents as an
//!    [`Environment`] whose `measure` is one arbitration round.
//!
//! Tenant rounds run thread-parallel on [`FleetRunner`] with
//! index-slotted results: every tenant job owns its environment,
//! optimizer, and seeds, so trajectories are **byte-identical to the
//! sequential run** for any worker count.
//!
//! EXPERIMENTS.md §Multi-tenant arbitration documents the policies,
//! invariants, and how to run the scenario family; ARCHITECTURE.md
//! places the arbiter in the closed-loop diagram.
//!
//! On the live path the generic `Router<S: ModelServer>` stays the
//! single admission front door across tenants:
//! [`TenantArbiter::apply_to_router`] pushes each round's arbitrated
//! concurrency levels into the registered per-model stacks, and the
//! router's shared `rejected` counter must survive those
//! reconfigurations (pinned by regression tests).

use crate::coordinator::{ModelServer, Router};
use crate::device::{ConfigSpace, Dim, HwConfig, Measured};
use crate::models::ModelKind;
use crate::optimizer::{Constraints, CoralOptimizer};

use super::cache::{CacheStats, CachedEnv};
use super::engine::{ControlLoop, ControlLoopConfig, DriftConfig, DEFAULT_BUDGET};
use super::env::{Environment, FleetEnv};
use super::fleet::FleetRunner;

/// Headroom a water-filled tenant keeps above its measured draw, so
/// normal window-to-window variation does not immediately re-starve it.
pub const WATERFILL_HEADROOM: f64 = 0.05;

/// Hold-phase drift restarts allowed per tenant per round (keeps a
/// never-settling surface from wedging the round).
pub const MAX_DRIFT_RESTARTS: u64 = 2;

/// One tenant of the shared box: a model with its own throughput target
/// and a relative demand weight for the weighted budget splits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Tenant {
    pub name: &'static str,
    /// The model this tenant serves — also the admission key the shared
    /// `Router` files its stack under (one tenant per model per box).
    pub model: ModelKind,
    /// τ_target (fps) of the tenant's dual-constraint scenario.
    pub target_fps: f64,
    /// Relative demand weight (demand-weighted and water-filling base
    /// shares are proportional to it).
    pub weight: f64,
    /// Optional accuracy floor (mAP). A tenant whose environment carries
    /// a variant axis may degrade its served variant down to this floor
    /// when its sub-budget tightens — trading accuracy instead of
    /// starving a neighbour — but never below it. `None` pins nothing:
    /// on a singleton-manifest box the search can only ever serve the
    /// baseline variant anyway.
    pub min_accuracy: Option<f64>,
}

/// How the global power budget is split into per-tenant sub-budgets.
#[derive(Debug, Clone, PartialEq)]
pub enum BudgetPolicy {
    /// Fixed fractional shares, one per tenant, in tenant order; must be
    /// non-negative and sum to ≤ 1.
    Static(Vec<f64>),
    /// Shares proportional to tenant weights, recomputed every round.
    DemandWeighted,
    /// Demand-weighted base shares, then water-filling: every tenant
    /// that held a feasible configuration last round keeps only its
    /// measured draw × (1 + [`WATERFILL_HEADROOM`]) (capped at its base
    /// share) and donates the rest, which is redistributed across the
    /// still-unsatisfied tenants in proportion to their weights. With
    /// every tenant satisfied the pooled slack stays unallocated —
    /// headroom for the box, never an excuse to exceed it.
    WaterFill,
}

impl BudgetPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            BudgetPolicy::Static(_) => "static",
            BudgetPolicy::DemandWeighted => "demand",
            BudgetPolicy::WaterFill => "waterfill",
        }
    }
}

/// One tenant's slice of a round.
#[derive(Debug, Clone, Copy)]
pub struct TenantRound {
    pub name: &'static str,
    pub model: ModelKind,
    /// The power sub-budget this round's search ran against (mW).
    pub sub_budget_mw: f64,
    /// Fresh measurement of the configuration the tenant holds after the
    /// round (its chosen best, or the floor configuration on fallback).
    pub chosen: Measured,
    /// Did the held window satisfy the tenant's (target, sub-budget)?
    pub feasible: bool,
    /// Hold-phase drift restarts of the tenant's [`ControlLoop`].
    pub restarts: u64,
    /// The search found nothing feasible; the arbiter parked the tenant
    /// on the space-minimum configuration for the round.
    pub fell_back: bool,
}

/// One arbitration round across all tenants.
#[derive(Debug, Clone)]
pub struct RoundReport {
    /// 1-based round counter.
    pub round: u64,
    pub tenants: Vec<TenantRound>,
    /// [`FleetEnv::combine`] over the per-tenant held windows: the
    /// observation the arbiter-as-[`Environment`] reports.
    pub combined: Measured,
    /// Σ of the per-tenant held windows' measured power (mW) — the power
    /// the shared box actually draws at this allocation.
    pub aggregate_power_mw: f64,
    /// max(0, aggregate − global budget): the arbitration failure metric
    /// the `bench_tenants` baseline comparison is scored on.
    pub overshoot_mw: f64,
}

/// Per-tenant driving state (self-contained: it is the unit shipped to
/// a [`FleetRunner`] job, so rounds parallelize on the runner's
/// persistent pool without sharing).
struct TenantState {
    spec: Tenant,
    seed: u64,
    cl: ControlLoop<Box<dyn Environment + Send>, CoralOptimizer>,
    /// Last round's held window + feasibility (water-filling input).
    last: Option<(Measured, bool)>,
}

/// The budget-splitting arbiter. See the module docs for the round
/// structure; see [`crate::experiments::scenarios::MULTI_TENANT_SCENARIOS`]
/// for ready-made tenant mixes and `coral tenants` / the `multi_tenant`
/// example / `bench_tenants` for the user surface.
pub struct TenantArbiter {
    global_budget_mw: f64,
    policy: BudgetPolicy,
    /// False only for the [`TenantArbiter::independent`] baseline.
    arbitrated: bool,
    tenants: Vec<TenantState>,
    space: Option<ConfigSpace>,
    runner: FleetRunner,
    round: u64,
    /// Online iterations per tenant search round.
    budget_iters: usize,
    /// Hold-phase windows per tenant per round (0 = no hold).
    hold_windows: u64,
    drift: DriftConfig,
    /// Wrap each tenant's environment in a private [`CachedEnv`] at
    /// registration ([`TenantArbiter::cached`]).
    cached: bool,
    history: Vec<RoundReport>,
}

impl TenantArbiter {
    pub fn new(global_budget_mw: f64, policy: BudgetPolicy) -> TenantArbiter {
        assert!(global_budget_mw > 0.0, "global power budget must be positive");
        TenantArbiter {
            global_budget_mw,
            policy,
            arbitrated: true,
            tenants: Vec::new(),
            space: None,
            runner: FleetRunner::auto(),
            round: 0,
            budget_iters: DEFAULT_BUDGET,
            hold_windows: 12,
            drift: DriftConfig::default(),
            cached: false,
            history: Vec::new(),
        }
    }

    /// The unarbitrated baseline: every tenant optimizes against the
    /// **full** global budget, as independent per-model controllers
    /// would (the PolyThrottle regime). Sub-budgets then sum to
    /// N × global — this constructor deliberately violates the
    /// arbitration invariant so `bench_tenants` can score the failure
    /// mode the arbiter exists to prevent.
    pub fn independent(global_budget_mw: f64) -> TenantArbiter {
        let mut arb = TenantArbiter::new(global_budget_mw, BudgetPolicy::DemandWeighted);
        arb.arbitrated = false;
        arb
    }

    /// Online iterations per tenant search round (default: the paper's
    /// 10-iteration budget).
    pub fn budget_iters(mut self, iters: usize) -> TenantArbiter {
        assert!(iters >= 1);
        self.budget_iters = iters;
        self
    }

    /// Hold-phase windows per tenant per round (default 12; 0 disables
    /// holds and the drift restarts that ride on them).
    pub fn hold_windows(mut self, windows: u64) -> TenantArbiter {
        self.hold_windows = windows;
        self
    }

    /// Hold-phase drift detection tunables.
    pub fn drift(mut self, drift: DriftConfig) -> TenantArbiter {
        self.drift = drift;
        self
    }

    /// Wrap every subsequently registered tenant environment in its own
    /// private [`CachedEnv`] (call before [`TenantArbiter::add_tenant`]).
    /// Re-measured allocations and the bootstrap presets every fresh
    /// round re-probes then hit the tenant's store, while epochs stay
    /// **per tenant**: one tenant's drift restart invalidates only its
    /// own entries, never a neighbour's.
    pub fn cached(mut self, cached: bool) -> TenantArbiter {
        self.cached = cached;
        self
    }

    /// Run tenant rounds on the caller's thread (identical results; used
    /// to assert the parallel path byte-for-byte).
    pub fn sequential(mut self) -> TenantArbiter {
        self.runner = FleetRunner::new(1);
        self
    }

    /// Register a tenant with its measurement environment. All tenants
    /// must share one configuration space (one box), and at most one
    /// tenant may serve each model (the live-path `Router` keys
    /// admission by model kind).
    pub fn add_tenant(
        &mut self,
        spec: Tenant,
        env: Box<dyn Environment + Send>,
        seed: u64,
    ) -> &mut TenantArbiter {
        assert!(spec.target_fps > 0.0, "tenant needs a throughput target");
        assert!(spec.weight > 0.0, "tenant needs a positive demand weight");
        match &self.space {
            None => self.space = Some(env.space().clone()),
            Some(s) => assert_eq!(
                s.device(),
                env.space().device(),
                "tenants must share one configuration space"
            ),
        }
        assert!(
            self.tenants.iter().all(|t| t.spec.model != spec.model),
            "one tenant per model: the router keys admission by model kind"
        );
        // Placeholder constraints; every round re-budgets (and restarts)
        // the loop before stepping it.
        let env: Box<dyn Environment + Send> = if self.cached {
            Box::new(CachedEnv::new(env))
        } else {
            env
        };
        let cons = tenant_cons(&spec, self.global_budget_mw);
        let opt = CoralOptimizer::new(env.space().clone(), cons, seed);
        let cl = ControlLoop::new(env, opt, cons, ControlLoopConfig {
            budget: self.budget_iters,
            drift: Some(self.drift),
            search_drift: None,
        });
        self.tenants.push(TenantState { spec, seed, cl, last: None });
        self
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    pub fn global_budget_mw(&self) -> f64 {
        self.global_budget_mw
    }

    pub fn policy(&self) -> &BudgetPolicy {
        &self.policy
    }

    /// Rounds run so far.
    pub fn rounds(&self) -> u64 {
        self.round
    }

    /// Every completed round, oldest first.
    pub fn history(&self) -> &[RoundReport] {
        &self.history
    }

    /// Registered tenant specs, in tenant order.
    pub fn specs(&self) -> Vec<Tenant> {
        self.tenants.iter().map(|t| t.spec).collect()
    }

    /// Per-tenant cache accounting, in tenant order (None for tenants
    /// whose environments carry no cache layer). The CLI's tenant
    /// report renders hit-rate / windows-saved columns from this.
    pub fn tenant_cache_stats(&self) -> Vec<Option<CacheStats>> {
        self.tenants
            .iter()
            .map(|t| t.cl.env().cache_stats())
            .collect()
    }

    /// Demand-weighted shares of the global budget.
    fn demand_shares(&self) -> Vec<f64> {
        let total: f64 = self.tenants.iter().map(|t| t.spec.weight).sum();
        self.tenants
            .iter()
            .map(|t| self.global_budget_mw * t.spec.weight / total)
            .collect()
    }

    /// The next round's per-tenant sub-budgets (mW), in tenant order.
    ///
    /// Safety invariant: for every arbitrated policy the returned values
    /// are non-negative and sum to ≤ the global budget — including after
    /// water-filling rebalance, and regardless of what the tenants'
    /// loops (drift restarts included) did last round. A final
    /// normalization clamps floating-point drift so the invariant holds
    /// bit-for-bit, not just approximately.
    pub fn sub_budgets(&self) -> Vec<f64> {
        let n = self.tenants.len();
        assert!(n > 0, "arbiter needs at least one tenant");
        let b = self.global_budget_mw;
        if !self.arbitrated {
            // Independent baseline: everyone believes the whole box
            // budget is theirs.
            return vec![b; n];
        }
        let mut out = match &self.policy {
            BudgetPolicy::Static(shares) => {
                assert_eq!(shares.len(), n, "one static share per tenant");
                let sum: f64 = shares.iter().sum();
                assert!(
                    shares.iter().all(|s| *s >= 0.0) && sum <= 1.0 + 1e-9,
                    "static shares must be non-negative and sum to ≤ 1 (got {sum})"
                );
                shares.iter().map(|s| s * b).collect()
            }
            BudgetPolicy::DemandWeighted => self.demand_shares(),
            BudgetPolicy::WaterFill => {
                let mut out = self.demand_shares();
                // Satisfied tenants keep measured draw + headroom and
                // donate the rest of their base share to the pool.
                let mut pool = 0.0;
                let mut needy_weight = 0.0;
                for (i, t) in self.tenants.iter().enumerate() {
                    match &t.last {
                        Some((m, true)) => {
                            let keep = (m.power_mw * (1.0 + WATERFILL_HEADROOM)).min(out[i]);
                            pool += out[i] - keep;
                            out[i] = keep;
                        }
                        _ => needy_weight += t.spec.weight,
                    }
                }
                // Water-fill the pooled slack over unsatisfied tenants.
                if pool > 0.0 && needy_weight > 0.0 {
                    for (i, t) in self.tenants.iter().enumerate() {
                        if !matches!(t.last, Some((_, true))) {
                            out[i] += pool * t.spec.weight / needy_weight;
                        }
                    }
                }
                out
            }
        };
        let sum: f64 = out.iter().sum();
        if sum > b {
            for s in out.iter_mut() {
                *s *= b / sum;
            }
        }
        out
    }

    /// Run one arbitration round: split the budget, drive every tenant's
    /// loop against its sub-budget (thread-parallel, index-slotted —
    /// byte-identical to sequential), measure the held allocation, and
    /// aggregate. Returns the recorded report.
    pub fn run_round(&mut self) -> &RoundReport {
        let subs = self.sub_budgets();
        self.round += 1;
        let round = self.round;
        let hold_windows = self.hold_windows;
        // Re-budget every tenant: fresh constraints + fresh optimizer.
        // The prohibited list is budget-relative — a configuration
        // prohibited under last round's tighter sub-budget may be
        // exactly what a water-filled bigger one should pick — so each
        // round searches with a clean, deterministically seeded PS.
        for (t, &sub) in self.tenants.iter_mut().zip(&subs) {
            let cons = tenant_cons(&t.spec, sub);
            t.cl.set_cons(cons);
            let opt = CoralOptimizer::new(
                t.cl.env().space().clone(),
                cons,
                tenant_seed(t.seed, round, 0),
            );
            t.cl.restart(opt);
        }
        let jobs: Vec<(TenantState, f64)> = self.tenants.drain(..).zip(subs).collect();
        let results = self.runner.map(jobs, move |(t, sub)| {
            tenant_round_job(t, sub, round, hold_windows)
        });
        let mut rounds = Vec::with_capacity(results.len());
        for (state, tr) in results {
            self.tenants.push(state);
            rounds.push(tr);
        }
        let chosen: Vec<Measured> = rounds.iter().map(|r| r.chosen).collect();
        let combined = FleetEnv::combine(&chosen);
        let aggregate: f64 = chosen.iter().map(|m| m.power_mw).sum();
        self.history.push(RoundReport {
            round,
            tenants: rounds,
            combined,
            aggregate_power_mw: aggregate,
            overshoot_mw: (aggregate - self.global_budget_mw).max(0.0),
        });
        self.history.last().expect("round just recorded")
    }

    /// Run `rounds` arbitration rounds; returns the full history.
    pub fn run(&mut self, rounds: usize) -> &[RoundReport] {
        for _ in 0..rounds {
            self.run_round();
        }
        self.history()
    }

    /// Push the latest round's arbitrated concurrency levels into the
    /// shared admission front door. The `Router` stays the single
    /// admission authority across tenants — its shared `rejected`
    /// counter must survive these per-tenant reconfigurations (pinned by
    /// the `tenant_arbiter` regression tests). Tenants without a
    /// registered stack (sim-only mixes) are skipped.
    pub fn apply_to_router<S: ModelServer>(&self, router: &mut Router<S>) {
        if let Some(report) = self.history.last() {
            for tr in &report.tenants {
                if let Some(server) = router.server_mut(tr.model) {
                    server.set_concurrency(tr.chosen.config.concurrency as usize);
                }
            }
        }
    }
}

/// The arbiter as an [`Environment`].
///
/// **Never wrap the arbiter itself in a [`CachedEnv`].** Its `measure`
/// ignores the proposed configuration and advances a stateful
/// arbitration round, so a content-addressed cache over it would replay
/// a stale round instead of running one (the deliberately space-only
/// default [`Environment::fingerprint`] could not tell two arbiters
/// apart either). Cache *inside* instead: [`TenantArbiter::cached`]
/// wraps each tenant's environment, which is where repeated windows
/// actually occur.
impl Environment for TenantArbiter {
    /// One measurement window of the arbitrated box = one arbitration
    /// round. The proposed configuration is **ignored** — tenants run
    /// their own searches under the shared envelope; what an outside
    /// observer can measure is the combined allocation each round
    /// settles on ([`FleetEnv::combine`] over the per-tenant held
    /// windows).
    fn measure(&mut self, _cfg: HwConfig) -> Measured {
        self.run_round().combined
    }

    fn space(&self) -> &ConfigSpace {
        self.space
            .as_ref()
            .expect("arbiter has at least one tenant")
    }

    /// Tenants measure concurrently on the shared box, so cost is the
    /// slowest tenant's clock (the [`FleetEnv`] convention).
    fn cost_s(&self) -> f64 {
        self.tenants
            .iter()
            .map(|t| t.cl.env().cost_s())
            .fold(0.0, f64::max)
    }

    /// Forwarded to every tenant environment — a box-wide invalidation
    /// (each tenant's own drift restarts already bump only that
    /// tenant's epoch through its [`ControlLoop`]).
    fn bump_epoch(&mut self) {
        for t in &mut self.tenants {
            t.cl.env_mut().bump_epoch();
        }
    }

    /// Merged tenant cache accounting — Some as soon as any tenant is
    /// cached (see [`TenantArbiter::tenant_cache_stats`] for the
    /// per-tenant view).
    fn cache_stats(&self) -> Option<CacheStats> {
        self.tenant_cache_stats()
            .into_iter()
            .flatten()
            .reduce(|a, b| a.merged(&b))
    }

    /// Always true: each window advances stateful round/search state,
    /// so a cache must never replay one. This makes the "never wrap the
    /// arbiter in a [`CachedEnv`]" rule above self-enforcing — a cache
    /// wrapper now routes every arbiter window through `measure_fresh`.
    fn history_dependent(&self) -> bool {
        true
    }

    /// Forwarded to every tenant's environment: a fault on the shared
    /// box (thermal soak, ambient shift) is visible to all tenants.
    fn inject_fault(&mut self, fault: &super::chaos::ChaosFault) {
        for t in &mut self.tenants {
            t.cl.env_mut().inject_fault(fault);
        }
    }
}

/// A tenant's constraints against a given power sub-budget: the
/// dual-constraint scenario, plus the tenant's accuracy floor when set
/// (see [`Tenant::min_accuracy`]).
fn tenant_cons(spec: &Tenant, budget_mw: f64) -> Constraints {
    let cons = Constraints::dual(spec.target_fps, budget_mw);
    match spec.min_accuracy {
        Some(floor) => cons.with_min_accuracy(floor),
        None => cons,
    }
}

/// Deterministic per-(tenant, round, restart) optimizer seed: parallel
/// scheduling can never perturb which RNG stream a search round uses.
fn tenant_seed(base: u64, round: u64, restart: u64) -> u64 {
    base ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ restart.wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// The arbiter's safety action for a tenant whose search found nothing
/// feasible under its sub-budget: park on the lowest-power valid corner
/// (every knob at minimum, one instance) instead of serving an
/// infeasible best that could blow the shared envelope.
fn floor_config(space: &ConfigSpace) -> HwConfig {
    HwConfig {
        cpu_freq_mhz: space.min(Dim::CpuFreq),
        cpu_cores: space.min(Dim::CpuCores),
        gpu_freq_mhz: space.min(Dim::GpuFreq),
        mem_freq_mhz: space.min(Dim::MemFreq),
        concurrency: space.min(Dim::Concurrency),
        max_batch: space.min(Dim::BatchCap),
        variant: space.min(Dim::Variant),
    }
}

/// One tenant's round: search → hold (drift restarts bounded by
/// [`MAX_DRIFT_RESTARTS`], deterministically re-seeded) → one fresh
/// window of the held configuration. Self-contained by construction so
/// [`FleetRunner`] scheduling cannot perturb anything.
fn tenant_round_job(
    mut t: TenantState,
    sub_budget_mw: f64,
    round: u64,
    hold_windows: u64,
) -> (TenantState, TenantRound) {
    let cons = t.cl.cons();
    let mut out = t.cl.run();
    let mut restarts = 0u64;
    if hold_windows > 0 {
        // Deployment between searches: hold the choice; a drifted hold
        // hands control back and the loop re-searches on the shifted
        // surface.
        let mut hold = t.cl.hold(hold_windows);
        while hold.drift.is_some() && restarts < MAX_DRIFT_RESTARTS {
            restarts += 1;
            let opt = CoralOptimizer::new(
                t.cl.env().space().clone(),
                cons,
                tenant_seed(t.seed, round, restarts),
            );
            t.cl.restart(opt);
            out = t.cl.run();
            hold = t.cl.hold(hold_windows);
        }
    }
    let fell_back = !out.best.map(|b| b.feasible).unwrap_or(false);
    let cfg = if fell_back {
        floor_config(t.cl.env().space())
    } else {
        out.best.expect("feasible best exists").config
    };
    // The round's reported window: a fresh measurement of the held
    // allocation (it reflects the surface as the round ends — search
    // probes are transient and not part of the steady-state allocation
    // the safety invariant governs).
    let chosen = t.cl.env_mut().measure(cfg);
    let feasible = cons.feasible(chosen.throughput_fps, chosen.power_mw)
        && cons.accuracy_ok(chosen.accuracy);
    let tr = TenantRound {
        name: t.spec.name,
        model: t.spec.model,
        sub_budget_mw,
        chosen,
        feasible,
        restarts,
        fell_back,
    };
    t.last = Some((chosen, feasible));
    (t, tr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::testkit::StepEnv;
    use crate::util::prop;

    const NAMES: [&str; 3] = ["alpha", "beta", "gamma"];
    const MODELS: [ModelKind; 3] = [ModelKind::Yolo, ModelKind::Frcnn, ModelKind::RetinaNet];

    fn spec(i: usize, target_fps: f64, weight: f64) -> Tenant {
        Tenant { name: NAMES[i], model: MODELS[i], target_fps, weight, min_accuracy: None }
    }

    /// Arbiter over scripted surfaces: tenant i serves `fps[i]` at
    /// `power[i]` mW forever (no drift).
    fn scripted(
        global: f64,
        policy: BudgetPolicy,
        tenants: &[(f64, f64, f64)], // (target, fps, power)
    ) -> TenantArbiter {
        let mut arb = TenantArbiter::new(global, policy).budget_iters(3).hold_windows(6);
        for (i, &(target, fps, power)) in tenants.iter().enumerate() {
            let env = StepEnv::constant().with_levels(fps, fps).with_power(power);
            arb.add_tenant(spec(i, target, 1.0), Box::new(env), 0x5EED + i as u64);
        }
        arb
    }

    #[test]
    fn demand_shares_proportional_to_weights() {
        let mut arb = TenantArbiter::new(12_000.0, BudgetPolicy::DemandWeighted);
        arb.add_tenant(spec(0, 30.0, 2.0), Box::new(StepEnv::constant()), 1);
        arb.add_tenant(spec(1, 8.0, 1.0), Box::new(StepEnv::constant()), 2);
        let subs = arb.sub_budgets();
        assert!((subs[0] - 8_000.0).abs() < 1e-9);
        assert!((subs[1] - 4_000.0).abs() < 1e-9);
    }

    #[test]
    fn static_shares_split_the_budget_as_written() {
        let mut arb = TenantArbiter::new(10_000.0, BudgetPolicy::Static(vec![0.7, 0.2]));
        arb.add_tenant(spec(0, 30.0, 1.0), Box::new(StepEnv::constant()), 1);
        arb.add_tenant(spec(1, 8.0, 1.0), Box::new(StepEnv::constant()), 2);
        let subs = arb.sub_budgets();
        assert_eq!(subs, vec![7_000.0, 2_000.0], "shares may undershoot 1");
    }

    #[test]
    #[should_panic(expected = "sum to ≤ 1")]
    fn static_shares_beyond_one_rejected() {
        let mut arb = TenantArbiter::new(10_000.0, BudgetPolicy::Static(vec![0.8, 0.5]));
        arb.add_tenant(spec(0, 30.0, 1.0), Box::new(StepEnv::constant()), 1);
        arb.add_tenant(spec(1, 8.0, 1.0), Box::new(StepEnv::constant()), 2);
        arb.sub_budgets();
    }

    #[test]
    fn waterfill_donates_slack_from_satisfied_tenants() {
        // Tenant 0 is satisfiable (30 fps ≥ 20 target at 3000 mW);
        // tenant 1 never reaches its target (10 < 20). Round 1 splits
        // 5000/5000 (no history); after it, tenant 0 keeps
        // 3000 · 1.05 = 3150 and the 1850 of slack water-fills to
        // tenant 1.
        let mut arb = scripted(
            10_000.0,
            BudgetPolicy::WaterFill,
            &[(20.0, 30.0, 3_000.0), (20.0, 10.0, 3_000.0)],
        );
        let r1 = arb.run_round().clone();
        assert!((r1.tenants[0].sub_budget_mw - 5_000.0).abs() < 1e-9);
        assert!(r1.tenants[0].feasible);
        assert!(!r1.tenants[1].feasible);
        assert!(r1.tenants[1].fell_back);

        let subs = arb.sub_budgets();
        assert!((subs[0] - 3_150.0).abs() < 1e-6, "donor keeps draw + headroom: {subs:?}");
        assert!((subs[1] - 6_850.0).abs() < 1e-6, "needy tenant water-filled: {subs:?}");
        assert!((subs.iter().sum::<f64>() - 10_000.0).abs() < 1e-6);

        let r2 = arb.run_round();
        assert!(r2.tenants[0].feasible, "donor stays satisfied on its kept share");
        assert_eq!(r2.round, 2);
    }

    #[test]
    fn independent_baseline_hands_everyone_the_full_budget() {
        let mut arb = TenantArbiter::independent(9_000.0).budget_iters(2).hold_windows(0);
        arb.add_tenant(spec(0, 20.0, 1.0), Box::new(StepEnv::constant().with_power(6_000.0)), 1);
        arb.add_tenant(spec(1, 20.0, 1.0), Box::new(StepEnv::constant().with_power(6_000.0)), 2);
        assert_eq!(arb.sub_budgets(), vec![9_000.0, 9_000.0]);
        let r = arb.run_round();
        // Both tenants individually meet "their" budget; the box does not.
        assert!((r.aggregate_power_mw - 12_000.0).abs() < 1e-9);
        assert!((r.overshoot_mw - 3_000.0).abs() < 1e-9);
    }

    #[test]
    fn drifted_hold_restarts_the_tenant_loop_bounded_and_counted() {
        // Search (3 windows) sees 30 fps; the surface steps to 15 fps at
        // env window 5, so the hold's windowed mean shifts and the
        // tenant's loop restarts (once — the re-searched 15-fps surface
        // then holds steady).
        let mut arb = TenantArbiter::new(8_000.0, BudgetPolicy::DemandWeighted)
            .budget_iters(3)
            .hold_windows(6);
        let env = StepEnv::new(5).with_levels(30.0, 15.0).with_power(3_000.0);
        arb.add_tenant(spec(0, 20.0, 1.0), Box::new(env), 7);
        let r = arb.run_round();
        assert_eq!(r.tenants[0].restarts, 1);
        assert!(
            r.tenants[0].fell_back,
            "the shifted surface no longer reaches the 20 fps target"
        );
        assert_eq!(r.tenants[0].chosen.throughput_fps, 15.0);
        // The invariant is untouched by restarts.
        assert!(r.tenants[0].sub_budget_mw <= 8_000.0);
    }

    #[test]
    fn arbiter_presents_as_an_environment() {
        let mut arb = scripted(
            12_000.0,
            BudgetPolicy::DemandWeighted,
            &[(20.0, 30.0, 3_000.0), (20.0, 25.0, 4_000.0)],
        );
        let probe = arb.space().midpoint();
        let m = arb.measure(probe);
        assert_eq!(arb.rounds(), 1, "one measure = one arbitration round");
        let r = &arb.history()[0];
        assert_eq!(
            m.power_mw,
            (r.tenants[0].chosen.power_mw + r.tenants[1].chosen.power_mw) / 2.0,
            "combined window is the fleet mean"
        );
        assert!(arb.cost_s() > 0.0);
        assert_eq!(arb.space().device(), crate::device::DeviceKind::XavierNx);
    }

    #[test]
    fn parallel_rounds_match_sequential_byte_for_byte() {
        let tenants = [(20.0, 30.0, 3_000.0), (10.0, 12.0, 2_500.0), (5.0, 4.0, 1_500.0)];
        let mut par = scripted(9_000.0, BudgetPolicy::WaterFill, &tenants);
        let mut seq = scripted(9_000.0, BudgetPolicy::WaterFill, &tenants).sequential();
        par.run(3);
        seq.run(3);
        assert_eq!(
            format!("{:?}", par.history()),
            format!("{:?}", seq.history()),
            "thread scheduling must never change a trajectory"
        );
    }

    #[test]
    fn cached_tenants_hit_across_rounds_with_per_tenant_epochs() {
        // Tenant 0's surface drifts mid-hold (steps at env window 5);
        // tenant 1 never shifts. The drift restart must bump only
        // tenant 0's epoch, while tenant 1 collects hits from its
        // re-measured allocation and the presets every round re-probes.
        let mut arb = TenantArbiter::new(10_000.0, BudgetPolicy::DemandWeighted)
            .budget_iters(3)
            .hold_windows(6)
            .cached(true);
        arb.add_tenant(spec(0, 20.0, 1.0), Box::new(StepEnv::new(5).with_power(3_000.0)), 7);
        arb.add_tenant(
            spec(1, 20.0, 1.0),
            Box::new(StepEnv::constant().with_levels(25.0, 25.0).with_power(3_000.0)),
            8,
        );
        arb.run(2);
        let stats = arb.tenant_cache_stats();
        let s0 = stats[0].expect("tenant 0 cached");
        let s1 = stats[1].expect("tenant 1 cached");
        assert!(s0.epoch >= 1, "drifting tenant bumped its own epoch: {s0:?}");
        assert_eq!(s1.epoch, 0, "steady tenant untouched by the neighbour's drift");
        assert!(s1.hits > 0, "re-measured allocations and presets hit the store");
        assert!(s1.refreshes > 0, "hold windows measured fresh");
        let merged = arb.cache_stats().expect("cached tenants merge through the arbiter");
        assert_eq!(merged.hits, s0.hits + s1.hits);
        assert_eq!(merged.epoch, s0.epoch.max(s1.epoch));
    }

    #[test]
    fn cached_parallel_rounds_match_sequential_byte_for_byte() {
        let tenants = [(20.0, 30.0, 3_000.0), (10.0, 12.0, 2_500.0)];
        let mk = |sequential: bool| {
            let mut arb = TenantArbiter::new(9_000.0, BudgetPolicy::WaterFill)
                .budget_iters(3)
                .hold_windows(6)
                .cached(true);
            if sequential {
                arb = arb.sequential();
            }
            for (i, &(target, fps, power)) in tenants.iter().enumerate() {
                let env = StepEnv::constant().with_levels(fps, fps).with_power(power);
                arb.add_tenant(spec(i, target, 1.0), Box::new(env), 0x5EED + i as u64);
            }
            arb
        };
        let mut par = mk(false);
        let mut seq = mk(true);
        par.run(3);
        seq.run(3);
        assert_eq!(
            format!("{:?}", par.history()),
            format!("{:?}", seq.history()),
            "caching must not make trajectories schedule-dependent"
        );
        assert_eq!(
            format!("{:?}", par.tenant_cache_stats()),
            format!("{:?}", seq.tenant_cache_stats())
        );
    }

    #[test]
    fn sub_budgets_never_exceed_global_for_any_policy() {
        // The arbiter's safety invariant, adversarially: random tenant
        // mixes, weights, targets, scripted drifting surfaces (so some
        // rounds restart on drift), all three policies, three rounds
        // each — Σ sub-budgets ≤ global on every round.
        prop::check("tenant sub-budget safety", 120, |g| {
            let n = g.rng.range_usize(1, 3);
            let global = g.rng.range_f64(3_000.0, 20_000.0);
            let policy = match g.rng.below(3) {
                0 => {
                    let raw = g.vec_f64(n, 0.05, 1.0);
                    let sum: f64 = raw.iter().sum();
                    BudgetPolicy::Static(raw.iter().map(|r| r / sum).collect())
                }
                1 => BudgetPolicy::DemandWeighted,
                _ => BudgetPolicy::WaterFill,
            };
            let mut arb = TenantArbiter::new(global, policy)
                .budget_iters(3)
                .hold_windows(6);
            for i in 0..n {
                let t = spec(
                    i,
                    g.rng.range_f64(5.0, 40.0),
                    g.rng.range_f64(0.5, 8.0),
                );
                let fps = g.rng.range_f64(8.0, 35.0);
                let env = StepEnv::new(g.rng.range_usize(2, 9) as u64)
                    .with_levels(fps, fps * 0.5)
                    .with_power(g.rng.range_f64(1_000.0, 9_000.0));
                arb.add_tenant(t, Box::new(env), g.rng.next_u64());
            }
            for _ in 0..3 {
                let pre: f64 = arb.sub_budgets().iter().sum();
                prop::assert_true(
                    pre <= global * (1.0 + 1e-9),
                    "pre-round sub-budget sum exceeds the global budget",
                )?;
                let report = arb.run_round();
                let sum: f64 = report.tenants.iter().map(|t| t.sub_budget_mw).sum();
                prop::assert_true(
                    sum <= global * (1.0 + 1e-9),
                    "round sub-budget sum exceeds the global budget",
                )?;
                prop::assert_true(
                    report.tenants.iter().all(|t| t.sub_budget_mw >= 0.0),
                    "negative sub-budget",
                )?;
            }
            Ok(())
        });
    }
}
