//! PJRT CPU client wrapper: artifact loading, one-time compilation, and
//! batched execution with pre-allocated input reuse.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::models::{ArtifactInfo, Manifest, ModelKind};

/// Decoded detector output for one image.
#[derive(Debug, Clone, PartialEq)]
pub struct Detections {
    /// `(x1, y1, x2, y2)` corner boxes, model-input pixel space.
    pub boxes: Vec<[f32; 4]>,
    /// Confidence per box (objectness × best class).
    pub scores: Vec<f32>,
}

impl Detections {
    /// Boxes above a confidence threshold.
    pub fn above(&self, threshold: f32) -> Vec<([f32; 4], f32)> {
        self.boxes
            .iter()
            .zip(&self.scores)
            .filter(|(_, &s)| s >= threshold)
            .map(|(&b, &s)| (b, s))
            .collect()
    }
}

/// The process-wide PJRT client (compile + execute).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load(&self, info: &ArtifactInfo) -> Result<CompiledModel> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&info.path)
            .with_context(|| format!("parsing HLO text {}", info.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", info.path.display()))?;
        log::info!(
            "compiled {} (batch {}) in {:.2}s",
            info.path.display(),
            info.batch,
            t0.elapsed().as_secs_f64()
        );
        Ok(CompiledModel {
            exe: Arc::new(exe),
            batch: info.batch,
            input_shape: info.input_shape,
            predictions: info.predictions,
        })
    }

    /// Load every batch variant of `model` listed in the manifest.
    pub fn load_model(&self, manifest: &Manifest, model: ModelKind) -> Result<ModelRuntime> {
        let infos = manifest.for_model(model);
        if infos.is_empty() {
            bail!("manifest has no artifacts for model {model}");
        }
        let mut variants = Vec::new();
        for info in infos {
            variants.push(self.load(info)?);
        }
        Ok(ModelRuntime { model, variants })
    }
}

/// One compiled (model, batch) executable.
#[derive(Clone)]
pub struct CompiledModel {
    exe: Arc<xla::PjRtLoadedExecutable>,
    /// Compiled batch size.
    pub batch: usize,
    /// NHWC input shape.
    pub input_shape: [usize; 4],
    /// Predictions per image.
    pub predictions: usize,
}

impl CompiledModel {
    /// Elements of one input image (H·W·C).
    pub fn image_elems(&self) -> usize {
        self.input_shape[1] * self.input_shape[2] * self.input_shape[3]
    }

    /// Run a full batch: `pixels` must hold exactly `batch` images,
    /// flattened NHWC f32 in [0, 1]. Returns per-image detections.
    pub fn infer(&self, pixels: &[f32]) -> Result<Vec<Detections>> {
        let want = self.batch * self.image_elems();
        if pixels.len() != want {
            bail!("input has {} floats, executable expects {}", pixels.len(), want);
        }
        let dims: Vec<i64> = self.input_shape.iter().map(|&d| d as i64).collect();
        let input = xla::Literal::vec1(pixels)
            .reshape(&dims)
            .context("reshaping input literal")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[input])
            .context("executing detector")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True: (boxes[B,P,4], scores[B,P]).
        let (boxes_lit, scores_lit) =
            result.to_tuple2().context("unpacking (boxes, scores) tuple")?;
        let boxes_flat = boxes_lit.to_vec::<f32>()?;
        let scores_flat = scores_lit.to_vec::<f32>()?;
        let p = self.predictions;
        if boxes_flat.len() != self.batch * p * 4 || scores_flat.len() != self.batch * p {
            bail!(
                "unexpected output sizes: boxes {} scores {} (batch {} × {} preds)",
                boxes_flat.len(),
                scores_flat.len(),
                self.batch,
                p
            );
        }
        let mut out = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let boxes = (0..p)
                .map(|i| {
                    let o = (b * p + i) * 4;
                    [boxes_flat[o], boxes_flat[o + 1], boxes_flat[o + 2], boxes_flat[o + 3]]
                })
                .collect();
            let scores = scores_flat[b * p..(b + 1) * p].to_vec();
            out.push(Detections { boxes, scores });
        }
        Ok(out)
    }
}

/// All compiled batch variants of one model; dispatches a request batch
/// to the smallest executable that fits (padding the tail).
pub struct ModelRuntime {
    pub model: ModelKind,
    variants: Vec<CompiledModel>,
}

impl ModelRuntime {
    /// Supported batch sizes (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.variants.iter().map(|v| v.batch).collect()
    }

    /// Largest supported batch.
    pub fn max_batch(&self) -> usize {
        self.variants.last().map(|v| v.batch).unwrap_or(0)
    }

    /// Input image side (square).
    pub fn input_side(&self) -> usize {
        self.variants[0].input_shape[1]
    }

    /// Smallest variant with `batch >= n` (None if n exceeds the max).
    pub fn variant_for(&self, n: usize) -> Option<&CompiledModel> {
        self.variants.iter().find(|v| v.batch >= n)
    }

    /// Run `n` images (flattened NHWC, n·H·W·C floats), padding up to the
    /// chosen executable's batch; returns exactly `n` detections.
    pub fn infer(&self, pixels: &[f32], n: usize) -> Result<Vec<Detections>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        let variant = self
            .variant_for(n)
            .ok_or_else(|| anyhow::anyhow!("batch {n} exceeds max {}", self.max_batch()))?;
        let per = variant.image_elems();
        if pixels.len() != n * per {
            bail!("expected {} floats for {} images, got {}", n * per, n, pixels.len());
        }
        let mut padded;
        let input = if variant.batch == n {
            pixels
        } else {
            padded = vec![0.0f32; variant.batch * per];
            padded[..pixels.len()].copy_from_slice(pixels);
            &padded[..]
        };
        let mut dets = variant.infer(input)?;
        dets.truncate(n);
        Ok(dets)
    }
}

#[cfg(test)]
mod tests {
    // PJRT integration tests live in rust/tests/runtime_integration.rs
    // (they need `make artifacts`). Pure-logic units here:
    use super::*;

    #[test]
    fn detections_threshold_filter() {
        let d = Detections {
            boxes: vec![[0.0, 0.0, 1.0, 1.0], [1.0, 1.0, 2.0, 2.0]],
            scores: vec![0.9, 0.2],
        };
        let kept = d.above(0.5);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].1, 0.9);
    }
}
