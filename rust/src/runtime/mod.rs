//! PJRT inference runtime — the serving hot path.
//!
//! Loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` (`make artifacts`), compiles them once on the
//! PJRT CPU client at start-up, and executes them per batch. Python never
//! runs here; the interchange is HLO text because the image's
//! xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id serialized protos
//! (see /opt/xla-example/README.md and DESIGN.md §1).

pub mod client;

pub use client::{CompiledModel, Detections, ModelRuntime, PjrtRuntime};
