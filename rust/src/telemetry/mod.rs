//! tegrastats/sysstat-style telemetry (paper §IV-A measurement setup).
//!
//! A [`Sampler`] polls a metric source at a fixed period into ring
//! buffers, skipping an initial warm-up (the paper starts measuring 2 s
//! after inference starts and updates every second). [`MetricsWindow`]
//! aggregates a window into the mean values the optimizer consumes, and
//! the serving coordinator reuses the same ring buffers for its
//! fps/latency gauges.

pub mod ring;
pub mod sampler;

pub use ring::RingBuffer;
pub use sampler::{MetricsWindow, Sample, Sampler};
