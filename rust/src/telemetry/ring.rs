//! Fixed-capacity ring buffer for metric samples.

/// Overwriting ring buffer of f64 samples.
#[derive(Debug, Clone)]
pub struct RingBuffer {
    buf: Vec<f64>,
    head: usize,
    len: usize,
}

impl RingBuffer {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "ring capacity must be positive");
        RingBuffer { buf: vec![0.0; cap], head: 0, len: 0 }
    }

    pub fn push(&mut self, v: f64) {
        self.buf[self.head] = v;
        self.head = (self.head + 1) % self.buf.len();
        self.len = (self.len + 1).min(self.buf.len());
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Samples oldest → newest.
    pub fn to_vec(&self) -> Vec<f64> {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(|i| self.buf[(start + i) % cap]).collect()
    }

    /// Most recent sample.
    pub fn last(&self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        let cap = self.buf.len();
        Some(self.buf[(self.head + cap - 1) % cap])
    }

    pub fn mean(&self) -> f64 {
        if self.len == 0 {
            return f64::NAN;
        }
        self.to_vec().iter().sum::<f64>() / self.len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_overwrites() {
        let mut r = RingBuffer::new(3);
        assert!(r.is_empty());
        for i in 1..=5 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.to_vec(), vec![3.0, 4.0, 5.0]);
        assert_eq!(r.last(), Some(5.0));
        assert!((r.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn partial_fill_ordering() {
        let mut r = RingBuffer::new(4);
        r.push(7.0);
        r.push(8.0);
        assert_eq!(r.to_vec(), vec![7.0, 8.0]);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn empty_stats() {
        let r = RingBuffer::new(2);
        assert!(r.mean().is_nan());
        assert_eq!(r.last(), None);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_capacity_rejected() {
        RingBuffer::new(0);
    }
}
