//! Metric sampler with the paper's measurement discipline: skip the
//! first `warmup` samples after a configuration change, then record at a
//! fixed period (1 Hz in the paper; time is logical here — the device
//! simulator and the serving loop both tick it).

use super::ring::RingBuffer;

/// One instantaneous sample (tegrastats line equivalent).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub throughput_fps: f64,
    pub power_mw: f64,
    pub gpu_util: f64,
    pub cpu_util: f64,
    pub mem_util: f64,
}

/// Aggregated view over the retained samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsWindow {
    pub samples: usize,
    pub throughput_fps: f64,
    pub power_mw: f64,
    pub gpu_util: f64,
    pub cpu_util: f64,
    pub mem_util: f64,
}

/// Warm-up-aware sampler over ring buffers.
#[derive(Debug, Clone)]
pub struct Sampler {
    warmup: usize,
    skipped: usize,
    tput: RingBuffer,
    power: RingBuffer,
    gpu: RingBuffer,
    cpu: RingBuffer,
    mem: RingBuffer,
}

impl Sampler {
    /// `warmup`: samples discarded after (re)start; `window`: retained
    /// sample count. The paper uses warmup = 2 (2 s at 1 Hz).
    pub fn new(warmup: usize, window: usize) -> Sampler {
        Sampler {
            warmup,
            skipped: 0,
            tput: RingBuffer::new(window),
            power: RingBuffer::new(window),
            gpu: RingBuffer::new(window),
            cpu: RingBuffer::new(window),
            mem: RingBuffer::new(window),
        }
    }

    /// Paper defaults: 2 s warm-up, 5-sample window.
    pub fn paper_default() -> Sampler {
        Sampler::new(2, 5)
    }

    /// Paper warm-up discipline with an arbitrarily large retained
    /// window — the fleet-telemetry configuration (W = 100 / 1k / 10k;
    /// see `experiments::scenarios::WINDOW_SCENARIOS`). Large histories
    /// feed the O(n log n) dCor engine via [`Sampler::throughput_series`]
    /// / [`Sampler::power_series`].
    pub fn with_window(window: usize) -> Sampler {
        Sampler::new(2, window)
    }

    /// Retained-window capacity (samples).
    pub fn window_capacity(&self) -> usize {
        self.tput.capacity()
    }

    /// Restart warm-up (configuration change).
    pub fn reset(&mut self) {
        *self = Sampler::new(self.warmup, self.tput.capacity());
    }

    /// Record one periodic sample; warm-up samples are discarded.
    /// Returns true if the sample was retained.
    ///
    /// Non-finite fields are sanitized to 0.0 before retention: the
    /// window means and the columnar dCor series downstream assume
    /// finite inputs, and one degenerate serving window (zero wall,
    /// dead worker pool) must not poison a whole retained history.
    pub fn record(&mut self, s: Sample) -> bool {
        if self.skipped < self.warmup {
            self.skipped += 1;
            return false;
        }
        let finite = |v: f64| if v.is_finite() { v } else { 0.0 };
        self.tput.push(finite(s.throughput_fps));
        self.power.push(finite(s.power_mw));
        self.gpu.push(finite(s.gpu_util));
        self.cpu.push(finite(s.cpu_util));
        self.mem.push(finite(s.mem_util));
        true
    }

    /// Retained-sample count.
    pub fn len(&self) -> usize {
        self.tput.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tput.is_empty()
    }

    /// Retained throughput samples, oldest → newest (columnar series for
    /// the correlation analysis).
    pub fn throughput_series(&self) -> Vec<f64> {
        self.tput.to_vec()
    }

    /// Retained power samples, oldest → newest.
    pub fn power_series(&self) -> Vec<f64> {
        self.power.to_vec()
    }

    /// Aggregate the retained samples (None until at least one retained).
    pub fn window(&self) -> Option<MetricsWindow> {
        if self.tput.is_empty() {
            return None;
        }
        Some(MetricsWindow {
            samples: self.tput.len(),
            throughput_fps: self.tput.mean(),
            power_mw: self.power.mean(),
            gpu_util: self.gpu.mean(),
            cpu_util: self.cpu.mean(),
            mem_util: self.mem.mean(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(t: f64, p: f64) -> Sample {
        Sample { throughput_fps: t, power_mw: p, gpu_util: 0.5, cpu_util: 0.25, mem_util: 0.1 }
    }

    #[test]
    fn warmup_samples_discarded() {
        let mut sm = Sampler::paper_default();
        assert!(!sm.record(s(1.0, 1.0)));
        assert!(!sm.record(s(2.0, 2.0)));
        assert!(sm.record(s(30.0, 6000.0)));
        let w = sm.window().unwrap();
        assert_eq!(w.samples, 1);
        assert_eq!(w.throughput_fps, 30.0);
    }

    #[test]
    fn window_means() {
        let mut sm = Sampler::new(0, 3);
        sm.record(s(10.0, 100.0));
        sm.record(s(20.0, 200.0));
        let w = sm.window().unwrap();
        assert!((w.throughput_fps - 15.0).abs() < 1e-12);
        assert!((w.power_mw - 150.0).abs() < 1e-12);
    }

    #[test]
    fn reset_restarts_warmup() {
        let mut sm = Sampler::new(1, 4);
        sm.record(s(1.0, 1.0));
        sm.record(s(2.0, 2.0));
        assert_eq!(sm.len(), 1);
        sm.reset();
        assert!(sm.window().is_none());
        assert!(!sm.record(s(3.0, 3.0)), "warm-up again after reset");
    }

    #[test]
    fn large_window_series_feed_dcor() {
        // Fleet-scale history: W=1000 retained samples flow straight into
        // the dCor workspace (fast path at this n) as columnar series.
        let mut sm = Sampler::with_window(1000);
        assert_eq!(sm.window_capacity(), 1000);
        for i in 0..1500 {
            sm.record(s(i as f64, 2.0 * i as f64));
        }
        assert_eq!(sm.len(), 1000);
        let t = sm.throughput_series();
        let p = sm.power_series();
        // Warm-up skips i = 0, 1; ring keeps the last 1000 retained.
        assert_eq!(t[0], 500.0);
        assert_eq!(t[999], 1499.0);
        let mut ws = crate::stats::dcov::DcorWorkspace::new();
        let m = ws.dcor_matrix(&[&t], std::slice::from_ref(&p));
        assert!((m[0][0] - 1.0).abs() < 1e-6, "linear series: dcor={}", m[0][0]);
    }

    #[test]
    fn non_finite_samples_sanitized() {
        // A degenerate serving window (inf fps from a zero-wall report,
        // NaN from a failed run) must not poison the retained means or
        // the dCor series with non-finite values.
        let mut sm = Sampler::new(0, 4);
        sm.record(s(f64::INFINITY, f64::NAN));
        sm.record(s(30.0, 6000.0));
        let w = sm.window().unwrap();
        assert!(w.throughput_fps.is_finite());
        assert!(w.power_mw.is_finite());
        assert!((w.throughput_fps - 15.0).abs() < 1e-12, "inf recorded as 0");
        assert!(sm.throughput_series().iter().all(|v| v.is_finite()));
        assert!(sm.power_series().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rolling_window_bounded() {
        let mut sm = Sampler::new(0, 2);
        for i in 0..10 {
            sm.record(s(i as f64, 0.0));
        }
        let w = sm.window().unwrap();
        assert_eq!(w.samples, 2);
        assert!((w.throughput_fps - 8.5).abs() < 1e-12);
    }
}
