//! Metric sampler with the paper's measurement discipline: skip the
//! first `warmup` samples after a configuration change, then record at a
//! fixed period (1 Hz in the paper; time is logical here — the device
//! simulator and the serving loop both tick it).

use super::ring::RingBuffer;

/// One instantaneous sample (tegrastats line equivalent).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub throughput_fps: f64,
    pub power_mw: f64,
    pub gpu_util: f64,
    pub cpu_util: f64,
    pub mem_util: f64,
}

/// Aggregated view over the retained samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsWindow {
    pub samples: usize,
    pub throughput_fps: f64,
    pub power_mw: f64,
    pub gpu_util: f64,
    pub cpu_util: f64,
    pub mem_util: f64,
    /// Post-warm-up samples dropped because a field was non-finite (a
    /// sensor glitch — NaN tegrastats line, inf from a zero-wall
    /// report). Glitches are *dropped*, never recorded as zeros: a
    /// zeroed glitch reads as a throughput collapse and falsely fires
    /// drift (`control::DriftDetector`), which is exactly the failure
    /// mode the chaos scenarios exercise.
    pub glitches: usize,
}

/// Warm-up-aware sampler over ring buffers.
#[derive(Debug, Clone)]
pub struct Sampler {
    warmup: usize,
    skipped: usize,
    /// Post-warm-up samples dropped for carrying a non-finite field
    /// (since the last [`Sampler::reset`]).
    glitches: usize,
    tput: RingBuffer,
    power: RingBuffer,
    gpu: RingBuffer,
    cpu: RingBuffer,
    mem: RingBuffer,
}

impl Sampler {
    /// `warmup`: samples discarded after (re)start; `window`: retained
    /// sample count. The paper uses warmup = 2 (2 s at 1 Hz).
    pub fn new(warmup: usize, window: usize) -> Sampler {
        Sampler {
            warmup,
            skipped: 0,
            glitches: 0,
            tput: RingBuffer::new(window),
            power: RingBuffer::new(window),
            gpu: RingBuffer::new(window),
            cpu: RingBuffer::new(window),
            mem: RingBuffer::new(window),
        }
    }

    /// Paper defaults: 2 s warm-up, 5-sample window.
    pub fn paper_default() -> Sampler {
        Sampler::new(2, 5)
    }

    /// Paper warm-up discipline with an arbitrarily large retained
    /// window — the fleet-telemetry configuration (W = 100 / 1k / 10k;
    /// see `experiments::scenarios::WINDOW_SCENARIOS`). Large histories
    /// feed the O(n log n) dCor engine via [`Sampler::throughput_series`]
    /// / [`Sampler::power_series`].
    pub fn with_window(window: usize) -> Sampler {
        Sampler::new(2, window)
    }

    /// Retained-window capacity (samples).
    pub fn window_capacity(&self) -> usize {
        self.tput.capacity()
    }

    /// Restart warm-up (configuration change).
    pub fn reset(&mut self) {
        *self = Sampler::new(self.warmup, self.tput.capacity());
    }

    /// Record one periodic sample; warm-up samples are discarded.
    /// Returns true if the sample was retained.
    ///
    /// A sample with any non-finite field is a sensor glitch (NaN
    /// tegrastats line, inf from a zero-wall report): it is **dropped
    /// whole** — nothing retained in any series — and counted in
    /// [`MetricsWindow::glitches`]. The historical sanitize-to-0.0
    /// behavior made a NaN burst indistinguishable from a real
    /// throughput collapse, deflating window means and falsely firing
    /// drift; dropping keeps the retained history finite *and* honest.
    pub fn record(&mut self, s: Sample) -> bool {
        if self.skipped < self.warmup {
            self.skipped += 1;
            return false;
        }
        let finite = s.throughput_fps.is_finite()
            && s.power_mw.is_finite()
            && s.gpu_util.is_finite()
            && s.cpu_util.is_finite()
            && s.mem_util.is_finite();
        if !finite {
            self.glitches += 1;
            return false;
        }
        self.tput.push(s.throughput_fps);
        self.power.push(s.power_mw);
        self.gpu.push(s.gpu_util);
        self.cpu.push(s.cpu_util);
        self.mem.push(s.mem_util);
        true
    }

    /// Post-warm-up samples dropped as glitches since the last reset.
    pub fn glitches(&self) -> usize {
        self.glitches
    }

    /// Retained-sample count.
    pub fn len(&self) -> usize {
        self.tput.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tput.is_empty()
    }

    /// Retained throughput samples, oldest → newest (columnar series for
    /// the correlation analysis).
    pub fn throughput_series(&self) -> Vec<f64> {
        self.tput.to_vec()
    }

    /// Retained power samples, oldest → newest.
    pub fn power_series(&self) -> Vec<f64> {
        self.power.to_vec()
    }

    /// Aggregate the retained samples (None until at least one retained).
    pub fn window(&self) -> Option<MetricsWindow> {
        if self.tput.is_empty() {
            return None;
        }
        Some(MetricsWindow {
            samples: self.tput.len(),
            throughput_fps: self.tput.mean(),
            power_mw: self.power.mean(),
            gpu_util: self.gpu.mean(),
            cpu_util: self.cpu.mean(),
            mem_util: self.mem.mean(),
            glitches: self.glitches,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(t: f64, p: f64) -> Sample {
        Sample { throughput_fps: t, power_mw: p, gpu_util: 0.5, cpu_util: 0.25, mem_util: 0.1 }
    }

    #[test]
    fn warmup_samples_discarded() {
        let mut sm = Sampler::paper_default();
        assert!(!sm.record(s(1.0, 1.0)));
        assert!(!sm.record(s(2.0, 2.0)));
        assert!(sm.record(s(30.0, 6000.0)));
        let w = sm.window().unwrap();
        assert_eq!(w.samples, 1);
        assert_eq!(w.throughput_fps, 30.0);
    }

    #[test]
    fn window_means() {
        let mut sm = Sampler::new(0, 3);
        sm.record(s(10.0, 100.0));
        sm.record(s(20.0, 200.0));
        let w = sm.window().unwrap();
        assert!((w.throughput_fps - 15.0).abs() < 1e-12);
        assert!((w.power_mw - 150.0).abs() < 1e-12);
    }

    #[test]
    fn reset_restarts_warmup() {
        let mut sm = Sampler::new(1, 4);
        sm.record(s(1.0, 1.0));
        sm.record(s(2.0, 2.0));
        assert_eq!(sm.len(), 1);
        sm.reset();
        assert!(sm.window().is_none());
        assert!(!sm.record(s(3.0, 3.0)), "warm-up again after reset");
    }

    #[test]
    fn large_window_series_feed_dcor() {
        // Fleet-scale history: W=1000 retained samples flow straight into
        // the dCor workspace (fast path at this n) as columnar series.
        let mut sm = Sampler::with_window(1000);
        assert_eq!(sm.window_capacity(), 1000);
        for i in 0..1500 {
            sm.record(s(i as f64, 2.0 * i as f64));
        }
        assert_eq!(sm.len(), 1000);
        let t = sm.throughput_series();
        let p = sm.power_series();
        // Warm-up skips i = 0, 1; ring keeps the last 1000 retained.
        assert_eq!(t[0], 500.0);
        assert_eq!(t[999], 1499.0);
        let mut ws = crate::stats::dcov::DcorWorkspace::new();
        let m = ws.dcor_matrix(&[&t], std::slice::from_ref(&p));
        assert!((m[0][0] - 1.0).abs() < 1e-6, "linear series: dcor={}", m[0][0]);
    }

    #[test]
    fn non_finite_samples_dropped_and_counted() {
        // A glitched sample (inf fps from a zero-wall report, NaN from a
        // dead sensor) is dropped whole — not sanitized to 0.0, which
        // read as a throughput collapse — and shows up in the window's
        // glitch counter instead.
        let mut sm = Sampler::new(0, 4);
        assert!(!sm.record(s(f64::INFINITY, f64::NAN)), "glitch not retained");
        assert!(sm.record(s(30.0, 6000.0)));
        assert_eq!(sm.glitches(), 1);
        let w = sm.window().unwrap();
        assert_eq!(w.samples, 1, "only the clean sample retained");
        assert_eq!(w.glitches, 1);
        assert!((w.throughput_fps - 30.0).abs() < 1e-12, "mean undeflated by the glitch");
        assert!(sm.throughput_series().iter().all(|v| v.is_finite()));
        assert!(sm.power_series().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn reset_clears_the_glitch_counter() {
        let mut sm = Sampler::new(0, 4);
        sm.record(s(f64::NAN, 1.0));
        assert_eq!(sm.glitches(), 1);
        sm.reset();
        assert_eq!(sm.glitches(), 0, "per-configuration counter");
    }

    #[test]
    fn rolling_window_bounded() {
        let mut sm = Sampler::new(0, 2);
        for i in 0..10 {
            sm.record(s(i as f64, 0.0));
        }
        let w = sm.window().unwrap();
        assert_eq!(w.samples, 2);
        assert!((w.throughput_fps - 8.5).abs() < 1e-12);
    }
}
