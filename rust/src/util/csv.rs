//! Tiny CSV writer/reader for experiment results (`results/*.csv`).
//!
//! Handles quoting (commas, quotes, newlines in fields) — enough for the
//! figure/table data this repo emits and reads back in tests.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// In-memory CSV table: header + rows.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row; panics if the arity differs from the header (a row
    /// with the wrong arity is always a bug in the experiment harness).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.header.len(),
            "csv row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Append a row of displayable values.
    pub fn push_display(&mut self, row: &[&dyn std::fmt::Display]) {
        self.push(row.iter().map(|v| format!("{v}")).collect());
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Serialize to CSV text.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_text())
    }

    /// Parse CSV text (first row = header).
    pub fn parse(text: &str) -> Result<Csv, String> {
        let mut rows = parse_rows(text)?;
        if rows.is_empty() {
            return Err("empty csv".into());
        }
        let header = rows.remove(0);
        for (i, row) in rows.iter().enumerate() {
            if row.len() != header.len() {
                return Err(format!(
                    "row {} has {} fields, header has {}",
                    i + 1,
                    row.len(),
                    header.len()
                ));
            }
        }
        Ok(Csv { header, rows })
    }
}

fn needs_quote(field: &str) -> bool {
    field.contains(',') || field.contains('"') || field.contains('\n') || field.contains('\r')
}

fn write_row(out: &mut String, row: &[String]) {
    for (i, field) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quote(field) {
            let _ = write!(out, "\"{}\"", field.replace('"', "\"\""));
        } else {
            out.push_str(field);
        }
    }
    out.push('\n');
}

fn parse_rows(text: &str) -> Result<Vec<Vec<String>>, String> {
    let mut rows = Vec::new();
    let mut row = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;

    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' if field.is_empty() => in_quotes = true,
                '"' => return Err("quote inside unquoted field".into()),
                ',' => row.push(std::mem::take(&mut field)),
                '\r' => {}
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quoted field".into());
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn simple_round_trip() {
        let mut csv = Csv::new(&["a", "b"]);
        csv.push(vec!["1".into(), "x".into()]);
        csv.push(vec!["2".into(), "y".into()]);
        let back = Csv::parse(&csv.to_text()).unwrap();
        assert_eq!(back, csv);
    }

    #[test]
    fn quoting_round_trip() {
        let mut csv = Csv::new(&["msg", "n"]);
        csv.push(vec!["hello, \"world\"\nline2".into(), "7".into()]);
        let back = Csv::parse(&csv.to_text()).unwrap();
        assert_eq!(back, csv);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut csv = Csv::new(&["a", "b"]);
        csv.push(vec!["1".into()]);
    }

    #[test]
    fn parse_rejects_ragged_rows() {
        assert!(Csv::parse("a,b\n1\n").is_err());
    }

    #[test]
    fn parse_rejects_empty() {
        assert!(Csv::parse("").is_err());
    }

    #[test]
    fn col_lookup() {
        let csv = Csv::new(&["alpha", "beta"]);
        assert_eq!(csv.col("beta"), Some(1));
        assert_eq!(csv.col("gamma"), None);
    }

    #[test]
    fn crlf_tolerated() {
        let csv = Csv::parse("a,b\r\n1,2\r\n").unwrap();
        assert_eq!(csv.rows, vec![vec!["1".to_string(), "2".to_string()]]);
    }

    #[test]
    fn prop_round_trip() {
        prop::check("csv round trip", 150, |g| {
            let cols = g.rng.range_usize(1, 5);
            let header: Vec<String> =
                (0..cols).map(|i| format!("c{i}")).collect();
            let mut csv = Csv { header, rows: Vec::new() };
            for _ in 0..g.rng.below(6) {
                csv.push((0..cols).map(|_| g.string(6)).collect());
            }
            let back = Csv::parse(&csv.to_text()).map_err(|e| e.to_string())?;
            prop::assert_eq_dbg(&back, &csv)
        });
    }

    #[test]
    fn save_creates_dirs() {
        let dir = std::env::temp_dir().join("coral_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("sub").join("t.csv");
        let mut csv = Csv::new(&["x"]);
        csv.push(vec!["1".into()]);
        csv.save(&path).unwrap();
        assert!(path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
