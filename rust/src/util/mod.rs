//! Std-only substrates.
//!
//! The offline crate mirror ships neither `rand`, `serde`, `serde_json`,
//! `csv`, `proptest` nor `criterion`, so the pieces of those crates this
//! project needs are implemented here from scratch (DESIGN.md §9). Each
//! submodule is small, fully tested, and used across the whole stack.

pub mod bench;
pub mod csv;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod table;

pub use rng::Rng;
