//! Logger backing the `log` facade: level filter from `CORAL_LOG`
//! (error|warn|info|debug|trace, default info), timestamps relative to
//! process start, writes to stderr so stdout stays machine-parseable.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INSTALLED: AtomicBool = AtomicBool::new(false);

struct CoralLogger {
    start: Instant,
}

impl log::Log for CoralLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed();
        let level = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>9.3}s {} {}] {}",
            t.as_secs_f64(),
            level,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Parse a level name (case-insensitive).
pub fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger once; honours `CORAL_LOG`. Safe to call repeatedly
/// (tests, examples): later calls only adjust the max level.
pub fn init() {
    let level = std::env::var("CORAL_LOG")
        .ok()
        .and_then(|v| parse_level(&v))
        .unwrap_or(LevelFilter::Info);
    if INSTALLED
        .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
        .is_ok()
    {
        let logger = Box::leak(Box::new(CoralLogger { start: Instant::now() }));
        let _ = log::set_logger(logger);
    }
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("TRACE"), Some(LevelFilter::Trace));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logger alive");
    }
}
