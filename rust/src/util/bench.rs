//! Micro-benchmark harness (the offline mirror has no `criterion`).
//!
//! Provides warm-up, calibrated iteration counts, and robust summary
//! statistics (mean / p50 / p99 / min). `cargo bench` targets are
//! `harness = false` binaries built on this module; each prints one row
//! per measurement in a stable, greppable format:
//!
//! ```text
//! bench <name> ... mean=12.3µs p50=12.1µs p99=14.0µs min=11.8µs iters=100000
//! ```

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl Measurement {
    /// ns per iteration (mean).
    pub fn ns_per_iter(&self) -> f64 {
        self.mean.as_nanos() as f64
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bench {:<44} mean={} p50={} p99={} min={} iters={}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p99),
            fmt_dur(self.min),
            self.iters
        )
    }
}

/// Benchmark runner with a wall-clock budget per measurement.
pub struct Bencher {
    /// Target wall time spent measuring each benchmark.
    pub budget: Duration,
    /// Number of timed samples (each sample runs a batch of iterations).
    pub samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(Duration::from_millis(300), 30)
    }
}

impl Bencher {
    pub fn new(budget: Duration, samples: usize) -> Self {
        Bencher { budget, samples, results: Vec::new() }
    }

    /// Benchmark `f`, printing and recording the measurement.
    /// `f` should return something observable to defeat DCE; its return
    /// value is passed through `std::hint::black_box`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warm-up + calibration: find iters/sample so that one sample
        // costs roughly budget / samples.
        let mut iters_per_sample = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed();
            if dt >= self.budget / (self.samples as u32) || iters_per_sample > (1 << 30) {
                break;
            }
            iters_per_sample = (iters_per_sample * 2).max(1);
        }

        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed();
            per_iter.push(dt.as_secs_f64() / iters_per_sample as f64);
            total_iters += iters_per_sample;
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let dur = |s: f64| Duration::from_secs_f64(s.max(0.0));
        let mean = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: total_iters,
            mean: dur(mean),
            p50: dur(percentile(&per_iter, 50.0)),
            p99: dur(percentile(&per_iter, 99.0)),
            min: dur(per_iter[0]),
        };
        println!("{m}");
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// All measurements so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Percentile over an already-sorted slice (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(Duration::from_millis(20), 5);
        let m = b.bench("noop-ish", || 1 + 1).clone();
        assert!(m.iters > 0);
        assert!(m.mean.as_nanos() < 1_000_000);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn display_formats() {
        let m = Measurement {
            name: "x".into(),
            iters: 10,
            mean: Duration::from_micros(12),
            p50: Duration::from_nanos(900),
            p99: Duration::from_millis(3),
            min: Duration::from_secs(2),
        };
        let s = format!("{m}");
        assert!(s.contains("µs") && s.contains("ns") && s.contains("ms") && s.contains("2.000s"));
    }
}
