//! ASCII table rendering for experiment reports (`coral experiment`,
//! `coral report`) — right-pads columns, aligns numbers right.

/// Render a table with a header row. Numeric-looking cells are
/// right-aligned, text cells left-aligned.
pub fn render(header: &[&str], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    for r in rows {
        assert_eq!(r.len(), cols, "table row arity mismatch");
    }
    let mut width = vec![0usize; cols];
    for (i, h) in header.iter().enumerate() {
        width[i] = h.chars().count();
    }
    for r in rows {
        for (i, cell) in r.iter().enumerate() {
            width[i] = width[i].max(cell.chars().count());
        }
    }

    let sep: String = {
        let mut s = String::from("+");
        for w in &width {
            s.push_str(&"-".repeat(w + 2));
            s.push('+');
        }
        s.push('\n');
        s
    };

    let fmt_row = |cells: &[String]| -> String {
        let mut s = String::from("|");
        for (i, cell) in cells.iter().enumerate() {
            let pad = width[i] - cell.chars().count();
            if is_numeric(cell) {
                s.push_str(&format!(" {}{} |", " ".repeat(pad), cell));
            } else {
                s.push_str(&format!(" {}{} |", cell, " ".repeat(pad)));
            }
        }
        s.push('\n');
        s
    };

    let mut out = sep.clone();
    out.push_str(&fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push_str(&sep);
    for r in rows {
        out.push_str(&fmt_row(r));
    }
    out.push_str(&sep);
    out
}

fn is_numeric(s: &str) -> bool {
    !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'E' | '%' | ','))
        && s.chars().any(|c| c.is_ascii_digit())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let out = render(
            &["name", "fps"],
            &[
                vec!["coral".into(), "33.1".into()],
                vec!["oracle-longer".into(), "34".into()],
            ],
        );
        assert!(out.contains("| name          | fps  |"));
        assert!(out.contains("| coral         | 33.1 |"));
        assert!(out.contains("| oracle-longer |   34 |"));
        // 3 separator lines (top, after header, bottom), 3 '+' each.
        assert_eq!(out.matches('+').count(), 9);
    }

    #[test]
    fn numeric_detection() {
        assert!(is_numeric("42"));
        assert!(is_numeric("-3.5"));
        assert!(is_numeric("96%"));
        assert!(!is_numeric("x86"));
        assert!(!is_numeric(""));
        assert!(!is_numeric("--"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn ragged_rows_panic() {
        render(&["a", "b"], &[vec!["1".into()]]);
    }

    #[test]
    fn unicode_width_by_chars() {
        let out = render(&["m"], &[vec!["é".into()]]);
        assert!(out.contains("| é |"));
    }
}
