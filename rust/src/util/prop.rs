//! Mini property-testing harness (the offline mirror has no `proptest`).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded generators; a
//! failing case re-runs with its seed printed so it can be replayed with
//! `check_seed`. Generators are deliberately simple — uniform draws over
//! caller-provided ranges — which matches how the paper's spaces look
//! (small discrete grids, bounded floats).

use super::rng::Rng;

/// Generator context handed to each property case.
pub struct Gen {
    pub rng: Rng,
    /// Seed of this case (for replay).
    pub seed: u64,
}

impl Gen {
    /// Random ASCII-ish string of length ≤ max_len (includes escapes-worthy chars).
    pub fn string(&mut self, max_len: usize) -> String {
        const ALPHABET: &[char] =
            &['a', 'b', 'z', 'é', '"', '\\', '\n', '\t', ' ', '0', '9', '{', '['];
        let n = self.rng.below(max_len + 1);
        (0..n).map(|_| *self.rng.choose(ALPHABET)).collect()
    }

    /// Vector of f64 drawn uniformly from [lo, hi).
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.range_f64(lo, hi)).collect()
    }

    /// Vector of usize in [lo, hi].
    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.rng.range_usize(lo, hi)).collect()
    }
}

/// Run a property over `cases` random cases. Panics (with the failing
/// seed) on the first counterexample.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0A1_u64
            .wrapping_mul(0x100)
            .wrapping_add(case)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = f(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 replay with util::prop::check_seed({seed:#x}, ...)"
            );
        }
    }
}

/// Replay a single seed (used when debugging a failure).
pub fn check_seed<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen { rng: Rng::new(seed), seed };
    if let Err(msg) = f(&mut g) {
        panic!("property failed on replay seed {seed:#x}: {msg}");
    }
}

/// Assertion helpers returning Result so properties compose with `?`.
pub fn assert_true(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn assert_eq_dbg<T: PartialEq + std::fmt::Debug>(a: &T, b: &T) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{a:?} != {b:?}"))
    }
}

/// |a−b| ≤ tol.
pub fn assert_close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{a} !≈ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn failing_property_panics_with_seed() {
        check("boom", 10, |g| {
            if g.rng.f64() < 2.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100, |g| {
            let v = g.vec_f64(10, -3.0, 7.0);
            assert_true(v.iter().all(|x| (-3.0..7.0).contains(x)), "f64 range")?;
            let u = g.vec_usize(10, 2, 5);
            assert_true(u.iter().all(|x| (2..=5).contains(x)), "usize range")
        });
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(assert_close(1.0, 2.0, 0.5).is_err());
    }

    #[test]
    fn seeds_are_deterministic() {
        let mut first = Vec::new();
        check("record", 5, |g| {
            first.push(g.rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 5, |g| {
            second.push(g.rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
