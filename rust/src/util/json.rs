//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Used for `artifacts/manifest.json`, experiment result files and config
//! files. Implements the full JSON grammar (RFC 8259) minus `\u` surrogate
//! pairs beyond the BMP; numbers parse as f64 (adequate: the manifest's
//! biggest integers are byte counts ≪ 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order for deterministic output.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructor: object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, message: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, message: format!("bad number '{text}'") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn error_reports_offset() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn pretty_round_trip() {
        let v = Json::parse(r#"{"b":[1,2.5,"s"],"a":{"k":true}}"#).unwrap();
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn escapes_round_trip() {
        let s = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&s.to_string_compact()).unwrap(), s);
    }

    /// Property: any randomly generated value round-trips through
    /// serialize → parse exactly.
    #[test]
    fn prop_round_trip() {
        prop::check("json round trip", 200, |g| {
            let v = gen_json(g, 3);
            let compact = v.to_string_compact();
            let back = Json::parse(&compact)
                .map_err(|e| format!("{e} in {compact}"))?;
            prop::assert_eq_dbg(&back, &v)
        });
    }

    fn gen_json(g: &mut prop::Gen, depth: usize) -> Json {
        match if depth == 0 { g.rng.below(4) } else { g.rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(g.rng.chance(0.5)),
            2 => {
                // Round-trippable numbers: modest integers or exact halves.
                let n = g.rng.range_f64(-1e6, 1e6).round() / 2.0;
                Json::Num(n)
            }
            3 => Json::Str(g.string(8)),
            4 => {
                let n = g.rng.below(4);
                Json::Arr((0..n).map(|_| gen_json(g, depth - 1)).collect())
            }
            _ => {
                let n = g.rng.below(4);
                Json::Obj(
                    (0..n)
                        .map(|_| (g.string(5), gen_json(g, depth - 1)))
                        .collect(),
                )
            }
        }
    }
}
