//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** generation.
//!
//! Every stochastic component in the stack (device measurement noise,
//! ALERT-Online's random trials, workload generation, property tests)
//! draws from this generator so that experiments are exactly reproducible
//! from a seed recorded in the results CSV.

/// xoshiro256** (Blackman & Vigna) seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (stable, order-sensitive).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for
    /// simulation purposes; exact rejection for small n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Multiplicative lognormal-ish noise factor centred on 1.0 with
    /// relative sigma `rel` — the telemetry measurement-noise primitive.
    pub fn noise_factor(&mut self, rel: f64) -> f64 {
        (self.normal() * rel).exp()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }
}

/// Stateless hash → f64 in [0,1): the device model's per-configuration
/// "chip lottery" (deterministic jitter that is *consistent across visits*
/// to the same configuration, unlike the telemetry noise stream).
pub fn hash_unit(parts: &[u64]) -> f64 {
    let mut state = 0x243F_6A88_85A3_08D3u64;
    for &p in parts {
        state ^= p.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let _ = splitmix64(&mut state);
        state = splitmix64(&mut state);
    }
    (state >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproducible_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_usize_inclusive() {
        let mut r = Rng::new(13);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range_usize(3, 5);
            assert!((3..=5).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(21);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn hash_unit_deterministic_and_spread() {
        assert_eq!(hash_unit(&[1, 2, 3]), hash_unit(&[1, 2, 3]));
        assert_ne!(hash_unit(&[1, 2, 3]), hash_unit(&[1, 2, 4]));
        let xs: Vec<f64> = (0..1000).map(|i| hash_unit(&[i, 42])).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.05);
        assert!(xs.iter().all(|x| (0.0..1.0).contains(x)));
    }

    #[test]
    fn noise_factor_centred_on_one() {
        let mut r = Rng::new(31);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.noise_factor(0.015)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }
}
