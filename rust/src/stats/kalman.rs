//! Scalar Kalman filter — the estimator inside the ALERT baseline
//! (Wan et al., ATC'20): tracks the ratio between observed and profiled
//! performance of the *current environment* so offline profiles can be
//! corrected online.

/// 1-D Kalman filter with random-walk state model:
/// `x_k = x_{k-1} + w`, `z_k = x_k + v`, `w ~ N(0,q)`, `v ~ N(0,r)`.
#[derive(Debug, Clone)]
pub struct Kalman1d {
    /// State estimate.
    x: f64,
    /// Estimate variance.
    p: f64,
    /// Process noise.
    q: f64,
    /// Measurement noise.
    r: f64,
}

impl Kalman1d {
    /// Create with initial estimate `x0` / variance `p0`.
    pub fn new(x0: f64, p0: f64, q: f64, r: f64) -> Self {
        assert!(p0 >= 0.0 && q >= 0.0 && r > 0.0, "bad kalman parameters");
        Kalman1d { x: x0, p: p0, q, r }
    }

    /// ALERT's defaults: wide prior around 1.0 (observed == profiled).
    pub fn alert_default() -> Self {
        Kalman1d::new(1.0, 1.0, 1e-3, 1e-2)
    }

    /// Fold in a measurement, returning the posterior estimate.
    pub fn update(&mut self, z: f64) -> f64 {
        // Predict.
        let p_pred = self.p + self.q;
        // Update.
        let k = p_pred / (p_pred + self.r);
        self.x += k * (z - self.x);
        self.p = (1.0 - k) * p_pred;
        self.x
    }

    /// Current estimate.
    pub fn estimate(&self) -> f64 {
        self.x
    }

    /// Current estimate variance.
    pub fn variance(&self) -> f64 {
        self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn converges_to_constant_signal() {
        let mut kf = Kalman1d::new(0.0, 1.0, 1e-4, 1e-2);
        for _ in 0..200 {
            kf.update(5.0);
        }
        assert!((kf.estimate() - 5.0).abs() < 0.01, "x={}", kf.estimate());
    }

    #[test]
    fn variance_shrinks_with_evidence() {
        let mut kf = Kalman1d::new(0.0, 1.0, 1e-5, 1e-2);
        let v0 = kf.variance();
        for _ in 0..50 {
            kf.update(1.0);
        }
        assert!(kf.variance() < v0 / 10.0);
    }

    #[test]
    fn filters_noise_better_than_raw() {
        let mut r = Rng::new(4);
        let truth = 2.5;
        let mut kf = Kalman1d::new(0.0, 1.0, 1e-4, 0.25);
        let mut last_raw = 0.0;
        for _ in 0..300 {
            let z = truth + r.normal() * 0.5;
            kf.update(z);
            last_raw = z;
        }
        assert!((kf.estimate() - truth).abs() < (last_raw - truth).abs() + 0.5);
        assert!((kf.estimate() - truth).abs() < 0.2, "x={}", kf.estimate());
    }

    #[test]
    fn tracks_slow_drift() {
        let mut kf = Kalman1d::new(0.0, 1.0, 1e-2, 1e-2);
        let mut truth = 0.0;
        for _ in 0..500 {
            truth += 0.01;
            kf.update(truth);
        }
        assert!((kf.estimate() - truth).abs() < 0.1);
    }

    #[test]
    #[should_panic(expected = "bad kalman")]
    fn rejects_zero_measurement_noise() {
        Kalman1d::new(0.0, 1.0, 0.0, 0.0);
    }
}
