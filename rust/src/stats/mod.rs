//! Statistical substrates: distance covariance / correlation (the paper's
//! core instrument, §II-A2 Eq. 1–4) with both the matrix reference and an
//! exact O(n log n) engine for large windows, a scalar Kalman filter
//! (ALERT's estimator), sliding observation windows, and summary helpers.

pub mod dcov;
pub mod fastdcov;
pub mod kalman;
pub mod summary;
pub mod window;

pub use dcov::{dcor, dcov2, DcorWorkspace, FAST_PATH_MIN_N};
pub use fastdcov::{dcor_fast, dcov2_fast, FastDcov};
pub use kalman::Kalman1d;
pub use window::SlidingWindow;
