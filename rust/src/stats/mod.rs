//! Statistical substrates: distance covariance / correlation (the paper's
//! core instrument, §II-A2 Eq. 1–4), a scalar Kalman filter (ALERT's
//! estimator), sliding observation windows, and summary helpers.

pub mod dcov;
pub mod kalman;
pub mod summary;
pub mod window;

pub use dcov::{dcor, dcov2, DcorWorkspace};
pub use kalman::Kalman1d;
pub use window::SlidingWindow;
