//! Summary statistics over metric series: mean, variance, percentiles —
//! used by telemetry aggregation and the experiment reports.

/// Running mean/variance (Welford) — single pass, numerically stable.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of an unsorted slice (copies + sorts; p in [0,100]).
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    crate::util::bench::percentile(&v, p)
}

/// Mean of a slice (NaN for empty).
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn running_matches_direct() {
        prop::check("welford == direct", 80, |g| {
            let n = g.rng.range_usize(1, 50);
            let xs = g.vec_f64(n, -100.0, 100.0);
            let mut r = Running::new();
            for &x in &xs {
                r.push(x);
            }
            let m = mean(&xs);
            let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n as f64;
            prop::assert_close(r.mean(), m, 1e-9)?;
            prop::assert_close(r.variance(), var, 1e-6)?;
            prop::assert_close(r.min(), xs.iter().cloned().fold(f64::INFINITY, f64::min), 0.0)?;
            prop::assert_close(r.max(), xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max), 0.0)
        });
    }

    #[test]
    fn empty_running_is_nan() {
        let r = Running::new();
        assert!(r.mean().is_nan());
        assert!(r.variance().is_nan());
        assert_eq!(r.count(), 0);
    }

    #[test]
    fn percentile_unsorted_input() {
        let v = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
