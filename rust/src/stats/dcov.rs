//! Distance covariance and distance correlation (Székely & Rizzo 2009).
//!
//! The paper's Eq. 1–4: for paired samples of a metric `m` (throughput or
//! power) and a hardware setting `s`,
//!
//! ```text
//! a_ij = |m_i − m_j|                       (pairwise distances)
//! A_ij = a_ij − ā_i· − ā_·j + ā_··         (double centering)
//! dCov²(m,s) = (1/n²) Σ_ij A_ij B_ij
//! dCor(m,s)  = dCov(m,s) / √(dCov(m,m)·dCov(s,s))
//! ```
//!
//! dCor ∈ [0, 1]; 0 ⇔ statistical independence (in the population
//! version), and it detects arbitrary non-linear dependence — the reason
//! the paper prefers it to Pearson correlation for DVFS spaces.
//!
//! Three implementations:
//! * [`dcor`] / [`dcov2`] — allocation-per-call matrix reference, used by
//!   tests and as the ground truth the fast path is verified against.
//! * [`super::fastdcov`] — exact O(n log n) univariate engine with O(n)
//!   scratch (no n×n matrix), for large sliding windows.
//! * [`DcorWorkspace`] — the optimizer's hot path (called every
//!   iteration; see EXPERIMENTS.md §Perf): reusable buffers + a fused
//!   pass computing dCor(τ, s_i) and dCor(p, s_i) for all parameter
//!   dimensions at once, auto-dispatching to the matrix path below
//!   [`FAST_PATH_MIN_N`] observations and the fast engine above it.

use super::fastdcov::FastDcov;

/// Window size at which [`DcorWorkspace`] switches from the O(n²) matrix
/// path to the O(n log n) engine. Below this the matrix fits in cache and
/// its constant factor wins; above it the asymptotics dominate (see
/// EXPERIMENTS.md §Perf and `benches/bench_dcov.rs`).
pub const FAST_PATH_MIN_N: usize = 64;

/// Double-centered distance "matrix" stored row-major, plus its own
/// dCov²(x,x) (needed for normalization).
#[derive(Debug, Clone)]
struct Centered {
    n: usize,
    m: Vec<f64>,
    self_dcov2: f64,
}

/// Center `x` into a freshly built matrix, handing the buffer to the
/// returned [`Centered`] (no copy — the reference path used to clone the
/// full n×n buffer here).
fn center(x: &[f64], row_means: &mut Vec<f64>) -> Centered {
    let n = x.len();
    let mut buf = vec![0.0; n * n];
    row_means.clear();
    row_means.resize(n, 0.0);

    // Pairwise |x_i − x_j| with row sums (symmetric: rows == cols means).
    let mut grand = 0.0;
    for i in 0..n {
        for j in 0..n {
            let d = (x[i] - x[j]).abs();
            buf[i * n + j] = d;
            row_means[i] += d;
        }
        grand += row_means[i];
        row_means[i] /= n as f64;
    }
    grand /= (n * n) as f64;

    let mut self_dcov2 = 0.0;
    for i in 0..n {
        for j in 0..n {
            let c = buf[i * n + j] - row_means[i] - row_means[j] + grand;
            buf[i * n + j] = c;
            self_dcov2 += c * c;
        }
    }
    Centered { n, m: buf, self_dcov2: self_dcov2 / (n * n) as f64 }
}

/// dCov²(x, y). Panics if lengths differ; returns 0 for n < 2.
pub fn dcov2(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dcov2: length mismatch");
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let mut rm = Vec::new();
    let cx = center(x, &mut rm);
    let cy = center(y, &mut rm);
    let mut s = 0.0;
    for i in 0..n * n {
        s += cx.m[i] * cy.m[i];
    }
    (s / (n * n) as f64).max(0.0)
}

/// dCor(x, y) ∈ [0, 1]. Returns 0 when either marginal is constant
/// (dCov(x,x) = 0) — a constant setting carries no signal.
pub fn dcor(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dcor: length mismatch");
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let mut rm = Vec::new();
    let cx = center(x, &mut rm);
    let cy = center(y, &mut rm);
    normalized(&cx, &cy)
}

fn normalized(cx: &Centered, cy: &Centered) -> f64 {
    debug_assert_eq!(cx.n, cy.n);
    let denom = cx.self_dcov2 * cy.self_dcov2;
    if denom <= 0.0 {
        return 0.0;
    }
    let n = cx.n;
    let mut s = 0.0;
    for i in 0..n * n {
        s += cx.m[i] * cy.m[i];
    }
    let d2 = (s / (n * n) as f64).max(0.0);
    (d2 / denom.sqrt()).sqrt().clamp(0.0, 1.0)
}

/// Reusable workspace computing dCor of two metrics against many setting
/// dimensions — the optimizer's per-iteration correlation analysis
/// (§III-D) in one call.
///
/// §Perf: unlike the reference path, the workspace (a) centers/preps each
/// metric once and reuses it across all setting dimensions, (b) keeps
/// every buffer across calls (zero steady-state allocation), (c) exploits
/// the symmetry of distance matrices on the small-n path (≈2× fewer
/// FLOPs), and (d) above [`FAST_PATH_MIN_N`] switches to the exact
/// O(n log n) [`FastDcov`] engine, which never materializes an n×n
/// matrix. See EXPERIMENTS.md §Perf for the methodology and
/// `benches/bench_dcov.rs` for before/after.
#[derive(Debug, Default)]
pub struct DcorWorkspace {
    /// One persistent centered matrix per metric (matrix path).
    metric_mats: Vec<Vec<f64>>,
    metric_self: Vec<f64>,
    /// Persistent centered matrix for the current setting dim.
    setting_mat: Vec<f64>,
    row_sums: Vec<f64>,
    /// O(n log n) engine for large windows.
    fast: FastDcov,
}

/// Symmetric in-place double-centering; returns dCov²(x, x).
fn center_sym(x: &[f64], m: &mut Vec<f64>, row_sums: &mut Vec<f64>) -> f64 {
    let n = x.len();
    m.clear();
    m.resize(n * n, 0.0);
    row_sums.clear();
    row_sums.resize(n, 0.0);

    // Upper triangle of |x_i − x_j|, mirrored; diagonal is 0.
    for i in 0..n {
        let xi = x[i];
        for j in (i + 1)..n {
            let d = (xi - x[j]).abs();
            m[i * n + j] = d;
            m[j * n + i] = d;
            row_sums[i] += d;
            row_sums[j] += d;
        }
    }
    let grand = row_sums.iter().sum::<f64>() / (n * n) as f64;
    let inv_n = 1.0 / n as f64;

    // Centering + self product, upper triangle ×2 plus diagonal.
    let mut self_sum = 0.0;
    for i in 0..n {
        let rmi = row_sums[i] * inv_n;
        let cd = -rmi - rmi + grand; // diagonal: a_ii = 0
        m[i * n + i] = cd;
        self_sum += cd * cd;
        for j in (i + 1)..n {
            let c = m[i * n + j] - rmi - row_sums[j] * inv_n + grand;
            m[i * n + j] = c;
            m[j * n + i] = c;
            self_sum += 2.0 * c * c;
        }
    }
    self_sum / (n * n) as f64
}

/// Σ A∘B over symmetric matrices via the upper triangle.
fn product_sym(a: &[f64], b: &[f64], n: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..n {
        s += a[i * n + i] * b[i * n + i];
        let mut row = 0.0;
        for j in (i + 1)..n {
            row += a[i * n + j] * b[i * n + j];
        }
        s += 2.0 * row;
    }
    s
}

impl DcorWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Compute `out[k][d] = dCor(metrics[k], settings[d])` for all metric
    /// series (throughput, power) × setting dimensions. Each series must
    /// have the same length n; for n < 2 all correlations are 0.
    ///
    /// Settings are accepted as anything slice-like (`Vec<f64>` or
    /// `&[f64]`), so the sliding window's zero-copy columnar views feed
    /// in directly.
    pub fn dcor_matrix<S: AsRef<[f64]>>(
        &mut self,
        metrics: &[&[f64]],
        settings: &[S],
    ) -> Vec<Vec<f64>> {
        let n = metrics.first().map(|m| m.len()).unwrap_or(0);
        for m in metrics {
            assert_eq!(m.len(), n, "metric length mismatch");
        }
        for s in settings {
            assert_eq!(s.as_ref().len(), n, "setting length mismatch");
        }
        if n < 2 {
            return vec![vec![0.0; settings.len()]; metrics.len()];
        }
        if n >= FAST_PATH_MIN_N {
            // Large windows: O(n log n), O(n) scratch, no n×n matrix.
            return self.fast.dcor_matrix(metrics, settings);
        }

        // Small windows: center each metric once (reused across dims).
        self.metric_mats.resize_with(metrics.len(), Vec::new);
        self.metric_self.clear();
        for (k, m) in metrics.iter().enumerate() {
            let s = center_sym(m, &mut self.metric_mats[k], &mut self.row_sums);
            self.metric_self.push(s);
        }

        let mut out = vec![vec![0.0; settings.len()]; metrics.len()];
        let n2 = (n * n) as f64;
        for (d, s) in settings.iter().enumerate() {
            let s_self =
                center_sym(s.as_ref(), &mut self.setting_mat, &mut self.row_sums);
            for k in 0..metrics.len() {
                let denom = self.metric_self[k] * s_self;
                if denom <= 0.0 {
                    continue; // constant series ⇒ dCor = 0
                }
                let d2 = (product_sym(&self.metric_mats[k], &self.setting_mat, n)
                    / n2)
                    .max(0.0);
                out[k][d] = (d2 / denom.sqrt()).sqrt().clamp(0.0, 1.0);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn paper_worked_example() {
        // §III-D: τ, p, s_cpu from the paper's illustration. The paper
        // reports dCor ≈ 0.94 (throughput) and ≈ 0.99 (power).
        let tput = [15.2, 16.1, 15.8, 14.9, 15.5];
        let power = [9800.0, 10100.0, 10050.0, 9500.0, 9750.0];
        let cpu = [1200.0, 1400.0, 1400.0, 1000.0, 1200.0];
        let a = dcor(&tput, &cpu);
        let b = dcor(&power, &cpu);
        assert!((a - 0.94).abs() < 0.03, "alpha={a}");
        assert!((b - 0.99).abs() < 0.03, "beta={b}");
        assert!(b > a, "power correlation should dominate: {b} vs {a}");
    }

    #[test]
    fn perfect_linear_dependence_is_one() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 7.0).collect();
        assert!((dcor(&x, &y) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn nonlinear_dependence_detected() {
        // y = x² on symmetric support: Pearson ≈ 0, dCor must be well > 0.
        let x: Vec<f64> = (-10..=10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| v * v).collect();
        let pearson = {
            let mx = 0.0;
            let my = y.iter().sum::<f64>() / y.len() as f64;
            let cov: f64 =
                x.iter().zip(&y).map(|(a, b)| (a - mx) * (b - my)).sum();
            let vx: f64 = x.iter().map(|a| (a - mx).powi(2)).sum();
            let vy: f64 = y.iter().map(|b| (b - my).powi(2)).sum();
            cov / (vx * vy).sqrt()
        };
        assert!(pearson.abs() < 1e-9, "pearson={pearson}");
        assert!(dcor(&x, &y) > 0.4, "dcor={}", dcor(&x, &y));
    }

    #[test]
    fn independent_samples_near_zero() {
        let mut r = Rng::new(99);
        let n = 200;
        let x: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let y: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let d = dcor(&x, &y);
        // Finite-sample bias keeps this above 0; it must still be small.
        assert!(d < 0.25, "dcor={d}");
    }

    #[test]
    fn constant_series_gives_zero() {
        let x = [5.0; 6];
        let y = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(dcor(&x, &y), 0.0);
        assert_eq!(dcor(&y, &x), 0.0);
    }

    #[test]
    fn tiny_n_is_zero() {
        assert_eq!(dcor(&[1.0], &[2.0]), 0.0);
        assert_eq!(dcor(&[], &[]), 0.0);
    }

    #[test]
    fn dcov2_nonnegative_and_symmetric() {
        prop::check("dcov2 sym + nonneg", 60, |g| {
            let n = g.rng.range_usize(2, 12);
            let x = g.vec_f64(n, -10.0, 10.0);
            let y = g.vec_f64(n, -10.0, 10.0);
            let xy = dcov2(&x, &y);
            let yx = dcov2(&y, &x);
            prop::assert_true(xy >= 0.0, "nonneg")?;
            prop::assert_close(xy, yx, 1e-9)
        });
    }

    #[test]
    fn dcor_bounds_and_symmetry() {
        prop::check("dcor in [0,1], symmetric", 60, |g| {
            let n = g.rng.range_usize(2, 12);
            let x = g.vec_f64(n, -100.0, 100.0);
            let y = g.vec_f64(n, -100.0, 100.0);
            let d = dcor(&x, &y);
            prop::assert_true((0.0..=1.0).contains(&d), "bounds")?;
            prop::assert_close(d, dcor(&y, &x), 1e-9)
        });
    }

    #[test]
    fn dcor_invariant_to_affine_transforms() {
        // dCor(a + bx, c + dy) == dCor(x, y) for b, d > 0.
        prop::check("dcor affine invariance", 40, |g| {
            let n = g.rng.range_usize(3, 10);
            let x = g.vec_f64(n, -5.0, 5.0);
            let y = g.vec_f64(n, -5.0, 5.0);
            let b = g.rng.range_f64(0.1, 10.0);
            let d = g.rng.range_f64(0.1, 10.0);
            let xs: Vec<f64> = x.iter().map(|v| 3.0 + b * v).collect();
            let ys: Vec<f64> = y.iter().map(|v| -2.0 + d * v).collect();
            prop::assert_close(dcor(&xs, &ys), dcor(&x, &y), 1e-7)
        });
    }

    #[test]
    fn self_correlation_is_one() {
        let x = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0];
        assert!((dcor(&x, &x) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn workspace_matches_reference() {
        prop::check("workspace == reference dcor", 40, |g| {
            let n = g.rng.range_usize(2, 10);
            let tput = g.vec_f64(n, 0.0, 100.0);
            let power = g.vec_f64(n, 3000.0, 12000.0);
            let dims: Vec<Vec<f64>> =
                (0..5).map(|_| g.vec_f64(n, 0.0, 2000.0)).collect();
            let mut ws = DcorWorkspace::new();
            let got = ws.dcor_matrix(&[&tput, &power], &dims);
            for (d, s) in dims.iter().enumerate() {
                prop::assert_close(got[0][d], dcor(&tput, s), 1e-9)?;
                prop::assert_close(got[1][d], dcor(&power, s), 1e-9)?;
            }
            Ok(())
        });
    }

    #[test]
    fn workspace_dispatch_matches_reference_above_threshold() {
        // Same workspace call, n ≥ FAST_PATH_MIN_N → fast engine; the
        // answer must still match the matrix reference to 1e-9.
        prop::check("workspace fast dispatch == reference", 15, |g| {
            let n = FAST_PATH_MIN_N + g.rng.range_usize(0, 80);
            let tput = g.vec_f64(n, 0.0, 100.0);
            let power = g.vec_f64(n, 3000.0, 12000.0);
            let mut dims: Vec<Vec<f64>> =
                (0..4).map(|_| g.vec_f64(n, 0.0, 2000.0)).collect();
            dims.push(vec![42.0; n]); // constant dim ⇒ exactly 0
            let mut ws = DcorWorkspace::new();
            let got = ws.dcor_matrix(&[&tput, &power], &dims);
            for (d, s) in dims.iter().enumerate() {
                prop::assert_close(got[0][d], dcor(&tput, s), 1e-9)?;
                prop::assert_close(got[1][d], dcor(&power, s), 1e-9)?;
            }
            prop::assert_close(got[0][4], 0.0, 0.0)
        });
    }

    #[test]
    fn workspace_dispatch_is_continuous_at_threshold() {
        // Crossing the threshold must not produce a visible jump: both
        // paths compute the same statistic on the same data.
        let mut r = Rng::new(41);
        let base: Vec<f64> = (0..FAST_PATH_MIN_N + 1).map(|_| r.f64()).collect();
        let dep: Vec<f64> =
            base.iter().map(|v| (6.0 * v).sin() + 0.1 * v).collect();
        let mut ws = DcorWorkspace::new();
        let below = ws.dcor_matrix(
            &[&base[..FAST_PATH_MIN_N - 1]],
            &[dep[..FAST_PATH_MIN_N - 1].to_vec()],
        )[0][0];
        let above = ws.dcor_matrix(
            &[&base[..FAST_PATH_MIN_N + 1]],
            &[dep[..FAST_PATH_MIN_N + 1].to_vec()],
        )[0][0];
        assert!((below - above).abs() < 0.2, "below={below} above={above}");
        assert!(
            (above - dcor(&base[..FAST_PATH_MIN_N + 1], &dep[..FAST_PATH_MIN_N + 1]))
                .abs()
                < 1e-9
        );
    }

    #[test]
    fn workspace_empty_and_tiny() {
        let mut ws = DcorWorkspace::new();
        let out = ws.dcor_matrix(&[&[], &[]], &vec![vec![]; 3]);
        assert_eq!(out, vec![vec![0.0; 3]; 2]);
        let out = ws.dcor_matrix(&[&[1.0], &[2.0]], &vec![vec![3.0]; 2]);
        assert_eq!(out, vec![vec![0.0; 2]; 2]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        dcor(&[1.0, 2.0], &[1.0]);
    }
}
