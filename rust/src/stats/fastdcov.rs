//! Exact O(n log n) univariate distance covariance / correlation
//! (Huo & Székely, *Fast Computing for Distance Covariance*, 2016).
//!
//! The reference path in [`super::dcov`] materializes the n×n
//! double-centered distance matrices — O(n²) time **and memory**, which
//! caps the sliding window at toy sizes. For univariate series (all of
//! CORAL's metrics and setting dimensions are scalars) the same sample
//! statistic decomposes exactly:
//!
//! ```text
//! n²·dCov²(x,y) = D/n⁰ − 2/n·Σᵢ aᵢ·bᵢ· /1 + a··b··/n² ,  i.e.
//! dCov²(x,y) = D/n² − (2/n³)·Σᵢ aᵢ·bᵢ·  + a··b··/n⁴
//! ```
//!
//! where `aᵢ· = Σⱼ|xᵢ−xⱼ|` (row sums), `a·· = Σᵢⱼ|xᵢ−xⱼ|`, and
//! `D = Σᵢⱼ|xᵢ−xⱼ||yᵢ−yⱼ|`. Row sums fall out of one sort + prefix sums
//! (O(n log n)); `D` is the hard term — after sorting by `x`, the sign of
//! `(yⱼ−yᵢ)` splits each pair into "concordant" and "discordant" halves,
//! and a Fenwick tree over `y`-ranks accumulates the four running sums
//! (count, Σx, Σy, Σxy) needed to evaluate all pairs in O(n log n) with
//! O(n) scratch — no n×n buffer anywhere.
//!
//! [`FastDcov`] keeps every buffer across calls (zero steady-state
//! allocation) and is what [`super::dcov::DcorWorkspace`] dispatches to
//! above [`super::dcov::FAST_PATH_MIN_N`]. Equivalence with the matrix
//! reference (to 1e-9, including ties, constants and affine transforms)
//! is property-tested below; the asymptotic win is measured by
//! `benches/bench_dcov.rs` (see EXPERIMENTS.md §Perf).

/// Per-series O(n log n) precomputation, reused across pair scans.
#[derive(Debug, Clone, Default)]
struct SeriesPrep {
    /// Indices sorted ascending by value.
    order: Vec<u32>,
    /// 1-based rank of each original index in value order (ties get
    /// distinct adjacent ranks; tied pairs contribute |Δy| = 0 either
    /// way, so the tie-break never changes the statistic).
    rank: Vec<u32>,
    /// Distance-matrix row sums aᵢ· aligned to original indices.
    row_sums: Vec<f64>,
    /// Grand sum a··.
    sum: f64,
    /// dCov²(x, x) — the normalization term.
    self_d: f64,
    /// All values identical ⇒ every distance is exactly 0.
    constant: bool,
}

/// Reusable O(n log n) distance-covariance engine.
///
/// Scratch is O(n) per retained series plus one Fenwick tree — call
/// [`FastDcov::scratch_elems`] to audit (the matrix path needs n² per
/// centered series).
#[derive(Debug, Clone, Default)]
pub struct FastDcov {
    preps: Vec<SeriesPrep>,
    /// Fenwick tree over y-ranks: (count, Σx, Σy, Σxy) per node.
    bit: Vec<[f64; 4]>,
}

/// Sort + prefix-sum precomputation for one series.
fn prep_series(x: &[f64], p: &mut SeriesPrep) {
    let n = x.len();
    p.order.clear();
    p.order.extend(0..n as u32);
    p.order
        .sort_unstable_by(|&a, &b| x[a as usize].total_cmp(&x[b as usize]));
    p.rank.clear();
    p.rank.resize(n, 0);
    for (pos, &i) in p.order.iter().enumerate() {
        p.rank[i as usize] = pos as u32 + 1;
    }
    p.row_sums.clear();
    p.row_sums.resize(n, 0.0);
    p.constant = n == 0 || x[p.order[0] as usize] == x[p.order[n - 1] as usize];
    if p.constant {
        // Every |xᵢ−xⱼ| is exactly 0: short-circuit so the fast path
        // agrees bit-for-bit with the matrix path's "constant ⇒ 0".
        p.sum = 0.0;
        p.self_d = 0.0;
        return;
    }

    // Row sums via the sorted order: for the k-th smallest value,
    // Σⱼ|xᵢ−xⱼ| = xᵢ·(#smaller) − Σsmaller + Σlarger − xᵢ·(#larger).
    let total: f64 = x.iter().sum();
    let mut prefix = 0.0;
    for (k, &oi) in p.order.iter().enumerate() {
        let i = oi as usize;
        let xi = x[i];
        let suffix = total - prefix - xi;
        p.row_sums[i] =
            xi * k as f64 - prefix + suffix - xi * (n - 1 - k) as f64;
        prefix += xi;
    }
    p.sum = p.row_sums.iter().sum();

    // dCov²(x,x) needs no pair scan: Σᵢⱼ aᵢⱼ² = Σᵢⱼ(xᵢ−xⱼ)² = 2nΣ(x−x̄)²
    // (the centered form avoids the 2nΣx²−(Σx)² cancellation).
    let n_f = n as f64;
    let mean = total / n_f;
    let ss: f64 = x
        .iter()
        .map(|v| {
            let d = v - mean;
            d * d
        })
        .sum();
    let dxx = 2.0 * n_f * ss;
    let rr: f64 = p.row_sums.iter().map(|r| r * r).sum();
    let n2 = n_f * n_f;
    p.self_d = (dxx / n2 - 2.0 * rr / (n2 * n_f) + (p.sum * p.sum) / (n2 * n2))
        .max(0.0);
}

/// `D = Σᵢⱼ |xᵢ−xⱼ||yᵢ−yⱼ|` in O(n log n).
///
/// Walk indices in ascending-`x` order; for each `j`, every previously
/// inserted `i` has `xᵢ ≤ xⱼ`, so `|xⱼ−xᵢ||yⱼ−yᵢ| = ±(xⱼ−xᵢ)(yⱼ−yᵢ)`
/// with the sign decided by whether `yᵢ ≤ yⱼ`. A Fenwick tree over
/// `y`-ranks yields the (count, Σx, Σy, Σxy) of the `yᵢ ≤ yⱼ` subset in
/// O(log n), and the complement comes from running totals.
fn dist_product_sum(
    bit: &mut Vec<[f64; 4]>,
    x: &[f64],
    y: &[f64],
    x_order: &[u32],
    y_rank: &[u32],
) -> f64 {
    let n = x.len();
    bit.clear();
    bit.resize(n + 1, [0.0; 4]);
    let mut total = [0.0f64; 4];
    let mut acc = 0.0;
    for &oj in x_order {
        let j = oj as usize;
        let xj = x[j];
        let yj = y[j];
        let r = y_rank[j] as usize;

        // Prefix query: inserted points with y-rank ≤ r.
        let mut below = [0.0f64; 4];
        let mut i = r;
        while i > 0 {
            let t = bit[i];
            below[0] += t[0];
            below[1] += t[1];
            below[2] += t[2];
            below[3] += t[3];
            i &= i - 1;
        }
        let above = [
            total[0] - below[0],
            total[1] - below[1],
            total[2] - below[2],
            total[3] - below[3],
        ];
        // (xⱼ−xᵢ)(yⱼ−yᵢ) expanded over both subsets, discordant negated.
        acc += xj * yj * (below[0] - above[0]) - xj * (below[2] - above[2])
            - yj * (below[1] - above[1])
            + (below[3] - above[3]);

        // Insert j for subsequent queries.
        let v = [1.0, xj, yj, xj * yj];
        let mut i = r;
        while i <= n {
            let t = &mut bit[i];
            t[0] += v[0];
            t[1] += v[1];
            t[2] += v[2];
            t[3] += v[3];
            i += i & i.wrapping_neg();
        }
        total[0] += v[0];
        total[1] += v[1];
        total[2] += v[2];
        total[3] += v[3];
    }
    // Unordered pairs were each counted once; the double sum wants both
    // orientations (the diagonal is zero).
    2.0 * acc
}

/// dCov² from two preps + the cross pair-distance sum.
fn cross_dcov2(
    bit: &mut Vec<[f64; 4]>,
    x: &[f64],
    y: &[f64],
    px: &SeriesPrep,
    py: &SeriesPrep,
) -> f64 {
    let n = x.len();
    if n < 2 || px.constant || py.constant {
        return 0.0;
    }
    let n_f = n as f64;
    let n2 = n_f * n_f;
    let d = dist_product_sum(bit, x, y, &px.order, &py.rank);
    let rdot: f64 = px
        .row_sums
        .iter()
        .zip(&py.row_sums)
        .map(|(a, b)| a * b)
        .sum();
    (d / n2 - 2.0 * rdot / (n2 * n_f) + px.sum * py.sum / (n2 * n2)).max(0.0)
}

impl FastDcov {
    pub fn new() -> FastDcov {
        FastDcov::default()
    }

    /// Total scratch elements currently allocated (f64-equivalents) —
    /// O(n) per series; the audit hook for "no n×n allocation".
    pub fn scratch_elems(&self) -> usize {
        self.bit.capacity() * 4
            + self
                .preps
                .iter()
                .map(|p| p.order.capacity() + p.rank.capacity() + p.row_sums.capacity())
                .sum::<usize>()
    }

    fn ensure_slots(&mut self, slots: usize) {
        if self.preps.len() < slots {
            self.preps.resize_with(slots, SeriesPrep::default);
        }
    }

    /// dCov²(x, y) on the fast path. Panics if lengths differ; 0 for
    /// n < 2 or a constant marginal.
    pub fn dcov2_pair(&mut self, x: &[f64], y: &[f64]) -> f64 {
        assert_eq!(x.len(), y.len(), "dcov2: length mismatch");
        if x.len() < 2 {
            return 0.0;
        }
        self.ensure_slots(2);
        let (a, b) = self.preps.split_at_mut(1);
        prep_series(x, &mut a[0]);
        prep_series(y, &mut b[0]);
        cross_dcov2(&mut self.bit, x, y, &a[0], &b[0])
    }

    /// dCor(x, y) ∈ [0, 1] on the fast path (0 when either marginal is
    /// constant, like the reference).
    pub fn dcor_pair(&mut self, x: &[f64], y: &[f64]) -> f64 {
        assert_eq!(x.len(), y.len(), "dcor: length mismatch");
        if x.len() < 2 {
            return 0.0;
        }
        self.ensure_slots(2);
        let (a, b) = self.preps.split_at_mut(1);
        prep_series(x, &mut a[0]);
        prep_series(y, &mut b[0]);
        let denom = a[0].self_d * b[0].self_d;
        if denom <= 0.0 {
            return 0.0;
        }
        let d2 = cross_dcov2(&mut self.bit, x, y, &a[0], &b[0]);
        (d2 / denom.sqrt()).sqrt().clamp(0.0, 1.0)
    }

    /// `out[k][d] = dCor(metrics[k], settings[d])` — the fused
    /// per-iteration call, mirroring
    /// [`super::dcov::DcorWorkspace::dcor_matrix`]: each metric is
    /// prepped once and reused across every setting dimension.
    pub fn dcor_matrix<S: AsRef<[f64]>>(
        &mut self,
        metrics: &[&[f64]],
        settings: &[S],
    ) -> Vec<Vec<f64>> {
        let n = metrics.first().map(|m| m.len()).unwrap_or(0);
        let nm = metrics.len();
        let mut out = vec![vec![0.0; settings.len()]; nm];
        if n < 2 {
            return out;
        }
        self.ensure_slots(nm + 1);
        for (k, m) in metrics.iter().enumerate() {
            assert_eq!(m.len(), n, "metric length mismatch");
            prep_series(m, &mut self.preps[k]);
        }
        let (metric_preps, rest) = self.preps.split_at_mut(nm);
        let sprep = &mut rest[0];
        for (d, s) in settings.iter().enumerate() {
            let s = s.as_ref();
            assert_eq!(s.len(), n, "setting length mismatch");
            prep_series(s, sprep);
            if sprep.constant {
                continue; // dCor = 0 against every metric
            }
            for (k, m) in metrics.iter().enumerate() {
                let mp = &metric_preps[k];
                let denom = mp.self_d * sprep.self_d;
                if denom <= 0.0 {
                    continue;
                }
                let d2 = cross_dcov2(&mut self.bit, m, s, mp, sprep);
                out[k][d] = (d2 / denom.sqrt()).sqrt().clamp(0.0, 1.0);
            }
        }
        out
    }
}

/// One-shot fast dCov² (allocates a fresh engine; reuse [`FastDcov`] on
/// hot paths).
pub fn dcov2_fast(x: &[f64], y: &[f64]) -> f64 {
    FastDcov::new().dcov2_pair(x, y)
}

/// One-shot fast dCor.
pub fn dcor_fast(x: &[f64], y: &[f64]) -> f64 {
    FastDcov::new().dcor_pair(x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dcov::{dcor, dcov2};
    use crate::util::prop;
    use crate::util::rng::Rng;

    const TOL: f64 = 1e-9;

    #[test]
    fn matches_reference_on_random_series() {
        prop::check("fast == matrix dcor/dcov2", 60, |g| {
            let n = g.rng.range_usize(2, 200);
            let x = g.vec_f64(n, -50.0, 50.0);
            let y = g.vec_f64(n, -50.0, 50.0);
            prop::assert_close(dcor_fast(&x, &y), dcor(&x, &y), TOL)?;
            prop::assert_close(dcov2_fast(&x, &y), dcov2(&x, &y), TOL)
        });
    }

    #[test]
    fn matches_reference_with_heavy_ties() {
        // Discrete grids (DVFS settings!) are exactly the tied case.
        prop::check("fast == matrix under ties", 60, |g| {
            let n = g.rng.range_usize(2, 120);
            let x: Vec<f64> =
                g.vec_usize(n, 0, 3).into_iter().map(|v| v as f64).collect();
            let y: Vec<f64> =
                g.vec_usize(n, 0, 2).into_iter().map(|v| 100.0 * v as f64).collect();
            prop::assert_close(dcor_fast(&x, &y), dcor(&x, &y), TOL)?;
            prop::assert_close(dcov2_fast(&x, &y), dcov2(&x, &y), TOL)
        });
    }

    #[test]
    fn matches_reference_under_affine_transforms() {
        prop::check("fast == matrix under affine maps", 40, |g| {
            let n = g.rng.range_usize(3, 150);
            let x = g.vec_f64(n, -5.0, 5.0);
            let y = g.vec_f64(n, -5.0, 5.0);
            let b = g.rng.range_f64(0.1, 10.0);
            let d = g.rng.range_f64(0.1, 10.0);
            let xs: Vec<f64> = x.iter().map(|v| 300.0 + b * v).collect();
            let ys: Vec<f64> = y.iter().map(|v| -70.0 + d * v).collect();
            prop::assert_close(dcor_fast(&xs, &ys), dcor(&xs, &ys), TOL)?;
            // Affine invariance holds on the fast path itself.
            prop::assert_close(dcor_fast(&xs, &ys), dcor_fast(&x, &y), 1e-7)
        });
    }

    #[test]
    fn constants_give_exact_zero() {
        let c = [7.5; 40];
        let y: Vec<f64> = (0..40).map(|i| i as f64).collect();
        assert_eq!(dcor_fast(&c, &y), 0.0);
        assert_eq!(dcor_fast(&y, &c), 0.0);
        assert_eq!(dcov2_fast(&c, &c), 0.0);
        // Near-constant but not constant must still be finite and sane.
        let mut nearly = c;
        nearly[0] += 1e-9;
        let d = dcor_fast(&nearly, &y);
        assert!((0.0..=1.0).contains(&d), "d={d}");
    }

    #[test]
    fn perfect_linear_dependence_is_one() {
        let x: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| -2.0 * v + 11.0).collect();
        assert!((dcor_fast(&x, &y) - 1.0).abs() < 1e-9);
        assert!((dcor_fast(&x, &x) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tiny_n_is_zero() {
        assert_eq!(dcor_fast(&[1.0], &[2.0]), 0.0);
        assert_eq!(dcor_fast(&[], &[]), 0.0);
        assert_eq!(dcov2_fast(&[3.0], &[4.0]), 0.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        dcor_fast(&[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn engine_matrix_matches_pairwise_reference() {
        prop::check("engine dcor_matrix == reference", 25, |g| {
            let n = g.rng.range_usize(2, 90);
            let tput = g.vec_f64(n, 0.0, 100.0);
            let power = g.vec_f64(n, 3000.0, 12000.0);
            let dims: Vec<Vec<f64>> =
                (0..5).map(|_| g.vec_f64(n, 0.0, 2000.0)).collect();
            let mut eng = FastDcov::new();
            let got = eng.dcor_matrix(&[&tput, &power], &dims);
            for (d, s) in dims.iter().enumerate() {
                prop::assert_close(got[0][d], dcor(&tput, s), TOL)?;
                prop::assert_close(got[1][d], dcor(&power, s), TOL)?;
            }
            Ok(())
        });
    }

    #[test]
    fn scratch_stays_linear_no_nxn_buffer() {
        let n = 2048;
        let mut r = Rng::new(3);
        let x: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let y: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mut eng = FastDcov::new();
        let d = eng.dcor_pair(&x, &y);
        assert!((0.0..=1.0).contains(&d));
        let scratch = eng.scratch_elems();
        assert!(
            scratch < 64 * n,
            "scratch {scratch} elems should be O(n), not n² = {}",
            n * n
        );
    }

    #[test]
    fn engine_reuse_is_stable() {
        // Repeated calls over different lengths must not corrupt state.
        let mut eng = FastDcov::new();
        let x: Vec<f64> = (0..300).map(|i| (i as f64).sin()).collect();
        let y: Vec<f64> = (0..300).map(|i| (i as f64).cos()).collect();
        let first = eng.dcor_pair(&x, &y);
        let _ = eng.dcor_pair(&x[..10], &y[..10]);
        let _ = eng.dcor_matrix(&[&x[..50]], std::slice::from_ref(&&y[..50]));
        let again = eng.dcor_pair(&x, &y);
        assert_eq!(first, again);
    }
}
