//! Sliding observation window (§III-D): the W most recent
//! (configuration, throughput, power) observations, with columnar views
//! ready for the dCor computation.

use crate::device::HwConfig;

/// One online observation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub config: HwConfig,
    pub throughput_fps: f64,
    pub power_mw: f64,
}

/// Fixed-capacity FIFO of recent observations.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    cap: usize,
    items: Vec<Observation>,
}

impl SlidingWindow {
    /// Paper's default window size.
    pub const DEFAULT_W: usize = 10;

    pub fn new(cap: usize) -> Self {
        assert!(cap >= 2, "window must hold at least 2 observations");
        SlidingWindow { cap, items: Vec::with_capacity(cap) }
    }

    /// Push an observation, evicting the oldest when full.
    pub fn push(&mut self, obs: Observation) {
        if self.items.len() == self.cap {
            self.items.remove(0);
        }
        self.items.push(obs);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn iter(&self) -> impl Iterator<Item = &Observation> {
        self.items.iter()
    }

    pub fn last(&self) -> Option<&Observation> {
        self.items.last()
    }

    /// Columnar view: throughput series.
    pub fn throughputs(&self) -> Vec<f64> {
        self.items.iter().map(|o| o.throughput_fps).collect()
    }

    /// Columnar view: power series.
    pub fn powers(&self) -> Vec<f64> {
        self.items.iter().map(|o| o.power_mw).collect()
    }

    /// Columnar view: one series per configuration dimension, in
    /// [`HwConfig::DIMS`] order.
    pub fn setting_dims(&self) -> Vec<Vec<f64>> {
        let mut dims = vec![Vec::with_capacity(self.items.len()); HwConfig::NDIMS];
        for o in &self.items {
            for (d, v) in o.config.as_vec().into_iter().enumerate() {
                dims[d].push(v);
            }
        }
        dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::HwConfig;

    fn obs(cpu_mhz: u32, fps: f64, mw: f64) -> Observation {
        Observation {
            config: HwConfig {
                cpu_freq_mhz: cpu_mhz,
                cpu_cores: 4,
                gpu_freq_mhz: 800,
                mem_freq_mhz: 1600,
                concurrency: 2,
            },
            throughput_fps: fps,
            power_mw: mw,
        }
    }

    #[test]
    fn evicts_oldest() {
        let mut w = SlidingWindow::new(3);
        for i in 0..5 {
            w.push(obs(1000 + i, i as f64, 100.0 * i as f64));
        }
        assert_eq!(w.len(), 3);
        assert_eq!(w.throughputs(), vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn columnar_views_align() {
        let mut w = SlidingWindow::new(4);
        w.push(obs(1200, 15.2, 9800.0));
        w.push(obs(1400, 16.1, 10100.0));
        let dims = w.setting_dims();
        assert_eq!(dims.len(), HwConfig::NDIMS);
        assert_eq!(dims[0], vec![1200.0, 1400.0]); // cpu freq dim
        assert_eq!(w.powers(), vec![9800.0, 10100.0]);
        assert_eq!(w.last().unwrap().throughput_fps, 16.1);
    }

    #[test]
    #[should_panic(expected = "at least 2")]
    fn tiny_capacity_rejected() {
        SlidingWindow::new(1);
    }
}
