//! Sliding observation window (§III-D): the W most recent
//! (configuration, throughput, power) observations, with columnar views
//! ready for the dCor computation.
//!
//! Storage is a compacting ring: rows are appended to columnar `Vec`s
//! whose live region is `[start, len)`; eviction just advances `start`,
//! and when the dead prefix reaches W the buffers are compacted with one
//! `memmove` — O(1) amortized per push, **zero steady-state allocation**
//! (capacity is pre-reserved for 2·W rows), and every columnar view is a
//! contiguous `&[f64]` handed to
//! [`crate::stats::dcov::DcorWorkspace::dcor_matrix`] without copying.
//! This replaces the original `Vec::remove(0)` eviction, which shifted
//! the whole window (O(W)) on every push and re-collected each column
//! per iteration.

use crate::device::HwConfig;

/// One online observation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub config: HwConfig,
    pub throughput_fps: f64,
    pub power_mw: f64,
}

/// Fixed-capacity FIFO of recent observations with columnar views.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    cap: usize,
    /// First live row in the columnar buffers.
    start: usize,
    obs: Vec<Observation>,
    tput: Vec<f64>,
    power: Vec<f64>,
    dims: [Vec<f64>; HwConfig::NDIMS],
}

impl SlidingWindow {
    /// Paper's default window size.
    pub const DEFAULT_W: usize = 10;

    pub fn new(cap: usize) -> Self {
        assert!(cap >= 2, "window must hold at least 2 observations");
        // 2·cap so steady state never reallocates: the live region slides
        // through [0, 2cap) and compacts back to 0.
        SlidingWindow {
            cap,
            start: 0,
            obs: Vec::with_capacity(2 * cap),
            tput: Vec::with_capacity(2 * cap),
            power: Vec::with_capacity(2 * cap),
            dims: std::array::from_fn(|_| Vec::with_capacity(2 * cap)),
        }
    }

    /// Push an observation, evicting the oldest when full.
    pub fn push(&mut self, obs: Observation) {
        if self.len() == self.cap {
            self.start += 1;
            if self.start == self.cap {
                self.compact();
            }
        }
        self.obs.push(obs);
        self.tput.push(obs.throughput_fps);
        self.power.push(obs.power_mw);
        let v = obs.config.as_vec();
        for (d, col) in self.dims.iter_mut().enumerate() {
            col.push(v[d]);
        }
    }

    /// Drop the dead prefix with one memmove per buffer (runs once every
    /// `cap` evictions — amortized O(1), never reallocates).
    fn compact(&mut self) {
        let s = self.start;
        self.obs.drain(..s);
        self.tput.drain(..s);
        self.power.drain(..s);
        for col in self.dims.iter_mut() {
            col.drain(..s);
        }
        self.start = 0;
    }

    pub fn len(&self) -> usize {
        self.obs.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn iter(&self) -> impl Iterator<Item = &Observation> {
        self.obs[self.start..].iter()
    }

    pub fn last(&self) -> Option<&Observation> {
        self.obs.last()
    }

    /// Columnar view: throughput series, oldest → newest (zero-copy).
    pub fn throughputs(&self) -> &[f64] {
        &self.tput[self.start..]
    }

    /// Columnar view: power series (zero-copy).
    pub fn powers(&self) -> &[f64] {
        &self.power[self.start..]
    }

    /// Columnar views: one series per configuration dimension, in
    /// [`Dim::ALL`](crate::device::Dim) order (zero-copy, fixed array —
    /// no per-call allocation).
    pub fn setting_dims(&self) -> [&[f64]; HwConfig::NDIMS] {
        std::array::from_fn(|d| &self.dims[d][self.start..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::HwConfig;

    fn obs(cpu_mhz: u32, fps: f64, mw: f64) -> Observation {
        Observation {
            config: HwConfig {
                cpu_freq_mhz: cpu_mhz,
                cpu_cores: 4,
                gpu_freq_mhz: 800,
                mem_freq_mhz: 1600,
                concurrency: 2,
                max_batch: 1,
                variant: 0,
            },
            throughput_fps: fps,
            power_mw: mw,
        }
    }

    #[test]
    fn evicts_oldest() {
        let mut w = SlidingWindow::new(3);
        for i in 0..5 {
            w.push(obs(1000 + i, i as f64, 100.0 * i as f64));
        }
        assert_eq!(w.len(), 3);
        assert_eq!(w.throughputs(), vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn columnar_views_align() {
        let mut w = SlidingWindow::new(4);
        w.push(obs(1200, 15.2, 9800.0));
        w.push(obs(1400, 16.1, 10100.0));
        let dims = w.setting_dims();
        assert_eq!(dims.len(), HwConfig::NDIMS);
        assert_eq!(dims[0], vec![1200.0, 1400.0]); // cpu freq dim
        assert_eq!(w.powers(), vec![9800.0, 10100.0]);
        assert_eq!(w.last().unwrap().throughput_fps, 16.1);
    }

    #[test]
    #[should_panic(expected = "at least 2")]
    fn tiny_capacity_rejected() {
        SlidingWindow::new(1);
    }

    #[test]
    fn ring_matches_naive_fifo_over_long_runs() {
        // Drive well past several compaction cycles and check every view
        // against a naive FIFO model at each step.
        for cap in [2usize, 3, 7, 10] {
            let mut w = SlidingWindow::new(cap);
            let mut naive: Vec<(u32, f64, f64)> = Vec::new();
            for i in 0..10 * cap as u32 + 3 {
                w.push(obs(1000 + i, i as f64, 0.5 * i as f64));
                naive.push((1000 + i, i as f64, 0.5 * i as f64));
                if naive.len() > cap {
                    naive.remove(0);
                }
                assert_eq!(w.len(), naive.len());
                let want_t: Vec<f64> = naive.iter().map(|r| r.1).collect();
                let want_p: Vec<f64> = naive.iter().map(|r| r.2).collect();
                let want_cpu: Vec<f64> = naive.iter().map(|r| r.0 as f64).collect();
                assert_eq!(w.throughputs(), want_t);
                assert_eq!(w.powers(), want_p);
                assert_eq!(w.setting_dims()[0], want_cpu);
                assert_eq!(w.last().unwrap().throughput_fps, naive.last().unwrap().1);
                let iter_fps: Vec<f64> =
                    w.iter().map(|o| o.throughput_fps).collect();
                assert_eq!(iter_fps, want_t);
            }
        }
    }

    #[test]
    fn steady_state_never_reallocates() {
        let mut w = SlidingWindow::new(8);
        for i in 0..8 {
            w.push(obs(1000 + i, i as f64, 1.0));
        }
        let caps = (
            w.obs.capacity(),
            w.tput.capacity(),
            w.power.capacity(),
            w.dims[0].capacity(),
        );
        for i in 0..2000u32 {
            w.push(obs(2000 + i, i as f64, 1.0));
        }
        assert_eq!(
            caps,
            (
                w.obs.capacity(),
                w.tput.capacity(),
                w.power.capacity(),
                w.dims[0].capacity()
            ),
            "eviction must not allocate"
        );
        assert_eq!(w.len(), 8);
    }

    #[test]
    fn large_window_views_stay_contiguous() {
        let mut w = SlidingWindow::new(1000);
        for i in 0..2500u32 {
            w.push(obs(1000 + (i % 500), i as f64, 2.0 * i as f64));
        }
        assert_eq!(w.len(), 1000);
        let t = w.throughputs();
        assert_eq!(t.len(), 1000);
        assert_eq!(t[0], 1500.0);
        assert_eq!(t[999], 2499.0);
    }
}
