//! Synthetic traffic-monitoring video source.
//!
//! Stand-in for the paper's 1-second annotated real-world traffic clip:
//! a deterministic, seeded frame generator producing NHWC f32 frames of a
//! road scene with moving vehicle-like blobs. The serving path treats it
//! exactly like decoded camera frames (the paper decodes via OpenCV);
//! content only needs to be *plausible tensor input*, not photorealistic.

use crate::util::rng::Rng;

/// Default paper-like clip length: 1 s at 30 fps.
pub const DEFAULT_FRAMES: usize = 30;

/// One moving blob ("vehicle").
#[derive(Debug, Clone, Copy)]
struct Vehicle {
    x: f32,
    y: f32,
    vx: f32,
    w: f32,
    h: f32,
    tone: [f32; 3],
}

/// Deterministic looping video source producing `(side, side, 3)` f32
/// frames in [0, 1], flattened HWC.
#[derive(Debug, Clone)]
pub struct VideoSource {
    side: usize,
    frames: usize,
    vehicles: Vec<Vehicle>,
    cursor: usize,
}

impl VideoSource {
    /// `side`: square frame edge (matches the model input), `frames`:
    /// loop length, `seed`: scene layout.
    pub fn new(side: usize, frames: usize, seed: u64) -> VideoSource {
        assert!(side >= 8, "frame too small");
        assert!(frames > 0);
        let mut rng = Rng::new(seed);
        let n = 3 + rng.below(4); // 3–6 vehicles
        let vehicles = (0..n)
            .map(|_| Vehicle {
                x: rng.range_f64(0.0, side as f64) as f32,
                y: rng.range_f64(0.45 * side as f64, 0.85 * side as f64) as f32,
                vx: rng.range_f64(0.5, 3.0) as f32 * if rng.chance(0.5) { 1.0 } else { -1.0 },
                w: rng.range_f64(0.06 * side as f64, 0.16 * side as f64) as f32,
                h: rng.range_f64(0.04 * side as f64, 0.09 * side as f64) as f32,
                tone: [rng.f64() as f32, rng.f64() as f32, rng.f64() as f32],
            })
            .collect();
        VideoSource { side, frames, vehicles, cursor: 0 }
    }

    pub fn side(&self) -> usize {
        self.side
    }

    /// Frames per loop.
    pub fn len(&self) -> usize {
        self.frames
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Render frame `t` (wraps around the loop).
    pub fn frame(&self, t: usize) -> Vec<f32> {
        let t = t % self.frames;
        let s = self.side;
        let mut img = vec![0.0f32; s * s * 3];
        // Sky / road gradient background.
        for y in 0..s {
            let road = y as f32 / s as f32;
            let (r, g, b) = if road < 0.4 {
                (0.55, 0.7, 0.9) // sky
            } else {
                (0.25 + 0.1 * road, 0.25 + 0.1 * road, 0.28 + 0.1 * road) // asphalt
            };
            for x in 0..s {
                let i = (y * s + x) * 3;
                img[i] = r;
                img[i + 1] = g;
                img[i + 2] = b;
            }
        }
        // Lane markings.
        let lane_y = (0.62 * s as f32) as usize;
        for x in (0..s).step_by(8) {
            for dx in 0..4.min(s - x) {
                let i = (lane_y * s + x + dx) * 3;
                img[i] = 0.9;
                img[i + 1] = 0.9;
                img[i + 2] = 0.75;
            }
        }
        // Vehicles, advanced to time t.
        for v in &self.vehicles {
            let cx = (v.x + v.vx * t as f32).rem_euclid(s as f32);
            for dy in 0..v.h as usize {
                let y = (v.y as usize + dy).min(s - 1);
                for dx in 0..v.w as usize {
                    let x = (cx as usize + dx) % s;
                    let i = (y * s + x) * 3;
                    img[i] = v.tone[0];
                    img[i + 1] = v.tone[1];
                    img[i + 2] = v.tone[2];
                }
            }
        }
        img
    }

    /// Next frame in the loop (mutable cursor).
    pub fn next_frame(&mut self) -> Vec<f32> {
        let f = self.frame(self.cursor);
        self.cursor = (self.cursor + 1) % self.frames;
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_shape_and_range() {
        let v = VideoSource::new(64, DEFAULT_FRAMES, 1);
        let f = v.frame(0);
        assert_eq!(f.len(), 64 * 64 * 3);
        assert!(f.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = VideoSource::new(32, 10, 7).frame(3);
        let b = VideoSource::new(32, 10, 7).frame(3);
        assert_eq!(a, b);
        let c = VideoSource::new(32, 10, 8).frame(3);
        assert_ne!(a, c);
    }

    #[test]
    fn motion_changes_frames() {
        let v = VideoSource::new(64, 10, 2);
        assert_ne!(v.frame(0), v.frame(5));
    }

    #[test]
    fn loops_wrap() {
        let v = VideoSource::new(32, 10, 3);
        assert_eq!(v.frame(0), v.frame(10));
        let mut m = v.clone();
        for _ in 0..10 {
            m.next_frame();
        }
        assert_eq!(m.next_frame(), v.frame(0));
    }
}
