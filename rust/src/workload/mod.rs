//! Workloads: the synthetic traffic-monitoring video (stand-in for the
//! paper's 1-second annotated clip, DESIGN.md §2) and request generators
//! for the serving coordinator.

pub mod requests;
pub mod trace;
pub mod video;

pub use requests::{ArrivalPhase, ArrivalProfile, ClosedLoopGen, OpenLoopGen, Request};
pub use trace::{Trace, TraceReplay, TraceStep};
pub use video::VideoSource;
