//! Request generators for the serving coordinator.
//!
//! * [`ClosedLoopGen`] — N in-flight clients, a new request the moment
//!   one completes (the paper's evaluation loop: frames are always
//!   available from the decoded clip).
//! * [`OpenLoopGen`] — Poisson arrivals at a target rate, for
//!   latency-under-load experiments beyond the paper's setup.

use std::time::Duration;

use crate::util::rng::Rng;

/// One inference request: a frame index into the video loop plus its
/// submission id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub frame_index: usize,
}

/// Closed-loop generator: keeps exactly `inflight` requests outstanding.
#[derive(Debug, Clone)]
pub struct ClosedLoopGen {
    next_id: u64,
    frames: usize,
    inflight_target: usize,
    outstanding: usize,
}

impl ClosedLoopGen {
    pub fn new(inflight_target: usize, frames: usize) -> Self {
        assert!(inflight_target > 0 && frames > 0);
        ClosedLoopGen { next_id: 0, frames, inflight_target, outstanding: 0 }
    }

    /// Requests to submit now to restore the in-flight target.
    pub fn refill(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while self.outstanding < self.inflight_target {
            out.push(Request {
                id: self.next_id,
                frame_index: (self.next_id as usize) % self.frames,
            });
            self.next_id += 1;
            self.outstanding += 1;
        }
        out
    }

    /// Notify one completion.
    pub fn complete(&mut self) {
        assert!(self.outstanding > 0, "completion without outstanding request");
        self.outstanding -= 1;
    }

    pub fn issued(&self) -> u64 {
        self.next_id
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding
    }
}

/// Open-loop Poisson generator over logical time.
#[derive(Debug, Clone)]
pub struct OpenLoopGen {
    next_id: u64,
    frames: usize,
    rate_per_s: f64,
    rng: Rng,
    next_arrival_s: f64,
}

impl OpenLoopGen {
    pub fn new(rate_per_s: f64, frames: usize, seed: u64) -> Self {
        assert!(rate_per_s > 0.0 && frames > 0);
        let mut g = OpenLoopGen {
            next_id: 0,
            frames,
            rate_per_s,
            rng: Rng::new(seed),
            next_arrival_s: 0.0,
        };
        g.next_arrival_s = g.draw_gap();
        g
    }

    fn draw_gap(&mut self) -> f64 {
        // Exponential inter-arrival.
        -self.rng.f64().max(f64::MIN_POSITIVE).ln() / self.rate_per_s
    }

    /// All arrivals with timestamp ≤ `now`.
    pub fn poll(&mut self, now: Duration) -> Vec<Request> {
        let now_s = now.as_secs_f64();
        let mut out = Vec::new();
        while self.next_arrival_s <= now_s {
            out.push(Request {
                id: self.next_id,
                frame_index: (self.next_id as usize) % self.frames,
            });
            self.next_id += 1;
            let gap = self.draw_gap();
            self.next_arrival_s += gap;
        }
        out
    }

    pub fn issued(&self) -> u64 {
        self.next_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_maintains_inflight() {
        let mut g = ClosedLoopGen::new(3, 10);
        let first = g.refill();
        assert_eq!(first.len(), 3);
        assert!(g.refill().is_empty());
        g.complete();
        g.complete();
        assert_eq!(g.refill().len(), 2);
        assert_eq!(g.outstanding(), 3);
        assert_eq!(g.issued(), 5);
    }

    #[test]
    fn closed_loop_frame_indices_wrap() {
        let mut g = ClosedLoopGen::new(4, 3);
        let reqs = g.refill();
        assert_eq!(
            reqs.iter().map(|r| r.frame_index).collect::<Vec<_>>(),
            vec![0, 1, 2, 0]
        );
    }

    #[test]
    #[should_panic(expected = "without outstanding")]
    fn closed_loop_extra_completion_panics() {
        ClosedLoopGen::new(1, 1).complete();
    }

    #[test]
    fn open_loop_rate_roughly_matches() {
        let mut g = OpenLoopGen::new(100.0, 30, 11);
        let reqs = g.poll(Duration::from_secs(10));
        let n = reqs.len() as f64;
        assert!((n - 1000.0).abs() < 150.0, "n={n}");
        // Monotone ids.
        assert!(reqs.windows(2).all(|w| w[1].id == w[0].id + 1));
    }

    #[test]
    fn open_loop_poll_is_incremental() {
        let mut g = OpenLoopGen::new(50.0, 30, 5);
        let a = g.poll(Duration::from_secs(1)).len();
        let b = g.poll(Duration::from_secs(2)).len();
        let mut g2 = OpenLoopGen::new(50.0, 30, 5);
        let all = g2.poll(Duration::from_secs(2)).len();
        assert_eq!(a + b, all);
    }
}
