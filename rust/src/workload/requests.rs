//! Request generators for the serving coordinator.
//!
//! * [`ClosedLoopGen`] — N in-flight clients, a new request the moment
//!   one completes (the paper's evaluation loop: frames are always
//!   available from the decoded clip).
//! * [`OpenLoopGen`] — Poisson arrivals at a target rate, for
//!   latency-under-load experiments beyond the paper's setup.

use std::time::Duration;

use crate::util::rng::Rng;

/// One inference request: a frame index into the video loop plus its
/// submission id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub frame_index: usize,
}

/// Closed-loop generator: keeps exactly `inflight` requests outstanding.
#[derive(Debug, Clone)]
pub struct ClosedLoopGen {
    next_id: u64,
    frames: usize,
    inflight_target: usize,
    outstanding: usize,
}

impl ClosedLoopGen {
    pub fn new(inflight_target: usize, frames: usize) -> Self {
        assert!(inflight_target > 0 && frames > 0);
        ClosedLoopGen { next_id: 0, frames, inflight_target, outstanding: 0 }
    }

    /// Requests to submit now to restore the in-flight target.
    pub fn refill(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while self.outstanding < self.inflight_target {
            out.push(Request {
                id: self.next_id,
                frame_index: (self.next_id as usize) % self.frames,
            });
            self.next_id += 1;
            self.outstanding += 1;
        }
        out
    }

    /// Notify one completion.
    pub fn complete(&mut self) {
        assert!(self.outstanding > 0, "completion without outstanding request");
        self.outstanding -= 1;
    }

    pub fn issued(&self) -> u64 {
        self.next_id
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding
    }
}

/// One phase of a traffic shape: a multiplier on the profile's base
/// rate, held for a span of logical seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalPhase {
    /// Rate multiplier applied to the profile's base rate (> 0).
    pub rate_mult: f64,
    /// Phase length in logical seconds (> 0).
    pub dur_s: f64,
}

/// Traffic shape over logical time: Poisson arrivals whose rate follows
/// a repeating phase schedule. An empty schedule is steady traffic at
/// the base rate. Phases switch on **exact** logical-time boundaries
/// (half-open `[start, end)` — the instant `t == end` already belongs
/// to the next phase).
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalProfile {
    /// Offered load at `rate_mult = 1.0`, in arrivals per second.
    pub base_rate_fps: f64,
    /// Phase schedule, cycled forever. Empty = steady.
    pub phases: Vec<ArrivalPhase>,
    /// Seed of the Poisson draw stream of generators built from this
    /// profile (cache identity: same shape + different seed = different
    /// arrivals).
    pub seed: u64,
}

impl ArrivalProfile {
    /// Steady Poisson traffic at `rate_fps`.
    pub fn steady(rate_fps: f64, seed: u64) -> ArrivalProfile {
        ArrivalProfile { base_rate_fps: rate_fps, phases: Vec::new(), seed }
    }

    /// Day/night swing: trough → ramp → peak → ramp, repeating. The
    /// peak offers 1.6× the base rate, the trough 0.4×; the
    /// duration-weighted mean multiplier is exactly 1.0.
    pub fn diurnal(base_rate_fps: f64, seed: u64) -> ArrivalProfile {
        ArrivalProfile {
            base_rate_fps,
            phases: vec![
                ArrivalPhase { rate_mult: 0.4, dur_s: 300.0 },
                ArrivalPhase { rate_mult: 1.0, dur_s: 300.0 },
                ArrivalPhase { rate_mult: 1.6, dur_s: 300.0 },
                ArrivalPhase { rate_mult: 1.0, dur_s: 300.0 },
            ],
            seed,
        }
    }

    /// Flash crowd: long calm at the base rate, then a short 6× spike.
    pub fn flash_crowd(base_rate_fps: f64, seed: u64) -> ArrivalProfile {
        ArrivalProfile {
            base_rate_fps,
            phases: vec![
                ArrivalPhase { rate_mult: 1.0, dur_s: 540.0 },
                ArrivalPhase { rate_mult: 6.0, dur_s: 60.0 },
            ],
            seed,
        }
    }

    /// Named profile for CLI surfaces: `steady` | `diurnal` | `flash`.
    pub fn by_name(name: &str, base_rate_fps: f64, seed: u64) -> Option<ArrivalProfile> {
        match name.to_ascii_lowercase().as_str() {
            "steady" | "poisson" => Some(Self::steady(base_rate_fps, seed)),
            "diurnal" | "day" => Some(Self::diurnal(base_rate_fps, seed)),
            "flash" | "flash-crowd" | "burst" => Some(Self::flash_crowd(base_rate_fps, seed)),
            _ => None,
        }
    }

    fn assert_valid(&self) {
        assert!(
            self.base_rate_fps > 0.0 && self.base_rate_fps.is_finite(),
            "base rate must be finite and positive"
        );
        for p in &self.phases {
            assert!(p.rate_mult > 0.0 && p.rate_mult.is_finite(), "phase rate_mult");
            assert!(p.dur_s > 0.0 && p.dur_s.is_finite(), "phase duration");
        }
    }

    /// Length of one full schedule cycle (0 for steady profiles).
    pub fn cycle_s(&self) -> f64 {
        self.phases.iter().map(|p| p.dur_s).sum()
    }

    /// Offered rate at logical time `t_s` (piecewise constant over the
    /// repeating schedule; the boundary instant belongs to the *next*
    /// phase).
    pub fn rate_at(&self, t_s: f64) -> f64 {
        if self.phases.is_empty() {
            return self.base_rate_fps;
        }
        let mut t = t_s % self.cycle_s();
        for p in &self.phases {
            if t < p.dur_s {
                return self.base_rate_fps * p.rate_mult;
            }
            t -= p.dur_s;
        }
        // Float round-off at the cycle's very end: last phase still rules.
        self.base_rate_fps * self.phases.last().unwrap().rate_mult
    }

    /// The schedule's highest offered rate — what a config must survive
    /// to never shed over a full cycle.
    pub fn peak_rate_fps(&self) -> f64 {
        let peak_mult = self
            .phases
            .iter()
            .map(|p| p.rate_mult)
            .fold(1.0f64, f64::max);
        self.base_rate_fps * if self.phases.is_empty() { 1.0 } else { peak_mult }
    }

    /// Duration-weighted mean offered rate over one cycle.
    pub fn mean_rate_fps(&self) -> f64 {
        if self.phases.is_empty() {
            return self.base_rate_fps;
        }
        let weighted: f64 = self.phases.iter().map(|p| p.rate_mult * p.dur_s).sum();
        self.base_rate_fps * weighted / self.cycle_s()
    }

    /// Stable identity of the whole traffic shape (rate, every phase,
    /// seed) — folded into environment fingerprints so windows measured
    /// under different offered loads can never answer for each other
    /// from a cache.
    pub fn fingerprint(&self) -> u64 {
        let mut words = vec![
            0x4152_5249_5641_4Cu64, // "ARRIVAL" salt
            self.base_rate_fps.to_bits(),
            self.seed,
            self.phases.len() as u64,
        ];
        for p in &self.phases {
            words.push(p.rate_mult.to_bits());
            words.push(p.dur_s.to_bits());
        }
        crate::control::cache::stable_hash(&words)
    }
}

impl std::fmt::Display for ArrivalProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.phases.is_empty() {
            write!(f, "steady {:.1} req/s", self.base_rate_fps)
        } else {
            write!(
                f,
                "{:.1} req/s x{} phases (peak {:.1})",
                self.base_rate_fps,
                self.phases.len(),
                self.peak_rate_fps()
            )
        }
    }
}

/// Open-loop Poisson generator over logical time, optionally driven by
/// an [`ArrivalProfile`] phase schedule.
#[derive(Debug, Clone)]
pub struct OpenLoopGen {
    next_id: u64,
    frames: usize,
    rate_per_s: f64,
    rng: Rng,
    next_arrival_s: f64,
    /// Phase machinery (None = steady at `rate_per_s` forever).
    profile: Option<ArrivalProfile>,
}

impl OpenLoopGen {
    pub fn new(rate_per_s: f64, frames: usize, seed: u64) -> Self {
        assert!(rate_per_s > 0.0 && frames > 0);
        let mut g = OpenLoopGen {
            next_id: 0,
            frames,
            rate_per_s,
            rng: Rng::new(seed),
            next_arrival_s: 0.0,
            profile: None,
        };
        g.schedule_next(0.0);
        g
    }

    /// Arrivals following `profile`'s phase schedule (seeded by the
    /// profile itself).
    pub fn with_profile(profile: ArrivalProfile, frames: usize) -> Self {
        profile.assert_valid();
        assert!(frames > 0);
        let mut g = OpenLoopGen {
            next_id: 0,
            frames,
            rate_per_s: profile.base_rate_fps,
            rng: Rng::new(profile.seed),
            next_arrival_s: 0.0,
            profile: Some(profile),
        };
        g.schedule_next(0.0);
        g
    }

    /// Phase end strictly after `t_s` (∞ when steady).
    fn phase_end_after(&self, t_s: f64) -> f64 {
        let Some(p) = &self.profile else { return f64::INFINITY };
        if p.phases.is_empty() {
            return f64::INFINITY;
        }
        let cycle = p.cycle_s();
        let base = (t_s / cycle).floor() * cycle;
        let mut edge = base;
        for ph in &p.phases {
            edge += ph.dur_s;
            if edge > t_s {
                return edge;
            }
        }
        // Round-off landed `t_s` at the cycle's end: next cycle's first edge.
        base + cycle + p.phases[0].dur_s
    }

    fn rate_at(&self, t_s: f64) -> f64 {
        match &self.profile {
            Some(p) => p.rate_at(t_s),
            None => self.rate_per_s,
        }
    }

    /// Schedule the arrival after `from_s`: draw one unit-exponential
    /// and integrate it through the piecewise-constant rate. Phase
    /// switches happen on **exact** logical boundaries — the leftover
    /// exponential mass carries across the edge and continues at the
    /// new rate (this is the exact inversion of the inhomogeneous
    /// Poisson integral, not an approximation).
    fn schedule_next(&mut self, from_s: f64) {
        let mut units = -self.rng.f64().max(f64::MIN_POSITIVE).ln();
        let mut t = from_s;
        loop {
            let rate = self.rate_at(t);
            let end = self.phase_end_after(t);
            let span_units = (end - t) * rate;
            if units <= span_units || end.is_infinite() {
                self.next_arrival_s = t + units / rate;
                return;
            }
            units -= span_units;
            t = end;
        }
    }

    /// Timestamp of the next (not yet polled) arrival. Monotonically
    /// non-decreasing across `poll` calls.
    pub fn due(&self) -> Duration {
        Duration::from_secs_f64(self.next_arrival_s)
    }

    /// All arrivals with timestamp ≤ `now`.
    pub fn poll(&mut self, now: Duration) -> Vec<Request> {
        let now_s = now.as_secs_f64();
        let mut out = Vec::new();
        while self.next_arrival_s <= now_s {
            out.push(Request {
                id: self.next_id,
                frame_index: (self.next_id as usize) % self.frames,
            });
            self.next_id += 1;
            self.schedule_next(self.next_arrival_s);
        }
        out
    }

    pub fn issued(&self) -> u64 {
        self.next_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_maintains_inflight() {
        let mut g = ClosedLoopGen::new(3, 10);
        let first = g.refill();
        assert_eq!(first.len(), 3);
        assert!(g.refill().is_empty());
        g.complete();
        g.complete();
        assert_eq!(g.refill().len(), 2);
        assert_eq!(g.outstanding(), 3);
        assert_eq!(g.issued(), 5);
    }

    #[test]
    fn closed_loop_frame_indices_wrap() {
        let mut g = ClosedLoopGen::new(4, 3);
        let reqs = g.refill();
        assert_eq!(
            reqs.iter().map(|r| r.frame_index).collect::<Vec<_>>(),
            vec![0, 1, 2, 0]
        );
    }

    #[test]
    #[should_panic(expected = "without outstanding")]
    fn closed_loop_extra_completion_panics() {
        ClosedLoopGen::new(1, 1).complete();
    }

    #[test]
    fn open_loop_rate_roughly_matches() {
        let mut g = OpenLoopGen::new(100.0, 30, 11);
        let reqs = g.poll(Duration::from_secs(10));
        let n = reqs.len() as f64;
        assert!((n - 1000.0).abs() < 150.0, "n={n}");
        // Monotone ids.
        assert!(reqs.windows(2).all(|w| w[1].id == w[0].id + 1));
    }

    #[test]
    fn open_loop_poll_is_incremental() {
        let mut g = OpenLoopGen::new(50.0, 30, 5);
        let a = g.poll(Duration::from_secs(1)).len();
        let b = g.poll(Duration::from_secs(2)).len();
        let mut g2 = OpenLoopGen::new(50.0, 30, 5);
        let all = g2.poll(Duration::from_secs(2)).len();
        assert_eq!(a + b, all);
    }

    #[test]
    fn open_loop_seeded_determinism() {
        // Same profile (same seed) → identical arrival streams; a
        // different seed must diverge.
        let p = ArrivalProfile::diurnal(40.0, 17);
        let a = OpenLoopGen::with_profile(p.clone(), 30).poll(Duration::from_secs(700));
        let b = OpenLoopGen::with_profile(p.clone(), 30).poll(Duration::from_secs(700));
        assert_eq!(a, b);
        let mut other = p;
        other.seed = 18;
        let c = OpenLoopGen::with_profile(other, 30).poll(Duration::from_secs(700));
        assert_ne!(a.len(), 0);
        assert!(a.len() != c.len() || a != c, "seed must matter");
    }

    #[test]
    fn open_loop_empirical_rate_matches_profile_over_long_horizons() {
        // Property: over many cycles the empirical arrival rate lands
        // within a few percent of the profile's duration-weighted mean.
        for (name, p) in [
            ("steady", ArrivalProfile::steady(25.0, 3)),
            ("diurnal", ArrivalProfile::diurnal(25.0, 4)),
            ("flash", ArrivalProfile::flash_crowd(25.0, 5)),
        ] {
            let horizon_s = 6000.0; // 5–10 full cycles
            let n = OpenLoopGen::with_profile(p.clone(), 30)
                .poll(Duration::from_secs_f64(horizon_s))
                .len() as f64;
            let expect = p.mean_rate_fps() * horizon_s;
            let rel = (n - expect).abs() / expect;
            assert!(rel < 0.05, "{name}: n={n} expect={expect} rel={rel}");
        }
    }

    #[test]
    fn due_is_monotone_and_consistent_with_poll() {
        let mut g = OpenLoopGen::with_profile(ArrivalProfile::flash_crowd(30.0, 9), 30);
        let mut prev = Duration::ZERO;
        for step in 1..200u64 {
            let due_before = g.due();
            assert!(due_before >= prev, "due() never runs backwards");
            let now = Duration::from_millis(step * 500);
            let got = g.poll(now);
            if due_before <= now {
                assert!(!got.is_empty(), "an arrival was due by {now:?}");
            } else {
                assert!(got.is_empty(), "nothing was due before {now:?}");
            }
            assert!(g.due() > now, "poll drains everything due");
            prev = g.due();
        }
    }

    #[test]
    fn phase_transitions_land_on_exact_boundaries() {
        // Half-open phases: the boundary instant already belongs to the
        // next phase, including the wrap back to phase 0.
        let p = ArrivalProfile {
            base_rate_fps: 10.0,
            phases: vec![
                ArrivalPhase { rate_mult: 1.0, dur_s: 10.0 },
                ArrivalPhase { rate_mult: 5.0, dur_s: 10.0 },
            ],
            seed: 7,
        };
        assert_eq!(p.rate_at(0.0), 10.0);
        assert_eq!(p.rate_at(10.0 - 1e-9), 10.0);
        assert_eq!(p.rate_at(10.0), 50.0, "boundary belongs to the next phase");
        assert_eq!(p.rate_at(20.0 - 1e-9), 50.0);
        assert_eq!(p.rate_at(20.0), 10.0, "cycle wraps on the exact edge");
        assert_eq!(p.cycle_s(), 20.0);
        assert_eq!(p.peak_rate_fps(), 50.0);

        // The generator sees those rates: ~100 arrivals in the slow
        // half, ~500 in the fast half of each cycle.
        let mut g = OpenLoopGen::with_profile(p, 30);
        let slow = g.poll(Duration::from_secs_f64(10.0)).len() as f64;
        let fast = g.poll(Duration::from_secs_f64(20.0)).len() as f64;
        assert!((slow - 100.0).abs() < 50.0, "slow={slow}");
        assert!((fast - 500.0).abs() < 110.0, "fast={fast}");
        assert!(fast > 2.5 * slow, "spike visible: {slow} vs {fast}");
    }

    #[test]
    fn steady_profile_generator_matches_plain_open_loop() {
        // `with_profile(steady)` and the legacy constructor draw the
        // same exponential stream from the same seed.
        let a = OpenLoopGen::new(42.0, 30, 21).poll(Duration::from_secs(60));
        let b = OpenLoopGen::with_profile(ArrivalProfile::steady(42.0, 21), 30)
            .poll(Duration::from_secs(60));
        assert_eq!(a, b);
    }

    #[test]
    fn profile_fingerprints_separate_rate_phases_and_seed() {
        let base = ArrivalProfile::diurnal(30.0, 1);
        let mut rate = base.clone();
        rate.base_rate_fps = 31.0;
        let mut seed = base.clone();
        seed.seed = 2;
        let mut sched = base.clone();
        sched.phases[0].dur_s += 1.0;
        let fps: Vec<u64> = [&base, &rate, &seed, &sched]
            .iter()
            .map(|p| p.fingerprint())
            .collect();
        for i in 0..fps.len() {
            for j in i + 1..fps.len() {
                assert_ne!(fps[i], fps[j], "profiles {i} vs {j} must not collide");
            }
        }
        assert_ne!(
            ArrivalProfile::steady(30.0, 1).fingerprint(),
            ArrivalProfile::flash_crowd(30.0, 1).fingerprint()
        );
    }
}
