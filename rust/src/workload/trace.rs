//! Optimization-trace recording and replay.
//!
//! Every online search can be captured as an ordered list of
//! (iteration, configuration, throughput, power) rows — useful for
//! postmortem analysis of a deployment run, for regenerating the paper's
//! per-iteration convergence curves, and for *replaying* a recorded
//! environment against a different optimizer (counterfactual debugging).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::device::HwConfig;
use crate::util::csv::Csv;

/// One recorded step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceStep {
    pub iter: u64,
    pub config: HwConfig,
    pub throughput_fps: f64,
    pub power_mw: f64,
    pub failed: bool,
}

/// A recorded optimization run.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub steps: Vec<TraceStep>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn record(&mut self, config: HwConfig, throughput_fps: f64, power_mw: f64) {
        self.steps.push(TraceStep {
            iter: self.steps.len() as u64,
            config,
            throughput_fps,
            power_mw,
            failed: throughput_fps <= 0.0,
        });
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Best observed step under a feasibility check + efficiency ranking.
    pub fn best_feasible(
        &self,
        feasible: impl Fn(f64, f64) -> bool,
    ) -> Option<&TraceStep> {
        self.steps
            .iter()
            .filter(|s| !s.failed && feasible(s.throughput_fps, s.power_mw))
            .max_by(|a, b| {
                (a.throughput_fps / a.power_mw)
                    .partial_cmp(&(b.throughput_fps / b.power_mw))
                    .unwrap()
            })
    }

    /// Serialize to CSV.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "iter", "cpu_freq_mhz", "cpu_cores", "gpu_freq_mhz", "mem_freq_mhz",
            "concurrency", "max_batch", "variant", "throughput_fps", "power_mw", "failed",
        ]);
        for s in &self.steps {
            csv.push(vec![
                s.iter.to_string(),
                s.config.cpu_freq_mhz.to_string(),
                s.config.cpu_cores.to_string(),
                s.config.gpu_freq_mhz.to_string(),
                s.config.mem_freq_mhz.to_string(),
                s.config.concurrency.to_string(),
                s.config.max_batch.to_string(),
                s.config.variant.to_string(),
                format!("{:.3}", s.throughput_fps),
                format!("{:.1}", s.power_mw),
                (s.failed as u8).to_string(),
            ]);
        }
        csv
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_csv().save(path)?;
        Ok(())
    }

    /// Parse from CSV text.
    pub fn parse(text: &str) -> Result<Trace> {
        let csv = Csv::parse(text).map_err(|e| anyhow::anyhow!(e))?;
        let col = |name: &str| {
            csv.col(name)
                .ok_or_else(|| anyhow::anyhow!("trace csv missing column '{name}'"))
        };
        let (ci, cc, cg, cm, cl) = (
            col("cpu_freq_mhz")?,
            col("cpu_cores")?,
            col("gpu_freq_mhz")?,
            col("mem_freq_mhz")?,
            col("concurrency")?,
        );
        // Traces recorded before the batch/variant dimensions existed
        // lack those columns; they were measured at the implicit cap of
        // 1 serving the full-accuracy baseline variant.
        let cb = csv.col("max_batch");
        let cv = csv.col("variant");
        let (ti, pi, fi, ii) = (
            col("throughput_fps")?,
            col("power_mw")?,
            col("failed")?,
            col("iter")?,
        );
        let mut steps = Vec::new();
        for (r, row) in csv.rows.iter().enumerate() {
            let f = |i: usize| -> Result<f64> {
                row[i].parse().map_err(|_| anyhow::anyhow!("trace row {r}: bad number"))
            };
            steps.push(TraceStep {
                iter: f(ii)? as u64,
                config: HwConfig {
                    cpu_freq_mhz: f(ci)? as u32,
                    cpu_cores: f(cc)? as u32,
                    gpu_freq_mhz: f(cg)? as u32,
                    mem_freq_mhz: f(cm)? as u32,
                    concurrency: f(cl)? as u32,
                    max_batch: match cb {
                        Some(i) => f(i)? as u32,
                        None => 1,
                    },
                    variant: match cv {
                        Some(i) => f(i)? as u32,
                        None => 0,
                    },
                },
                throughput_fps: f(ti)?,
                power_mw: f(pi)?,
                failed: f(fi)? != 0.0,
            });
        }
        Ok(Trace { steps })
    }

    pub fn load(path: &Path) -> Result<Trace> {
        Self::parse(&std::fs::read_to_string(path)?)
    }
}

/// Replay a recorded environment: answers measurements from the trace
/// (exact-config lookup) instead of a live device — lets a different
/// optimizer be evaluated counterfactually on the same observations.
#[derive(Debug, Clone)]
pub struct TraceReplay {
    lookup: HashMap<HwConfig, (f64, f64)>,
}

impl TraceReplay {
    pub fn new(trace: &Trace) -> TraceReplay {
        let mut lookup = HashMap::new();
        for s in &trace.steps {
            lookup.insert(s.config, (s.throughput_fps, s.power_mw));
        }
        TraceReplay { lookup }
    }

    /// Number of distinct configurations with recorded measurements.
    pub fn coverage(&self) -> usize {
        self.lookup.len()
    }

    /// Measurement for a configuration; errors when the trace never
    /// visited it (a replay cannot invent data).
    pub fn measure(&self, cfg: &HwConfig) -> Result<(f64, f64)> {
        match self.lookup.get(cfg) {
            Some(&m) => Ok(m),
            None => bail!("trace has no measurement for {cfg}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::{ControlLoop, SimEnv};
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;
    use crate::optimizer::{Constraints, CoralOptimizer};

    fn sample_trace() -> Trace {
        // Every ControlLoop search records its trace as it drives.
        let dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 3);
        let cons = Constraints::dual(30.0, 6500.0);
        let opt = CoralOptimizer::new(dev.space().clone(), cons, 3);
        ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 10)
            .run()
            .trace
    }

    #[test]
    fn csv_round_trip() {
        let t = sample_trace();
        let text = t.to_csv().to_text();
        let back = Trace::parse(&text).unwrap();
        assert_eq!(back.len(), t.len());
        for (a, b) in t.steps.iter().zip(&back.steps) {
            assert_eq!(a.config, b.config);
            assert!((a.throughput_fps - b.throughput_fps).abs() < 1e-2);
            assert_eq!(a.failed, b.failed);
        }
    }

    #[test]
    fn best_feasible_picks_max_efficiency() {
        let t = sample_trace();
        let best = t.best_feasible(|f, p| f >= 30.0 && p <= 6500.0);
        assert!(best.is_some());
        let b = best.unwrap();
        assert!(b.throughput_fps >= 30.0 && b.power_mw <= 6500.0);
    }

    #[test]
    fn replay_answers_recorded_configs_only() {
        let t = sample_trace();
        let replay = TraceReplay::new(&t);
        assert!(replay.coverage() >= 8);
        let first = t.steps[0];
        let (f, p) = replay.measure(&first.config).unwrap();
        // Lookup keeps the *last* measurement of a config; first config
        // may repeat, so compare against its last occurrence.
        let last_of_first = t
            .steps
            .iter()
            .rev()
            .find(|s| s.config == first.config)
            .unwrap();
        assert_eq!((f, p), (last_of_first.throughput_fps, last_of_first.power_mw));
        let unseen = HwConfig {
            cpu_freq_mhz: 1,
            cpu_cores: 1,
            gpu_freq_mhz: 1,
            mem_freq_mhz: 1,
            concurrency: 1,
            max_batch: 1,
            variant: 0,
        };
        assert!(replay.measure(&unseen).is_err());
    }

    #[test]
    fn legacy_csv_without_batch_column_parses_at_cap_one() {
        let text = "iter,cpu_freq_mhz,cpu_cores,gpu_freq_mhz,mem_freq_mhz,concurrency,throughput_fps,power_mw,failed\n\
                    0,1390,4,630,1690,2,31.500,6400.0,0\n";
        let t = Trace::parse(text).unwrap();
        assert_eq!(t.steps[0].config.max_batch, 1);
        assert_eq!(t.steps[0].config.variant, 0, "legacy traces served the baseline variant");
        assert_eq!(t.steps[0].config.concurrency, 2);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Trace::parse("not,a,trace\n1,2,3\n").is_err());
        assert!(Trace::parse("").is_err());
    }
}
