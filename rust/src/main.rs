//! `coral` binary — the L3 leader entry point.
//!
//! See `coral help` (or cli::commands::USAGE) for the command catalog.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(coral::cli::main_with(argv));
}
