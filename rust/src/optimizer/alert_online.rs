//! ALERT-Online baseline (§IV-A): ALERT with the offline profile replaced
//! by random online trials inside the same 10-iteration budget CORAL
//! gets. Selection stays throughput-first (it is still ALERT); with ~2–6%
//! of the space feasible in the dual-constraint scenarios, its random
//! exploration rarely lands a valid configuration (§IV-B).

use super::constraints::Constraints;
use super::reward::reward;
use super::{BestConfig, Optimizer};
use crate::device::{ConfigSpace, HwConfig};
use crate::util::Rng;

/// Random-trial variant of ALERT.
pub struct AlertOnlineOptimizer {
    space: ConfigSpace,
    cons: Constraints,
    rng: Rng,
    tried: Vec<HwConfig>,
    best: Option<BestConfig>,
}

impl AlertOnlineOptimizer {
    pub fn new(space: ConfigSpace, cons: Constraints, seed: u64) -> AlertOnlineOptimizer {
        AlertOnlineOptimizer {
            space,
            cons,
            rng: Rng::new(seed),
            tried: Vec::new(),
            best: None,
        }
    }
}

impl Optimizer for AlertOnlineOptimizer {
    fn propose(&mut self) -> HwConfig {
        // Uniform random trials, avoiding exact repeats.
        for _ in 0..64 {
            let c = self.space.random(&mut self.rng);
            if !self.tried.contains(&c) {
                return c;
            }
        }
        self.space.random(&mut self.rng)
    }

    fn observe(
        &mut self,
        config: HwConfig,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    ) {
        self.tried.push(config);
        let out = reward(&self.cons, throughput_fps, power_mw, p99_latency_ms, accuracy);
        let cand = BestConfig {
            config,
            throughput_fps,
            power_mw,
            p99_latency_ms,
            accuracy,
            reward: out.reward,
            feasible: out.feasible,
        };
        // Throughput-first selection, like ALERT.
        if self
            .best
            .map(|b| cand.throughput_fps > b.throughput_fps)
            .unwrap_or(true)
        {
            self.best = Some(cand);
        }
    }

    fn best(&self) -> Option<BestConfig> {
        self.best
    }

    fn name(&self) -> &'static str {
        "alert-online"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;
    use crate::optimizer::tests::drive;

    #[test]
    fn mostly_fails_dual_constraints() {
        // Paper §IV-B: random exploration misses the narrow feasible
        // region within the 10-iteration budget (NX: ~2 % of the space).
        let mut feasible = 0;
        for seed in 0..20 {
            let mut dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 500 + seed);
            let mut opt = AlertOnlineOptimizer::new(
                dev.space().clone(),
                Constraints::dual(30.0, 6500.0),
                seed,
            );
            if drive(&mut opt, &mut dev, 10).unwrap().feasible {
                feasible += 1;
            }
        }
        assert!(feasible <= 6, "feasible in {feasible}/20 runs — should mostly fail");
    }

    #[test]
    fn no_offline_cost() {
        let opt = AlertOnlineOptimizer::new(
            DeviceKind::OrinNano.space(),
            Constraints::none(),
            1,
        );
        assert_eq!(opt.offline_cost_windows(), 0);
    }

    #[test]
    fn avoids_exact_repeats_within_budget() {
        let mut dev = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 2);
        let mut opt = AlertOnlineOptimizer::new(
            dev.space().clone(),
            Constraints::max_throughput(),
            2,
        );
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10 {
            let c = opt.propose();
            assert!(seen.insert(c), "repeat proposal {c}");
            let m = dev.run(c);
            opt.observe(c, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
        }
    }
}
