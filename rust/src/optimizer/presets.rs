//! Manufacturer-preset baselines (§IV-A): `max-power` and `default`
//! nvpmodel modes. A preset is a fixed configuration — no search, no
//! application-knob tuning (concurrency stays at the framework default).
//!
//! Presets generalize to any [`ConfigSpace`] — including the normalized
//! fleet grids of [`crate::device::NormSpace`] — through
//! [`PresetOptimizer::max_power_of`] / [`PresetOptimizer::default_of`]:
//! the space supplies its own preset anchors, so a "max-power preset" on
//! a mixed NX/Orin fleet means every member at its own maximum.

use super::constraints::Constraints;
use super::reward::reward;
use super::{BestConfig, Optimizer};
use crate::device::{ConfigSpace, DeviceKind, HwConfig};

/// Fixed-configuration baseline.
pub struct PresetOptimizer {
    config: HwConfig,
    cons: Constraints,
    label: &'static str,
    best: Option<BestConfig>,
}

impl PresetOptimizer {
    /// The manufacturer's maximum-performance mode.
    pub fn max_power(dev: DeviceKind, cons: Constraints) -> PresetOptimizer {
        PresetOptimizer {
            config: dev.preset_max_power(),
            cons,
            label: "max-power",
            best: None,
        }
    }

    /// The manufacturer's default power mode.
    pub fn default_mode(dev: DeviceKind, cons: Constraints) -> PresetOptimizer {
        PresetOptimizer {
            config: dev.preset_default(),
            cons,
            label: "default",
            best: None,
        }
    }

    /// Any fixed configuration (custom presets).
    pub fn fixed(config: HwConfig, cons: Constraints, label: &'static str) -> PresetOptimizer {
        PresetOptimizer { config, cons, label, best: None }
    }

    /// The maximum-performance preset of an arbitrary space — identical
    /// to [`PresetOptimizer::max_power`] on a native device grid; on a
    /// normalized fleet grid every hardware knob sits at rank 1.0 with
    /// concurrency at the framework default.
    pub fn max_power_of(space: &ConfigSpace, cons: Constraints) -> PresetOptimizer {
        PresetOptimizer::fixed(space.preset_max_power(), cons, "max-power")
    }

    /// The default-mode preset of an arbitrary space (see
    /// [`PresetOptimizer::max_power_of`]).
    pub fn default_of(space: &ConfigSpace, cons: Constraints) -> PresetOptimizer {
        PresetOptimizer::fixed(space.preset_default(), cons, "default")
    }
}

impl Optimizer for PresetOptimizer {
    fn propose(&mut self) -> HwConfig {
        self.config
    }

    fn observe(
        &mut self,
        config: HwConfig,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    ) {
        let out = reward(&self.cons, throughput_fps, power_mw, p99_latency_ms, accuracy);
        // Keep the latest measurement (steady-state view of the preset).
        self.best = Some(BestConfig {
            config,
            throughput_fps,
            power_mw,
            p99_latency_ms,
            accuracy,
            reward: out.reward,
            feasible: out.feasible,
        });
    }

    fn best(&self) -> Option<BestConfig> {
        self.best
    }

    fn name(&self) -> &'static str {
        self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;
    use crate::optimizer::tests::drive;

    #[test]
    fn presets_never_move() {
        let mut opt =
            PresetOptimizer::max_power(DeviceKind::XavierNx, Constraints::none());
        let first = opt.propose();
        opt.observe(first, 10.0, 9000.0, 10.0, 27.6);
        assert_eq!(opt.propose(), first);
    }

    #[test]
    fn dual_scenario_presets_fail_on_nx_yolo() {
        // Paper Figs 5–6: max-power violates the budget, default misses
        // the target.
        let cons = Constraints::dual(30.0, 6500.0);
        let mut dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 4);
        let mut mp = PresetOptimizer::max_power(DeviceKind::XavierNx, cons);
        let b = drive(&mut mp, &mut dev, 3).unwrap();
        assert!(b.power_mw > 6500.0, "max-power over budget");
        let mut dm = PresetOptimizer::default_mode(DeviceKind::XavierNx, cons);
        let b = drive(&mut dm, &mut dev, 3).unwrap();
        assert!(b.throughput_fps < 30.0, "default under target");
        assert!(!b.feasible);
    }

    #[test]
    fn fixed_preset_label() {
        let cfg = DeviceKind::OrinNano.preset_default();
        let opt = PresetOptimizer::fixed(cfg, Constraints::none(), "custom");
        assert_eq!(opt.name(), "custom");
    }

    #[test]
    fn space_presets_match_device_presets_on_native_grids() {
        let cons = Constraints::none();
        for d in DeviceKind::ALL {
            let s = d.space();
            assert_eq!(
                PresetOptimizer::max_power_of(&s, cons).propose(),
                PresetOptimizer::max_power(d, cons).propose(),
                "{d}"
            );
            assert_eq!(
                PresetOptimizer::default_of(&s, cons).propose(),
                PresetOptimizer::default_mode(d, cons).propose(),
                "{d}"
            );
        }
    }

    #[test]
    fn space_presets_on_normalized_grids_are_on_grid() {
        let ns = crate::device::NormSpace::new(vec![
            DeviceKind::XavierNx.space(),
            DeviceKind::OrinNano.space(),
        ]);
        let g = ns.grid();
        let cons = Constraints::none();
        let mp = PresetOptimizer::max_power_of(g, cons).propose();
        assert!(g.contains(&mp));
        assert_eq!(mp.concurrency, 0, "framework default: minimum rank");
        let dm = PresetOptimizer::default_of(g, cons).propose();
        assert!(g.contains(&dm));
        assert_ne!(mp, dm);
    }
}
