//! ALERT baseline (Wan et al., ATC'20; paper §IV-A).
//!
//! Profiling-based: an **offline** exhaustive profile maps every
//! configuration to expected throughput/power; **online**, a scalar
//! Kalman filter per metric tracks the ratio between observed and
//! profiled values (environment drift, unit-to-unit variation) and the
//! controller picks the profile entry with the best *corrected*
//! prediction.
//!
//! Faithful to the paper's characterization: ALERT is throughput-first —
//! it selects the configuration maximizing corrected throughput (meeting
//! the target when possible) and does **not** enforce the power budget,
//! which is exactly why it overshoots to ~8.5 W in the dual-constraint
//! scenario (§IV-B).

use super::constraints::Constraints;
use super::reward::reward;
use super::{BestConfig, Optimizer};
use crate::device::HwConfig;
use crate::stats::kalman::Kalman1d;

/// One offline-profile entry.
#[derive(Debug, Clone, Copy)]
pub struct ProfileEntry {
    pub config: HwConfig,
    pub throughput_fps: f64,
    pub power_mw: f64,
}

/// Profiling-based baseline with Kalman-corrected predictions.
pub struct AlertOptimizer {
    profile: Vec<ProfileEntry>,
    cons: Constraints,
    /// Ratio observed/profiled for throughput.
    kt: Kalman1d,
    /// Ratio observed/profiled for power.
    kp: Kalman1d,
    offline_windows: u64,
    last_idx: Option<usize>,
    best: Option<BestConfig>,
}

impl AlertOptimizer {
    /// `profile`: offline measurements (crashed configs excluded);
    /// `offline_windows`: measurement windows the profiling consumed.
    pub fn new(
        profile: Vec<ProfileEntry>,
        cons: Constraints,
        offline_windows: u64,
    ) -> AlertOptimizer {
        assert!(!profile.is_empty(), "ALERT needs a non-empty profile");
        AlertOptimizer {
            profile,
            cons,
            kt: Kalman1d::alert_default(),
            kp: Kalman1d::alert_default(),
            offline_windows,
            last_idx: None,
            best: None,
        }
    }

    /// Profile a device exhaustively (the offline phase). Uses its own
    /// device instance — in deployment this is a *different* unit and an
    /// earlier point in time than the serving device, which is why the
    /// online Kalman correction exists.
    pub fn profile_device(dev: &mut crate::device::Device) -> Vec<ProfileEntry> {
        let mut out = Vec::new();
        for cfg in dev.space().clone().enumerate() {
            let m = dev.run(cfg);
            if m.failed.is_none() {
                out.push(ProfileEntry {
                    config: m.config,
                    throughput_fps: m.throughput_fps,
                    power_mw: m.power_mw,
                });
            }
        }
        out
    }

    /// Index of the profile entry ALERT currently predicts as best:
    /// max corrected throughput (throughput-first selection).
    fn select(&self) -> usize {
        let rt = self.kt.estimate();
        let mut best = 0;
        let mut best_t = f64::NEG_INFINITY;
        for (i, e) in self.profile.iter().enumerate() {
            let t = e.throughput_fps * rt;
            if t > best_t {
                best_t = t;
                best = i;
            }
        }
        best
    }
}

impl Optimizer for AlertOptimizer {
    fn propose(&mut self) -> HwConfig {
        let i = self.select();
        self.last_idx = Some(i);
        self.profile[i].config
    }

    fn observe(
        &mut self,
        config: HwConfig,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    ) {
        if let Some(i) = self.last_idx.take() {
            let e = self.profile[i];
            if e.config == config && e.throughput_fps > 0.0 && throughput_fps > 0.0 {
                self.kt.update(throughput_fps / e.throughput_fps);
                self.kp.update(power_mw / e.power_mw);
            }
        }
        let out = reward(&self.cons, throughput_fps, power_mw, p99_latency_ms, accuracy);
        let cand = BestConfig {
            config,
            throughput_fps,
            power_mw,
            p99_latency_ms,
            accuracy,
            reward: out.reward,
            feasible: out.feasible,
        };
        // ALERT's own ranking is throughput-first: it keeps the highest-
        // throughput configuration it has actually run.
        if self
            .best
            .map(|b| cand.throughput_fps > b.throughput_fps)
            .unwrap_or(true)
        {
            self.best = Some(cand);
        }
    }

    fn best(&self) -> Option<BestConfig> {
        self.best
    }

    fn name(&self) -> &'static str {
        "alert"
    }

    fn offline_cost_windows(&self) -> u64 {
        self.offline_windows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;
    use crate::optimizer::tests::drive;

    fn build(dev_kind: DeviceKind, seed_profile: u64) -> (AlertOptimizer, Device) {
        // Profile on one unit, serve on another (different seeds).
        let mut prof_dev = Device::new(dev_kind, ModelKind::Yolo, seed_profile);
        let profile = AlertOptimizer::profile_device(&mut prof_dev);
        let windows = prof_dev.windows_run();
        let serving = Device::new(dev_kind, ModelKind::Yolo, seed_profile + 77);
        let opt = AlertOptimizer::new(
            profile,
            Constraints::dual(30.0, 6500.0),
            windows,
        );
        (opt, serving)
    }

    #[test]
    fn alert_overshoots_power_budget_in_dual_scenario() {
        // Paper §IV-B: ALERT prioritizes throughput and exceeds the
        // budget (8.5 W on XAVIER-NX with a 6.5 W limit).
        let (mut opt, mut dev) = build(DeviceKind::XavierNx, 11);
        let best = drive(&mut opt, &mut dev, 10).unwrap();
        assert!(best.throughput_fps > 30.0, "meets throughput");
        assert!(best.power_mw > 6500.0, "exceeds the power budget: {}", best.power_mw);
        assert!(!best.feasible);
    }

    #[test]
    fn alert_near_oracle_on_single_target() {
        // Paper Figs 3–4: with its offline profile, ALERT tops the
        // single-constraint scenario.
        let mut prof_dev = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 5);
        let profile = AlertOptimizer::profile_device(&mut prof_dev);
        let best_profiled = profile
            .iter()
            .map(|e| e.throughput_fps)
            .fold(0.0f64, f64::max);
        let mut dev = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 99);
        let mut opt =
            AlertOptimizer::new(profile, Constraints::max_throughput(), prof_dev.windows_run());
        let best = drive(&mut opt, &mut dev, 10).unwrap();
        assert!(best.throughput_fps > 0.9 * best_profiled);
    }

    #[test]
    fn offline_cost_is_reported() {
        let (opt, _) = build(DeviceKind::XavierNx, 3);
        assert_eq!(opt.offline_cost_windows(), 2160);
    }

    #[test]
    fn kalman_corrects_toward_observations() {
        let space = DeviceKind::XavierNx.space();
        let cfg = space.midpoint();
        let profile = vec![ProfileEntry { config: cfg, throughput_fps: 30.0, power_mw: 6000.0 }];
        let mut opt = AlertOptimizer::new(profile, Constraints::none(), 1);
        for _ in 0..50 {
            let c = opt.propose();
            opt.observe(c, 24.0, 6600.0, 10.0, 27.6); // env runs 20 % slower, 10 % hotter
        }
        assert!((opt.kt.estimate() - 0.8).abs() < 0.05);
        assert!((opt.kp.estimate() - 1.1).abs() < 0.05);
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn empty_profile_rejected() {
        AlertOptimizer::new(Vec::new(), Constraints::none(), 0);
    }
}
