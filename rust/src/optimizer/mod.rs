//! Configuration optimizers: CORAL (the paper's contribution, §III) and
//! every baseline of §IV-A — ORACLE, ALERT, ALERT-Online, and the
//! manufacturer presets — behind one [`Optimizer`] trait so the
//! experiment harness and the serving coordinator drive them uniformly.
//!
//! Every strategy is expressed in grid operations on its
//! [`crate::device::ConfigSpace`], never in device-specific units — so
//! the same implementations search a normalized fleet grid
//! ([`crate::device::NormSpace`], rank fractions spanning mixed NX/Orin
//! members) without any trait change: proposals come out in normalized
//! space and the fleet environment decodes them per member
//! ([`crate::control::FleetEnv`]; EXPERIMENTS.md §Heterogeneous fleets).

pub mod alert;
pub mod alert_online;
pub mod constraints;
pub mod coral;
pub mod oracle;
pub mod presets;
pub mod random_search;
pub mod reward;

pub use alert::AlertOptimizer;
pub use alert_online::AlertOnlineOptimizer;
pub use constraints::Constraints;
pub use coral::{CoralConfig, CoralOptimizer};
pub use oracle::OracleOptimizer;
pub use presets::PresetOptimizer;
pub use random_search::RandomOptimizer;
pub use reward::{reward, RewardOutcome};

use crate::device::HwConfig;

/// A configuration the optimizer settled on, with its measured metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BestConfig {
    pub config: HwConfig,
    pub throughput_fps: f64,
    pub power_mw: f64,
    /// p99 request latency (ms) of the winning window. Equal to the mean
    /// latency under closed-loop measurement; carries the queueing tail
    /// under open-loop load (∞ for a shed window).
    pub p99_latency_ms: f64,
    /// Modeled accuracy (mAP) of the variant the winning window served;
    /// 0 for failed windows. Equals the model's full mAP everywhere on a
    /// singleton-variant (legacy) space.
    pub accuracy: f64,
    /// Reward score (efficiency τ/p for feasible configurations).
    pub reward: f64,
    /// Whether the configuration met all active constraints when measured.
    pub feasible: bool,
}

/// Common interface of all search strategies.
///
/// The driving loop is measurement-agnostic and lives in one place —
/// [`crate::control::ControlLoop`] — over any
/// [`crate::control::Environment`] (simulated device, live serving
/// stack, fleet):
/// ```text
/// for _ in 0..budget {
///     let cfg = opt.propose();
///     let m = env.measure(cfg);            // sim, live server, or fleet
///     opt.observe(cfg, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
/// }
/// let chosen = opt.best();
/// ```
pub trait Optimizer {
    /// Next configuration to try.
    fn propose(&mut self) -> HwConfig;

    /// Feed back the measured metrics of a proposed configuration.
    /// Failed configurations report `throughput_fps == 0.0`; shed
    /// open-loop windows report `p99_latency_ms == f64::INFINITY`;
    /// `accuracy` is the modeled mAP of the variant the window served
    /// (0 for failed windows).
    fn observe(
        &mut self,
        config: HwConfig,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    );

    /// Best configuration found so far (feasible preferred).
    fn best(&self) -> Option<BestConfig>;

    /// Human-readable strategy name (tables, CSV rows).
    fn name(&self) -> &'static str;

    /// Iterations of real measurement the strategy consumed *before* the
    /// online phase (offline profiling cost — e.g. ALERT/ORACLE sweeps).
    /// Used to report search cost next to quality.
    fn offline_cost_windows(&self) -> u64 {
        0
    }

    /// Throughput series the strategy retains in its sliding observation
    /// window, oldest → newest. The control loop's search-phase drift
    /// monitor feeds on this (see `control::ControlLoopConfig::search_drift`);
    /// strategies without a window return `&[]`, which disables the
    /// monitor for them.
    fn window_throughputs(&self) -> &[f64] {
        &[]
    }

    /// Begin a fresh search round in response to a detected mid-search
    /// surface shift, keeping the knowledge that survives a shift
    /// (CORAL keeps its prohibited list: a configuration that crashed or
    /// blew the budget is not rehabilitated by a throughput drift).
    /// Stale per-surface state — sliding window, best/second-best —
    /// must be dropped. Default: no-op (stateless strategies restart
    /// implicitly).
    fn reset_search(&mut self) {}
}

/// Boxed optimizers (the experiment runner's heterogeneous method
/// lineup) drive through [`crate::control::ControlLoop`] like any
/// concrete optimizer.
impl<T: Optimizer + ?Sized> Optimizer for Box<T> {
    fn propose(&mut self) -> HwConfig {
        (**self).propose()
    }

    fn observe(
        &mut self,
        config: HwConfig,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    ) {
        (**self).observe(config, throughput_fps, power_mw, p99_latency_ms, accuracy)
    }

    fn best(&self) -> Option<BestConfig> {
        (**self).best()
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn offline_cost_windows(&self) -> u64 {
        (**self).offline_cost_windows()
    }

    fn window_throughputs(&self) -> &[f64] {
        (**self).window_throughputs()
    }

    fn reset_search(&mut self) {
        (**self).reset_search()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;

    /// Drive any optimizer for `iters` online iterations on a device.
    pub(crate) fn drive(
        opt: &mut dyn Optimizer,
        dev: &mut Device,
        iters: usize,
    ) -> Option<BestConfig> {
        for _ in 0..iters {
            let cfg = opt.propose();
            let m = dev.run(cfg);
            opt.observe(cfg, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
        }
        opt.best()
    }

    #[test]
    fn trait_objects_compose() {
        let mut dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 7);
        let cons = Constraints::throughput_only(25.0);
        let mut opts: Vec<Box<dyn Optimizer>> = vec![
            Box::new(RandomOptimizer::new(dev.space().clone(), cons, 1)),
            Box::new(PresetOptimizer::max_power(DeviceKind::XavierNx, cons)),
        ];
        for opt in opts.iter_mut() {
            let best = drive(opt.as_mut(), &mut dev, 3);
            assert!(best.is_some(), "{}", opt.name());
        }
    }
}
