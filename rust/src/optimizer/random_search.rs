//! Pure random search with Algorithm-1 ranking — an extra ablation
//! baseline isolating the value of CORAL's guided steps from the value of
//! its reward function (ALERT-Online ranks throughput-first; this ranks
//! by the same reward CORAL uses).
//!
//! Draws uniformly from whatever [`ConfigSpace`] it is given — a native
//! device grid or a normalized fleet grid
//! ([`crate::device::NormSpace`]) — so it doubles as the unguided
//! baseline for heterogeneous fleets.

use super::constraints::Constraints;
use super::reward::reward;
use super::{BestConfig, Optimizer};
use crate::device::{ConfigSpace, HwConfig};
use crate::util::Rng;

/// Uniform random search ranked by Algorithm 1 reward.
pub struct RandomOptimizer {
    space: ConfigSpace,
    cons: Constraints,
    rng: Rng,
    best: Option<BestConfig>,
}

impl RandomOptimizer {
    pub fn new(space: ConfigSpace, cons: Constraints, seed: u64) -> RandomOptimizer {
        RandomOptimizer { space, cons, rng: Rng::new(seed), best: None }
    }
}

impl Optimizer for RandomOptimizer {
    fn propose(&mut self) -> HwConfig {
        self.space.random(&mut self.rng)
    }

    fn observe(
        &mut self,
        config: HwConfig,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    ) {
        let out = reward(&self.cons, throughput_fps, power_mw, p99_latency_ms, accuracy);
        let cand = BestConfig {
            config,
            throughput_fps,
            power_mw,
            p99_latency_ms,
            accuracy,
            reward: out.reward,
            feasible: out.feasible,
        };
        if self.best.map(|b| cand.reward > b.reward).unwrap_or(true) {
            self.best = Some(cand);
        }
    }

    fn best(&self) -> Option<BestConfig> {
        self.best
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;
    use crate::optimizer::tests::drive;

    #[test]
    fn keeps_highest_reward() {
        let mut dev = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 8);
        let mut opt =
            RandomOptimizer::new(dev.space().clone(), Constraints::none(), 8);
        let best = drive(&mut opt, &mut dev, 20).unwrap();
        assert!(best.reward > 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let s = DeviceKind::XavierNx.space();
        let mut a = RandomOptimizer::new(s.clone(), Constraints::none(), 3);
        let mut b = RandomOptimizer::new(s, Constraints::none(), 3);
        for _ in 0..5 {
            assert_eq!(a.propose(), b.propose());
        }
    }
}
