//! Optimization constraints (paper Eq. 6): throughput target τ_target
//! and/or power budget p_budget.

/// What "best" means once constraints are handled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Prefer higher efficiency η = τ/p among feasible configurations
    /// (the paper's dual-constraint scenario, Eq. 7).
    Efficiency,
    /// Prefer raw throughput (the paper's single-constraint scenario,
    /// where CORAL is compared on % of ORACLE throughput). The throughput
    /// target is set unreachably high so the search always pushes up.
    Throughput,
}

/// Scenario constraints. `None` disables a constraint — the paper's
/// single-constraint scenario sets only the throughput target.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Constraints {
    /// τ_target (fps): τ(s) ≥ target required.
    pub throughput_target_fps: Option<f64>,
    /// p_budget (mW): p(s) ≤ budget required.
    pub power_budget_mw: Option<f64>,
    /// Power floor p_min (mW): below this, further power reduction is not
    /// worth chasing (Algorithm 2's `p_min`; defaults to 0 = always try).
    pub power_floor_mw: f64,
    /// Ranking objective.
    pub objective: Objective,
}

impl Constraints {
    /// Single-constraint throughput-maximization scenario (paper Figs
    /// 3–4): no power budget, unreachable target (search always climbs),
    /// ranking by raw throughput.
    pub fn max_throughput() -> Constraints {
        Constraints {
            throughput_target_fps: Some(f64::INFINITY),
            power_budget_mw: None,
            power_floor_mw: 0.0,
            objective: Objective::Throughput,
        }
    }

    /// Dual-constraint scenario (paper §IV-B).
    pub fn dual(throughput_fps: f64, power_mw: f64) -> Constraints {
        Constraints {
            throughput_target_fps: Some(throughput_fps),
            power_budget_mw: Some(power_mw),
            power_floor_mw: 0.0,
            objective: Objective::Efficiency,
        }
    }

    /// Single-constraint scenario: maximize throughput subject to a
    /// (soft) target; no power budget.
    pub fn throughput_only(target_fps: f64) -> Constraints {
        Constraints {
            throughput_target_fps: Some(target_fps),
            power_budget_mw: None,
            power_floor_mw: 0.0,
            objective: Objective::Efficiency,
        }
    }

    /// Unconstrained efficiency search.
    pub fn none() -> Constraints {
        Constraints {
            throughput_target_fps: None,
            power_budget_mw: None,
            power_floor_mw: 0.0,
            objective: Objective::Efficiency,
        }
    }

    pub fn with_power_floor(mut self, floor_mw: f64) -> Constraints {
        self.power_floor_mw = floor_mw;
        self
    }

    /// Feasibility check (paper Eq. 6). Failed runs (τ = 0) are always
    /// infeasible when any constraint is active.
    pub fn feasible(&self, throughput_fps: f64, power_mw: f64) -> bool {
        if let Some(t) = self.throughput_target_fps {
            if throughput_fps < t {
                return false;
            }
        }
        if let Some(p) = self.power_budget_mw {
            if power_mw > p {
                return false;
            }
        }
        if self.throughput_target_fps.is_none()
            && self.power_budget_mw.is_none()
            && throughput_fps <= 0.0
        {
            return false; // a crashed config is never acceptable
        }
        true
    }

    /// τ_target, with the convention that "no target" behaves as 0
    /// (any throughput satisfies it).
    pub fn target_or_zero(&self) -> f64 {
        self.throughput_target_fps.unwrap_or(0.0)
    }

    /// p_budget, with "no budget" = ∞.
    pub fn budget_or_inf(&self) -> f64 {
        self.power_budget_mw.unwrap_or(f64::INFINITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_feasibility() {
        let c = Constraints::dual(30.0, 6500.0);
        assert!(c.feasible(30.0, 6500.0));
        assert!(!c.feasible(29.9, 6000.0));
        assert!(!c.feasible(35.0, 6501.0));
        assert!(!c.feasible(0.0, 3000.0));
    }

    #[test]
    fn single_ignores_power() {
        let c = Constraints::throughput_only(30.0);
        assert!(c.feasible(31.0, 99_999.0));
        assert!(!c.feasible(29.0, 1.0));
    }

    #[test]
    fn none_rejects_only_crashes() {
        let c = Constraints::none();
        assert!(c.feasible(1.0, 1e9));
        assert!(!c.feasible(0.0, 100.0));
    }

    #[test]
    fn max_throughput_scenario() {
        let c = Constraints::max_throughput();
        assert_eq!(c.objective, Objective::Throughput);
        assert!(!c.feasible(1000.0, 100.0), "target unreachable by design");
        assert_eq!(c.budget_or_inf(), f64::INFINITY);
    }

    #[test]
    fn accessors() {
        let c = Constraints::dual(30.0, 6500.0).with_power_floor(4000.0);
        assert_eq!(c.target_or_zero(), 30.0);
        assert_eq!(c.budget_or_inf(), 6500.0);
        assert_eq!(c.power_floor_mw, 4000.0);
        assert_eq!(Constraints::none().budget_or_inf(), f64::INFINITY);
    }
}
