//! Optimization constraints (paper Eq. 6): throughput target τ_target
//! and/or power budget p_budget, plus the serving extension's p99
//! latency SLO for open-loop (arrival-driven) scenarios.
//!
//! Every constructor sanitizes non-finite bounds to `None`: an infinite
//! or NaN target/budget/SLO constrains nothing, and letting one leak
//! into `feasible`/`target_or_zero` silently inverted comparisons (the
//! historical `max_throughput` preset carried `Some(f64::INFINITY)`).
//! "Always climb" semantics live in [`Constraints::climb_target_fps`],
//! keyed off the objective rather than a sentinel target.

/// What "best" means once constraints are handled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Prefer higher efficiency η = τ/p among feasible configurations
    /// (the paper's dual-constraint scenario, Eq. 7).
    Efficiency,
    /// Prefer raw throughput (the paper's single-constraint scenario,
    /// where CORAL is compared on % of ORACLE throughput). The throughput
    /// target is set unreachably high so the search always pushes up.
    Throughput,
}

/// Scenario constraints. `None` disables a constraint — the paper's
/// single-constraint scenario sets only the throughput target.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Constraints {
    /// τ_target (fps): τ(s) ≥ target required.
    pub throughput_target_fps: Option<f64>,
    /// p_budget (mW): p(s) ≤ budget required.
    pub power_budget_mw: Option<f64>,
    /// Power floor p_min (mW): below this, further power reduction is not
    /// worth chasing (Algorithm 2's `p_min`; defaults to 0 = always try).
    pub power_floor_mw: f64,
    /// p99 latency SLO (ms): p99(s) ≤ slo required. `None` disables the
    /// clause — closed-loop scenarios never set it.
    pub latency_slo_ms: Option<f64>,
    /// Accuracy floor (modeled mAP): acc(s) ≥ floor required. `None`
    /// disables the clause — fixed-model scenarios never set it; it only
    /// bites when the configuration space carries a real variant axis.
    pub min_accuracy: Option<f64>,
    /// Ranking objective.
    pub objective: Objective,
}

/// A bound that is not a finite number constrains nothing.
fn finite(bound: Option<f64>) -> Option<f64> {
    bound.filter(|v| v.is_finite())
}

impl Constraints {
    /// Single-constraint throughput-maximization scenario (paper Figs
    /// 3–4): no power budget, no reachable target — the search always
    /// climbs (see [`Constraints::climb_target_fps`]) and ranking is by
    /// raw throughput.
    pub fn max_throughput() -> Constraints {
        Constraints {
            throughput_target_fps: None,
            power_budget_mw: None,
            power_floor_mw: 0.0,
            latency_slo_ms: None,
            min_accuracy: None,
            objective: Objective::Throughput,
        }
    }

    /// Dual-constraint scenario (paper §IV-B).
    pub fn dual(throughput_fps: f64, power_mw: f64) -> Constraints {
        Constraints {
            throughput_target_fps: finite(Some(throughput_fps)),
            power_budget_mw: finite(Some(power_mw)),
            power_floor_mw: 0.0,
            latency_slo_ms: None,
            min_accuracy: None,
            objective: Objective::Efficiency,
        }
    }

    /// Single-constraint scenario: maximize throughput subject to a
    /// (soft) target; no power budget.
    pub fn throughput_only(target_fps: f64) -> Constraints {
        Constraints {
            throughput_target_fps: finite(Some(target_fps)),
            power_budget_mw: None,
            power_floor_mw: 0.0,
            latency_slo_ms: None,
            min_accuracy: None,
            objective: Objective::Efficiency,
        }
    }

    /// Unconstrained efficiency search.
    pub fn none() -> Constraints {
        Constraints {
            throughput_target_fps: None,
            power_budget_mw: None,
            power_floor_mw: 0.0,
            latency_slo_ms: None,
            min_accuracy: None,
            objective: Objective::Efficiency,
        }
    }

    pub fn with_power_floor(mut self, floor_mw: f64) -> Constraints {
        self.power_floor_mw = floor_mw;
        self
    }

    /// Add a p99 latency SLO (ms). Non-finite values disable the clause.
    pub fn with_latency_slo(mut self, slo_ms: f64) -> Constraints {
        self.latency_slo_ms = finite(Some(slo_ms));
        self
    }

    /// Add an accuracy floor (modeled mAP). Non-finite values disable
    /// the clause.
    pub fn with_min_accuracy(mut self, map: f64) -> Constraints {
        self.min_accuracy = finite(Some(map));
        self
    }

    /// Feasibility check (paper Eq. 6). Failed runs (τ = 0) are always
    /// infeasible when any constraint is active.
    pub fn feasible(&self, throughput_fps: f64, power_mw: f64) -> bool {
        if let Some(t) = self.throughput_target_fps {
            if throughput_fps < t {
                return false;
            }
        }
        if let Some(p) = self.power_budget_mw {
            if power_mw > p {
                return false;
            }
        }
        if self.throughput_target_fps.is_none()
            && self.power_budget_mw.is_none()
            && throughput_fps <= 0.0
        {
            return false; // a crashed config is never acceptable
        }
        true
    }

    /// Full satisfaction check for one measurement: Eq. 6 plus the p99
    /// latency clause plus the accuracy floor. A shed configuration
    /// (p99 = ∞) fails any active SLO; a failed window (accuracy 0)
    /// fails any active floor.
    pub fn satisfied(
        &self,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    ) -> bool {
        self.feasible(throughput_fps, power_mw)
            && self.latency_ok(p99_latency_ms)
            && self.accuracy_ok(accuracy)
    }

    /// The p99 latency clause alone (`true` when no SLO is set).
    pub fn latency_ok(&self, p99_latency_ms: f64) -> bool {
        match self.latency_slo_ms {
            Some(slo) => p99_latency_ms <= slo,
            None => true,
        }
    }

    /// The accuracy clause alone (`true` when no floor is set).
    pub fn accuracy_ok(&self, accuracy: f64) -> bool {
        match self.min_accuracy {
            Some(floor) => accuracy >= floor,
            None => true,
        }
    }

    /// τ_target, with the convention that "no target" behaves as 0
    /// (any throughput satisfies it).
    pub fn target_or_zero(&self) -> f64 {
        self.throughput_target_fps.unwrap_or(0.0)
    }

    /// The throughput level above which Algorithm 2 stops climbing and
    /// starts trading power down. Under [`Objective::Throughput`] there
    /// is no such level — the search always climbs — so this is ∞;
    /// otherwise it is the target (0 when unset).
    pub fn climb_target_fps(&self) -> f64 {
        if self.objective == Objective::Throughput {
            f64::INFINITY
        } else {
            self.target_or_zero()
        }
    }

    /// p_budget, with "no budget" = ∞.
    pub fn budget_or_inf(&self) -> f64 {
        self.power_budget_mw.unwrap_or(f64::INFINITY)
    }

    /// Human-readable summary for scenario tables and CLI output.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if let Some(t) = self.throughput_target_fps {
            parts.push(format!("tput>={t:.0}fps"));
        }
        if let Some(p) = self.power_budget_mw {
            parts.push(format!("power<={p:.0}mW"));
        }
        if let Some(l) = self.latency_slo_ms {
            parts.push(format!("p99<={l:.0}ms"));
        }
        if let Some(a) = self.min_accuracy {
            parts.push(format!("acc>={a:.1}mAP"));
        }
        if parts.is_empty() {
            parts.push(match self.objective {
                Objective::Throughput => "max-throughput".to_string(),
                Objective::Efficiency => "unconstrained".to_string(),
            });
        }
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_feasibility() {
        let c = Constraints::dual(30.0, 6500.0);
        assert!(c.feasible(30.0, 6500.0));
        assert!(!c.feasible(29.9, 6000.0));
        assert!(!c.feasible(35.0, 6501.0));
        assert!(!c.feasible(0.0, 3000.0));
    }

    #[test]
    fn single_ignores_power() {
        let c = Constraints::throughput_only(30.0);
        assert!(c.feasible(31.0, 99_999.0));
        assert!(!c.feasible(29.0, 1.0));
    }

    #[test]
    fn none_rejects_only_crashes() {
        let c = Constraints::none();
        assert!(c.feasible(1.0, 1e9));
        assert!(!c.feasible(0.0, 100.0));
    }

    #[test]
    fn max_throughput_scenario() {
        let c = Constraints::max_throughput();
        assert_eq!(c.objective, Objective::Throughput);
        assert_eq!(c.throughput_target_fps, None, "no sentinel target");
        assert!(c.feasible(1000.0, 100.0), "any running config satisfies Eq. 6");
        assert!(!c.feasible(0.0, 100.0), "crashes never do");
        assert_eq!(c.budget_or_inf(), f64::INFINITY);
        assert_eq!(c.climb_target_fps(), f64::INFINITY, "the search always climbs");
    }

    #[test]
    fn non_finite_bounds_sanitize_to_none() {
        // Regression: `max_throughput` used to carry
        // `throughput_target_fps: Some(f64::INFINITY)`, which made
        // `target_or_zero()` return ∞ and every measurement infeasible.
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let c = Constraints::dual(bad, bad);
            assert_eq!(c.throughput_target_fps, None, "{bad} target");
            assert_eq!(c.power_budget_mw, None, "{bad} budget");
            assert_eq!(c.target_or_zero(), 0.0);
            assert_eq!(c.budget_or_inf(), f64::INFINITY);
            assert!(c.feasible(10.0, 5000.0), "sanitized bounds constrain nothing");
            let t = Constraints::throughput_only(bad);
            assert_eq!(t.throughput_target_fps, None);
            let s = Constraints::none().with_latency_slo(bad);
            assert_eq!(s.latency_slo_ms, None);
            assert!(s.latency_ok(f64::INFINITY), "disabled SLO passes even sheds");
        }
        // Finite bounds pass through untouched.
        assert_eq!(Constraints::dual(30.0, 6500.0).throughput_target_fps, Some(30.0));
    }

    #[test]
    fn latency_slo_clause() {
        let c = Constraints::dual(25.0, 6500.0).with_latency_slo(80.0);
        assert_eq!(c.latency_slo_ms, Some(80.0));
        assert!(c.satisfied(30.0, 6000.0, 79.9, 30.0));
        assert!(c.satisfied(30.0, 6000.0, 80.0, 30.0), "boundary is inclusive");
        assert!(!c.satisfied(30.0, 6000.0, 80.1, 30.0), "tail too long");
        assert!(!c.satisfied(30.0, 6000.0, f64::INFINITY, 30.0), "shed violates the SLO");
        assert!(!c.satisfied(20.0, 6000.0, 10.0, 30.0), "Eq. 6 still applies");
        // Without an SLO, satisfied == feasible for any p99.
        let d = Constraints::dual(25.0, 6500.0);
        assert!(d.satisfied(30.0, 6000.0, f64::INFINITY, 30.0));
    }

    #[test]
    fn accuracy_floor_clause() {
        let c = Constraints::dual(25.0, 6500.0).with_min_accuracy(26.0);
        assert_eq!(c.min_accuracy, Some(26.0));
        assert!(c.accuracy_ok(27.6));
        assert!(c.accuracy_ok(26.0), "boundary is inclusive");
        assert!(!c.accuracy_ok(24.6), "degraded below the floor");
        assert!(!c.accuracy_ok(0.0), "failed windows carry accuracy 0");
        assert!(c.satisfied(30.0, 6000.0, 0.0, 27.6));
        assert!(!c.satisfied(30.0, 6000.0, 0.0, 24.6), "floor is a fourth clause");
        assert!(!c.satisfied(20.0, 6000.0, 0.0, 27.6), "Eq. 6 still applies");
        // Non-finite floors disable the clause; no floor accepts anything.
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let s = Constraints::none().with_min_accuracy(bad);
            assert_eq!(s.min_accuracy, None);
            assert!(s.accuracy_ok(0.0), "disabled floor passes even failures");
        }
        assert!(Constraints::dual(25.0, 6500.0).accuracy_ok(0.0));
    }

    #[test]
    fn climb_target_matches_eq6_target_for_efficiency() {
        assert_eq!(Constraints::dual(30.0, 6500.0).climb_target_fps(), 30.0);
        assert_eq!(Constraints::none().climb_target_fps(), 0.0);
        assert_eq!(Constraints::throughput_only(24.0).climb_target_fps(), 24.0);
    }

    #[test]
    fn describe_lists_active_clauses() {
        let c = Constraints::dual(30.0, 6500.0).with_latency_slo(80.0);
        assert_eq!(c.describe(), "tput>=30fps power<=6500mW p99<=80ms");
        assert_eq!(
            Constraints::dual(30.0, 6500.0).with_min_accuracy(26.4).describe(),
            "tput>=30fps power<=6500mW acc>=26.4mAP"
        );
        assert_eq!(Constraints::max_throughput().describe(), "max-throughput");
        assert_eq!(Constraints::none().describe(), "unconstrained");
    }

    #[test]
    fn accessors() {
        let c = Constraints::dual(30.0, 6500.0).with_power_floor(4000.0);
        assert_eq!(c.target_or_zero(), 30.0);
        assert_eq!(c.budget_or_inf(), 6500.0);
        assert_eq!(c.power_floor_mw, 4000.0);
        assert_eq!(Constraints::none().budget_or_inf(), f64::INFINITY);
    }
}
