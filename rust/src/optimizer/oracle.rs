//! ORACLE baseline (§IV-A): exhaustive offline profiling of the entire
//! configuration space; the upper bound every method is scored against.
//!
//! Driven through the same propose/observe loop as everyone else — it
//! simply proposes every grid point once (thousands of measurement
//! windows; the experiment reports surface that cost next to CORAL's 10).
//!
//! Space-agnostic like the rest of the lineup: handed a normalized fleet
//! grid ([`crate::device::NormSpace`]) it sweeps the union rank-fraction
//! grid, giving the exhaustive upper bound for heterogeneous fleets too.

use super::constraints::Constraints;
use super::reward::reward;
use super::{BestConfig, Optimizer};
use crate::device::{ConfigSpace, HwConfig};

/// Exhaustive-search upper-bound baseline.
pub struct OracleOptimizer {
    space_list: Vec<HwConfig>,
    cons: Constraints,
    cursor: usize,
    best: Option<BestConfig>,
    measured: u64,
}

impl OracleOptimizer {
    pub fn new(space: ConfigSpace, cons: Constraints) -> OracleOptimizer {
        OracleOptimizer {
            space_list: space.enumerate(),
            cons,
            cursor: 0,
            best: None,
            measured: 0,
        }
    }

    /// Number of proposals needed for a complete sweep.
    pub fn sweep_len(&self) -> usize {
        self.space_list.len()
    }

    /// True once every configuration has been proposed.
    pub fn done(&self) -> bool {
        self.cursor >= self.space_list.len()
    }
}

impl Optimizer for OracleOptimizer {
    fn propose(&mut self) -> HwConfig {
        // After a full sweep, re-propose the best (steady state).
        if self.done() {
            return self.best.map(|b| b.config).unwrap_or(self.space_list[0]);
        }
        let c = self.space_list[self.cursor];
        self.cursor += 1;
        c
    }

    fn observe(
        &mut self,
        config: HwConfig,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    ) {
        self.measured += 1;
        let out = reward(&self.cons, throughput_fps, power_mw, p99_latency_ms, accuracy);
        let cand = BestConfig {
            config,
            throughput_fps,
            power_mw,
            p99_latency_ms,
            accuracy,
            reward: out.reward,
            feasible: out.feasible,
        };
        if self.best.map(|b| cand.reward > b.reward).unwrap_or(true) {
            self.best = Some(cand);
        }
    }

    fn best(&self) -> Option<BestConfig> {
        self.best
    }

    fn name(&self) -> &'static str {
        "oracle"
    }

    fn offline_cost_windows(&self) -> u64 {
        self.measured
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;

    #[test]
    fn full_sweep_finds_global_best() {
        let mut dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 42);
        let cons = Constraints::dual(30.0, 6500.0);
        let mut o = OracleOptimizer::new(dev.space().clone(), cons);
        let n = o.sweep_len();
        assert_eq!(n, 2160);
        for _ in 0..n {
            let c = o.propose();
            let m = dev.run(c);
            o.observe(c, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
        }
        assert!(o.done());
        let best = o.best().unwrap();
        assert!(best.feasible, "oracle must find the feasible region");
        assert!(best.throughput_fps >= 30.0 && best.power_mw <= 6500.0);
        assert_eq!(o.offline_cost_windows(), n as u64);
        // Steady state: keeps proposing the winner.
        assert_eq!(o.propose(), best.config);
    }

    #[test]
    fn infeasible_scenario_reports_infeasible_best() {
        let mut dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1);
        let cons = Constraints::dual(500.0, 3000.0); // impossible
        let mut o = OracleOptimizer::new(dev.space().clone(), cons);
        for _ in 0..o.sweep_len() {
            let c = o.propose();
            let m = dev.run(c);
            o.observe(c, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
        }
        assert!(!o.best().unwrap().feasible);
    }
}
