//! CORAL — the paper's online optimizer (§III).
//!
//! Per iteration:
//! 1. **Reward evaluation** (Algorithm 1, [`super::reward`]): feasible
//!    configurations score efficiency τ/p; violators are penalized and
//!    added to the prohibited list `PS`.
//! 2. **Correlation analysis** (§III-D): distance correlation of every
//!    configuration dimension against throughput (α) and power (β) over
//!    the sliding window of recent observations.
//! 3. **Configuration search** (Algorithm 2): dCor-weighted steps from
//!    the best/second-best configurations, direction chosen by whether
//!    the throughput target is already met, values snapped onto the
//!    device grid, plus the power-optimization heuristic (lines 14–17).
//!
//! Implementation notes for details the paper leaves open:
//! * **Bootstrap** — the window needs contrast before dCor means
//!   anything; iterations 0–1 probe the manufacturer default preset and
//!   the all-max configuration (max concurrency), giving every dimension
//!   two distinct values.
//! * **`aside` flag** — Algorithm 2 swaps the (low, high) anchors between
//!   best and second-best; we toggle it whenever a proposal collides with
//!   the prohibited/visited set, so consecutive collisions explore the
//!   other flank (§III-E "adapts its search direction").
//! * **Collisions** — proposals already in `PS` (or already measured,
//!   when `avoid_revisits` is on) are nudged to the nearest untried
//!   neighbour along dimensions in decreasing correlation order; if the
//!   whole neighbourhood is exhausted, a seeded random unvisited
//!   configuration is drawn (keeps the 10-iteration budget useful).
//! * **Heuristic target** — §III-E's text says *CPU frequency* to min,
//!   Algorithm 2 line 15 says *CPU cores*; [`Heuristic::Both`] (default)
//!   applies both, and the ablation bench compares all variants.
//! * **Heterogeneous fleets** — the search is expressed entirely in
//!   grid operations on its [`ConfigSpace`] (snap, neighbours, presets),
//!   so handing it a normalized fleet grid
//!   ([`crate::device::NormSpace`]) makes the same algorithm tune mixed
//!   NX/Orin fleets: steps and dCor weights live in rank-fraction space,
//!   the fleet environment decodes per member (EXPERIMENTS.md
//!   §Heterogeneous fleets).

use std::collections::HashSet;

use super::constraints::Constraints;
use super::reward::reward;
use super::{BestConfig, Optimizer};
use crate::device::{ConfigSpace, Dim, HwConfig};
use crate::stats::dcov::DcorWorkspace;
use crate::stats::window::{Observation, SlidingWindow};
use crate::util::Rng;

/// Power-optimization heuristic variant (Algorithm 2 lines 14–17).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Heuristic {
    /// Disabled (ablation).
    Off,
    /// §III-E text: CPU frequency → min, concurrency → max.
    FreqMin,
    /// Algorithm 2 pseudocode: CPU cores → min, concurrency → max.
    CoresMin,
    /// Both CPU knobs → min, concurrency → max (default).
    Both,
}

/// Where a step starts from (Algorithm 2 is ambiguous; see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Anchor {
    /// Step from the **current** (last-measured) configuration — §III-E's
    /// "adapts its search direction based on the current configuration's
    /// performance". Best/second-best only set the step *scale*. Default:
    /// converges reliably within the paper's 10-iteration budget.
    Last,
    /// Literal Algorithm-2 pseudocode: step from the best/second-best
    /// values with the `aside` flank swap (ablation variant).
    BestSecond,
}

/// Tunables of the CORAL search (paper defaults).
#[derive(Debug, Clone, Copy)]
pub struct CoralConfig {
    /// Sliding-window size W.
    pub window: usize,
    /// Power-optimization heuristic variant.
    pub heuristic: Heuristic,
    /// Skip configurations that were already measured (not just the
    /// prohibited ones) — each of the 10 iterations buys information.
    pub avoid_revisits: bool,
    /// Use dCor weights (γ = max(α, β)). Off = unweighted steps (γ = 1),
    /// the ablation showing the value of distance correlation.
    pub use_dcor: bool,
    /// Step anchoring interpretation.
    pub anchor: Anchor,
}

impl Default for CoralConfig {
    fn default() -> Self {
        CoralConfig {
            window: SlidingWindow::DEFAULT_W,
            heuristic: Heuristic::Both,
            avoid_revisits: true,
            use_dcor: true,
            anchor: Anchor::Last,
        }
    }
}

impl CoralConfig {
    /// Paper defaults with a custom sliding-window size. Windows far
    /// beyond the paper's W=10 (100 / 1k / 10k) stay cheap because
    /// [`DcorWorkspace`] switches to the O(n log n) dCor engine above
    /// [`crate::stats::dcov::FAST_PATH_MIN_N`] observations.
    pub fn with_window(window: usize) -> CoralConfig {
        CoralConfig { window, ..CoralConfig::default() }
    }
}

/// Scored observation retained for best/second-best tracking.
#[derive(Debug, Clone, Copy)]
struct Scored {
    config: HwConfig,
    throughput_fps: f64,
    power_mw: f64,
    p99_latency_ms: f64,
    accuracy: f64,
    reward: f64,
    feasible: bool,
}

/// The CORAL optimizer (paper §III).
pub struct CoralOptimizer {
    space: ConfigSpace,
    cons: Constraints,
    cfg: CoralConfig,
    window: SlidingWindow,
    ws: DcorWorkspace,
    prohibited: HashSet<HwConfig>,
    visited: HashSet<HwConfig>,
    best: Option<Scored>,
    second: Option<Scored>,
    last: Option<Scored>,
    /// Highest-throughput observation so far (drives the power heuristic:
    /// it proves the target is reachable and from which configuration).
    best_tput: Option<Scored>,
    /// α (throughput) and β (power) correlation weights per dimension.
    alpha: [f64; HwConfig::NDIMS],
    beta: [f64; HwConfig::NDIMS],
    aside: bool,
    iter: u64,
    rng: Rng,
    pending: Option<HwConfig>,
}

impl CoralOptimizer {
    pub fn new(space: ConfigSpace, cons: Constraints, seed: u64) -> CoralOptimizer {
        Self::with_config(space, cons, CoralConfig::default(), seed)
    }

    pub fn with_config(
        space: ConfigSpace,
        cons: Constraints,
        cfg: CoralConfig,
        seed: u64,
    ) -> CoralOptimizer {
        CoralOptimizer {
            window: SlidingWindow::new(cfg.window.max(2)),
            ws: DcorWorkspace::new(),
            prohibited: HashSet::new(),
            visited: HashSet::new(),
            best: None,
            second: None,
            last: None,
            best_tput: None,
            alpha: [0.0; HwConfig::NDIMS],
            beta: [0.0; HwConfig::NDIMS],
            aside: false,
            iter: 0,
            rng: Rng::new(seed),
            pending: None,
            space,
            cons,
            cfg,
        }
    }

    /// Current correlation weights (α: throughput, β: power) — exposed
    /// for the experiment reports and tests.
    pub fn weights(&self) -> ([f64; HwConfig::NDIMS], [f64; HwConfig::NDIMS]) {
        (self.alpha, self.beta)
    }

    /// Prohibited-set size (paper's PS).
    pub fn prohibited_len(&self) -> usize {
        self.prohibited.len()
    }

    /// Observations currently held in the sliding window.
    pub fn window_len(&self) -> usize {
        self.window.len()
    }

    /// §III-D: recompute α, β over the sliding window. The window hands
    /// out zero-copy columnar views, so this is allocation-free up to the
    /// workspace's reused buffers regardless of W.
    fn update_weights(&mut self) {
        if self.window.len() < 2 {
            return;
        }
        let tput = self.window.throughputs();
        let power = self.window.powers();
        let dims = self.window.setting_dims();
        let m = self.ws.dcor_matrix(&[tput, power], &dims);
        for d in 0..HwConfig::NDIMS {
            self.alpha[d] = m[0][d];
            self.beta[d] = m[1][d];
        }
    }

    /// Is this configuration proposable?
    fn untried(&self, c: &HwConfig) -> bool {
        !self.prohibited.contains(c)
            && (!self.cfg.avoid_revisits || !self.visited.contains(c))
    }

    /// Algorithm 2: generate the next configuration from best/second-best.
    fn search(&mut self) -> HwConfig {
        let (x, y) = match (self.best, self.second) {
            (Some(b), Some(s)) => (b, s),
            // Bootstrap: default preset, then all-max (max contrast).
            // After `reset_search` the prohibited list survives the
            // cleared anchors, so the probes go through the same
            // untried-or-nudge gate as every other proposal — a
            // restarted round must never re-propose a prohibited preset.
            _ => {
                // The probes come from the *space*, not the device:
                // native grids use the manufacturer presets, normalized
                // fleet grids (`device::NormSpace`) their rank-fraction
                // analogues — so CORAL tunes mixed-device fleets through
                // the same bootstrap discipline.
                let z = if self.iter == 0 {
                    self.space.preset_default()
                } else {
                    let mut c = self.space.preset_max_power();
                    c.concurrency = self.space.max(Dim::Concurrency);
                    // Span the batch axis too: presets carry the axis
                    // minimum, so without this probe the |best − second|
                    // spread along `max_batch` is zero and Eq. 10 steps
                    // never explore batching. On legacy singleton axes
                    // max = min = 1 — the probe is unchanged there.
                    c.max_batch = self.space.max(Dim::BatchCap);
                    // Same discipline for the variant axis: probe the
                    // most-degraded variant so |best − second| spans the
                    // seventh dimension and the search can trade accuracy.
                    // Singleton (legacy) axes leave the probe unchanged.
                    c.variant = self.space.max(Dim::Variant);
                    c
                };
                return self.next_untried(z);
            }
        };

        let last = self.last.unwrap_or(x);
        // `climb_target_fps` is ∞ under the throughput objective (the
        // search always climbs) — previously encoded as a sentinel
        // `Some(f64::INFINITY)` target, now explicit.
        let go_down = last.throughput_fps > self.cons.climb_target_fps()
            && last.power_mw >= self.cons.power_floor_mw;

        let xv = x.config.as_vec();
        let yv = y.config.as_vec();
        let lv = last.config.as_vec();
        let mut v = [0.0f64; HwConfig::NDIMS];
        for d in 0..HwConfig::NDIMS {
            // γ_i = max(α_i, β_i): the dominant correlation (§III-D).
            let gamma = if self.cfg.use_dcor {
                self.alpha[d].max(self.beta[d])
            } else {
                1.0
            };
            // Δ_i = ½ |x_i − y_i| · γ_i  (Eq. 10): the spread between the
            // two best configurations sets the step scale — wide early
            // (bootstrap probes), shrinking as the search converges.
            let delta = 0.5 * (xv[d] - yv[d]).abs() * gamma;
            v[d] = match self.cfg.anchor {
                Anchor::Last => {
                    if go_down {
                        lv[d] - delta
                    } else {
                        lv[d] + delta
                    }
                }
                Anchor::BestSecond => {
                    let (l, h) = if self.aside { (yv[d], xv[d]) } else { (xv[d], yv[d]) };
                    if go_down {
                        l - delta
                    } else {
                        h + delta
                    }
                }
            };
        }
        let mut z = self.space.snap_config(v); // MINMAX(ROUND(v), r)

        // Power-optimization heuristic (lines 14–17): the target has been
        // reached somewhere and power is still above the floor → keep
        // that configuration's GPU-side settings, cut the CPU side, and
        // lean on concurrency to keep throughput (§III-E). The paper
        // pins concurrency to max; we keep the proven level of the
        // highest-throughput observation — on contention-heavy surfaces
        // (NX) max concurrency degrades throughput, and the subsequent
        // collision nudges sweep the neighbouring levels anyway
        // (DESIGN.md §2 notes this interpretation).
        if let Some(bt) = self.best_tput {
            if bt.throughput_fps > self.cons.climb_target_fps()
                && bt.power_mw > self.cons.power_floor_mw
                && self.cfg.heuristic != Heuristic::Off
            {
                z = bt.config;
                z.concurrency = bt.config.concurrency;
                match self.cfg.heuristic {
                    Heuristic::Off => unreachable!(),
                    Heuristic::FreqMin => {
                        z.cpu_freq_mhz = self.space.min(Dim::CpuFreq);
                    }
                    Heuristic::CoresMin => {
                        z.cpu_cores = self.space.min(Dim::CpuCores);
                    }
                    Heuristic::Both => {
                        z.cpu_freq_mhz = self.space.min(Dim::CpuFreq);
                        z.cpu_cores = self.space.min(Dim::CpuCores);
                    }
                }
            }
        }

        self.next_untried(z)
    }

    /// The untried-or-nudge gate every proposal passes through: return
    /// `z` when it is proposable, otherwise sweep the neighbourhood for
    /// the nearest untried configuration.
    fn next_untried(&mut self, z: HwConfig) -> HwConfig {
        if self.untried(&z) {
            return z;
        }
        self.aside = !self.aside; // explore the other flank next time

        // Collision, stage 1: concurrency is the only non-monotone knob
        // (pipelining vs contention), so sweep its untried levels around
        // the proposal first — nearest level first.
        {
            let vals = self.space.values(Dim::Concurrency).to_vec();
            let cur = z.concurrency;
            let mut levels: Vec<u32> = vals.clone();
            levels.sort_by_key(|&v| (v as i64 - cur as i64).unsigned_abs());
            for lvl in levels {
                let cand = z.with(Dim::Concurrency, lvl);
                if self.untried(&cand) {
                    return cand;
                }
            }
        }

        // Collision, stage 2: nudge along dimensions in decreasing-γ order.
        let mut order: Vec<usize> = (0..HwConfig::NDIMS).collect();
        let alpha = self.alpha;
        let beta = self.beta;
        order.sort_by(|&a, &b| {
            let ga = alpha[a].max(beta[a]);
            let gb = alpha[b].max(beta[b]);
            gb.partial_cmp(&ga).unwrap()
        });
        for &d in &order {
            let dim = Dim::ALL[d];
            let vals = self.space.values(dim);
            let pos = vals.binary_search(&z.get(dim)).unwrap_or(0);
            for step in 1..vals.len() {
                for dir in [1i64, -1] {
                    let q = pos as i64 + dir * step as i64;
                    if q < 0 || q as usize >= vals.len() {
                        continue;
                    }
                    let cand = z.with(dim, vals[q as usize]);
                    if self.untried(&cand) {
                        return cand;
                    }
                }
            }
        }
        // Neighbourhood exhausted: seeded random unvisited draw.
        for _ in 0..256 {
            let cand = self.space.random(&mut self.rng);
            if self.untried(&cand) {
                return cand;
            }
        }
        z // space exhausted — let the caller re-measure the proposal
    }
}

impl Optimizer for CoralOptimizer {
    fn propose(&mut self) -> HwConfig {
        self.update_weights();
        let z = self.search();
        self.pending = Some(z);
        z
    }

    fn observe(
        &mut self,
        config: HwConfig,
        throughput_fps: f64,
        power_mw: f64,
        p99_latency_ms: f64,
        accuracy: f64,
    ) {
        self.iter += 1;
        self.pending = None;
        self.visited.insert(config);

        // Step 1: reward evaluation (Algorithm 1, SLO-aware). A window
        // that violates the latency SLO joins PS like any other
        // constraint violation — the tail is a property of the
        // configuration under the current offered load.
        let out = reward(&self.cons, throughput_fps, power_mw, p99_latency_ms, accuracy);
        if !out.feasible {
            self.prohibited.insert(config); // PS.APPEND(x)
        }
        let scored = Scored {
            config,
            throughput_fps,
            power_mw,
            p99_latency_ms,
            accuracy,
            reward: out.reward,
            feasible: out.feasible,
        };
        self.last = Some(scored);
        if throughput_fps > 0.0
            && self
                .best_tput
                .map(|b| throughput_fps > b.throughput_fps)
                .unwrap_or(true)
        {
            self.best_tput = Some(scored);
        }

        // Window feeds the correlation analysis; crashed configs carry no
        // performance signal and would poison dCor with zeros.
        if throughput_fps > 0.0 {
            self.window.push(Observation {
                config,
                throughput_fps,
                power_mw,
            });
        }

        // Best / second-best tracking by reward.
        match self.best {
            None => self.best = Some(scored),
            Some(b) if scored.reward > b.reward => {
                if scored.config != b.config {
                    self.second = Some(b);
                }
                self.best = Some(scored);
            }
            Some(b) => {
                if scored.config != b.config {
                    match self.second {
                        None => self.second = Some(scored),
                        Some(s) if scored.reward > s.reward => self.second = Some(scored),
                        _ => {}
                    }
                }
            }
        }
    }

    fn best(&self) -> Option<BestConfig> {
        self.best.map(|b| BestConfig {
            config: b.config,
            throughput_fps: b.throughput_fps,
            power_mw: b.power_mw,
            p99_latency_ms: b.p99_latency_ms,
            accuracy: b.accuracy,
            reward: b.reward,
            feasible: b.feasible,
        })
    }

    fn name(&self) -> &'static str {
        "coral"
    }

    fn window_throughputs(&self) -> &[f64] {
        self.window.throughputs()
    }

    /// Mid-search surface shift: every observation in the window, the
    /// best/second-best anchors, and the dCor weights describe a surface
    /// that no longer exists — drop them. The prohibited list survives
    /// (crashes and budget violations are properties of the
    /// configuration, not of the drifted throughput level), and so does
    /// the RNG stream (the restarted round keeps the run deterministic).
    fn reset_search(&mut self) {
        self.window = SlidingWindow::new(self.cfg.window.max(2));
        self.visited.clear();
        self.best = None;
        self.second = None;
        self.last = None;
        self.best_tput = None;
        self.alpha = [0.0; HwConfig::NDIMS];
        self.beta = [0.0; HwConfig::NDIMS];
        self.aside = false;
        self.iter = 0;
        self.pending = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::models::ModelKind;
    use crate::optimizer::tests::drive;
    use crate::util::prop;

    const BUDGET: usize = 10; // the paper's iteration budget

    fn dual_cons(dev: DeviceKind) -> Constraints {
        match dev {
            DeviceKind::XavierNx => Constraints::dual(30.0, 6500.0),
            DeviceKind::OrinNano => Constraints::dual(60.0, 5600.0),
        }
    }

    #[test]
    fn finds_dual_feasible_on_both_devices_yolo() {
        // Paper §IV-B headline: CORAL satisfies both constraints on both
        // devices within 10 iterations.
        for dev in DeviceKind::ALL {
            let mut hits = 0;
            for seed in 0..10 {
                let mut device = Device::new(dev, ModelKind::Yolo, 1000 + seed);
                let mut opt =
                    CoralOptimizer::new(device.space().clone(), dual_cons(dev), seed);
                let best = drive(&mut opt, &mut device, BUDGET).unwrap();
                if best.feasible {
                    hits += 1;
                }
            }
            assert!(hits >= 8, "{dev}: feasible in {hits}/10 seeded runs");
        }
    }

    #[test]
    fn single_target_reaches_96pct_of_oracle() {
        // Paper §IV-B: 96–100 % of ORACLE throughput.
        for dev in DeviceKind::ALL {
            // ORACLE: true max throughput over the valid space.
            let probe = Device::new(dev, ModelKind::Yolo, 0);
            let oracle_fps = crate::device::failure::valid_configs(dev, ModelKind::Yolo)
                .iter()
                .map(|c| probe.true_point(c).0.throughput_fps)
                .fold(0.0f64, f64::max);

            let mut ratios = Vec::new();
            for seed in 0..10 {
                let mut device = Device::new(dev, ModelKind::Yolo, 2000 + seed);
                let mut opt = CoralOptimizer::new(
                    device.space().clone(),
                    Constraints::max_throughput(),
                    seed,
                );
                let best = drive(&mut opt, &mut device, BUDGET).unwrap();
                ratios.push(best.throughput_fps / oracle_fps);
            }
            let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
            assert!(mean >= 0.93, "{dev}: mean ratio {mean:.3} ({ratios:?})");
        }
    }

    #[test]
    fn prohibited_configs_never_reproposed() {
        prop::check("PS respected", 20, |g| {
            let dev = *g.rng.choose(&DeviceKind::ALL);
            let seed = g.rng.next_u64();
            let mut device = Device::new(dev, ModelKind::RetinaNet, seed);
            let mut opt = CoralOptimizer::new(device.space().clone(), dual_cons(dev), seed);
            let mut seen_prohibited: Vec<HwConfig> = Vec::new();
            for _ in 0..15 {
                let cfg = opt.propose();
                prop::assert_true(
                    !seen_prohibited.contains(&cfg),
                    "re-proposed a prohibited config",
                )?;
                let m = device.run(cfg);
                opt.observe(cfg, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
                if !reward(&dual_cons(dev), m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy)
                    .feasible
                {
                    seen_prohibited.push(cfg);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn proposals_always_on_grid() {
        prop::check("proposals on grid", 20, |g| {
            let dev = *g.rng.choose(&DeviceKind::ALL);
            let model = *g.rng.choose(&ModelKind::ALL);
            let seed = g.rng.next_u64();
            let mut device = Device::new(dev, model, seed);
            let space = device.space().clone();
            let mut opt = CoralOptimizer::new(space.clone(), dual_cons(dev), seed);
            for _ in 0..12 {
                let cfg = opt.propose();
                prop::assert_true(space.contains(&cfg), "on grid")?;
                let m = device.run(cfg);
                opt.observe(cfg, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
            }
            Ok(())
        });
    }

    #[test]
    fn weights_identify_gpu_for_gpu_bound_model() {
        // On a GPU-bound workload the dominant dCor weight should land on
        // GPU frequency (or concurrency) rather than memory frequency.
        let mut device = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 5);
        let mut opt = CoralOptimizer::new(
            device.space().clone(),
            Constraints::max_throughput(),
            5,
        );
        drive(&mut opt, &mut device, 10);
        let (alpha, _beta) = opt.weights();
        let gpu = alpha[Dim::GpuFreq.index()];
        let max = alpha.iter().cloned().fold(0.0f64, f64::max);
        // Bootstrap moves are partially confounded (all dims move
        // together), so demand "highly informative", not strictly top:
        // a strong absolute weight within 0.1 of the strongest dim.
        assert!(
            gpu > 0.5 && gpu >= max - 0.1,
            "gpu dCor {gpu:.2} should be near-dominant: {alpha:?}"
        );
    }

    #[test]
    fn best_tracking_keeps_distinct_second() {
        let space = DeviceKind::XavierNx.space();
        let mut opt = CoralOptimizer::new(space.clone(), Constraints::none(), 1);
        let a = space.midpoint();
        let b = a.with(Dim::GpuFreq, 510);
        opt.observe(a, 30.0, 6000.0, 10.0, 27.6);
        opt.observe(a, 31.0, 6000.0, 10.0, 27.6); // same config better score
        opt.observe(b, 20.0, 5000.0, 10.0, 27.6);
        assert_eq!(opt.best().unwrap().config, a);
        assert_eq!(opt.second.unwrap().config, b);
    }

    #[test]
    fn crashed_configs_enter_ps_and_leave_window_clean() {
        let space = DeviceKind::XavierNx.space();
        let mut opt =
            CoralOptimizer::new(space.clone(), Constraints::dual(30.0, 6500.0), 1);
        let c = space.midpoint();
        opt.observe(c, 0.0, 2350.0, f64::INFINITY, 0.0);
        assert_eq!(opt.prohibited_len(), 1);
        assert_eq!(opt.window.len(), 0);
        assert_eq!(opt.best().unwrap().reward, f64::NEG_INFINITY);
    }

    #[test]
    fn large_window_runs_on_fast_dcor_path() {
        // W far beyond the paper's 10: the window must cap correctly and
        // the per-iteration dCor (now on the O(n log n) engine once the
        // window passes FAST_PATH_MIN_N) must keep producing weights in
        // [0, 1] while the search still functions.
        let mut device = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 9);
        let cfg = CoralConfig::with_window(100);
        let mut opt = CoralOptimizer::with_config(
            device.space().clone(),
            Constraints::dual(30.0, 6500.0),
            cfg,
            9,
        );
        for _ in 0..140 {
            let c = opt.propose();
            let m = device.run(c);
            opt.observe(c, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
        }
        assert!(
            opt.window_len() > crate::stats::dcov::FAST_PATH_MIN_N,
            "window {} should exceed the fast-path threshold",
            opt.window_len()
        );
        assert!(opt.window_len() <= 100, "window must cap at W");
        let (alpha, beta) = opt.weights();
        for w in alpha.iter().chain(beta.iter()) {
            assert!((0.0..=1.0).contains(w), "weight {w}");
        }
        assert!(opt.best().is_some());
    }

    #[test]
    fn reset_search_keeps_prohibited_list_drops_surface_state() {
        let space = DeviceKind::XavierNx.space();
        let cons = Constraints::dual(30.0, 6500.0);
        let mut opt = CoralOptimizer::new(space.clone(), cons, 7);
        let a = space.midpoint();
        let b = a.with(Dim::GpuFreq, 510);
        opt.observe(a, 10.0, 9000.0, 10.0, 27.6); // infeasible both ways -> PS
        opt.observe(b, 35.0, 6000.0, 10.0, 27.6); // feasible
        assert_eq!(opt.prohibited_len(), 1);
        assert_eq!(opt.window_len(), 2);
        assert!(opt.best().is_some());

        opt.reset_search();
        assert_eq!(opt.prohibited_len(), 1, "PS survives the shift");
        assert_eq!(opt.window_len(), 0, "stale observations dropped");
        assert!(opt.best().is_none(), "best anchors dropped");
        assert!(opt.window_throughputs().is_empty());
        let (alpha, beta) = opt.weights();
        assert!(alpha.iter().chain(beta.iter()).all(|w| *w == 0.0));
        // The prohibited config stays unproposable on the new surface.
        for _ in 0..12 {
            let cfg = opt.propose();
            assert_ne!(cfg, a, "prohibited config re-proposed after reset");
            opt.observe(cfg, 20.0, 5000.0, 10.0, 27.6);
        }
    }

    #[test]
    fn window_throughputs_exposes_sliding_window_series() {
        let space = DeviceKind::XavierNx.space();
        let mut opt = CoralOptimizer::new(space.clone(), Constraints::none(), 1);
        let c = space.midpoint();
        opt.observe(c, 30.0, 6000.0, 10.0, 27.6);
        opt.observe(c, 0.0, 2000.0, f64::INFINITY, 0.0); // crashed window: not recorded
        opt.observe(c, 28.0, 5900.0, 10.0, 27.6);
        assert_eq!(opt.window_throughputs(), &[30.0, 28.0]);
    }

    #[test]
    fn normalized_grid_proposals_stay_on_the_virtual_grid() {
        // CORAL over a mixed NX/Orin normalized space: bootstrap probes,
        // guided steps, collision nudges, and random fallbacks must all
        // stay on the rank-fraction grid (the fleet environment decodes
        // them per member — on-grid proposals are what make every
        // decoded config land on a native grid).
        use crate::device::NormSpace;
        let ns = NormSpace::new(vec![
            DeviceKind::XavierNx.space(),
            DeviceKind::OrinNano.space(),
        ]);
        let g = ns.grid().clone();
        let cons = Constraints::dual(40.0, 6400.0);
        let mut opt = CoralOptimizer::new(g.clone(), cons, 11);
        for i in 0..12 {
            let cfg = opt.propose();
            assert!(g.contains(&cfg), "iteration {i}: {cfg:?} off the virtual grid");
            // A smooth synthetic response keeps the search moving.
            let fps = 30.0 + cfg.gpu_freq_mhz as f64 / 50.0;
            let mw = 4000.0 + 2.0 * cfg.gpu_freq_mhz as f64 + cfg.concurrency as f64;
            opt.observe(cfg, fps, mw, 10.0, 27.6);
        }
        assert!(opt.best().is_some());
        // Probe 0 is the normalized default (mid knobs, min concurrency),
        // probe 1 the all-max — the same contrast discipline as native.
        let (alpha, beta) = opt.weights();
        for w in alpha.iter().chain(beta.iter()) {
            assert!((0.0..=1.0).contains(w), "weight {w}");
        }
    }

    #[test]
    fn bootstrap_probe_spans_the_variant_axis() {
        // On a space with a real variant axis the second bootstrap probe
        // must pin `variant` to the axis max — otherwise the |best −
        // second| spread along the seventh dimension is zero and Eq. 10
        // never explores degraded variants.
        let space = DeviceKind::XavierNx.space().with_variant_axis(4);
        let mut opt = CoralOptimizer::new(space.clone(), Constraints::none(), 2);
        let p0 = opt.propose();
        assert_eq!(p0.variant, 0, "probe 0 is the full-accuracy default");
        opt.observe(p0, 30.0, 6000.0, 10.0, 27.6);
        let p1 = opt.propose();
        assert_eq!(p1.variant, 3, "probe 1 spans the variant axis");
        // Legacy singleton axis: the probe is unchanged (variant 0).
        let legacy = DeviceKind::XavierNx.space();
        let mut opt = CoralOptimizer::new(legacy, Constraints::none(), 2);
        let p0 = opt.propose();
        opt.observe(p0, 30.0, 6000.0, 10.0, 27.6);
        assert_eq!(opt.propose().variant, 0);
    }

    #[test]
    fn accuracy_floor_prohibits_variants_below_it() {
        // A window served below the accuracy floor joins PS like any
        // other constraint violation.
        let space = DeviceKind::XavierNx.space().with_variant_axis(4);
        let cons = Constraints::dual(30.0, 6500.0).with_min_accuracy(26.0);
        let mut opt = CoralOptimizer::new(space.clone(), cons, 3);
        let c = space.midpoint().with(Dim::Variant, 3);
        opt.observe(c, 50.0, 5000.0, 10.0, 21.8); // fast, cheap, too coarse
        assert_eq!(opt.prohibited_len(), 1);
        assert!(!opt.best().unwrap().feasible);
    }

    #[test]
    fn ablation_unweighted_steps_still_run() {
        let mut device = Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 3);
        let cfg = CoralConfig { use_dcor: false, ..CoralConfig::default() };
        let mut opt = CoralOptimizer::with_config(
            device.space().clone(),
            dual_cons(DeviceKind::OrinNano),
            cfg,
            3,
        );
        let best = drive(&mut opt, &mut device, BUDGET);
        assert!(best.is_some());
    }
}
