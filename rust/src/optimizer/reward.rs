//! Reward calculation (paper Algorithm 1).
//!
//! Feasible configurations score their efficiency r = τ/p (Eq. 7);
//! infeasible ones score the negative inverted ratio r = −(p/τ) (Eq. 8),
//! guaranteeing every infeasible configuration ranks below every feasible
//! one while still ordering infeasible configs by how badly they waste
//! power.

use super::constraints::{Constraints, Objective};

/// Outcome of evaluating one measurement (Algorithm 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RewardOutcome {
    /// Reward score `r`.
    pub reward: f64,
    /// Whether both constraints were satisfied.
    pub feasible: bool,
}

/// Algorithm 1: feasibility check + reward score.
///
/// Edge cases beyond the paper's pseudocode: a crashed configuration
/// (τ = 0) gets −∞ so it sorts below every other infeasible config, and a
/// zero power reading (impossible physically) is clamped to avoid ±∞
/// efficiency.
///
/// The serving extension adds the p99 latency SLO: when
/// [`Constraints::latency_slo_ms`] is set, an SLO-violating window is
/// infeasible and its penalty is the Eq. 8 inverted ratio scaled by how
/// badly the tail missed (`p99 / slo`), so among violators the search
/// still feels a gradient toward the SLO region and a shed window
/// (p99 = ∞) ranks with crashes. With no SLO the score is untouched.
///
/// The variant extension adds the accuracy floor: when
/// [`Constraints::min_accuracy`] is set, a window served below the floor
/// is infeasible with the plain Eq. 8 penalty — the variant axis is
/// discrete, so no shaped gradient is needed; the search simply learns
/// which variants clear the floor. With no floor the `accuracy`
/// argument is inert.
pub fn reward(
    cons: &Constraints,
    throughput_fps: f64,
    power_mw: f64,
    p99_latency_ms: f64,
    accuracy: f64,
) -> RewardOutcome {
    let p = power_mw.max(1e-9);
    let latency_ok = cons.latency_ok(p99_latency_ms);
    let accuracy_ok = cons.accuracy_ok(accuracy);
    // Eq. 8 penalty, amplified by the SLO miss ratio when that is the
    // violated clause (ratio > 1 by construction; ∞ p99 → −∞ reward).
    let penalty = |t: f64| -> f64 {
        let base = -(p / t);
        match cons.latency_slo_ms {
            Some(slo) if !latency_ok => base * (p99_latency_ms / slo),
            _ => base,
        }
    };
    if cons.objective == Objective::Throughput {
        // Single-constraint throughput maximization (Figs 3–4): no
        // reachable target, so ranking is raw throughput among
        // configurations that run within budget (and SLO / accuracy
        // floor, if any).
        return if throughput_fps > 0.0
            && power_mw <= cons.budget_or_inf()
            && latency_ok
            && accuracy_ok
        {
            RewardOutcome { reward: throughput_fps, feasible: true }
        } else if throughput_fps <= 0.0 {
            RewardOutcome { reward: f64::NEG_INFINITY, feasible: false }
        } else {
            RewardOutcome { reward: penalty(throughput_fps), feasible: false }
        };
    }
    if cons.feasible(throughput_fps, power_mw) && latency_ok && accuracy_ok {
        RewardOutcome { reward: throughput_fps / p, feasible: true }
    } else if throughput_fps <= 0.0 {
        RewardOutcome { reward: f64::NEG_INFINITY, feasible: false }
    } else {
        RewardOutcome { reward: penalty(throughput_fps), feasible: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn feasible_reward_is_efficiency() {
        let c = Constraints::dual(30.0, 6500.0);
        let r = reward(&c, 33.0, 5500.0, 0.0, 30.0);
        assert!(r.feasible);
        assert!((r.reward - 33.0 / 5500.0).abs() < 1e-12);
    }

    #[test]
    fn infeasible_reward_is_negative_inverse() {
        let c = Constraints::dual(30.0, 6500.0);
        let r = reward(&c, 20.0, 7000.0, 0.0, 30.0);
        assert!(!r.feasible);
        assert!((r.reward + 7000.0 / 20.0).abs() < 1e-12);
    }

    #[test]
    fn crashed_config_is_worst() {
        let c = Constraints::dual(30.0, 6500.0);
        let r = reward(&c, 0.0, 2350.0, 0.0, 30.0);
        assert!(!r.feasible);
        assert_eq!(r.reward, f64::NEG_INFINITY);
    }

    #[test]
    fn throughput_objective_ranks_by_fps() {
        let c = Constraints::max_throughput();
        let hi = reward(&c, 40.0, 9000.0, 0.0, 30.0);
        let lo = reward(&c, 30.0, 3000.0, 0.0, 30.0);
        assert!(hi.feasible && lo.feasible);
        assert!(hi.reward > lo.reward, "raw fps ranking");
        assert_eq!(reward(&c, 0.0, 2000.0, 0.0, 30.0).reward, f64::NEG_INFINITY);
    }

    #[test]
    fn slo_violation_is_infeasible_and_shaped() {
        let c = Constraints::dual(25.0, 6500.0).with_latency_slo(80.0);
        let ok = reward(&c, 30.0, 6000.0, 50.0, 30.0);
        assert!(ok.feasible);
        assert!((ok.reward - 30.0 / 6000.0).abs() < 1e-12);
        // Same window, tail past the SLO: infeasible, penalty scaled by
        // the miss ratio — a worse miss ranks strictly lower.
        let near = reward(&c, 30.0, 6000.0, 100.0, 30.0);
        let far = reward(&c, 30.0, 6000.0, 400.0, 30.0);
        assert!(!near.feasible && !far.feasible);
        assert!((near.reward + (6000.0 / 30.0) * (100.0 / 80.0)).abs() < 1e-9);
        assert!(far.reward < near.reward, "deeper SLO miss ranks lower");
        // A shed window (p99 = ∞) ranks with crashes.
        assert_eq!(reward(&c, 30.0, 6000.0, f64::INFINITY, 30.0).reward, f64::NEG_INFINITY);
        // No SLO set: the p99 argument is inert.
        let d = Constraints::dual(25.0, 6500.0);
        assert_eq!(
            reward(&d, 30.0, 6000.0, f64::INFINITY, 30.0),
            reward(&d, 30.0, 6000.0, 0.0, 30.0),
        );
    }

    #[test]
    fn slo_applies_to_throughput_objective_too() {
        let c = Constraints::max_throughput().with_latency_slo(80.0);
        assert!(reward(&c, 40.0, 9000.0, 50.0, 30.0).feasible);
        let miss = reward(&c, 40.0, 9000.0, 160.0, 30.0);
        assert!(!miss.feasible);
        assert!((miss.reward + (9000.0 / 40.0) * 2.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_floor_gates_feasibility() {
        let c = Constraints::dual(25.0, 6500.0).with_min_accuracy(26.0);
        let full = reward(&c, 30.0, 6000.0, 0.0, 27.6);
        assert!(full.feasible);
        assert!((full.reward - 30.0 / 6000.0).abs() < 1e-12);
        // Same window served by a variant below the floor: infeasible
        // with the plain Eq. 8 penalty (no latency shaping involved).
        let degraded = reward(&c, 30.0, 6000.0, 0.0, 24.6);
        assert!(!degraded.feasible);
        assert!((degraded.reward + 6000.0 / 30.0).abs() < 1e-12);
        // The floor applies under the throughput objective too.
        let t = Constraints::max_throughput().with_min_accuracy(26.0);
        assert!(reward(&t, 40.0, 9000.0, 0.0, 27.6).feasible);
        assert!(!reward(&t, 40.0, 9000.0, 0.0, 24.6).feasible);
        // No floor set: the accuracy argument is inert.
        let d = Constraints::dual(25.0, 6500.0);
        assert_eq!(
            reward(&d, 30.0, 6000.0, 0.0, 0.0),
            reward(&d, 30.0, 6000.0, 0.0, 41.5),
        );
    }

    #[test]
    fn prop_feasible_always_outranks_infeasible() {
        // The paper's design goal for Eq. 8.
        prop::check("feasible > infeasible reward", 300, |g| {
            let mut c = Constraints::dual(g.rng.range_f64(1.0, 100.0), g.rng.range_f64(3000.0, 9000.0));
            if g.rng.below(2) == 0 {
                c = c.with_latency_slo(g.rng.range_f64(50.0, 300.0));
            }
            if g.rng.below(2) == 0 {
                c = c.with_min_accuracy(g.rng.range_f64(20.0, 40.0));
            }
            let t1 = g.rng.range_f64(0.0, 120.0);
            let p1 = g.rng.range_f64(2000.0, 10_000.0);
            let t2 = g.rng.range_f64(0.0, 120.0);
            let p2 = g.rng.range_f64(2000.0, 10_000.0);
            let l1 = if g.rng.below(2) == 0 { g.rng.range_f64(1.0, 500.0) } else { 0.0 };
            let l2 = if g.rng.below(2) == 0 { g.rng.range_f64(1.0, 500.0) } else { 0.0 };
            let a1 = g.rng.range_f64(15.0, 45.0);
            let a2 = g.rng.range_f64(15.0, 45.0);
            let r1 = reward(&c, t1, p1, l1, a1);
            let r2 = reward(&c, t2, p2, l2, a2);
            if r1.feasible && !r2.feasible {
                prop::assert_true(r1.reward > r2.reward, "feasible outranks")?;
            }
            if r2.feasible && !r1.feasible {
                prop::assert_true(r2.reward > r1.reward, "feasible outranks")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_feasible_ranking_prefers_efficiency() {
        prop::check("higher efficiency ranks higher", 200, |g| {
            let c = Constraints::none();
            let t1 = g.rng.range_f64(1.0, 100.0);
            let p1 = g.rng.range_f64(2000.0, 10_000.0);
            let t2 = g.rng.range_f64(1.0, 100.0);
            let p2 = g.rng.range_f64(2000.0, 10_000.0);
            let r1 = reward(&c, t1, p1, 0.0, 30.0).reward;
            let r2 = reward(&c, t2, p2, 0.0, 30.0).reward;
            prop::assert_true(
                (r1 > r2) == (t1 / p1 > t2 / p2),
                "efficiency ordering",
            )
        });
    }
}
