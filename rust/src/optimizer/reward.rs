//! Reward calculation (paper Algorithm 1).
//!
//! Feasible configurations score their efficiency r = τ/p (Eq. 7);
//! infeasible ones score the negative inverted ratio r = −(p/τ) (Eq. 8),
//! guaranteeing every infeasible configuration ranks below every feasible
//! one while still ordering infeasible configs by how badly they waste
//! power.

use super::constraints::{Constraints, Objective};

/// Outcome of evaluating one measurement (Algorithm 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RewardOutcome {
    /// Reward score `r`.
    pub reward: f64,
    /// Whether both constraints were satisfied.
    pub feasible: bool,
}

/// Algorithm 1: feasibility check + reward score.
///
/// Edge cases beyond the paper's pseudocode: a crashed configuration
/// (τ = 0) gets −∞ so it sorts below every other infeasible config, and a
/// zero power reading (impossible physically) is clamped to avoid ±∞
/// efficiency.
pub fn reward(cons: &Constraints, throughput_fps: f64, power_mw: f64) -> RewardOutcome {
    let p = power_mw.max(1e-9);
    if cons.objective == Objective::Throughput {
        // Single-constraint throughput maximization (Figs 3–4): the
        // target is unreachable by construction, so ranking is raw
        // throughput among configurations that run within budget.
        return if throughput_fps > 0.0 && power_mw <= cons.budget_or_inf() {
            RewardOutcome { reward: throughput_fps, feasible: true }
        } else if throughput_fps <= 0.0 {
            RewardOutcome { reward: f64::NEG_INFINITY, feasible: false }
        } else {
            RewardOutcome { reward: -(p / throughput_fps), feasible: false }
        };
    }
    if cons.feasible(throughput_fps, power_mw) {
        RewardOutcome { reward: throughput_fps / p, feasible: true }
    } else if throughput_fps <= 0.0 {
        RewardOutcome { reward: f64::NEG_INFINITY, feasible: false }
    } else {
        RewardOutcome { reward: -(p / throughput_fps), feasible: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn feasible_reward_is_efficiency() {
        let c = Constraints::dual(30.0, 6500.0);
        let r = reward(&c, 33.0, 5500.0);
        assert!(r.feasible);
        assert!((r.reward - 33.0 / 5500.0).abs() < 1e-12);
    }

    #[test]
    fn infeasible_reward_is_negative_inverse() {
        let c = Constraints::dual(30.0, 6500.0);
        let r = reward(&c, 20.0, 7000.0);
        assert!(!r.feasible);
        assert!((r.reward + 7000.0 / 20.0).abs() < 1e-12);
    }

    #[test]
    fn crashed_config_is_worst() {
        let c = Constraints::dual(30.0, 6500.0);
        let r = reward(&c, 0.0, 2350.0);
        assert!(!r.feasible);
        assert_eq!(r.reward, f64::NEG_INFINITY);
    }

    #[test]
    fn throughput_objective_ranks_by_fps() {
        let c = Constraints::max_throughput();
        let hi = reward(&c, 40.0, 9000.0);
        let lo = reward(&c, 30.0, 3000.0);
        assert!(hi.feasible && lo.feasible);
        assert!(hi.reward > lo.reward, "raw fps ranking");
        assert_eq!(reward(&c, 0.0, 2000.0).reward, f64::NEG_INFINITY);
    }

    #[test]
    fn prop_feasible_always_outranks_infeasible() {
        // The paper's design goal for Eq. 8.
        prop::check("feasible > infeasible reward", 300, |g| {
            let c = Constraints::dual(g.rng.range_f64(1.0, 100.0), g.rng.range_f64(3000.0, 9000.0));
            let t1 = g.rng.range_f64(0.0, 120.0);
            let p1 = g.rng.range_f64(2000.0, 10_000.0);
            let t2 = g.rng.range_f64(0.0, 120.0);
            let p2 = g.rng.range_f64(2000.0, 10_000.0);
            let r1 = reward(&c, t1, p1);
            let r2 = reward(&c, t2, p2);
            if r1.feasible && !r2.feasible {
                prop::assert_true(r1.reward > r2.reward, "feasible outranks")?;
            }
            if r2.feasible && !r1.feasible {
                prop::assert_true(r2.reward > r1.reward, "feasible outranks")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_feasible_ranking_prefers_efficiency() {
        prop::check("higher efficiency ranks higher", 200, |g| {
            let c = Constraints::none();
            let t1 = g.rng.range_f64(1.0, 100.0);
            let p1 = g.rng.range_f64(2000.0, 10_000.0);
            let t2 = g.rng.range_f64(1.0, 100.0);
            let p2 = g.rng.range_f64(2000.0, 10_000.0);
            let r1 = reward(&c, t1, p1).reward;
            let r2 = reward(&c, t2, p2).reward;
            prop::assert_true(
                (r1 > r2) == (t1 / p1 > t2 / p2),
                "efficiency ordering",
            )
        });
    }
}
