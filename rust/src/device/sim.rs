//! The simulated Jetson board: applies configurations (nvpmodel-style),
//! runs measurement windows with the paper's telemetry discipline
//! (2 s warm-up, 1 Hz samples), and layers per-chip variation +
//! measurement noise on the deterministic models.

use super::dvfs::{ConfigSpace, HwConfig};
use super::failure::{self, FailureKind};
use super::perf;
use super::power;
use super::specs::DeviceKind;
use super::thermal::ThermalModel;
use crate::models::{ModelKind, VariantManifest};
use crate::util::rng::{hash_unit, Rng};

/// One aggregated measurement window (what the optimizer observes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measured {
    pub config: HwConfig,
    /// Mean throughput over the window (fps). 0 for failed configs.
    pub throughput_fps: f64,
    /// Mean module power over the window (mW).
    pub power_mw: f64,
    /// Mean per-frame latency (ms). ∞ for failed configs.
    pub latency_ms: f64,
    /// 99th-percentile per-frame latency (ms). Equal to `latency_ms`
    /// under closed-loop measurement (no external queue); under an
    /// offered load it adds the queueing tail (see
    /// [`under_offered_load`]). ∞ for failed or saturated configs.
    pub p99_latency_ms: f64,
    pub gpu_util: f64,
    pub cpu_util: f64,
    pub mem_util: f64,
    /// Modeled accuracy (mAP) of the model variant this window served —
    /// the third objective next to throughput and power. 0 for failed
    /// or dropped windows (no frames were served at any accuracy).
    pub accuracy: f64,
    /// Set when the configuration failed to run (paper §IV-A exclusions).
    pub failed: Option<FailureKind>,
}

/// Timing constants of the paper's measurement loop (§IV-A).
pub const WARMUP_S: f64 = 2.0;
pub const SAMPLES_PER_WINDOW: usize = 5;

/// A simulated Jetson device running one model.
#[derive(Debug, Clone)]
pub struct Device {
    kind: DeviceKind,
    model: ModelKind,
    space: ConfigSpace,
    /// The runnable variants of `model` this board serves;
    /// `HwConfig::variant` indexes into it. Defaults to the singleton
    /// identity manifest (the legacy fixed-model surface).
    manifest: VariantManifest,
    current: HwConfig,
    rng: Rng,
    thermal: Option<ThermalModel>,
    /// Noise-seed lineage as passed to [`Device::new`] (cache identity).
    seed: u64,
    /// Multiplier on measurement noise (robustness experiments).
    noise_scale: f64,
    /// Simulated wall-clock spent in warm-up + measurement (s) — used to
    /// report search cost (CORAL's 10 iterations vs ORACLE's exhaustive
    /// sweep).
    sim_clock_s: f64,
    windows_run: u64,
}

impl Device {
    /// Create a device running `model`, at the manufacturer default
    /// preset. `seed` drives only measurement noise; the underlying
    /// response surface is deterministic per (device, model, config).
    pub fn new(kind: DeviceKind, model: ModelKind, seed: u64) -> Device {
        Device {
            kind,
            model,
            space: kind.space(),
            manifest: VariantManifest::full(model),
            current: kind.preset_default(),
            rng: Rng::new(seed ^ (kind.id() << 32) ^ model.id()),
            thermal: None,
            seed,
            noise_scale: 1.0,
            sim_clock_s: 0.0,
            windows_run: 0,
        }
    }

    /// Enable the thermal-throttle extension (ablation benches).
    pub fn with_thermal(mut self, t: ThermalModel) -> Device {
        self.thermal = Some(t);
        self
    }

    /// Open the batch axis to `caps`, making `max_batch` a live sixth
    /// search dimension on this board (the default axis is the legacy
    /// singleton `[1]`; see [`ConfigSpace::with_batch_caps`]).
    pub fn with_batch_caps(mut self, caps: Vec<u32>) -> Device {
        self.space = self.space.with_batch_caps(caps);
        self
    }

    /// Serve `manifest`'s variant family on this board, opening the
    /// variant axis to its indices — the served variant becomes a live
    /// seventh search dimension (the default manifest is the singleton
    /// [`VariantManifest::full`], the legacy fixed-model surface).
    pub fn with_variants(mut self, manifest: VariantManifest) -> Device {
        assert_eq!(
            manifest.model(),
            self.model,
            "manifest is for a different model than this device serves"
        );
        self.space = self.space.with_variant_axis(manifest.len());
        self.manifest = manifest;
        self
    }

    /// Scale measurement noise (robustness experiments): 1.0 = the
    /// calibrated tegrastats-class noise, 0.0 = noise-free oracle reads.
    pub fn with_noise_scale(mut self, scale: f64) -> Device {
        assert!(scale >= 0.0);
        self.noise_scale = scale;
        self
    }

    pub fn kind(&self) -> DeviceKind {
        self.kind
    }

    pub fn model(&self) -> ModelKind {
        self.model
    }

    pub fn space(&self) -> &ConfigSpace {
        &self.space
    }

    /// The variant family this board serves (cache identity: two
    /// devices with different manifests expose different surfaces).
    pub fn manifest(&self) -> &VariantManifest {
        &self.manifest
    }

    pub fn current_config(&self) -> HwConfig {
        self.current
    }

    /// The noise seed this device was created with (cache identity —
    /// two same-surface devices with different seeds draw different
    /// noise and must never share cache entries).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Current measurement-noise multiplier (cache identity).
    pub fn noise_scale(&self) -> f64 {
        self.noise_scale
    }

    /// Whether the thermal-throttle extension is active (a thermal
    /// device's surface is history-dependent — cache identity).
    pub fn has_thermal(&self) -> bool {
        self.thermal.is_some()
    }

    /// Enable (or replace) the thermal extension on a live device —
    /// the in-place counterpart of [`Device::with_thermal`], used by
    /// fault injection (`control::chaos`) to switch throttling on
    /// mid-run.
    pub fn enable_thermal(&mut self, t: ThermalModel) {
        self.thermal = Some(t);
    }

    /// Mutable view of the active thermal model, if any (fault
    /// injection: heat soaks, ambient shifts).
    pub fn thermal_mut(&mut self) -> Option<&mut ThermalModel> {
        self.thermal.as_mut()
    }

    /// Simulated seconds spent measuring so far.
    pub fn sim_clock_s(&self) -> f64 {
        self.sim_clock_s
    }

    pub fn windows_run(&self) -> u64 {
        self.windows_run
    }

    /// Apply a configuration (nvpmodel + app concurrency). Off-grid
    /// values are snapped to the grid, as nvpmodel does.
    pub fn apply(&mut self, cfg: HwConfig) -> HwConfig {
        self.current = self.space.snap_config(cfg.as_vec());
        self.current
    }

    /// Noise-free ground truth (used by tests and the ORACLE's *ranking*
    /// verification — the ORACLE baseline itself measures like everyone
    /// else).
    pub fn true_point(&self, cfg: &HwConfig) -> (perf::PerfPoint, power::PowerBreakdown) {
        let v = self.manifest.get(cfg.variant);
        let mut pf = perf::evaluate_variant(self.kind, self.model, v, cfg);
        if let Some(t) = &self.thermal {
            let derate = t.clock_factor();
            pf.throughput_fps *= derate;
            pf.latency_ms /= derate;
        }
        let pw = power::evaluate_variant(self.kind, v, cfg, &pf);
        (pf, pw)
    }

    /// Apply `cfg` and run one measurement window: 2 s warm-up, then
    /// [`SAMPLES_PER_WINDOW`] 1 Hz samples averaged — the optimizer's
    /// single observation. Failed configurations return a window with
    /// `failed` set, zero throughput and idle-ish power (the inference
    /// crashed; the board still draws power).
    pub fn run(&mut self, cfg: HwConfig) -> Measured {
        let applied = self.apply(cfg);
        let window_s = WARMUP_S + SAMPLES_PER_WINDOW as f64;
        self.sim_clock_s += window_s;
        self.windows_run += 1;

        let variant = self.manifest.get(applied.variant);
        if let Some(kind) = failure::check_variant(self.kind, self.model, variant, &applied) {
            let p = self.kind.model_params();
            if let Some(t) = &mut self.thermal {
                t.step(p.static_mw, window_s);
            }
            return Measured {
                config: applied,
                throughput_fps: 0.0,
                power_mw: p.static_mw
                    * self.rng.noise_factor(p.noise_rel * self.noise_scale),
                latency_ms: f64::INFINITY,
                p99_latency_ms: f64::INFINITY,
                gpu_util: 0.0,
                cpu_util: 0.0,
                mem_util: 0.0,
                accuracy: 0.0,
                failed: Some(kind),
            };
        }

        let (pf, pw) = self.true_point(&applied);
        if let Some(t) = &mut self.thermal {
            t.step(pw.total_mw(), window_s);
        }

        // Per-chip variation: consistent across repeated visits to the
        // same configuration (manufacturing spread, binning). Keyed on
        // the hardware knobs alone (`hw_key`): silicon is a property of
        // the DVFS state, never of the app's batch cap — and the 5-word
        // key keeps every `max_batch = 1` read bit-identical to the
        // pre-batch model.
        let p = self.kind.model_params();
        let mut key = applied.hw_key().to_vec();
        key.extend_from_slice(&[self.model.id(), self.kind.id(), 0x1077]);
        let lot_t = 1.0 + p.lottery_rel * 2.0 * (hash_unit(&key) - 0.5);
        *key.last_mut().unwrap() = 0x1077 + 1;
        let lot_p = 1.0 + p.lottery_rel * 2.0 * (hash_unit(&key) - 0.5);

        // Measurement noise shrinks with window averaging.
        let rel = p.noise_rel * self.noise_scale / (SAMPLES_PER_WINDOW as f64).sqrt();
        let tput = pf.throughput_fps * lot_t * self.rng.noise_factor(rel);
        let pwr = pw.total_mw() * lot_p * self.rng.noise_factor(rel);

        // Frames in flight: c instances × max_batch frames each. The
        // u32 multiply by 1 is exact, so 5-dim reads are byte-identical.
        let in_flight = (applied.concurrency * applied.max_batch.max(1)) as f64;
        let latency_ms = in_flight / (tput / 1000.0);
        Measured {
            config: applied,
            throughput_fps: tput,
            power_mw: pwr,
            latency_ms,
            p99_latency_ms: latency_ms,
            gpu_util: pf.gpu_util,
            cpu_util: pf.cpu_util,
            mem_util: pf.mem_util,
            // The modeled mAP of the served variant — deterministic per
            // variant (accuracy does not jitter with tegrastats noise).
            accuracy: variant.accuracy,
            failed: None,
        }
    }

    /// Run one measurement window under an open-loop offered load of
    /// `offered_fps` arrivals per second (see [`under_offered_load`]).
    pub fn run_under_load(&mut self, cfg: HwConfig, offered_fps: f64) -> Measured {
        let m = self.run(cfg);
        under_offered_load(m, offered_fps, self.kind.model_params().static_mw)
    }
}

/// Transform a closed-loop window into what the same configuration
/// observes under an open-loop offered load of `offered_fps` (fluid
/// M/M/1-flavored approximation, fully deterministic):
///
/// * saturated (λ ≥ μ) — the backlog grows without bound: the config
///   **sheds**, served throughput pins at capacity and p99 → ∞;
/// * stable (λ < μ) — the device serves exactly what arrives; mean
///   latency gains the mean queue wait ρ/(μ−λ) and p99 gains the tail
///   wait ln(100·ρ)/(μ−λ) (from P(wait > t) ≈ ρ·e^{−(μ−λ)t});
/// * utilizations scale with ρ and power interpolates from `static_mw`
///   toward the full-rate draw — an idling device cools down.
pub fn under_offered_load(mut m: Measured, offered_fps: f64, static_mw: f64) -> Measured {
    assert!(
        offered_fps.is_finite() && offered_fps >= 0.0,
        "offered load must be finite and non-negative: {offered_fps}"
    );
    if m.failed.is_some() || m.throughput_fps <= 0.0 {
        m.p99_latency_ms = f64::INFINITY;
        return m;
    }
    let mu = m.throughput_fps;
    let rho = offered_fps / mu;
    if rho >= 1.0 {
        m.p99_latency_ms = f64::INFINITY;
        return m;
    }
    let mean_wait_s = rho / (mu - offered_fps);
    let p99_wait_s = (100.0 * rho).ln().max(0.0) / (mu - offered_fps);
    m.p99_latency_ms = m.latency_ms + p99_wait_s * 1000.0;
    m.latency_ms += mean_wait_s * 1000.0;
    m.throughput_fps = offered_fps;
    m.gpu_util *= rho;
    m.cpu_util *= rho;
    m.mem_util *= rho;
    m.power_mw = static_mw + (m.power_mw - static_mw).max(0.0) * rho;
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::dvfs::Dim;

    #[test]
    fn repeated_runs_are_consistent_not_identical() {
        let mut d = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1);
        let cfg = d.space().midpoint();
        let a = d.run(cfg);
        let b = d.run(cfg);
        assert!(a.throughput_fps != b.throughput_fps, "noise present");
        let rel = (a.throughput_fps - b.throughput_fps).abs() / a.throughput_fps;
        assert!(rel < 0.05, "noise bounded: {rel}");
    }

    #[test]
    fn same_seed_same_trace() {
        let mut d1 = Device::new(DeviceKind::OrinNano, ModelKind::Frcnn, 9);
        let mut d2 = Device::new(DeviceKind::OrinNano, ModelKind::Frcnn, 9);
        let cfg = d1.space().midpoint();
        assert_eq!(d1.run(cfg), d2.run(cfg));
    }

    #[test]
    fn failed_config_reports_failure() {
        // RetinaNet at max concurrency on NX exceeds the memory budget.
        let mut d = Device::new(DeviceKind::XavierNx, ModelKind::RetinaNet, 3);
        let mut cfg = d.space().midpoint();
        cfg.concurrency = 3;
        let m = d.run(cfg);
        assert!(m.failed.is_some());
        assert_eq!(m.throughput_fps, 0.0);
        assert!(m.power_mw > 1000.0, "board still draws power");
    }

    #[test]
    fn apply_snaps_to_grid() {
        let mut d = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 0);
        let applied = d.apply(HwConfig {
            cpu_freq_mhz: 1333,
            cpu_cores: 9,
            gpu_freq_mhz: 0,
            mem_freq_mhz: 1700,
            concurrency: 2,
            max_batch: 7,
            variant: 3,
        });
        assert!(d.space().contains(&applied));
        assert_eq!(applied.cpu_cores, 6);
        assert_eq!(applied.gpu_freq_mhz, 510);
        // The device space carries the legacy singleton batch and
        // variant axes.
        assert_eq!(applied.max_batch, 1);
        assert_eq!(applied.variant, 0);
    }

    #[test]
    fn closed_loop_p99_equals_mean_latency() {
        let mut d = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 4);
        let m = d.run(d.space().midpoint());
        assert_eq!(m.p99_latency_ms, m.latency_ms);
        assert!(m.p99_latency_ms.is_finite());
    }

    #[test]
    fn offered_load_adds_queueing_tail_then_sheds() {
        let mut d =
            Device::new(DeviceKind::OrinNano, ModelKind::Yolo, 5).with_noise_scale(0.0);
        let cfg = d.space().midpoint();
        let free = d.run(cfg);
        let mu = free.throughput_fps;

        // Light load: served rate == offered rate, modest tail.
        let light = d.run_under_load(cfg, 0.3 * mu);
        assert!((light.throughput_fps - 0.3 * mu).abs() < 1e-9);
        assert!(light.p99_latency_ms >= light.latency_ms);
        assert!(light.p99_latency_ms.is_finite());
        assert!(light.power_mw < free.power_mw, "idling device draws less");

        // Heavy-but-stable load: the tail blows up as ρ → 1.
        let heavy = d.run_under_load(cfg, 0.97 * mu);
        assert!(heavy.p99_latency_ms > light.p99_latency_ms * 3.0);

        // Saturation: p99 is unbounded — the config sheds.
        let shed = d.run_under_load(cfg, 1.05 * mu);
        assert!(shed.p99_latency_ms.is_infinite());
        assert!(shed.failed.is_none(), "shedding is overload, not a crash");
    }

    #[test]
    fn under_offered_load_is_deterministic_and_monotone_in_rate() {
        let mut d =
            Device::new(DeviceKind::XavierNx, ModelKind::Frcnn, 6).with_noise_scale(0.0);
        let cfg = d.space().midpoint();
        let base = d.run(cfg);
        let static_mw = DeviceKind::XavierNx.model_params().static_mw;
        let mut prev = 0.0;
        for frac in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let m = under_offered_load(base, frac * base.throughput_fps, static_mw);
            let again = under_offered_load(base, frac * base.throughput_fps, static_mw);
            assert_eq!(m, again, "pure function of (window, rate)");
            assert!(m.p99_latency_ms >= prev, "tail grows with offered load");
            prev = m.p99_latency_ms;
        }
    }

    #[test]
    fn sim_clock_advances_per_window() {
        let mut d = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 0);
        let cfg = d.space().midpoint();
        d.run(cfg);
        d.run(cfg);
        assert_eq!(d.windows_run(), 2);
        assert!((d.sim_clock_s() - 2.0 * (WARMUP_S + SAMPLES_PER_WINDOW as f64)).abs() < 1e-9);
    }

    #[test]
    fn noise_scale_zero_gives_lottery_only_reads() {
        let mut a = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1).with_noise_scale(0.0);
        let cfg = a.space().midpoint();
        let m1 = a.run(cfg);
        let m2 = a.run(cfg);
        assert_eq!(m1.throughput_fps, m2.throughput_fps, "no sampling noise");
    }

    #[test]
    fn singleton_manifest_device_is_byte_identical_to_default() {
        // `.with_variants(full)` is the PR-8 `with_batch_caps([1])`
        // story for the seventh dimension: same space, same draws, same
        // windows, bit for bit.
        let mut plain = Device::new(DeviceKind::XavierNx, ModelKind::Frcnn, 11);
        let mut varied = Device::new(DeviceKind::XavierNx, ModelKind::Frcnn, 11)
            .with_variants(VariantManifest::full(ModelKind::Frcnn));
        assert_eq!(plain.space(), varied.space());
        let mut rng = Rng::new(23);
        for _ in 0..20 {
            let cfg = plain.space().random(&mut rng);
            assert_eq!(plain.run(cfg), varied.run(cfg));
        }
    }

    #[test]
    fn variant_axis_trades_accuracy_for_throughput_and_power() {
        let manifest = ModelKind::Yolo.standard_variants();
        let mut d = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 7)
            .with_variants(manifest.clone())
            .with_noise_scale(0.0);
        assert_eq!(d.space().max(Dim::Variant), manifest.len() as u32 - 1);
        let base_cfg = d.space().midpoint().with(Dim::Variant, 0);
        let base = d.run(base_cfg);
        assert_eq!(base.accuracy, ModelKind::Yolo.map());
        for idx in 1..manifest.len() as u32 {
            let m = d.run(base_cfg.with(Dim::Variant, idx));
            assert!(m.failed.is_none());
            assert_eq!(m.accuracy, manifest.get(idx).accuracy);
            assert!(m.accuracy < base.accuracy, "variant {idx} is less accurate");
            assert!(m.throughput_fps > base.throughput_fps, "variant {idx} is faster");
            assert!(m.power_mw < base.power_mw, "variant {idx} draws less");
        }
    }

    #[test]
    #[should_panic(expected = "different model")]
    fn mismatched_manifest_model_panics() {
        let _ = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 0)
            .with_variants(VariantManifest::full(ModelKind::Frcnn));
    }

    #[test]
    fn thermal_extension_derates_under_sustained_load() {
        let mut d = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 0)
            .with_thermal(ThermalModel::default());
        let cfg = DeviceKind::XavierNx.preset_max_power().with(Dim::Concurrency, 2);
        let first = d.run(cfg).throughput_fps;
        for _ in 0..100 {
            d.run(cfg);
        }
        let later = d.run(cfg).throughput_fps;
        assert!(later < first * 0.95, "throttled: {first} -> {later}");
    }
}
