//! Analytic power model (DESIGN.md §2).
//!
//! Deterministic "true" power of a (device, model, configuration) triple,
//! given the utilizations produced by [`super::perf`]. Rail structure
//! mirrors tegrastats' INA3221 channels on the paper's boards:
//!
//! ```text
//! P = P_static                                   (SoC, board, rails)
//!   + c_cpu · idle(f_cpu)                        (clock-scaled core idle)
//!   + k_cpu · c_cpu · (f_cpu/1e3)^γcpu · u_cpu   (CPU dynamic)
//!   + k_gpu · (f_gpu/1e3)^γgpu · (i + (1−i)·u_gpu)  (GPU dynamic+idle)
//!   + k_mem · (f_mem/1e3) · (0.3 + 0.7·u_mem)    (EMC)
//! ```
//!
//! γ ≈ 2–2.2 reflects the DVFS V∝f operating region (P ∝ C·V²·f). Power
//! and throughput therefore interact through the *same* utilizations,
//! giving the paper's non-linear joint response surface.

use super::dvfs::HwConfig;
use super::perf::PerfPoint;
use super::specs::DeviceKind;
use crate::models::ModelVariant;

/// Per-rail breakdown (mW), matching the tegrastats channels the paper
/// samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerBreakdown {
    pub static_mw: f64,
    pub cpu_mw: f64,
    pub gpu_mw: f64,
    pub mem_mw: f64,
}

impl PowerBreakdown {
    pub fn total_mw(&self) -> f64 {
        self.static_mw + self.cpu_mw + self.gpu_mw + self.mem_mw
    }
}

/// Evaluate the deterministic power model.
pub fn evaluate(dev: DeviceKind, cfg: &HwConfig, perf: &PerfPoint) -> PowerBreakdown {
    let p = dev.model_params();
    let cores = cfg.cpu_cores.max(1) as f64;
    let f_cpu = cfg.cpu_freq_mhz as f64 / 1000.0;
    let f_gpu = cfg.gpu_freq_mhz as f64 / 1000.0;
    let f_mem = cfg.mem_freq_mhz as f64 / 1000.0;

    // Clock-gated but powered cores: idle draw grows with the pinned
    // clock (jetson_clocks-style governors keep V·f high).
    let cpu_idle = p.cpu_idle_mw_per_core * cores * f_cpu.powf(1.5);
    let cpu_dyn = p.cpu_dyn_mw * cores * f_cpu.powf(p.cpu_gamma) * perf.cpu_util;

    let mut gpu_mw = p.gpu_dyn_mw
        * f_gpu.powf(p.gpu_gamma)
        * (p.gpu_idle_frac + (1.0 - p.gpu_idle_frac) * perf.gpu_util);
    // Batched kernels keep more SMs resident per launch: a small draw
    // bump per extra frame in the batch. Throughput grows faster than
    // this (perf.rs), so energy-per-frame still improves — and the
    // `max_batch = 1` path is structurally untouched (byte-identity).
    if cfg.max_batch > 1 {
        gpu_mw *= 1.0 + 0.06 * (cfg.max_batch - 1) as f64;
    }

    let mem_mw = p.mem_dyn_mw * f_mem * (0.3 + 0.7 * perf.mem_util);

    PowerBreakdown {
        static_mw: p.static_mw,
        cpu_mw: cpu_idle + cpu_dyn,
        gpu_mw,
        mem_mw,
    }
}

/// Power for a served model variant: the same rail structure, with the
/// variant's precision/depth discount applied to the GPU dynamic rail
/// (int8 tensor-core paths switch less silicon per cycle; shallower
/// networks launch fewer kernels). `perf` must come from
/// [`super::perf::evaluate_variant`] for the same variant — the variant
/// keeps the *utilizations* unchanged (every stage rescales together),
/// so the discount enters only through this explicit multiplier. The
/// identity variant is structurally skipped, keeping every `variant = 0`
/// draw bit-identical to the fixed-model surface.
pub fn evaluate_variant(
    dev: DeviceKind,
    v: &ModelVariant,
    cfg: &HwConfig,
    perf: &PerfPoint,
) -> PowerBreakdown {
    let mut pw = evaluate(dev, cfg, perf);
    if !v.is_identity() {
        pw.gpu_mw *= v.power_mult;
    }
    pw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::perf;
    use crate::models::ModelKind;
    use crate::util::prop;

    fn full(dev: DeviceKind, cfg: &HwConfig) -> (PerfPoint, PowerBreakdown) {
        let pf = perf::evaluate(dev, ModelKind::Yolo, cfg);
        let pw = evaluate(dev, cfg, &pf);
        (pf, pw)
    }

    #[test]
    fn max_preset_draws_more_than_default() {
        for dev in DeviceKind::ALL {
            let (_, hi) = full(dev, &dev.preset_max_power());
            let (_, lo) = full(dev, &dev.preset_default());
            assert!(hi.total_mw() > lo.total_mw(), "{dev}");
        }
    }

    #[test]
    fn nx_power_range_is_jetson_class() {
        // NX module: ~3.5 W floor to ~9 W under full load (DESIGN.md §6).
        let space = DeviceKind::XavierNx.space();
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for cfg in space.enumerate() {
            let (_, pw) = full(DeviceKind::XavierNx, &cfg);
            lo = lo.min(pw.total_mw());
            hi = hi.max(pw.total_mw());
        }
        assert!(lo > 2500.0 && lo < 5000.0, "floor {lo}");
        assert!(hi > 7000.0 && hi < 11_000.0, "peak {hi}");
    }

    #[test]
    fn rails_positive_and_additive() {
        prop::check("power rails sane", 120, |g| {
            let dev = *g.rng.choose(&DeviceKind::ALL);
            let model = *g.rng.choose(&ModelKind::ALL);
            let mut rng = g.rng.fork(2);
            let cfg = dev.space().random(&mut rng);
            let pf = perf::evaluate(dev, model, &cfg);
            let pw = evaluate(dev, &cfg, &pf);
            prop::assert_true(pw.static_mw > 0.0, "static")?;
            prop::assert_true(pw.cpu_mw > 0.0, "cpu")?;
            prop::assert_true(pw.gpu_mw > 0.0, "gpu")?;
            prop::assert_true(pw.mem_mw > 0.0, "mem")?;
            prop::assert_close(
                pw.total_mw(),
                pw.static_mw + pw.cpu_mw + pw.gpu_mw + pw.mem_mw,
                1e-9,
            )
        });
    }

    #[test]
    fn gpu_rail_scales_with_clock_and_util() {
        let dev = DeviceKind::XavierNx;
        let base = dev.preset_default();
        let mut hi_clk = base;
        hi_clk.gpu_freq_mhz = 1100;
        let (pf_a, pw_a) = full(dev, &base);
        let pf_b = perf::evaluate(dev, ModelKind::Yolo, &hi_clk);
        let pw_b = evaluate(dev, &hi_clk, &pf_b);
        assert!(pw_b.gpu_mw > pw_a.gpu_mw);
        assert!(pf_b.throughput_fps > pf_a.throughput_fps);
    }

    #[test]
    fn batching_costs_power_but_improves_energy_per_frame() {
        let dev = DeviceKind::XavierNx;
        let mut a = dev.preset_max_power();
        a.concurrency = 2;
        let mut b = a;
        b.max_batch = 4;
        let (pf_a, pw_a) = full(dev, &a);
        let pf_b = perf::evaluate(dev, ModelKind::Yolo, &b);
        let pw_b = evaluate(dev, &b, &pf_b);
        assert!(pw_b.total_mw() > pw_a.total_mw(), "batch draws more");
        let epf = |pw: &PowerBreakdown, pf: &PerfPoint| pw.total_mw() / pf.throughput_fps;
        assert!(
            epf(&pw_b, &pf_b) < epf(&pw_a, &pf_a),
            "mJ/frame: b4={} b1={}",
            epf(&pw_b, &pf_b),
            epf(&pw_a, &pf_a)
        );
    }

    #[test]
    fn degraded_variants_discount_the_gpu_rail_only() {
        let dev = DeviceKind::XavierNx;
        let model = ModelKind::Yolo;
        let manifest = model.standard_variants();
        let cfg = dev.preset_max_power();
        let base_pf = perf::evaluate(dev, model, &cfg);
        let base_pw = evaluate(dev, &cfg, &base_pf);
        // Identity variant: bit-identical to the fixed-model rails.
        let id = crate::models::ModelVariant::identity(model);
        assert_eq!(evaluate_variant(dev, &id, &cfg, &base_pf), base_pw);
        for v in manifest.variants().iter().skip(1) {
            let pf = perf::evaluate_variant(dev, model, v, &cfg);
            let pw = evaluate_variant(dev, v, &cfg, &pf);
            // Utilizations are invariant under the variant rescaling up
            // to rounding, so the discount shows up only on the GPU rail.
            assert!((pw.gpu_mw - base_pw.gpu_mw * v.power_mult).abs() < 1e-6, "{}", v.label());
            assert!((pw.cpu_mw - base_pw.cpu_mw).abs() < 1e-6, "{}", v.label());
            assert!((pw.mem_mw - base_pw.mem_mw).abs() < 1e-6, "{}", v.label());
            assert!(pw.total_mw() < base_pw.total_mw(), "{}", v.label());
        }
    }

    #[test]
    fn more_cores_cost_idle_power() {
        let dev = DeviceKind::OrinNano;
        let mut a = dev.preset_default();
        a.cpu_cores = 2;
        let mut b = a;
        b.cpu_cores = 6;
        let (_, pa) = full(dev, &a);
        let (_, pb) = full(dev, &b);
        assert!(pb.cpu_mw > pa.cpu_mw + 200.0);
    }
}
