//! Analytic latency/throughput model (DESIGN.md §2).
//!
//! Deterministic "true" performance of a (device, model, configuration)
//! triple; measurement noise and per-chip variation are layered on top by
//! [`super::sim::Device`]. The structure is a three-stage pipeline
//! (CPU pre/post-processing, GPU kernels, memory traffic) with
//! concurrency-driven overlap, GPU contention and memory-bus
//! interference — producing the paper's phenomena:
//!
//! * concurrency = 1 serializes CPU and GPU stages → the GPU idles and
//!   throughput is well below GPU capacity (why presets underperform);
//! * moderate concurrency pipelines the stages → throughput approaches
//!   GPU capacity, at sub-linear contention cost;
//! * high concurrency adds memory-bus interference → non-monotone gains;
//! * memory frequency rescales effective GPU speed (bandwidth-bound
//!   phases), more for heavier models;
//! * parameters interact non-linearly (the reason the paper uses
//!   distance correlation rather than per-parameter linear models).

use super::dvfs::HwConfig;
use super::specs::DeviceKind;
use crate::models::{CostProfile, ModelKind, ModelVariant};

/// Deterministic performance of one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfPoint {
    /// Steady-state throughput, frames per second.
    pub throughput_fps: f64,
    /// Mean end-to-end latency per frame at this concurrency (ms).
    pub latency_ms: f64,
    /// GPU busy fraction [0, 1].
    pub gpu_util: f64,
    /// CPU busy fraction of the active cores [0, 1].
    pub cpu_util: f64,
    /// Memory-subsystem busy fraction [0, 1].
    pub mem_util: f64,
}

/// Stage times of one frame (ms) — exposed for tests and §Perf analysis.
#[derive(Debug, Clone, Copy)]
pub struct StageTimes {
    /// GPU kernel time including concurrency contention.
    pub gpu_ms: f64,
    /// CPU pre/post-processing time on one thread.
    pub cpu_ms: f64,
    /// Memory traffic time.
    pub mem_ms: f64,
}

/// Per-frame stage times under configuration `cfg`.
pub fn stage_times(dev: DeviceKind, model: ModelKind, cfg: &HwConfig) -> StageTimes {
    stage_times_profile(dev, &model.profile(), cfg)
}

/// Stage times for an explicit cost profile — the entry point a model
/// variant shares with the fixed-model surface (a variant is just a
/// rescaled profile, [`ModelVariant::scaled_profile`]).
pub fn stage_times_profile(dev: DeviceKind, prof: &CostProfile, cfg: &HwConfig) -> StageTimes {
    let p = dev.model_params();
    let c = cfg.concurrency.max(1) as f64;

    // Memory-bandwidth efficiency saturates with the EMC clock; GPU
    // kernels are partially bandwidth-bound, so it rescales GPU speed.
    let mem_eff = cfg.mem_freq_mhz as f64 / (cfg.mem_freq_mhz as f64 + p.mem_half_mhz);

    let gpu_exclusive =
        prof.gpu_work / (cfg.gpu_freq_mhz as f64 * p.gpu_arch_eff * mem_eff);
    // Shared SMs: each extra resident instance inflates kernel time.
    let gpu_ms = gpu_exclusive * (1.0 + p.gpu_contention * (c - 1.0));

    let cpu_ms = prof.cpu_work / (cfg.cpu_freq_mhz as f64 * p.cpu_arch_eff);
    let mem_ms = prof.mem_work / cfg.mem_freq_mhz as f64;
    StageTimes { gpu_ms, cpu_ms, mem_ms }
}

/// Evaluate the deterministic model at its full-accuracy profile.
pub fn evaluate(dev: DeviceKind, model: ModelKind, cfg: &HwConfig) -> PerfPoint {
    evaluate_profile(dev, &model.profile(), cfg)
}

/// Evaluate a served model variant: the same pipeline model over the
/// variant's rescaled cost profile, so a cheaper (int8 / shallower /
/// lower-resolution) variant is genuinely faster on the *same* hardware
/// state. The identity variant returns the untouched profile
/// ([`ModelVariant::scaled_profile`]), keeping every `variant = 0`
/// measurement bit-identical to the fixed-model surface.
pub fn evaluate_variant(
    dev: DeviceKind,
    model: ModelKind,
    v: &ModelVariant,
    cfg: &HwConfig,
) -> PerfPoint {
    evaluate_profile(dev, &v.scaled_profile(model), cfg)
}

/// Evaluate the deterministic model for an explicit cost profile.
pub fn evaluate_profile(dev: DeviceKind, prof: &CostProfile, cfg: &HwConfig) -> PerfPoint {
    let p = dev.model_params();
    let c = cfg.concurrency.max(1) as f64;
    let cores = cfg.cpu_cores.max(1) as f64;
    let t = stage_times_profile(dev, prof, cfg);

    // Per-instance serial latency: an instance must pre-process, launch,
    // and post-process each frame; a quarter of the memory traffic is not
    // hidden behind compute.
    let serial_ms = t.cpu_ms + t.gpu_ms + 0.25 * t.mem_ms;

    // Resource capacities (frames/ms).
    let cap_gpu = 1.0 / t.gpu_ms;
    let cpu_threads = (c * p.cpu_threads_per_instance).min(cores * p.cpu_usable_frac);
    let cap_cpu = cpu_threads / t.cpu_ms;
    let cap_mem = 1.0 / t.mem_ms;

    // c instances in flight, gated by the binding resource, degraded by
    // memory-bus interference between instances.
    let interference = (1.0 - p.mem_interference * (c - 1.0)).max(0.2);
    let mut tput_ms = (c / serial_ms).min(cap_gpu).min(cap_cpu).min(cap_mem) * interference;

    // Batching amortizes kernel launches and CPU pre/post dispatch over
    // `max_batch` frames: sublinear throughput gain (b=4 → ~1.41×), paid
    // for in per-frame residency — a frame now waits for its whole batch
    // to clear the pipeline. The `max_batch = 1` path is structurally
    // unchanged so legacy 5-dim results stay byte-identical.
    let b = cfg.max_batch.max(1) as f64;
    let batch_gain = if cfg.max_batch > 1 {
        (1.0 + 0.28 * (b - 1.0)) / (1.0 + 0.10 * (b - 1.0))
    } else {
        1.0
    };
    if cfg.max_batch > 1 {
        tput_ms *= batch_gain;
    }

    let throughput_fps = tput_ms * 1000.0;
    // Little's law over frames in flight: c instances × b frames each.
    let latency_ms = if cfg.max_batch > 1 { c * b / tput_ms } else { c / tput_ms };

    PerfPoint {
        throughput_fps,
        latency_ms,
        // Batched kernels spend less GPU/CPU time per frame (amortized
        // launches); memory traffic per frame is unchanged.
        gpu_util: (tput_ms * t.gpu_ms / batch_gain).clamp(0.0, 1.0),
        cpu_util: (tput_ms * t.cpu_ms / batch_gain / (cores * p.cpu_usable_frac))
            .clamp(0.0, 1.0),
        mem_util: (tput_ms * t.mem_ms).clamp(0.0, 1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::dvfs::Dim;
    use crate::util::prop;

    fn cfg(cpu: u32, cores: u32, gpu: u32, mem: u32, c: u32) -> HwConfig {
        HwConfig {
            cpu_freq_mhz: cpu,
            cpu_cores: cores,
            gpu_freq_mhz: gpu,
            mem_freq_mhz: mem,
            concurrency: c,
            max_batch: 1,
            variant: 0,
        }
    }

    #[test]
    fn gpu_freq_monotone_at_fixed_everything_else() {
        let lo = evaluate(DeviceKind::XavierNx, ModelKind::Yolo, &cfg(1908, 6, 510, 1866, 2));
        let hi = evaluate(DeviceKind::XavierNx, ModelKind::Yolo, &cfg(1908, 6, 1100, 1866, 2));
        assert!(hi.throughput_fps > lo.throughput_fps);
    }

    #[test]
    fn concurrency_pipelines_then_saturates() {
        // c=2 must beat c=1 (pipeline overlap); the marginal gain must
        // shrink (contention + interference) — the paper's non-linearity.
        let f = |c| {
            evaluate(DeviceKind::OrinNano, ModelKind::Yolo, &cfg(1510, 6, 624, 3199, c))
                .throughput_fps
        };
        let t1 = f(1);
        let t2 = f(2);
        let t5 = f(5);
        assert!(t2 > t1 * 1.2, "pipelining gain: {t1} -> {t2}");
        assert!(t5 < t2 * 1.5, "saturation: {t2} -> {t5}");
    }

    #[test]
    fn heavier_models_are_slower() {
        let c = cfg(1908, 6, 1100, 1866, 2);
        let y = evaluate(DeviceKind::XavierNx, ModelKind::Yolo, &c).throughput_fps;
        let f = evaluate(DeviceKind::XavierNx, ModelKind::Frcnn, &c).throughput_fps;
        let r = evaluate(DeviceKind::XavierNx, ModelKind::RetinaNet, &c).throughput_fps;
        assert!(y > 2.0 * f && f > 1.5 * r, "y={y} f={f} r={r}");
    }

    #[test]
    fn orin_outpaces_nx_on_yolo() {
        // Fig 1: Orin reaches ~75 fps where NX tops out ~40.
        let nx = DeviceKind::XavierNx.preset_max_power().with(Dim::Concurrency, 2);
        let orin = DeviceKind::OrinNano.preset_max_power().with(Dim::Concurrency, 2);
        let t_nx = evaluate(DeviceKind::XavierNx, ModelKind::Yolo, &nx).throughput_fps;
        let t_orin = evaluate(DeviceKind::OrinNano, ModelKind::Yolo, &orin).throughput_fps;
        assert!(t_orin > 1.5 * t_nx, "orin={t_orin} nx={t_nx}");
    }

    #[test]
    fn interaction_gpu_gain_depends_on_concurrency() {
        // The benefit of a GPU frequency step is larger when the pipeline
        // is GPU-bound (c>=2) than when it is serialized (c=1): a
        // non-additive interaction — exactly what dCor must detect and a
        // linear per-parameter model misses.
        let gain = |c| {
            let lo =
                evaluate(DeviceKind::XavierNx, ModelKind::Yolo, &cfg(1190, 2, 630, 1866, c));
            let hi =
                evaluate(DeviceKind::XavierNx, ModelKind::Yolo, &cfg(1190, 2, 1100, 1866, c));
            hi.throughput_fps / lo.throughput_fps
        };
        assert!(gain(3) > gain(1) * 1.05, "g3={} g1={}", gain(3), gain(1));
    }

    #[test]
    fn utils_in_unit_interval_and_latency_consistent() {
        prop::check("perf sanity over random configs", 150, |g| {
            let dev = *g.rng.choose(&DeviceKind::ALL);
            let model = *g.rng.choose(&ModelKind::ALL);
            let space = dev.space();
            let mut rng = g.rng.fork(1);
            let c = space.random(&mut rng);
            let p = evaluate(dev, model, &c);
            prop::assert_true(p.throughput_fps > 0.0, "tput > 0")?;
            prop::assert_true((0.0..=1.0).contains(&p.gpu_util), "gpu util")?;
            prop::assert_true((0.0..=1.0).contains(&p.cpu_util), "cpu util")?;
            prop::assert_true((0.0..=1.0).contains(&p.mem_util), "mem util")?;
            // Little's law: latency == concurrency / throughput.
            prop::assert_close(
                p.latency_ms,
                c.concurrency as f64 / (p.throughput_fps / 1000.0),
                1e-6,
            )
        });
    }

    #[test]
    fn batching_gains_throughput_sublinearly_and_costs_latency() {
        let at = |b: u32| {
            let mut c = cfg(1908, 6, 1100, 1866, 2);
            c.max_batch = b;
            evaluate(DeviceKind::XavierNx, ModelKind::Yolo, &c)
        };
        let b1 = at(1);
        let b2 = at(2);
        let b8 = at(8);
        // Throughput improves with batch, but never linearly.
        assert!(b2.throughput_fps > b1.throughput_fps * 1.05);
        assert!(b8.throughput_fps > b2.throughput_fps);
        assert!(b8.throughput_fps < b1.throughput_fps * 3.0);
        // Per-frame latency grows: a frame rides with its whole batch.
        assert!(b2.latency_ms > b1.latency_ms);
        assert!(b8.latency_ms > b2.latency_ms);
        // Generalized Little's law: latency == frames-in-flight / rate.
        let expect = 2.0 * 8.0 / (b8.throughput_fps / 1000.0);
        assert!((b8.latency_ms - expect).abs() < 1e-9, "{} {expect}", b8.latency_ms);
    }

    #[test]
    fn batch_of_one_is_bit_identical_to_the_legacy_model() {
        // `max_batch = 1` must reproduce the 5-dim surface exactly; the
        // batch terms are structurally skipped, not merely ≈1.
        for dev in DeviceKind::ALL {
            for model in ModelKind::ALL {
                for c in dev.space().enumerate().into_iter().step_by(97) {
                    let p = evaluate(dev, model, &c);
                    assert!((p.latency_ms
                        - c.concurrency as f64 / (p.throughput_fps / 1000.0))
                        .abs()
                        < 1e-12);
                }
            }
        }
    }

    #[test]
    fn identity_variant_is_bit_identical_to_the_fixed_model() {
        for dev in DeviceKind::ALL {
            for model in ModelKind::ALL {
                let id = ModelVariant::identity(model);
                for c in dev.space().enumerate().into_iter().step_by(131) {
                    let fixed = evaluate(dev, model, &c);
                    let via_variant = evaluate_variant(dev, model, &id, &c);
                    assert_eq!(fixed, via_variant, "{dev}/{model}/{c}");
                }
            }
        }
    }

    #[test]
    fn degraded_variants_scale_throughput_by_their_perf_multiplier() {
        // Every stage time divides by the same perf multiplier, so the
        // binding resource, the serial path and the caps all scale
        // together: throughput is exactly ×perf_mult and utilizations
        // are unchanged.
        let manifest = ModelKind::RetinaNet.standard_variants();
        let c = cfg(1908, 6, 1100, 1866, 2);
        let base = evaluate(DeviceKind::XavierNx, ModelKind::RetinaNet, &c);
        for v in manifest.variants().iter().skip(1) {
            let p = evaluate_variant(DeviceKind::XavierNx, ModelKind::RetinaNet, v, &c);
            let ratio = p.throughput_fps / base.throughput_fps;
            assert!(
                (ratio - v.perf_mult).abs() < 1e-9,
                "{}: ratio {ratio} vs perf_mult {}",
                v.label(),
                v.perf_mult
            );
            assert!((p.gpu_util - base.gpu_util).abs() < 1e-9);
            assert!((p.cpu_util - base.cpu_util).abs() < 1e-9);
            assert!((p.mem_util - base.mem_util).abs() < 1e-9);
        }
    }

    #[test]
    fn mem_freq_matters_more_for_heavy_models() {
        let rel_gain = |m: ModelKind| {
            let lo = evaluate(DeviceKind::XavierNx, m, &cfg(1908, 6, 1100, 1500, 2));
            let hi = evaluate(DeviceKind::XavierNx, m, &cfg(1908, 6, 1100, 1866, 2));
            hi.throughput_fps / lo.throughput_fps
        };
        assert!(rel_gain(ModelKind::RetinaNet) >= rel_gain(ModelKind::Yolo) * 0.999);
    }
}
