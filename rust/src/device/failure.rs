//! Configuration-failure model (paper §IV-A, Table 4).
//!
//! The paper exhaustively tested every grid point and excluded configs
//! "that failed due to memory constraints or runtime errors", with
//! heavier models failing more. This module reproduces that filter:
//!
//! * **Memory rule** — estimated peak footprint (weights + per-instance
//!   activations + OS) against the 8 GB budget, with a deterministic
//!   per-config jitter standing in for allocator/fragmentation variance.
//!   LPDDR5 on Orin packs tighter (compression, larger burst) via a
//!   per-device factor.
//! * **Runtime-error rule** — a small per-model deterministic hash
//!   failure rate covering driver/timeout flakes.
//!
//! Both rules are pure functions of (device, model, config) so every run
//! sees the same valid set — as the paper's fixed exclusion list does.

use super::dvfs::HwConfig;
use super::specs::DeviceKind;
use crate::models::{CostProfile, ModelKind, ModelVariant};
use crate::util::rng::hash_unit;

/// Memory-packing factor: Orin's LPDDR5 + newer JetPack allocator fit the
/// same workload in less resident memory.
fn lpddr_factor(dev: DeviceKind) -> f64 {
    match dev {
        DeviceKind::XavierNx => 1.0,
        DeviceKind::OrinNano => 0.62,
    }
}

/// Baseline runtime-error rate per model (heavier engines hit more
/// driver/timeout flakes during the paper's exhaustive sweep).
fn runtime_error_rate(model: ModelKind) -> f64 {
    match model {
        ModelKind::Yolo => 0.045,
        ModelKind::Frcnn => 0.035,
        ModelKind::RetinaNet => 0.02,
    }
}

/// OS + runtime baseline footprint (GB).
const OS_GB: f64 = 2.0;

/// Share of an instance's footprint that is per-frame activations —
/// the part that grows with every extra frame a batch holds in flight
/// (weights are shared across the batch).
const ACTIVATION_BATCH_FRAC: f64 = 0.35;

/// Estimated peak memory footprint (GB) of `model` at `cfg`. Batch
/// caps above 1 stack extra activation buffers per instance; the
/// `max_batch = 1` footprint is byte-identical to the historical
/// 5-dim model (the batch term is structurally skipped).
pub fn peak_memory_gb(dev: DeviceKind, model: ModelKind, cfg: &HwConfig) -> f64 {
    peak_memory_gb_profile(dev, &model.profile(), cfg)
}

/// Peak footprint of a served model variant: an int8 / shallower
/// variant's weights and activations shrink by its memory multiplier
/// ([`ModelVariant::scaled_profile`]), so configurations that OOM at
/// the full-accuracy baseline can be valid at a degraded variant. The
/// identity variant returns the untouched profile (byte-identity).
pub fn peak_memory_gb_variant(
    dev: DeviceKind,
    model: ModelKind,
    v: &ModelVariant,
    cfg: &HwConfig,
) -> f64 {
    peak_memory_gb_profile(dev, &v.scaled_profile(model), cfg)
}

fn peak_memory_gb_profile(dev: DeviceKind, prof: &CostProfile, cfg: &HwConfig) -> f64 {
    let per_instance = prof.mem_gb_per_instance * lpddr_factor(dev);
    let mut peak = OS_GB + prof.mem_gb_base + per_instance * cfg.concurrency as f64;
    if cfg.max_batch > 1 {
        peak += per_instance
            * cfg.concurrency as f64
            * ACTIVATION_BATCH_FRAC
            * (cfg.max_batch - 1) as f64;
    }
    peak
}

/// Why a configuration is excluded — or, for [`FailureKind::Dropout`],
/// why a window carries no observation at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// Peak footprint exceeded the memory budget (OOM).
    OutOfMemory,
    /// Non-deterministic-looking runtime error (driver, timeout).
    RuntimeError,
    /// The board vanished mid-window (fleet member dropout, a panicked
    /// member job). A property of the *moment*, not of the
    /// configuration: never returned by [`check`], injected only by the
    /// fleet layer (`control::env::FleetEnv`) and the chaos decorator
    /// (`control::chaos::ChaosEnv`), and aggregated as a missing member
    /// rather than a prohibited config.
    Dropout,
}

/// Check a configuration at the full-accuracy baseline; `None` = valid.
pub fn check(dev: DeviceKind, model: ModelKind, cfg: &HwConfig) -> Option<FailureKind> {
    check_variant(dev, model, &ModelVariant::identity(model), cfg)
}

/// Check a configuration serving a model variant; `None` = valid. Both
/// hash streams are keyed exactly as [`check`]'s — allocator variance
/// and driver flakes belong to the DVFS state and the engine family,
/// not to which variant of it is resident — so only the deterministic
/// footprint changes with the variant, and the identity variant's
/// verdicts are bit-identical to `check`'s.
pub fn check_variant(
    dev: DeviceKind,
    model: ModelKind,
    v: &ModelVariant,
    cfg: &HwConfig,
) -> Option<FailureKind> {
    let p = dev.model_params();

    // Deterministic per-config jitter: allocator/fragmentation variance
    // observed when the paper's sweep ran each config on real hardware.
    // Keyed on the hardware knobs alone (`hw_key`): allocator variance
    // belongs to the DVFS state, and the 5-word key keeps every
    // `max_batch = 1` verdict bit-identical to the pre-batch model.
    let mut key = cfg.hw_key().to_vec();
    key.push(model.id());
    key.push(dev.id());
    key.push(0xA110C); // salt: memory stream
    let mem_jitter = hash_unit(&key) - 0.5; // [-0.5, 0.5)

    // 2 GB for the OS/runtime is included in peak_memory_gb; the budget
    // below is total physical memory.
    let peak = peak_memory_gb_variant(dev, model, v, cfg) + 0.8 * mem_jitter;
    if peak > OS_GB + p.mem_gb_budget {
        return Some(FailureKind::OutOfMemory);
    }

    *key.last_mut().unwrap() = 0xE4404; // salt: runtime-error stream
    if hash_unit(&key) < runtime_error_rate(model) {
        return Some(FailureKind::RuntimeError);
    }
    None
}

/// All valid configurations of `model` on `dev` (the paper's evaluated
/// space, Table 4).
pub fn valid_configs(dev: DeviceKind, model: ModelKind) -> Vec<HwConfig> {
    dev.space()
        .enumerate()
        .into_iter()
        .filter(|c| check(dev, model, c).is_none())
        .collect()
}

/// Valid-config count (Table 4 cell).
pub fn valid_count(dev: DeviceKind, model: ModelKind) -> usize {
    valid_configs(dev, model).len()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 4.
    const PAPER: [(DeviceKind, ModelKind, usize); 6] = [
        (DeviceKind::XavierNx, ModelKind::Yolo, 2067),
        (DeviceKind::XavierNx, ModelKind::Frcnn, 1813),
        (DeviceKind::XavierNx, ModelKind::RetinaNet, 1491),
        (DeviceKind::OrinNano, ModelKind::Yolo, 1522),
        (DeviceKind::OrinNano, ModelKind::Frcnn, 1371),
        (DeviceKind::OrinNano, ModelKind::RetinaNet, 1223),
    ];

    #[test]
    fn table4_counts_within_tolerance() {
        for (dev, model, paper) in PAPER {
            let got = valid_count(dev, model);
            let rel = (got as f64 - paper as f64).abs() / paper as f64;
            assert!(
                rel < 0.10,
                "{dev}/{model}: got {got}, paper {paper} ({:+.1}%)",
                (got as f64 / paper as f64 - 1.0) * 100.0
            );
        }
    }

    #[test]
    fn heavier_models_have_fewer_valid_configs() {
        for dev in DeviceKind::ALL {
            let y = valid_count(dev, ModelKind::Yolo);
            let f = valid_count(dev, ModelKind::Frcnn);
            let r = valid_count(dev, ModelKind::RetinaNet);
            assert!(y > f && f > r, "{dev}: {y} {f} {r}");
        }
    }

    #[test]
    fn failures_deterministic() {
        // Verdict stability must hold across *independently constructed*
        // spaces and devices — the fixed-exclusion-list property the
        // paper's sweep relies on — not merely for one `check` call
        // compared against itself.
        for dev in DeviceKind::ALL {
            for model in ModelKind::ALL {
                let first: Vec<Option<FailureKind>> = dev
                    .space()
                    .enumerate()
                    .iter()
                    .map(|c| check(dev, model, c))
                    .collect();
                // Second pass: fresh space, fresh enumeration, fresh
                // config values.
                let second: Vec<Option<FailureKind>> = dev
                    .space()
                    .enumerate()
                    .iter()
                    .map(|c| check(dev, model, c))
                    .collect();
                assert_eq!(first, second, "{dev}/{model}: verdicts drifted");
            }
        }
    }

    #[test]
    fn memory_and_runtime_salt_streams_diverge() {
        // The two rules draw from *differently salted* hash streams; if a
        // salt regression collapsed them onto one stream, the memory
        // jitter and the runtime-error draw would correlate perfectly.
        // At least one config must see the streams disagree.
        let dev = DeviceKind::XavierNx;
        let model = ModelKind::Yolo;
        let diverged = dev.space().enumerate().iter().any(|cfg| {
            let mut key = cfg.hw_key().to_vec();
            key.push(model.id());
            key.push(dev.id());
            key.push(0xA110C);
            let mem = hash_unit(&key);
            *key.last_mut().unwrap() = 0xE4404;
            let rt = hash_unit(&key);
            (mem - rt).abs() > 1e-12
        });
        assert!(diverged, "memory and runtime-error streams are identical");
    }

    #[test]
    fn dropout_never_returned_by_check() {
        // `Dropout` is a property of the moment (fleet member vanished),
        // injected by the fleet/chaos layers — the config filter must
        // never produce it.
        for dev in DeviceKind::ALL {
            for model in ModelKind::ALL {
                for cfg in dev.space().enumerate() {
                    assert_ne!(check(dev, model, &cfg), Some(FailureKind::Dropout));
                }
            }
        }
    }

    #[test]
    fn oom_only_at_high_concurrency() {
        // Memory failures require stacking instances; c=1 never OOMs.
        for dev in DeviceKind::ALL {
            for model in ModelKind::ALL {
                for cfg in dev.space().enumerate() {
                    if cfg.concurrency == 1 {
                        assert_ne!(
                            check(dev, model, &cfg),
                            Some(FailureKind::OutOfMemory),
                            "{dev}/{model}/{cfg}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn degraded_variants_reclaim_oom_configs_but_keep_runtime_flakes() {
        // RetinaNet on NX has the tightest memory envelope (Table 4);
        // its int8-416 variant halves the resident footprint, so some
        // baseline-OOM configs become valid — while the runtime-error
        // stream, keyed identically, never changes verdicts.
        let dev = DeviceKind::XavierNx;
        let model = ModelKind::RetinaNet;
        let manifest = model.standard_variants();
        let id = ModelVariant::identity(model);
        let worst = manifest.get(manifest.len() as u32 - 1);
        let mut reclaimed = 0usize;
        for cfg in dev.space().enumerate() {
            let base = check_variant(dev, model, &id, &cfg);
            assert_eq!(base, check(dev, model, &cfg), "identity matches check: {cfg}");
            let degraded = check_variant(dev, model, worst, &cfg);
            match (base, degraded) {
                // Baseline OOM: the smaller footprint may fit (reclaim),
                // still OOM, or unmask the runtime-error draw.
                (Some(FailureKind::OutOfMemory), d) => {
                    if d.is_none() {
                        reclaimed += 1;
                    }
                }
                // Baseline fits: the degraded footprint is no larger and
                // the runtime-error stream is variant-blind, so the
                // verdict must be unchanged.
                (a, b) => assert_eq!(a, b, "verdict drifted with the variant: {cfg}"),
            }
        }
        assert!(reclaimed > 50, "only {reclaimed} configs reclaimed");
    }

    #[test]
    fn peak_memory_monotone_in_concurrency() {
        let dev = DeviceKind::OrinNano;
        let base = dev.space().midpoint();
        let mut prev = 0.0;
        for c in 1..=5 {
            let mut cfg = base;
            cfg.concurrency = c;
            let m = peak_memory_gb(dev, ModelKind::RetinaNet, &cfg);
            assert!(m > prev);
            prev = m;
        }
    }
}
