//! Device data sheets: paper Tables 1 + 2, the manufacturer preset modes,
//! and the calibrated parameters of the analytic power/latency models.

use super::dvfs::{ConfigSpace, HwConfig};

/// The two evaluation boards (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceKind {
    /// NVIDIA Jetson Xavier NX — 6× Carmel @1.9 GHz, 384-core Volta
    /// @1100 MHz, 8 GB LPDDR4X, JetPack 5.1.
    XavierNx,
    /// NVIDIA Jetson Orin Nano — 6× Cortex-A78AE @1.5 GHz, 1024-core
    /// Ampere @625 MHz, 8 GB LPDDR5, JetPack 6.1.
    OrinNano,
}

impl DeviceKind {
    pub const ALL: [DeviceKind; 2] = [DeviceKind::XavierNx, DeviceKind::OrinNano];

    pub fn name(self) -> &'static str {
        match self {
            DeviceKind::XavierNx => "xavier-nx",
            DeviceKind::OrinNano => "orin-nano",
        }
    }

    pub fn parse(s: &str) -> Option<DeviceKind> {
        match s.to_ascii_lowercase().as_str() {
            "xavier-nx" | "xavier_nx" | "xaviernx" | "nx" => Some(DeviceKind::XavierNx),
            "orin-nano" | "orin_nano" | "orinnano" | "orin" => Some(DeviceKind::OrinNano),
            _ => None,
        }
    }

    /// Stable small id (hash inputs).
    pub fn id(self) -> u64 {
        match self {
            DeviceKind::XavierNx => 0,
            DeviceKind::OrinNano => 1,
        }
    }

    /// Tunable parameter grid (paper Table 2, discretized with the
    /// paper's ~100 MHz steps; §IV-A: 2160 raw configs on NX, 1600 on
    /// Orin). Endpoints match the data-sheet ranges.
    pub fn space(self) -> ConfigSpace {
        match self {
            DeviceKind::XavierNx => ConfigSpace::new(
                self,
                // 8 CPU frequencies, 1190–1908 MHz.
                vec![1190, 1290, 1390, 1490, 1590, 1690, 1790, 1908],
                // 5 core counts, 2–6.
                vec![2, 3, 4, 5, 6],
                // 6 GPU frequencies, 510–1100 MHz.
                vec![510, 630, 750, 870, 990, 1100],
                // 3 memory frequencies, 1500–1866 MHz.
                vec![1500, 1690, 1866],
                // 3 concurrency levels.
                vec![1, 2, 3],
            ),
            DeviceKind::OrinNano => ConfigSpace::new(
                self,
                // 8 CPU frequencies, 806–1510 MHz.
                vec![806, 906, 1006, 1106, 1206, 1306, 1406, 1510],
                vec![2, 3, 4, 5, 6],
                // 4 GPU frequencies, 306–624 MHz.
                vec![306, 412, 518, 624],
                // 2 memory frequencies (LPDDR5 operating points).
                vec![2133, 3199],
                // 5 concurrency levels.
                vec![1, 2, 3, 4, 5],
            ),
        }
    }

    /// Manufacturer max-performance preset (`nvpmodel` highest mode +
    /// `jetson_clocks`): everything pinned to max, app-level concurrency
    /// left at the framework default of 1 — presets do not manage
    /// application knobs (paper §II-A1).
    pub fn preset_max_power(self) -> HwConfig {
        let s = self.space();
        HwConfig {
            cpu_freq_mhz: s.max(super::dvfs::Dim::CpuFreq),
            cpu_cores: s.max(super::dvfs::Dim::CpuCores),
            gpu_freq_mhz: s.max(super::dvfs::Dim::GpuFreq),
            mem_freq_mhz: s.max(super::dvfs::Dim::MemFreq),
            concurrency: 1,
            max_batch: 1,
            variant: 0,
        }
    }

    /// Manufacturer default power mode (NX: 10 W desktop default — 4
    /// cores capped mid-clock; Orin Nano: 7 W default).
    pub fn preset_default(self) -> HwConfig {
        match self {
            DeviceKind::XavierNx => HwConfig {
                cpu_freq_mhz: 1390,
                cpu_cores: 4,
                gpu_freq_mhz: 630,
                mem_freq_mhz: 1690,
                concurrency: 1,
                max_batch: 1,
                variant: 0,
            },
            DeviceKind::OrinNano => HwConfig {
                cpu_freq_mhz: 1006,
                cpu_cores: 4,
                gpu_freq_mhz: 412,
                mem_freq_mhz: 2133,
                concurrency: 1,
                max_batch: 1,
                variant: 0,
            },
        }
    }

    /// Calibrated analytic-model parameters (see `perf.rs` / `power.rs`;
    /// calibration anchors in DESIGN.md §6, verified by
    /// `device::sim::tests` and EXPERIMENTS.md).
    pub fn model_params(self) -> DeviceModelParams {
        match self {
            DeviceKind::XavierNx => DeviceModelParams {
                gpu_arch_eff: 1.0,
                cpu_arch_eff: 1.0,
                mem_half_mhz: 600.0,
                gpu_contention: 0.16,
                mem_interference: 0.035,
                cpu_threads_per_instance: 2.0,
                cpu_usable_frac: 0.9, // cgroups 90 % cap (paper §IV-A)
                static_mw: 2350.0,
                cpu_idle_mw_per_core: 110.0,
                cpu_dyn_mw: 260.0,
                cpu_gamma: 2.2,
                gpu_dyn_mw: 2900.0,
                gpu_gamma: 2.0,
                gpu_idle_frac: 0.12,
                mem_dyn_mw: 520.0,
                mem_gb_budget: 7.4,
                noise_rel: 0.015,
                lottery_rel: 0.03,
            },
            DeviceKind::OrinNano => DeviceModelParams {
                // 1024 Ampere cores @ ≤624 MHz vs 384 Volta @ ≤1100 MHz:
                // much higher per-MHz throughput.
                gpu_arch_eff: 3.35,
                cpu_arch_eff: 1.18, // A78AE IPC edge over Carmel
                mem_half_mhz: 900.0,
                gpu_contention: 0.10,
                mem_interference: 0.025,
                cpu_threads_per_instance: 2.0,
                cpu_usable_frac: 0.9,
                static_mw: 2050.0,
                cpu_idle_mw_per_core: 90.0,
                cpu_dyn_mw: 300.0,
                cpu_gamma: 2.2,
                gpu_dyn_mw: 6300.0,
                gpu_gamma: 2.0,
                gpu_idle_frac: 0.10,
                mem_dyn_mw: 260.0,
                mem_gb_budget: 7.4,
                noise_rel: 0.015,
                lottery_rel: 0.03,
            },
        }
    }
}

impl std::fmt::Display for DeviceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Calibrated constants of the analytic device model.
///
/// Latency model (perf.rs): stage times scale as work / effective-clock;
/// power model (power.rs): static + per-rail dynamic terms with DVFS
/// exponents (P_dyn ∝ f^γ, γ ≈ 2–2.2 in the V∝f region).
#[derive(Debug, Clone, Copy)]
pub struct DeviceModelParams {
    /// GPU per-MHz throughput multiplier (architecture + core count).
    pub gpu_arch_eff: f64,
    /// CPU per-MHz throughput multiplier (IPC).
    pub cpu_arch_eff: f64,
    /// Memory half-saturation clock: GPU efficiency = f_mem/(f_mem+half).
    pub mem_half_mhz: f64,
    /// GPU time inflation per extra concurrent instance (shared SMs).
    pub gpu_contention: f64,
    /// Throughput loss per extra instance from memory-bus interference.
    pub mem_interference: f64,
    /// CPU threads one inference instance keeps busy (pre/post-process).
    pub cpu_threads_per_instance: f64,
    /// Usable CPU fraction (cgroup cap from the paper's setup).
    pub cpu_usable_frac: f64,
    /// Idle/base power: SoC, carrier board, rails (mW).
    pub static_mw: f64,
    /// Per-active-core idle power (mW).
    pub cpu_idle_mw_per_core: f64,
    /// CPU dynamic power coefficient (mW at 1 GHz, 1 core, 100 % util).
    pub cpu_dyn_mw: f64,
    /// CPU DVFS exponent.
    pub cpu_gamma: f64,
    /// GPU dynamic power coefficient (mW at 1 GHz, 100 % util).
    pub gpu_dyn_mw: f64,
    /// GPU DVFS exponent.
    pub gpu_gamma: f64,
    /// GPU idle draw as a fraction of its dynamic term at current clock.
    pub gpu_idle_frac: f64,
    /// Memory dynamic power coefficient (mW at 1 GHz, 100 % util).
    pub mem_dyn_mw: f64,
    /// Usable device memory before configs start failing (GB of 8 GB).
    pub mem_gb_budget: f64,
    /// Telemetry measurement noise (relative sigma per 1 s sample).
    pub noise_rel: f64,
    /// Per-configuration deterministic "chip lottery" spread.
    pub lottery_rel: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::dvfs::Dim;

    #[test]
    fn table2_space_sizes() {
        // §IV-A: 5·8·6·3·3 = 2160 on NX, 5·8·4·2·5 = 1600 on Orin.
        assert_eq!(DeviceKind::XavierNx.space().raw_size(), 2160);
        assert_eq!(DeviceKind::OrinNano.space().raw_size(), 1600);
    }

    #[test]
    fn table2_ranges() {
        let nx = DeviceKind::XavierNx.space();
        assert_eq!(nx.min(Dim::CpuFreq), 1190);
        assert_eq!(nx.max(Dim::CpuFreq), 1908);
        assert_eq!(nx.min(Dim::GpuFreq), 510);
        assert_eq!(nx.max(Dim::GpuFreq), 1100);
        assert_eq!(nx.max(Dim::Concurrency), 3);
        let orin = DeviceKind::OrinNano.space();
        assert_eq!(orin.min(Dim::CpuFreq), 806);
        assert_eq!(orin.max(Dim::CpuFreq), 1510);
        assert_eq!(orin.max(Dim::GpuFreq), 624);
        assert_eq!(orin.max(Dim::Concurrency), 5);
        assert_eq!(orin.values(Dim::MemFreq), &[2133, 3199]);
    }

    #[test]
    fn presets_are_in_space() {
        for d in DeviceKind::ALL {
            let s = d.space();
            assert!(s.contains(&d.preset_max_power()), "{d} max");
            assert!(s.contains(&d.preset_default()), "{d} default");
        }
    }

    #[test]
    fn max_preset_dominates_default() {
        for d in DeviceKind::ALL {
            let hi = d.preset_max_power();
            let lo = d.preset_default();
            assert!(hi.cpu_freq_mhz > lo.cpu_freq_mhz);
            assert!(hi.gpu_freq_mhz > lo.gpu_freq_mhz);
            assert!(hi.cpu_cores >= lo.cpu_cores);
        }
    }

    #[test]
    fn names_round_trip() {
        for d in DeviceKind::ALL {
            assert_eq!(DeviceKind::parse(d.name()), Some(d));
        }
        assert_eq!(DeviceKind::parse("NX"), Some(DeviceKind::XavierNx));
        assert_eq!(DeviceKind::parse("orin"), Some(DeviceKind::OrinNano));
    }
}
