//! Thermal-throttle extension (off by default).
//!
//! The paper's short measurement windows avoid sustained throttling, but
//! a deployed optimizer will meet it; this first-order RC thermal model
//! lets the ablation benches inject it: junction temperature integrates
//! power, and past the throttle point the effective GPU clock derates —
//! CORAL then sees the drifting environment through its sliding window.

/// First-order thermal model with a soft throttle curve.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    /// Junction temperature (°C).
    pub temp_c: f64,
    /// Ambient (°C).
    pub ambient_c: f64,
    /// °C per (W·s) of heating.
    pub heat_per_ws: f64,
    /// Fraction of the excess over ambient shed per second.
    pub cool_rate: f64,
    /// Throttling starts here (°C).
    pub throttle_start_c: f64,
    /// Full derate reached here (°C).
    pub throttle_full_c: f64,
    /// Max clock derate at full throttle (fraction of nominal).
    pub max_derate: f64,
}

impl Default for ThermalModel {
    fn default() -> Self {
        ThermalModel {
            temp_c: 35.0,
            ambient_c: 35.0,
            heat_per_ws: 0.6,
            cool_rate: 0.08,
            throttle_start_c: 70.0,
            throttle_full_c: 95.0,
            max_derate: 0.35,
        }
    }
}

impl ThermalModel {
    pub fn temperature_c(&self) -> f64 {
        self.temp_c
    }

    /// Advance the model by `dt_s` seconds at `power_mw` draw.
    pub fn step(&mut self, power_mw: f64, dt_s: f64) {
        let heat = power_mw / 1000.0 * self.heat_per_ws * dt_s;
        let cool = (self.temp_c - self.ambient_c) * self.cool_rate * dt_s;
        self.temp_c += heat - cool;
    }

    /// Effective clock multiplier at the current temperature, in
    /// `[1 − max_derate, 1]`.
    pub fn clock_factor(&self) -> f64 {
        if self.temp_c <= self.throttle_start_c {
            return 1.0;
        }
        let span = self.throttle_full_c - self.throttle_start_c;
        let frac = ((self.temp_c - self.throttle_start_c) / span).clamp(0.0, 1.0);
        1.0 - self.max_derate * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cool_device_does_not_throttle() {
        let t = ThermalModel::default();
        assert_eq!(t.clock_factor(), 1.0);
    }

    #[test]
    fn sustained_load_heats_and_throttles() {
        let mut t = ThermalModel::default();
        for _ in 0..600 {
            t.step(9000.0, 1.0);
        }
        assert!(t.temperature_c() > t.throttle_start_c);
        assert!(t.clock_factor() < 1.0);
        assert!(t.clock_factor() >= 1.0 - t.max_derate);
    }

    #[test]
    fn equilibrium_is_bounded() {
        let mut t = ThermalModel::default();
        for _ in 0..10_000 {
            t.step(9000.0, 1.0);
        }
        let eq = t.temperature_c();
        t.step(9000.0, 1.0);
        assert!((t.temperature_c() - eq).abs() < 0.05, "settled");
    }

    #[test]
    fn idle_cools_back_to_ambient() {
        let mut t = ThermalModel::default();
        for _ in 0..300 {
            t.step(9000.0, 1.0);
        }
        for _ in 0..2000 {
            t.step(0.0, 1.0);
        }
        assert!((t.temperature_c() - t.ambient_c).abs() < 1.0);
    }
}
