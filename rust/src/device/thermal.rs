//! Thermal-throttle extension (off by default).
//!
//! The paper's short measurement windows avoid sustained throttling, but
//! a deployed optimizer will meet it; this first-order RC thermal model
//! lets the ablation benches inject it: junction temperature integrates
//! power, and past the throttle point the effective GPU clock derates —
//! CORAL then sees the drifting environment through its sliding window.

/// First-order thermal model with a soft throttle curve.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    /// Junction temperature (°C).
    pub temp_c: f64,
    /// Ambient (°C).
    pub ambient_c: f64,
    /// °C per (W·s) of heating.
    pub heat_per_ws: f64,
    /// Fraction of the excess over ambient shed per second.
    pub cool_rate: f64,
    /// Throttling starts here (°C).
    pub throttle_start_c: f64,
    /// Full derate reached here (°C).
    pub throttle_full_c: f64,
    /// Max clock derate at full throttle (fraction of nominal).
    pub max_derate: f64,
}

impl Default for ThermalModel {
    fn default() -> Self {
        ThermalModel {
            temp_c: 35.0,
            ambient_c: 35.0,
            heat_per_ws: 0.6,
            cool_rate: 0.08,
            throttle_start_c: 70.0,
            throttle_full_c: 95.0,
            max_derate: 0.35,
        }
    }
}

impl ThermalModel {
    pub fn temperature_c(&self) -> f64 {
        self.temp_c
    }

    /// Advance the model by `dt_s` seconds at `power_mw` draw.
    ///
    /// Closed-form exponential relaxation toward the step's equilibrium
    /// `T_eq = ambient + heating_rate / cool_rate` — exact for constant
    /// power within the step, for *any* `dt_s`. The explicit-Euler form
    /// this replaced overshot below ambient (and could oscillate) once
    /// `cool_rate * dt_s > 1`, which chaos schedules and long idle gaps
    /// between rounds actually reach; here cooling monotonically
    /// approaches ambient and never crosses it.
    pub fn step(&mut self, power_mw: f64, dt_s: f64) {
        let heating_c_per_s = power_mw / 1000.0 * self.heat_per_ws;
        if self.cool_rate <= 0.0 {
            // Degenerate (adiabatic) configuration: no equilibrium to
            // relax toward, heat just integrates.
            self.temp_c += heating_c_per_s * dt_s;
            return;
        }
        let t_eq = self.ambient_c + heating_c_per_s / self.cool_rate;
        self.temp_c = t_eq + (self.temp_c - t_eq) * (-self.cool_rate * dt_s).exp();
    }

    /// Effective clock multiplier at the current temperature, in
    /// `[1 − max_derate, 1]`.
    pub fn clock_factor(&self) -> f64 {
        if self.temp_c <= self.throttle_start_c {
            return 1.0;
        }
        let span = self.throttle_full_c - self.throttle_start_c;
        let frac = ((self.temp_c - self.throttle_start_c) / span).clamp(0.0, 1.0);
        1.0 - self.max_derate * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cool_device_does_not_throttle() {
        let t = ThermalModel::default();
        assert_eq!(t.clock_factor(), 1.0);
    }

    #[test]
    fn sustained_load_heats_and_throttles() {
        let mut t = ThermalModel::default();
        for _ in 0..600 {
            t.step(9000.0, 1.0);
        }
        assert!(t.temperature_c() > t.throttle_start_c);
        assert!(t.clock_factor() < 1.0);
        assert!(t.clock_factor() >= 1.0 - t.max_derate);
    }

    #[test]
    fn equilibrium_is_bounded() {
        let mut t = ThermalModel::default();
        for _ in 0..10_000 {
            t.step(9000.0, 1.0);
        }
        let eq = t.temperature_c();
        t.step(9000.0, 1.0);
        assert!((t.temperature_c() - eq).abs() < 0.05, "settled");
    }

    #[test]
    fn large_dt_cooling_never_overshoots_ambient() {
        // Explicit Euler with cool_rate * dt > 1 used to swing below
        // ambient and oscillate; the closed form relaxes monotonically.
        let mut t = ThermalModel { temp_c: 90.0, ..ThermalModel::default() };
        let mut prev = t.temp_c;
        for _ in 0..5 {
            t.step(0.0, 60.0); // cool_rate * dt = 4.8 ≫ 1
            assert!(t.temp_c >= t.ambient_c, "crossed ambient: {}", t.temp_c);
            assert!(t.temp_c <= prev, "cooling must be monotone");
            prev = t.temp_c;
        }
        assert!((t.temp_c - t.ambient_c).abs() < 1e-6);
    }

    #[test]
    fn large_dt_heating_lands_on_the_step_equilibrium() {
        // T_eq = 35 + (9 W · 0.6 °C/Ws) / 0.08 = 102.5 °C; one giant
        // step lands on it exactly, never beyond.
        let mut t = ThermalModel::default();
        t.step(9000.0, 1e6);
        assert!((t.temp_c - 102.5).abs() < 1e-9);
    }

    #[test]
    fn idle_cools_back_to_ambient() {
        let mut t = ThermalModel::default();
        for _ in 0..300 {
            t.step(9000.0, 1.0);
        }
        for _ in 0..2000 {
            t.step(0.0, 1.0);
        }
        assert!((t.temperature_c() - t.ambient_c).abs() < 1.0);
    }
}
