//! DVFS + concurrency configuration space (paper Eq. 5), plus the
//! normalized encoding that lets one optimizer span different devices.
//!
//! A configuration is the 7-tuple `s = (s_cpu, c_cpu, s_gpu, s_mem, c,
//! b, v)` — the paper's 5 DVFS/concurrency knobs (Table 2 ranges with
//! ~100 MHz steps, §IV-A) plus `max_batch`, the coordinator's batch cap
//! promoted into the search space (the joint batching+DVFS optimum is
//! coupled — Xu et al., arXiv 2504.14611), plus `variant`, the index
//! into the model's [`crate::models::VariantManifest`] (the
//! accuracy–energy co-design axis of Jayakodi et al., arXiv
//! 1901.10584). Device grids default the batch axis to the singleton
//! `[1]` (the paper's per-frame serving) and the variant axis to the
//! singleton `[0]` (the full-accuracy baseline), so every legacy
//! surface is the `b = 1, v = 0` slice of this space;
//! [`ConfigSpace::with_batch_caps`] / [`ConfigSpace::with_variant_axis`]
//! open the axes. This module provides enumeration, clamping/rounding
//! onto the grid (Algorithm 2's `MINMAX(ROUND(v), r)`), indexing and
//! neighbourhood moves.
//!
//! **Heterogeneous fleets** (ARCHITECTURE.md, EXPERIMENTS.md
//! §Heterogeneous fleets): the paper tunes one device class at a time,
//! and raw-frequency features transfer poorly between classes (an Orin
//! GPU "step" is a different number of MHz than an NX one). [`NormSpace`]
//! normalizes every dimension to its **rank fraction** — position along
//! the device's sorted values, scaled to `[0, 1]` — so a single search
//! surface spans mixed NX/Orin fleets: one [`NormConfig`] decodes onto
//! each member's native grid ([`ConfigSpace::decode`]), always landing
//! exactly on-grid, with the same deterministic tie-break as
//! [`ConfigSpace::snap`].

use super::specs::DeviceKind;

/// One hardware configuration (paper Eq. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct HwConfig {
    /// CPU frequency, MHz.
    pub cpu_freq_mhz: u32,
    /// Active CPU cores.
    pub cpu_cores: u32,
    /// GPU frequency, MHz.
    pub gpu_freq_mhz: u32,
    /// Memory (EMC) frequency, MHz.
    pub mem_freq_mhz: u32,
    /// Concurrency level: number of inference instances.
    pub concurrency: u32,
    /// Batch cap: frames aggregated per inference call (the
    /// coordinator's `max_batch`, now a search dimension). 1 = the
    /// paper's per-frame serving.
    pub max_batch: u32,
    /// Model-variant index into the device's
    /// [`crate::models::VariantManifest`]. 0 = the full-accuracy
    /// baseline (the paper's fixed model).
    pub variant: u32,
}

/// Configuration dimensions, in the canonical order used everywhere
/// (sliding-window columns, correlation weights, search steps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dim {
    CpuFreq,
    CpuCores,
    GpuFreq,
    MemFreq,
    Concurrency,
    /// The batch cap — appended after the five hardware knobs so those
    /// columns keep their historical order everywhere (window columns,
    /// dCor weight indices, enumeration order on singleton-batch grids).
    BatchCap,
    /// The model-variant index — appended last, by the same rule: the
    /// first six columns keep their PR-8 order, and singleton-variant
    /// grids enumerate in the historical 6-dim order.
    Variant,
}

impl Dim {
    pub const ALL: [Dim; 7] = [
        Dim::CpuFreq,
        Dim::CpuCores,
        Dim::GpuFreq,
        Dim::MemFreq,
        Dim::Concurrency,
        Dim::BatchCap,
        Dim::Variant,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Dim::CpuFreq => "cpu_freq_mhz",
            Dim::CpuCores => "cpu_cores",
            Dim::GpuFreq => "gpu_freq_mhz",
            Dim::MemFreq => "mem_freq_mhz",
            Dim::Concurrency => "concurrency",
            Dim::BatchCap => "max_batch",
            Dim::Variant => "variant",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Dim::CpuFreq => 0,
            Dim::CpuCores => 1,
            Dim::GpuFreq => 2,
            Dim::MemFreq => 3,
            Dim::Concurrency => 4,
            Dim::BatchCap => 5,
            Dim::Variant => 6,
        }
    }
}

impl HwConfig {
    /// Number of tunable dimensions.
    pub const NDIMS: usize = 7;

    /// Configuration as an f64 vector in [`Dim::ALL`] order.
    pub fn as_vec(&self) -> [f64; Self::NDIMS] {
        [
            self.cpu_freq_mhz as f64,
            self.cpu_cores as f64,
            self.gpu_freq_mhz as f64,
            self.mem_freq_mhz as f64,
            self.concurrency as f64,
            self.max_batch as f64,
            self.variant as f64,
        ]
    }

    /// Build from an f64 vector (values must already be on-grid).
    pub fn from_vec(v: [f64; Self::NDIMS]) -> HwConfig {
        HwConfig {
            cpu_freq_mhz: v[0] as u32,
            cpu_cores: v[1] as u32,
            gpu_freq_mhz: v[2] as u32,
            mem_freq_mhz: v[3] as u32,
            concurrency: v[4] as u32,
            max_batch: v[5] as u32,
            variant: v[6] as u32,
        }
    }

    /// Value along one dimension.
    pub fn get(&self, dim: Dim) -> u32 {
        match dim {
            Dim::CpuFreq => self.cpu_freq_mhz,
            Dim::CpuCores => self.cpu_cores,
            Dim::GpuFreq => self.gpu_freq_mhz,
            Dim::MemFreq => self.mem_freq_mhz,
            Dim::Concurrency => self.concurrency,
            Dim::BatchCap => self.max_batch,
            Dim::Variant => self.variant,
        }
    }

    /// Copy with one dimension replaced.
    pub fn with(&self, dim: Dim, value: u32) -> HwConfig {
        let mut c = *self;
        match dim {
            Dim::CpuFreq => c.cpu_freq_mhz = value,
            Dim::CpuCores => c.cpu_cores = value,
            Dim::GpuFreq => c.gpu_freq_mhz = value,
            Dim::MemFreq => c.mem_freq_mhz = value,
            Dim::Concurrency => c.concurrency = value,
            Dim::BatchCap => c.max_batch = value,
            Dim::Variant => c.variant = value,
        }
        c
    }

    /// Stable hash-input encoding of the full tuple.
    pub fn key(&self) -> [u64; 7] {
        [
            self.cpu_freq_mhz as u64,
            self.cpu_cores as u64,
            self.gpu_freq_mhz as u64,
            self.mem_freq_mhz as u64,
            self.concurrency as u64,
            self.max_batch as u64,
            self.variant as u64,
        ]
    }

    /// Stable hash-input encoding of the hardware knobs alone. The
    /// simulator's chip-lottery draw hashes this — silicon variance is
    /// a property of the DVFS state, never of the application's batch
    /// cap or served model variant — which also keeps every
    /// `max_batch = 1, variant = 0` measurement bit-identical to the
    /// historical 5-dim surface.
    pub fn hw_key(&self) -> [u64; 5] {
        [
            self.cpu_freq_mhz as u64,
            self.cpu_cores as u64,
            self.gpu_freq_mhz as u64,
            self.mem_freq_mhz as u64,
            self.concurrency as u64,
        ]
    }
}

impl std::fmt::Display for HwConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cpu={}MHzx{} gpu={}MHz mem={}MHz conc={} batch={} var={}",
            self.cpu_freq_mhz, self.cpu_cores, self.gpu_freq_mhz, self.mem_freq_mhz,
            self.concurrency, self.max_batch, self.variant
        )
    }
}

/// The discrete configuration grid of one device — or, when
/// [`ConfigSpace::is_normalized`] holds, the rank-fraction grid of a
/// [`NormSpace`] (values in permille of each dimension's range).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigSpace {
    device: DeviceKind,
    dims: [Vec<u32>; HwConfig::NDIMS],
    /// True for a [`NormSpace`] search grid (values are rank fractions
    /// in permille, not native MHz / cores / instances).
    normalized: bool,
}

impl ConfigSpace {
    /// Build a grid over the paper's five knobs; the batch axis starts
    /// as the singleton `[1]` and the variant axis as the singleton
    /// `[0]` (the legacy 5-dim surface). Open them with
    /// [`ConfigSpace::with_batch_caps`] /
    /// [`ConfigSpace::with_variant_axis`].
    pub fn new(
        device: DeviceKind,
        cpu_freqs: Vec<u32>,
        cpu_cores: Vec<u32>,
        gpu_freqs: Vec<u32>,
        mem_freqs: Vec<u32>,
        concurrency: Vec<u32>,
    ) -> ConfigSpace {
        let dims = [cpu_freqs, cpu_cores, gpu_freqs, mem_freqs, concurrency, vec![1], vec![0]];
        for (i, d) in dims.iter().enumerate() {
            assert!(!d.is_empty(), "dimension {i} empty");
            assert!(d.windows(2).all(|w| w[0] < w[1]), "dimension {i} not sorted/unique");
        }
        ConfigSpace { device, dims, normalized: false }
    }

    /// Open the batch axis to `caps` (sorted, unique, non-empty). The
    /// default singleton `[1]` is exactly the legacy 5-dim space; any
    /// wider axis makes `max_batch` a sixth search dimension.
    pub fn with_batch_caps(mut self, caps: Vec<u32>) -> ConfigSpace {
        assert!(!caps.is_empty(), "batch axis empty");
        assert!(caps.windows(2).all(|w| w[0] < w[1]), "batch axis not sorted/unique");
        assert!(caps[0] >= 1, "a batch cap below 1 serves nothing");
        self.dims[Dim::BatchCap.index()] = caps;
        self
    }

    /// Open the variant axis to the indices `0..n` of an `n`-entry
    /// [`crate::models::VariantManifest`]. The default singleton `[0]`
    /// serves only the full-accuracy baseline (the legacy surface); any
    /// wider axis makes the served variant a seventh search dimension.
    pub fn with_variant_axis(mut self, n: usize) -> ConfigSpace {
        assert!(n >= 1, "variant axis empty");
        self.dims[Dim::Variant.index()] = (0..n as u32).collect();
        self
    }

    /// Device this grid belongs to. A normalized grid spans several
    /// devices; its tag is member 0's kind — a representative for
    /// display, never a semantic device (check
    /// [`ConfigSpace::is_normalized`] before interpreting it).
    pub fn device(&self) -> DeviceKind {
        self.device
    }

    /// True for a [`NormSpace`] search grid, whose values are
    /// per-dimension rank fractions in permille rather than native
    /// hardware units.
    pub fn is_normalized(&self) -> bool {
        self.normalized
    }

    /// Allowed values along one dimension (sorted ascending).
    pub fn values(&self, dim: Dim) -> &[u32] {
        &self.dims[dim.index()]
    }

    pub fn min(&self, dim: Dim) -> u32 {
        *self.values(dim).first().unwrap()
    }

    pub fn max(&self, dim: Dim) -> u32 {
        *self.values(dim).last().unwrap()
    }

    /// Total grid size (before failure exclusion — paper's "raw" count).
    pub fn raw_size(&self) -> usize {
        self.dims.iter().map(|d| d.len()).product()
    }

    /// Is `cfg` exactly on the grid?
    pub fn contains(&self, cfg: &HwConfig) -> bool {
        Dim::ALL
            .iter()
            .all(|&d| self.values(d).binary_search(&cfg.get(d)).is_ok())
    }

    /// Snap a continuous value onto the grid: nearest allowed value
    /// (Algorithm 2's `MINMAX(ROUND(v), r)` — clamp + round in one).
    ///
    /// **Tie-break rule**: a value exactly halfway between two grid
    /// points snaps to the **lower** one (the scan keeps the first of
    /// two equidistant candidates, and values are sorted ascending).
    /// [`ConfigSpace::decode`] applies the same rule in rank space, so
    /// every member of a heterogeneous fleet resolves a tied proposal
    /// identically on every run and every thread schedule.
    pub fn snap(&self, dim: Dim, v: f64) -> u32 {
        let vals = self.values(dim);
        let mut best = vals[0];
        let mut best_d = f64::INFINITY;
        for &x in vals {
            let d = (x as f64 - v).abs();
            if d < best_d {
                best_d = d;
                best = x;
            }
        }
        best
    }

    /// Snap a full vector onto the grid.
    pub fn snap_config(&self, v: [f64; HwConfig::NDIMS]) -> HwConfig {
        let mut out = [0u32; HwConfig::NDIMS];
        for (i, &d) in Dim::ALL.iter().enumerate() {
            out[i] = self.snap(d, v[i]);
        }
        HwConfig {
            cpu_freq_mhz: out[0],
            cpu_cores: out[1],
            gpu_freq_mhz: out[2],
            mem_freq_mhz: out[3],
            concurrency: out[4],
            max_batch: out[5],
            variant: out[6],
        }
    }

    /// Enumerate the full grid in lexicographic order (the variant axis
    /// iterates innermost, then the batch axis, so singleton-batch,
    /// singleton-variant grids enumerate in the historical 5-dim order).
    pub fn enumerate(&self) -> Vec<HwConfig> {
        let mut out = Vec::with_capacity(self.raw_size());
        for &cf in &self.dims[0] {
            for &cc in &self.dims[1] {
                for &gf in &self.dims[2] {
                    for &mf in &self.dims[3] {
                        for &c in &self.dims[4] {
                            for &b in &self.dims[5] {
                                for &v in &self.dims[6] {
                                    out.push(HwConfig {
                                        cpu_freq_mhz: cf,
                                        cpu_cores: cc,
                                        gpu_freq_mhz: gf,
                                        mem_freq_mhz: mf,
                                        concurrency: c,
                                        max_batch: b,
                                        variant: v,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Lexicographic index of an on-grid configuration.
    pub fn index_of(&self, cfg: &HwConfig) -> Option<usize> {
        let mut idx = 0usize;
        for &d in &Dim::ALL {
            let vals = self.values(d);
            let pos = vals.binary_search(&cfg.get(d)).ok()?;
            idx = idx * vals.len() + pos;
        }
        Some(idx)
    }

    /// The "middle" configuration — a neutral starting point for online
    /// search when no preset is given.
    pub fn midpoint(&self) -> HwConfig {
        let mid = |d: Dim| {
            let v = self.values(d);
            v[v.len() / 2]
        };
        HwConfig {
            cpu_freq_mhz: mid(Dim::CpuFreq),
            cpu_cores: mid(Dim::CpuCores),
            gpu_freq_mhz: mid(Dim::GpuFreq),
            mem_freq_mhz: mid(Dim::MemFreq),
            concurrency: mid(Dim::Concurrency),
            max_batch: mid(Dim::BatchCap),
            variant: mid(Dim::Variant),
        }
    }

    /// Uniform random on-grid configuration. A singleton dimension has
    /// nothing to choose, so it consumes no randomness — which keeps
    /// the draw stream (and thus every same-seed trajectory) of a
    /// singleton-batch grid bit-identical to the historical 5-dim one.
    pub fn random(&self, rng: &mut crate::util::Rng) -> HwConfig {
        let pick = |d: Dim, rng: &mut crate::util::Rng| {
            let v = self.values(d);
            if v.len() == 1 {
                v[0]
            } else {
                v[rng.below(v.len())]
            }
        };
        HwConfig {
            cpu_freq_mhz: pick(Dim::CpuFreq, rng),
            cpu_cores: pick(Dim::CpuCores, rng),
            gpu_freq_mhz: pick(Dim::GpuFreq, rng),
            mem_freq_mhz: pick(Dim::MemFreq, rng),
            concurrency: pick(Dim::Concurrency, rng),
            max_batch: pick(Dim::BatchCap, rng),
            variant: pick(Dim::Variant, rng),
        }
    }

    /// Encode a configuration as per-dimension rank fractions. Off-grid
    /// values are snapped first ([`ConfigSpace::snap`]), so `encode` is
    /// total; a single-value dimension encodes to 0.
    pub fn encode(&self, cfg: &HwConfig) -> NormConfig {
        let mut out = [0.0f64; HwConfig::NDIMS];
        for (i, &d) in Dim::ALL.iter().enumerate() {
            let vals = self.values(d);
            let v = self.snap(d, cfg.get(d) as f64);
            let rank = vals.binary_search(&v).expect("snapped value is on the grid");
            out[i] = rank as f64 / (vals.len() - 1).max(1) as f64;
        }
        NormConfig(out)
    }

    /// Decode rank fractions onto this grid: each fraction maps to the
    /// nearest rank along the dimension's sorted values, so the result
    /// is always exactly on-grid. A fraction landing halfway between
    /// two ranks takes the **lower** one — the same deterministic
    /// tie-break [`ConfigSpace::snap`] applies to values.
    pub fn decode(&self, nc: &NormConfig) -> HwConfig {
        let nc = nc.clamped();
        let mut out = [0.0f64; HwConfig::NDIMS];
        for (i, &d) in Dim::ALL.iter().enumerate() {
            let vals = self.values(d);
            let t = nc.get(d) * (vals.len() - 1) as f64;
            let lo = t.floor();
            let rank = if t - lo > 0.5 { lo as usize + 1 } else { lo as usize };
            out[i] = vals[rank] as f64;
        }
        HwConfig::from_vec(out)
    }

    /// The space's "manufacturer default" anchor — CORAL's first
    /// bootstrap probe. Native grids use the device's default nvpmodel
    /// preset; a normalized grid has no manufacturer, so the neutral
    /// [`ConfigSpace::midpoint`] stands in, with concurrency at the
    /// framework default (the dimension minimum, as presets never touch
    /// application knobs — paper §II-A1).
    pub fn preset_default(&self) -> HwConfig {
        if self.normalized {
            let mut c = self.midpoint();
            c.concurrency = self.min(Dim::Concurrency);
            c.max_batch = self.min(Dim::BatchCap);
            c.variant = self.min(Dim::Variant);
            c
        } else {
            let mut c = self.device.preset_default();
            c.max_batch = self.min(Dim::BatchCap);
            c.variant = self.min(Dim::Variant);
            c
        }
    }

    /// The space's "max performance" anchor — CORAL's second bootstrap
    /// probe. Native grids use the device's max nvpmodel preset; on a
    /// normalized grid every hardware knob sits at rank 1.0 (each
    /// member's own maximum after decoding) with concurrency at the
    /// framework default.
    pub fn preset_max_power(&self) -> HwConfig {
        if self.normalized {
            HwConfig {
                cpu_freq_mhz: self.max(Dim::CpuFreq),
                cpu_cores: self.max(Dim::CpuCores),
                gpu_freq_mhz: self.max(Dim::GpuFreq),
                mem_freq_mhz: self.max(Dim::MemFreq),
                concurrency: self.min(Dim::Concurrency),
                max_batch: self.min(Dim::BatchCap),
                variant: self.min(Dim::Variant),
            }
        } else {
            let mut c = self.device.preset_max_power();
            c.max_batch = self.min(Dim::BatchCap);
            c.variant = self.min(Dim::Variant);
            c
        }
    }

    /// Every dimension pinned to its maximum grid value — the "all-max"
    /// configuration the chaos baselines serve statically. Distinct
    /// from [`ConfigSpace::preset_max_power`] (the manufacturer preset,
    /// which leaves the application knobs at their minimum): this maxes
    /// concurrency and the batch axis too. On a normalized grid every
    /// dimension sits at rank 1.0, which decodes to each member's own
    /// maximum. Note that `snap_config([1.0; 7])` does **not** build
    /// this configuration — 1.0 is a raw grid value there and snaps to
    /// each dimension's *minimum*.
    pub fn max_config(&self) -> HwConfig {
        HwConfig {
            cpu_freq_mhz: self.max(Dim::CpuFreq),
            cpu_cores: self.max(Dim::CpuCores),
            gpu_freq_mhz: self.max(Dim::GpuFreq),
            mem_freq_mhz: self.max(Dim::MemFreq),
            concurrency: self.max(Dim::Concurrency),
            max_batch: self.max(Dim::BatchCap),
            variant: self.max(Dim::Variant),
        }
    }

    /// Render `cfg` with its space context. Heterogeneous-fleet reports
    /// must distinguish an NX configuration from an Orin one with
    /// identical raw values — bare [`HwConfig`]'s `Display` cannot —
    /// and normalized grid points are rank fractions, which would be
    /// nonsense printed as MHz.
    pub fn describe(&self, cfg: &HwConfig) -> String {
        if self.normalized {
            let pct = |v: u32| 100.0 * v as f64 / NormSpace::RESOLUTION as f64;
            format!(
                "norm cpu={:.0}%x{:.0}% gpu={:.0}% mem={:.0}% conc={:.0}% batch={:.0}% var={:.0}%",
                pct(cfg.cpu_freq_mhz),
                pct(cfg.cpu_cores),
                pct(cfg.gpu_freq_mhz),
                pct(cfg.mem_freq_mhz),
                pct(cfg.concurrency),
                pct(cfg.max_batch),
                pct(cfg.variant),
            )
        } else {
            format!("{} {cfg}", self.device.name())
        }
    }
}

/// A configuration expressed as per-dimension **rank fractions**: each
/// value is the configuration's position along a grid dimension's sorted
/// values, scaled to `[0, 1]` (0 = the dimension's minimum, 1 = its
/// maximum). Raw-frequency features transfer poorly across device
/// generations (PolyThrottle's per-device grids); rank fractions are the
/// encoding that lets one distance-correlation surface span
/// heterogeneous hardware (Fulcrum's GMD scheduler normalizes the same
/// way).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NormConfig(pub [f64; HwConfig::NDIMS]);

impl NormConfig {
    /// Fraction along one dimension.
    pub fn get(&self, dim: Dim) -> f64 {
        self.0[dim.index()]
    }

    /// Clamp every fraction into `[0, 1]`; non-finite values collapse
    /// to 0 (the conservative end of every dimension).
    pub fn clamped(mut self) -> NormConfig {
        for v in self.0.iter_mut() {
            *v = if v.is_finite() { v.clamp(0.0, 1.0) } else { 0.0 };
        }
        self
    }
}

/// The shared search space of a heterogeneous fleet: the member grids
/// (different devices), plus one **normalized grid** any optimizer can
/// search without knowing a member's native units.
///
/// The normalized grid's values are the union of every member's rank
/// fractions, stored in permille ([`NormSpace::RESOLUTION`]), so every
/// member grid point stays exactly representable and the grid is itself
/// a [`ConfigSpace`] — the existing [`crate::optimizer::Optimizer`]
/// implementations search it unchanged. Decoding a normalized proposal
/// for member `i` ([`NormSpace::decode_for`]) always lands on member
/// `i`'s native grid.
#[derive(Debug, Clone, PartialEq)]
pub struct NormSpace {
    members: Vec<ConfigSpace>,
    grid: ConfigSpace,
}

impl NormSpace {
    /// Fixed-point resolution of the normalized grid: a fraction `f` is
    /// stored as `round(f · RESOLUTION)`. 1000 keeps every realistic
    /// rank fraction distinct (dimensions have ≤ tens of values) while
    /// staying exact under the `u32` grid representation.
    pub const RESOLUTION: u32 = 1000;

    pub fn new(members: Vec<ConfigSpace>) -> NormSpace {
        assert!(!members.is_empty(), "a normalized space needs at least one member");
        let dim_vals = |d: Dim| -> Vec<u32> {
            let mut vals: Vec<u32> = members
                .iter()
                .flat_map(|m| {
                    let n = m.values(d).len();
                    (0..n).map(move |rank| {
                        (Self::RESOLUTION as f64 * rank as f64 / (n - 1).max(1) as f64)
                            .round() as u32
                    })
                })
                .collect();
            vals.sort_unstable();
            vals.dedup();
            vals
        };
        let grid = ConfigSpace {
            device: members[0].device(),
            dims: [
                dim_vals(Dim::CpuFreq),
                dim_vals(Dim::CpuCores),
                dim_vals(Dim::GpuFreq),
                dim_vals(Dim::MemFreq),
                dim_vals(Dim::Concurrency),
                dim_vals(Dim::BatchCap),
                dim_vals(Dim::Variant),
            ],
            normalized: true,
        };
        NormSpace { members, grid }
    }

    /// Member grids, in fleet order.
    pub fn members(&self) -> &[ConfigSpace] {
        &self.members
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// The normalized search grid ([`ConfigSpace::is_normalized`]
    /// holds). Its `device()` tag is member 0's kind — a representative
    /// for display, not a semantic device.
    pub fn grid(&self) -> &ConfigSpace {
        &self.grid
    }

    /// Fractions of a normalized grid point (permille → `[0, 1]`).
    pub fn fractions(cfg: &HwConfig) -> NormConfig {
        let f = |v: u32| v as f64 / Self::RESOLUTION as f64;
        NormConfig([
            f(cfg.cpu_freq_mhz),
            f(cfg.cpu_cores),
            f(cfg.gpu_freq_mhz),
            f(cfg.mem_freq_mhz),
            f(cfg.concurrency),
            f(cfg.max_batch),
            f(cfg.variant),
        ])
        .clamped()
    }

    /// Decode a normalized proposal onto member `i`'s native grid.
    pub fn decode_for(&self, member: usize, cfg: &HwConfig) -> HwConfig {
        self.members[member].decode(&Self::fractions(cfg))
    }

    /// Encode member `i`'s configuration onto the normalized grid
    /// (exact for on-grid member configurations: every member rank
    /// fraction is a grid value by construction).
    pub fn encode_from(&self, member: usize, cfg: &HwConfig) -> HwConfig {
        let nc = self.members[member].encode(cfg);
        let v = |d: Dim| nc.get(d) * Self::RESOLUTION as f64;
        self.grid.snap_config([
            v(Dim::CpuFreq),
            v(Dim::CpuCores),
            v(Dim::GpuFreq),
            v(Dim::MemFreq),
            v(Dim::Concurrency),
            v(Dim::BatchCap),
            v(Dim::Variant),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn nx() -> ConfigSpace {
        DeviceKind::XavierNx.space()
    }

    #[test]
    fn enumerate_matches_raw_size_and_is_unique() {
        let s = nx();
        let all = s.enumerate();
        assert_eq!(all.len(), s.raw_size());
        let mut sorted = all.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), all.len());
        assert!(all.iter().all(|c| s.contains(c)));
    }

    #[test]
    fn index_of_is_enumeration_order() {
        let s = DeviceKind::OrinNano.space();
        for (i, cfg) in s.enumerate().iter().enumerate().step_by(97) {
            assert_eq!(s.index_of(cfg), Some(i));
        }
    }

    #[test]
    fn index_of_off_grid_is_none() {
        let s = nx();
        let mut c = s.midpoint();
        c.cpu_freq_mhz = 1234;
        assert_eq!(s.index_of(&c), None);
        assert!(!s.contains(&c));
    }

    #[test]
    fn snap_picks_nearest() {
        let s = nx();
        assert_eq!(s.snap(Dim::CpuFreq, 1200.0), 1190);
        assert_eq!(s.snap(Dim::CpuFreq, 1345.0), 1390);
        assert_eq!(s.snap(Dim::CpuFreq, -1e9), 1190);
        assert_eq!(s.snap(Dim::CpuFreq, 1e9), 1908);
        assert_eq!(s.snap(Dim::Concurrency, 2.4), 2);
    }

    #[test]
    fn snap_is_idempotent_and_in_range() {
        prop::check("snap idempotent", 200, |g| {
            let s = if g.rng.chance(0.5) {
                DeviceKind::XavierNx.space()
            } else {
                DeviceKind::OrinNano.space()
            };
            let v = [
                g.rng.range_f64(-100.0, 4000.0),
                g.rng.range_f64(-2.0, 10.0),
                g.rng.range_f64(-100.0, 2000.0),
                g.rng.range_f64(0.0, 5000.0),
                g.rng.range_f64(-1.0, 9.0),
                g.rng.range_f64(-1.0, 20.0),
                g.rng.range_f64(-1.0, 6.0),
            ];
            let cfg = s.snap_config(v);
            prop::assert_true(s.contains(&cfg), "snapped config on grid")?;
            let again = s.snap_config(cfg.as_vec());
            prop::assert_eq_dbg(&again, &cfg)
        });
    }

    #[test]
    fn random_configs_are_on_grid() {
        let s = DeviceKind::OrinNano.space();
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            assert!(s.contains(&s.random(&mut rng)));
        }
    }

    #[test]
    fn midpoint_on_grid() {
        for d in DeviceKind::ALL {
            let s = d.space();
            assert!(s.contains(&s.midpoint()));
        }
    }

    #[test]
    fn with_and_get_round_trip() {
        let c = nx().midpoint();
        for &d in &Dim::ALL {
            let c2 = c.with(d, c.get(d));
            assert_eq!(c, c2);
        }
        let c3 = c.with(Dim::GpuFreq, 510);
        assert_eq!(c3.gpu_freq_mhz, 510);
    }

    #[test]
    fn as_vec_from_vec_round_trip() {
        let c = nx().midpoint();
        assert_eq!(HwConfig::from_vec(c.as_vec()), c);
    }

    fn orin() -> ConfigSpace {
        DeviceKind::OrinNano.space()
    }

    fn nx_orin() -> NormSpace {
        NormSpace::new(vec![nx(), orin()])
    }

    #[test]
    fn encode_decode_round_trips_exactly_on_grid() {
        prop::check("norm round-trip", 200, |g| {
            let s = if g.rng.chance(0.5) { nx() } else { orin() };
            let mut rng = g.rng.fork(7);
            let cfg = s.random(&mut rng);
            let nc = s.encode(&cfg);
            prop::assert_true(
                nc.0.iter().all(|f| (0.0..=1.0).contains(f)),
                "fractions in the unit interval",
            )?;
            prop::assert_eq_dbg(&s.decode(&nc), &cfg)
        });
    }

    #[test]
    fn decode_always_lands_on_grid_for_arbitrary_fractions() {
        prop::check("decode on grid", 200, |g| {
            let s = if g.rng.chance(0.5) { nx() } else { orin() };
            let raw = [
                g.rng.range_f64(-0.5, 1.5),
                g.rng.range_f64(-0.5, 1.5),
                g.rng.range_f64(-0.5, 1.5),
                g.rng.range_f64(-0.5, 1.5),
                g.rng.range_f64(-0.5, 1.5),
                g.rng.range_f64(-0.5, 1.5),
                g.rng.range_f64(-0.5, 1.5),
            ];
            let cfg = s.decode(&NormConfig(raw));
            prop::assert_true(s.contains(&cfg), "decoded config on the native grid")?;
            // Decoding is idempotent through encode: the fraction of an
            // on-grid config decodes back to itself.
            prop::assert_eq_dbg(&s.decode(&s.encode(&cfg)), &cfg)
        });
    }

    #[test]
    fn decode_tie_breaks_to_the_lower_rank() {
        // NX memory grid [1500, 1690, 1866]: fraction 0.25 puts the
        // rank target at exactly 0.5 — halfway between ranks 0 and 1 —
        // and must take the lower one, matching snap's value rule.
        let s = nx();
        let mut nc = s.encode(&s.midpoint());
        nc.0[Dim::MemFreq.index()] = 0.25;
        assert_eq!(s.decode(&nc).mem_freq_mhz, 1500);
        nc.0[Dim::MemFreq.index()] = 0.75; // rank target 1.5: ties down to 1
        assert_eq!(s.decode(&nc).mem_freq_mhz, 1690);
        // Non-finite fractions collapse to the dimension minimum.
        nc.0[Dim::MemFreq.index()] = f64::NAN;
        assert_eq!(s.decode(&nc).mem_freq_mhz, 1500);
        nc.0[Dim::MemFreq.index()] = f64::INFINITY;
        assert_eq!(s.decode(&nc).mem_freq_mhz, 1500);
    }

    #[test]
    fn norm_grid_spans_all_member_ranks() {
        let ns = nx_orin();
        let g = ns.grid();
        assert!(g.is_normalized());
        assert!(!nx().is_normalized());
        for &d in &Dim::ALL {
            assert_eq!(g.min(d), 0, "{d:?}");
            if d == Dim::BatchCap || d == Dim::Variant {
                // Both members keep the singleton batch and variant
                // axes, whose only rank fraction is 0.
                assert_eq!(g.values(d), &[0], "{d:?}");
            } else {
                assert_eq!(g.max(d), NormSpace::RESOLUTION, "{d:?}");
            }
        }
        // Equal-length dims coincide (8 CPU clocks on both boards);
        // unequal ones union (6 NX + 4 Orin GPU clocks → 8 distinct
        // permille ranks; 3 + 5 concurrency levels → 5).
        assert_eq!(g.values(Dim::CpuFreq).len(), 8);
        assert_eq!(g.values(Dim::GpuFreq).len(), 8);
        assert_eq!(g.values(Dim::Concurrency).len(), 5);
        assert_eq!(g.values(Dim::MemFreq), &[0, 500, 1000]);
        assert_eq!(ns.len(), 2);
        assert!(!ns.is_empty());
    }

    #[test]
    fn decode_for_any_grid_point_is_on_every_member_grid() {
        prop::check("norm decode_for", 120, |g| {
            let ns = nx_orin();
            let mut rng = g.rng.fork(3);
            let p = ns.grid().random(&mut rng);
            for i in 0..ns.len() {
                let native = ns.decode_for(i, &p);
                prop::assert_true(ns.members()[i].contains(&native), "on member grid")?;
                // Round-trip through the member: re-encoding the native
                // config lands on a grid point that decodes identically.
                let back = ns.encode_from(i, &native);
                prop::assert_true(ns.grid().contains(&back), "encode_from on grid")?;
                prop::assert_eq_dbg(&ns.decode_for(i, &back), &native)?;
            }
            Ok(())
        });
    }

    #[test]
    fn member_grid_points_are_exactly_representable() {
        let ns = nx_orin();
        for (i, m) in ns.members().iter().enumerate() {
            for cfg in m.enumerate().iter().step_by(53) {
                let p = ns.encode_from(i, cfg);
                assert!(ns.grid().contains(&p));
                assert_eq!(ns.decode_for(i, &p), *cfg, "member {i}: {cfg}");
            }
        }
    }

    #[test]
    fn normalized_presets_and_describe() {
        let ns = nx_orin();
        let g = ns.grid();
        let d = g.preset_default();
        assert!(g.contains(&d));
        assert_eq!(d.concurrency, 0, "framework default: minimum rank");
        let m = g.preset_max_power();
        assert!(g.contains(&m));
        assert_eq!(m.gpu_freq_mhz, NormSpace::RESOLUTION);
        assert_eq!(m.concurrency, 0);
        let txt = g.describe(&m);
        assert!(txt.starts_with("norm "), "{txt}");
        assert!(txt.contains("gpu=100%"), "{txt}");
        // Native spaces keep the device presets and a device-tagged
        // description — an NX config and an Orin config with identical
        // raw values render distinguishably.
        let s = nx();
        assert_eq!(s.preset_default(), DeviceKind::XavierNx.preset_default());
        assert_eq!(s.preset_max_power(), DeviceKind::XavierNx.preset_max_power());
        let cfg = s.midpoint();
        assert!(s.describe(&cfg).starts_with("xavier-nx "), "{}", s.describe(&cfg));
        assert_ne!(s.describe(&cfg), orin().describe(&cfg));
    }

    #[test]
    fn max_config_is_the_per_dim_maximum_not_snap_of_ones() {
        for d in DeviceKind::ALL {
            let s = d.space();
            let m = s.max_config();
            assert!(s.contains(&m), "{d:?}");
            for &dim in &Dim::ALL {
                assert_eq!(m.get(dim), s.max(dim), "{d:?} {dim:?}");
            }
        }
        // On the normalized permille grid, raw 1.0 is a *value* and
        // snaps to each dimension's minimum — the opposite corner.
        let ns = nx_orin();
        let g = ns.grid();
        let ones = g.snap_config([1.0; HwConfig::NDIMS]);
        for &dim in &Dim::ALL {
            assert_eq!(ones.get(dim), g.min(dim), "{dim:?}");
        }
        assert_ne!(ones, g.max_config());
        // All-max decodes to every member's own native maxima.
        let p = g.max_config();
        for (i, m) in ns.members().iter().enumerate() {
            assert_eq!(ns.decode_for(i, &p), m.max_config(), "member {i}");
        }
    }

    #[test]
    fn default_batch_axis_is_the_legacy_singleton() {
        for d in DeviceKind::ALL {
            let s = d.space();
            assert_eq!(s.values(Dim::BatchCap), &[1], "{d:?}");
            assert_eq!(s.midpoint().max_batch, 1);
            assert_eq!(s.preset_default().max_batch, 1);
            assert_eq!(s.preset_max_power().max_batch, 1);
        }
    }

    #[test]
    fn with_batch_caps_opens_a_real_sixth_dimension() {
        let s = nx().with_batch_caps(vec![1, 2, 4, 8]);
        assert_eq!(s.raw_size(), nx().raw_size() * 4);
        assert_eq!(s.values(Dim::BatchCap), &[1, 2, 4, 8]);
        assert_eq!(s.snap(Dim::BatchCap, 3.0), 2, "halfway ties to the lower cap");
        assert_eq!(s.snap(Dim::BatchCap, 100.0), 8);
        assert_eq!(s.midpoint().max_batch, 4);
        // Presets stay at the axis minimum: frameworks never touch
        // application knobs (same rule as concurrency).
        assert_eq!(s.preset_default().max_batch, 1);
        assert_eq!(s.preset_max_power().max_batch, 1);
        // Enumeration covers every batch cap and index_of still matches.
        let all = s.enumerate();
        assert_eq!(all.len(), s.raw_size());
        for (i, cfg) in all.iter().enumerate().step_by(131) {
            assert_eq!(s.index_of(cfg), Some(i));
        }
        let mut rng = Rng::new(3);
        let drawn: std::collections::BTreeSet<u32> =
            (0..200).map(|_| s.random(&mut rng).max_batch).collect();
        assert_eq!(drawn.into_iter().collect::<Vec<_>>(), vec![1, 2, 4, 8]);
    }

    #[test]
    fn singleton_batch_axis_preserves_the_random_draw_stream() {
        // The whole byte-identity story for legacy scenarios: a
        // singleton batch axis must consume no randomness, so the
        // same-seed draw sequence matches the historical 5-dim grid's.
        let s = nx();
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        for _ in 0..50 {
            let cfg = s.random(&mut a);
            assert_eq!(cfg.max_batch, 1);
            // Replay the historical five draws by hand on the twin rng.
            let mut v = [0.0f64; HwConfig::NDIMS];
            for (i, &d) in Dim::ALL.iter().enumerate() {
                let vals = s.values(d);
                v[i] = if vals.len() == 1 {
                    vals[0] as f64
                } else {
                    vals[b.below(vals.len())] as f64
                };
            }
            assert_eq!(cfg, HwConfig::from_vec(v));
        }
    }

    #[test]
    fn normalized_grid_over_batched_members_spans_the_axis() {
        let ns = NormSpace::new(vec![
            nx().with_batch_caps(vec![1, 2, 4, 8]),
            orin().with_batch_caps(vec![1, 4]),
        ]);
        let g = ns.grid();
        assert_eq!(g.min(Dim::BatchCap), 0);
        assert_eq!(g.max(Dim::BatchCap), NormSpace::RESOLUTION);
        let mut p = g.midpoint();
        p.max_batch = NormSpace::RESOLUTION;
        assert_eq!(ns.decode_for(0, &p).max_batch, 8);
        assert_eq!(ns.decode_for(1, &p).max_batch, 4);
        p.max_batch = 0;
        assert_eq!(ns.decode_for(0, &p).max_batch, 1);
        assert_eq!(ns.decode_for(1, &p).max_batch, 1);
    }

    #[test]
    #[should_panic(expected = "batch axis")]
    fn unsorted_batch_caps_panic() {
        let _ = nx().with_batch_caps(vec![4, 2]);
    }

    #[test]
    fn default_variant_axis_is_the_legacy_singleton() {
        for d in DeviceKind::ALL {
            let s = d.space();
            assert_eq!(s.values(Dim::Variant), &[0], "{d:?}");
            assert_eq!(s.midpoint().variant, 0);
            assert_eq!(s.preset_default().variant, 0);
            assert_eq!(s.preset_max_power().variant, 0);
            assert_eq!(s.max_config().variant, 0);
        }
    }

    #[test]
    fn with_variant_axis_opens_a_real_seventh_dimension() {
        let s = nx().with_variant_axis(4);
        assert_eq!(s.raw_size(), nx().raw_size() * 4);
        assert_eq!(s.values(Dim::Variant), &[0, 1, 2, 3]);
        assert_eq!(s.snap(Dim::Variant, 0.5), 0, "halfway ties to the lower index");
        assert_eq!(s.snap(Dim::Variant, 100.0), 3);
        assert_eq!(s.midpoint().variant, 2);
        // Presets serve the full-accuracy baseline: the variant is an
        // application knob, like max_batch and concurrency.
        assert_eq!(s.preset_default().variant, 0);
        assert_eq!(s.preset_max_power().variant, 0);
        // Enumeration covers every variant and index_of still matches.
        let all = s.enumerate();
        assert_eq!(all.len(), s.raw_size());
        for (i, cfg) in all.iter().enumerate().step_by(233) {
            assert_eq!(s.index_of(cfg), Some(i));
        }
        let mut rng = Rng::new(9);
        let drawn: std::collections::BTreeSet<u32> =
            (0..200).map(|_| s.random(&mut rng).variant).collect();
        assert_eq!(drawn.into_iter().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn singleton_variant_axis_preserves_the_random_draw_stream() {
        // Same byte-identity argument as the batch axis: a singleton
        // variant axis consumes no randomness, so every same-seed draw
        // matches a batched-but-unvarianted grid's exactly.
        let s = nx().with_batch_caps(vec![1, 2, 4]);
        let mut a = Rng::new(41);
        let mut b = Rng::new(41);
        for _ in 0..50 {
            let cfg = s.random(&mut a);
            assert_eq!(cfg.variant, 0);
            let mut v = [0.0f64; HwConfig::NDIMS];
            for (i, &d) in Dim::ALL.iter().enumerate() {
                let vals = s.values(d);
                v[i] = if vals.len() == 1 {
                    vals[0] as f64
                } else {
                    vals[b.below(vals.len())] as f64
                };
            }
            assert_eq!(cfg, HwConfig::from_vec(v));
        }
    }

    #[test]
    fn variant_encode_decode_round_trips_exactly_on_grid() {
        // The satellite round-trip property over manifest-sized variant
        // axes: any validated manifest length opens an axis whose grid
        // points encode/decode exactly.
        prop::check("variant norm round-trip", 150, |g| {
            let n = 1 + g.rng.below(6);
            let s = if g.rng.chance(0.5) { nx() } else { orin() }.with_variant_axis(n);
            let mut rng = g.rng.fork(11);
            let cfg = s.random(&mut rng);
            let nc = s.encode(&cfg);
            prop::assert_true(
                nc.0.iter().all(|f| (0.0..=1.0).contains(f)),
                "fractions in the unit interval",
            )?;
            prop::assert_eq_dbg(&s.decode(&nc), &cfg)
        });
    }

    #[test]
    fn variant_decode_tie_breaks_to_the_lower_rank() {
        // A 3-variant axis [0, 1, 2]: fraction 0.25 puts the rank
        // target at exactly 0.5 — halfway between ranks 0 and 1 — and
        // must take the lower (more accurate) variant, matching snap's
        // value rule.
        let s = nx().with_variant_axis(3);
        let mut nc = s.encode(&s.preset_default());
        nc.0[Dim::Variant.index()] = 0.25;
        assert_eq!(s.decode(&nc).variant, 0);
        nc.0[Dim::Variant.index()] = 0.75; // rank target 1.5: ties down to 1
        assert_eq!(s.decode(&nc).variant, 1);
        // Non-finite fractions collapse to the full-accuracy baseline.
        nc.0[Dim::Variant.index()] = f64::NAN;
        assert_eq!(s.decode(&nc).variant, 0);
        nc.0[Dim::Variant.index()] = f64::INFINITY;
        assert_eq!(s.decode(&nc).variant, 0);
    }

    #[test]
    fn normalized_grid_over_variant_members_spans_the_axis() {
        let ns = NormSpace::new(vec![
            nx().with_variant_axis(4),
            orin().with_variant_axis(2),
        ]);
        let g = ns.grid();
        assert_eq!(g.min(Dim::Variant), 0);
        assert_eq!(g.max(Dim::Variant), NormSpace::RESOLUTION);
        let mut p = g.midpoint();
        p.variant = NormSpace::RESOLUTION;
        assert_eq!(ns.decode_for(0, &p).variant, 3);
        assert_eq!(ns.decode_for(1, &p).variant, 1);
        p.variant = 0;
        assert_eq!(ns.decode_for(0, &p).variant, 0);
        assert_eq!(ns.decode_for(1, &p).variant, 0);
    }

    #[test]
    #[should_panic(expected = "variant axis")]
    fn empty_variant_axis_panics() {
        let _ = nx().with_variant_axis(0);
    }
}
