//! DVFS + concurrency configuration space (paper Eq. 5).
//!
//! A configuration is the 5-tuple `s = (s_cpu, c_cpu, s_gpu, s_mem, c)`.
//! The space is a discrete grid per device (paper Table 2 ranges with
//! ~100 MHz steps, §IV-A); this module provides enumeration, clamping/
//! rounding onto the grid (Algorithm 2's `MINMAX(ROUND(v), r)`), indexing
//! and neighbourhood moves.

use super::specs::DeviceKind;

/// One hardware configuration (paper Eq. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct HwConfig {
    /// CPU frequency, MHz.
    pub cpu_freq_mhz: u32,
    /// Active CPU cores.
    pub cpu_cores: u32,
    /// GPU frequency, MHz.
    pub gpu_freq_mhz: u32,
    /// Memory (EMC) frequency, MHz.
    pub mem_freq_mhz: u32,
    /// Concurrency level: number of inference instances.
    pub concurrency: u32,
}

/// Configuration dimensions, in the canonical order used everywhere
/// (sliding-window columns, correlation weights, search steps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dim {
    CpuFreq,
    CpuCores,
    GpuFreq,
    MemFreq,
    Concurrency,
}

impl Dim {
    pub const ALL: [Dim; 5] =
        [Dim::CpuFreq, Dim::CpuCores, Dim::GpuFreq, Dim::MemFreq, Dim::Concurrency];

    pub fn name(self) -> &'static str {
        match self {
            Dim::CpuFreq => "cpu_freq_mhz",
            Dim::CpuCores => "cpu_cores",
            Dim::GpuFreq => "gpu_freq_mhz",
            Dim::MemFreq => "mem_freq_mhz",
            Dim::Concurrency => "concurrency",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Dim::CpuFreq => 0,
            Dim::CpuCores => 1,
            Dim::GpuFreq => 2,
            Dim::MemFreq => 3,
            Dim::Concurrency => 4,
        }
    }
}

impl HwConfig {
    /// Number of tunable dimensions.
    pub const NDIMS: usize = 5;

    /// Configuration as an f64 vector in [`Dim::ALL`] order.
    pub fn as_vec(&self) -> [f64; Self::NDIMS] {
        [
            self.cpu_freq_mhz as f64,
            self.cpu_cores as f64,
            self.gpu_freq_mhz as f64,
            self.mem_freq_mhz as f64,
            self.concurrency as f64,
        ]
    }

    /// Build from an f64 vector (values must already be on-grid).
    pub fn from_vec(v: [f64; Self::NDIMS]) -> HwConfig {
        HwConfig {
            cpu_freq_mhz: v[0] as u32,
            cpu_cores: v[1] as u32,
            gpu_freq_mhz: v[2] as u32,
            mem_freq_mhz: v[3] as u32,
            concurrency: v[4] as u32,
        }
    }

    /// Value along one dimension.
    pub fn get(&self, dim: Dim) -> u32 {
        match dim {
            Dim::CpuFreq => self.cpu_freq_mhz,
            Dim::CpuCores => self.cpu_cores,
            Dim::GpuFreq => self.gpu_freq_mhz,
            Dim::MemFreq => self.mem_freq_mhz,
            Dim::Concurrency => self.concurrency,
        }
    }

    /// Copy with one dimension replaced.
    pub fn with(&self, dim: Dim, value: u32) -> HwConfig {
        let mut c = *self;
        match dim {
            Dim::CpuFreq => c.cpu_freq_mhz = value,
            Dim::CpuCores => c.cpu_cores = value,
            Dim::GpuFreq => c.gpu_freq_mhz = value,
            Dim::MemFreq => c.mem_freq_mhz = value,
            Dim::Concurrency => c.concurrency = value,
        }
        c
    }

    /// Stable hash-input encoding.
    pub fn key(&self) -> [u64; 5] {
        [
            self.cpu_freq_mhz as u64,
            self.cpu_cores as u64,
            self.gpu_freq_mhz as u64,
            self.mem_freq_mhz as u64,
            self.concurrency as u64,
        ]
    }
}

impl std::fmt::Display for HwConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cpu={}MHzx{} gpu={}MHz mem={}MHz conc={}",
            self.cpu_freq_mhz, self.cpu_cores, self.gpu_freq_mhz, self.mem_freq_mhz,
            self.concurrency
        )
    }
}

/// The discrete configuration grid of one device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigSpace {
    device: DeviceKind,
    dims: [Vec<u32>; HwConfig::NDIMS],
}

impl ConfigSpace {
    pub fn new(
        device: DeviceKind,
        cpu_freqs: Vec<u32>,
        cpu_cores: Vec<u32>,
        gpu_freqs: Vec<u32>,
        mem_freqs: Vec<u32>,
        concurrency: Vec<u32>,
    ) -> ConfigSpace {
        let dims = [cpu_freqs, cpu_cores, gpu_freqs, mem_freqs, concurrency];
        for (i, d) in dims.iter().enumerate() {
            assert!(!d.is_empty(), "dimension {i} empty");
            assert!(d.windows(2).all(|w| w[0] < w[1]), "dimension {i} not sorted/unique");
        }
        ConfigSpace { device, dims }
    }

    pub fn device(&self) -> DeviceKind {
        self.device
    }

    /// Allowed values along one dimension (sorted ascending).
    pub fn values(&self, dim: Dim) -> &[u32] {
        &self.dims[dim.index()]
    }

    pub fn min(&self, dim: Dim) -> u32 {
        *self.values(dim).first().unwrap()
    }

    pub fn max(&self, dim: Dim) -> u32 {
        *self.values(dim).last().unwrap()
    }

    /// Total grid size (before failure exclusion — paper's "raw" count).
    pub fn raw_size(&self) -> usize {
        self.dims.iter().map(|d| d.len()).product()
    }

    /// Is `cfg` exactly on the grid?
    pub fn contains(&self, cfg: &HwConfig) -> bool {
        Dim::ALL
            .iter()
            .all(|&d| self.values(d).binary_search(&cfg.get(d)).is_ok())
    }

    /// Snap a continuous value onto the grid: nearest allowed value
    /// (Algorithm 2's `MINMAX(ROUND(v), r)` — clamp + round in one).
    pub fn snap(&self, dim: Dim, v: f64) -> u32 {
        let vals = self.values(dim);
        let mut best = vals[0];
        let mut best_d = f64::INFINITY;
        for &x in vals {
            let d = (x as f64 - v).abs();
            if d < best_d {
                best_d = d;
                best = x;
            }
        }
        best
    }

    /// Snap a full vector onto the grid.
    pub fn snap_config(&self, v: [f64; HwConfig::NDIMS]) -> HwConfig {
        let mut out = [0u32; HwConfig::NDIMS];
        for (i, &d) in Dim::ALL.iter().enumerate() {
            out[i] = self.snap(d, v[i]);
        }
        HwConfig {
            cpu_freq_mhz: out[0],
            cpu_cores: out[1],
            gpu_freq_mhz: out[2],
            mem_freq_mhz: out[3],
            concurrency: out[4],
        }
    }

    /// Enumerate the full grid in lexicographic order.
    pub fn enumerate(&self) -> Vec<HwConfig> {
        let mut out = Vec::with_capacity(self.raw_size());
        for &cf in &self.dims[0] {
            for &cc in &self.dims[1] {
                for &gf in &self.dims[2] {
                    for &mf in &self.dims[3] {
                        for &c in &self.dims[4] {
                            out.push(HwConfig {
                                cpu_freq_mhz: cf,
                                cpu_cores: cc,
                                gpu_freq_mhz: gf,
                                mem_freq_mhz: mf,
                                concurrency: c,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Lexicographic index of an on-grid configuration.
    pub fn index_of(&self, cfg: &HwConfig) -> Option<usize> {
        let mut idx = 0usize;
        for &d in &Dim::ALL {
            let vals = self.values(d);
            let pos = vals.binary_search(&cfg.get(d)).ok()?;
            idx = idx * vals.len() + pos;
        }
        Some(idx)
    }

    /// The "middle" configuration — a neutral starting point for online
    /// search when no preset is given.
    pub fn midpoint(&self) -> HwConfig {
        let mid = |d: Dim| {
            let v = self.values(d);
            v[v.len() / 2]
        };
        HwConfig {
            cpu_freq_mhz: mid(Dim::CpuFreq),
            cpu_cores: mid(Dim::CpuCores),
            gpu_freq_mhz: mid(Dim::GpuFreq),
            mem_freq_mhz: mid(Dim::MemFreq),
            concurrency: mid(Dim::Concurrency),
        }
    }

    /// Uniform random on-grid configuration.
    pub fn random(&self, rng: &mut crate::util::Rng) -> HwConfig {
        let pick = |d: Dim, rng: &mut crate::util::Rng| {
            let v = self.values(d);
            v[rng.below(v.len())]
        };
        HwConfig {
            cpu_freq_mhz: pick(Dim::CpuFreq, rng),
            cpu_cores: pick(Dim::CpuCores, rng),
            gpu_freq_mhz: pick(Dim::GpuFreq, rng),
            mem_freq_mhz: pick(Dim::MemFreq, rng),
            concurrency: pick(Dim::Concurrency, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn nx() -> ConfigSpace {
        DeviceKind::XavierNx.space()
    }

    #[test]
    fn enumerate_matches_raw_size_and_is_unique() {
        let s = nx();
        let all = s.enumerate();
        assert_eq!(all.len(), s.raw_size());
        let mut sorted = all.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), all.len());
        assert!(all.iter().all(|c| s.contains(c)));
    }

    #[test]
    fn index_of_is_enumeration_order() {
        let s = DeviceKind::OrinNano.space();
        for (i, cfg) in s.enumerate().iter().enumerate().step_by(97) {
            assert_eq!(s.index_of(cfg), Some(i));
        }
    }

    #[test]
    fn index_of_off_grid_is_none() {
        let s = nx();
        let mut c = s.midpoint();
        c.cpu_freq_mhz = 1234;
        assert_eq!(s.index_of(&c), None);
        assert!(!s.contains(&c));
    }

    #[test]
    fn snap_picks_nearest() {
        let s = nx();
        assert_eq!(s.snap(Dim::CpuFreq, 1200.0), 1190);
        assert_eq!(s.snap(Dim::CpuFreq, 1345.0), 1390);
        assert_eq!(s.snap(Dim::CpuFreq, -1e9), 1190);
        assert_eq!(s.snap(Dim::CpuFreq, 1e9), 1908);
        assert_eq!(s.snap(Dim::Concurrency, 2.4), 2);
    }

    #[test]
    fn snap_is_idempotent_and_in_range() {
        prop::check("snap idempotent", 200, |g| {
            let s = if g.rng.chance(0.5) {
                DeviceKind::XavierNx.space()
            } else {
                DeviceKind::OrinNano.space()
            };
            let v = [
                g.rng.range_f64(-100.0, 4000.0),
                g.rng.range_f64(-2.0, 10.0),
                g.rng.range_f64(-100.0, 2000.0),
                g.rng.range_f64(0.0, 5000.0),
                g.rng.range_f64(-1.0, 9.0),
            ];
            let cfg = s.snap_config(v);
            prop::assert_true(s.contains(&cfg), "snapped config on grid")?;
            let again = s.snap_config(cfg.as_vec());
            prop::assert_eq_dbg(&again, &cfg)
        });
    }

    #[test]
    fn random_configs_are_on_grid() {
        let s = DeviceKind::OrinNano.space();
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            assert!(s.contains(&s.random(&mut rng)));
        }
    }

    #[test]
    fn midpoint_on_grid() {
        for d in DeviceKind::ALL {
            let s = d.space();
            assert!(s.contains(&s.midpoint()));
        }
    }

    #[test]
    fn with_and_get_round_trip() {
        let c = nx().midpoint();
        for &d in &Dim::ALL {
            let c2 = c.with(d, c.get(d));
            assert_eq!(c, c2);
        }
        let c3 = c.with(Dim::GpuFreq, 510);
        assert_eq!(c3.gpu_freq_mhz, 510);
    }

    #[test]
    fn as_vec_from_vec_round_trip() {
        let c = nx().midpoint();
        assert_eq!(HwConfig::from_vec(c.as_vec()), c);
    }
}
