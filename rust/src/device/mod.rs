//! Jetson device simulator.
//!
//! Substitutes the paper's physical Xavier NX / Orin Nano boards
//! (DESIGN.md §2): a 5-dimensional DVFS + concurrency configuration space
//! with the paper's exact tunable ranges (Table 2) — extensible with a
//! batch-cap axis (`ConfigSpace::with_batch_caps`) and a model-variant
//! axis (`ConfigSpace::with_variant_axis`) — analytic latency and
//! power models reproducing the paper's response-surface structure
//! (non-linear, interacting, with the Fig. 1 iso-throughput/iso-power
//! spreads), a config-failure model reproducing Table 4's valid-config
//! counts, and an optional thermal-throttle extension.

pub mod dvfs;
pub mod failure;
pub mod perf;
pub mod power;
pub mod sim;
pub mod specs;
pub mod thermal;

pub use dvfs::{ConfigSpace, Dim, HwConfig, NormConfig, NormSpace};
pub use sim::{Device, Measured};
pub use specs::DeviceKind;
