//! Model-variant manifests: one logical model, many runnable variants.
//!
//! PB-AI serves a detector as an ordered family of runnable variants
//! (depth fraction, numeric precision, input resolution) behind a
//! `min_runnable_depth` validity floor; Jayakodi et al. (arXiv
//! 1901.10584) show the accuracy–energy trade-off those variants open
//! is itself worth co-optimizing. This module is that manifest:
//! [`VariantManifest`] is a validated, ordered list of
//! [`ModelVariant`]s — entry 0 is the full-accuracy baseline, later
//! entries are strictly cheaper (higher throughput multiplier, no more
//! power, no more memory) and never more accurate.
//!
//! The optimizer sees a manifest as one discrete axis:
//! [`crate::device::Dim::Variant`] indexes into the list, and the
//! device simulator applies the entry's multipliers to its
//! throughput/power/OOM surfaces (`device::{perf,power,failure}`).
//! The default manifest is the singleton [`VariantManifest::full`],
//! under which every surface is byte-identical to the pre-variant
//! model — exactly how `Dim::BatchCap` kept the 5-dim history intact.

use std::fmt;

use super::{CostProfile, ModelKind};

/// Numeric precision a variant's engine is built at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Half-precision floats — the baseline TensorRT build.
    Fp16,
    /// Post-training-quantized 8-bit integers.
    Int8,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
        }
    }

    /// Stable small id (hash inputs).
    pub fn id(self) -> u64 {
        match self {
            Precision::Fp16 => 0,
            Precision::Int8 => 1,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One runnable variant of a logical model.
///
/// The three multipliers act on the baseline surface: `perf_mult`
/// scales throughput up (all per-frame work shrinks by that factor),
/// `power_mult` scales the GPU dynamic rail down (int8 maths costs
/// less energy per op), `mem_mult` scales the resident footprint down
/// (smaller weights and activations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelVariant {
    /// Fraction of the full network depth kept, (0, 1].
    pub depth_frac: f64,
    /// Engine precision.
    pub precision: Precision,
    /// Square input resolution (pixels per side).
    pub input_res: u32,
    /// Modeled COCO mAP@0.5:0.95 of this variant.
    pub accuracy: f64,
    /// Throughput multiplier ≥ 1 (strictly increasing along a manifest).
    pub perf_mult: f64,
    /// GPU dynamic-power multiplier in (0, 1] (non-increasing).
    pub power_mult: f64,
    /// Memory-footprint multiplier in (0, 1] (non-increasing).
    pub mem_mult: f64,
}

impl ModelVariant {
    /// The full-accuracy baseline: unmodified depth/resolution, all
    /// multipliers exactly 1 — the surface it produces is the
    /// pre-variant model, bit for bit.
    pub fn identity(model: ModelKind) -> ModelVariant {
        ModelVariant {
            depth_frac: 1.0,
            precision: Precision::Fp16,
            input_res: 640,
            accuracy: model.map(),
            perf_mult: 1.0,
            power_mult: 1.0,
            mem_mult: 1.0,
        }
    }

    /// Whether every multiplier is exactly 1 (the structural-skip guard:
    /// identity variants must not touch the legacy surface at all).
    pub fn is_identity(&self) -> bool {
        self.perf_mult == 1.0 && self.power_mult == 1.0 && self.mem_mult == 1.0
    }

    /// The baseline cost profile with this variant's multipliers
    /// applied. Identity variants return the profile untouched.
    pub fn scaled_profile(&self, model: ModelKind) -> CostProfile {
        let p = model.profile();
        if self.is_identity() {
            return p;
        }
        CostProfile {
            gpu_work: p.gpu_work / self.perf_mult,
            cpu_work: p.cpu_work / self.perf_mult,
            mem_work: p.mem_work / self.perf_mult,
            mem_gb_per_instance: p.mem_gb_per_instance * self.mem_mult,
            mem_gb_base: p.mem_gb_base * self.mem_mult,
        }
    }

    /// Short human-readable label (`fp16-640`, `int8-416-d0.75`).
    pub fn label(&self) -> String {
        if self.depth_frac < 1.0 {
            format!("{}-{}-d{:.2}", self.precision, self.input_res, self.depth_frac)
        } else {
            format!("{}-{}", self.precision, self.input_res)
        }
    }

    /// Content words for cache identity (bit-exact field encoding).
    fn words(&self) -> [u64; 7] {
        [
            self.depth_frac.to_bits(),
            self.precision.id(),
            self.input_res as u64,
            self.accuracy.to_bits(),
            self.perf_mult.to_bits(),
            self.power_mult.to_bits(),
            self.mem_mult.to_bits(),
        ]
    }
}

/// Why a manifest was rejected — each case names the violated invariant
/// (and the first offending entry), so property tests can assert the
/// *specific* failure rather than a blanket error.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ManifestError {
    /// No variants at all.
    Empty,
    /// Entry 0 must be the full-accuracy baseline (all multipliers 1,
    /// full depth).
    BaselineNotIdentity,
    /// A field of entry `index` is out of its domain.
    BadValue { index: usize, field: &'static str },
    /// Entry `index` keeps less depth than the `min_runnable` floor.
    BelowDepthFloor { index: usize },
    /// Entry `index` is not strictly cheaper than its predecessor
    /// (perf_mult must strictly increase; power/memory multipliers must
    /// not increase).
    CostNotDecreasing { index: usize },
    /// Entry `index` claims more accuracy than its (cheaper) predecessor.
    AccuracyIncreased { index: usize },
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Empty => write!(f, "manifest has no variants"),
            ManifestError::BaselineNotIdentity => {
                write!(f, "variant 0 must be the identity baseline")
            }
            ManifestError::BadValue { index, field } => {
                write!(f, "variant {index}: field '{field}' out of domain")
            }
            ManifestError::BelowDepthFloor { index } => {
                write!(f, "variant {index}: depth below the min_runnable floor")
            }
            ManifestError::CostNotDecreasing { index } => {
                write!(f, "variant {index}: not strictly cheaper than its predecessor")
            }
            ManifestError::AccuracyIncreased { index } => {
                write!(f, "variant {index}: accuracy above its cheaper predecessor")
            }
        }
    }
}

impl std::error::Error for ManifestError {}

/// A validated, ordered family of runnable variants of one model.
///
/// Invariants (checked by [`VariantManifest::new`], in this order so
/// rejection is deterministic):
/// 1. non-empty;
/// 2. every entry's fields are in domain (depth ∈ (0, 1], resolution ∈
///    [64, 2048], accuracy ∈ (0, 100], perf_mult ≥ 1, power/mem
///    multipliers ∈ (0, 1], all finite);
/// 3. every entry keeps at least `min_runnable` depth (the PB-AI
///    validity floor);
/// 4. entry 0 is the identity baseline;
/// 5. cost strictly decreases along the list (perf_mult strictly
///    increases, power_mult and mem_mult never increase);
/// 6. accuracy never increases along the list.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantManifest {
    model: ModelKind,
    variants: Vec<ModelVariant>,
    min_runnable_depth: f64,
}

impl VariantManifest {
    /// Default PB-AI-style validity floor: variants keeping less than
    /// half the network are rejected as unrunnable.
    pub const DEFAULT_MIN_RUNNABLE_DEPTH: f64 = 0.5;

    /// Validate and build a manifest. `min_runnable_depth` is the floor
    /// below which entries are rejected (must itself lie in (0, 1]).
    pub fn new(
        model: ModelKind,
        variants: Vec<ModelVariant>,
        min_runnable_depth: f64,
    ) -> Result<VariantManifest, ManifestError> {
        assert!(
            min_runnable_depth > 0.0 && min_runnable_depth <= 1.0,
            "min_runnable_depth must be in (0, 1]: {min_runnable_depth}"
        );
        if variants.is_empty() {
            return Err(ManifestError::Empty);
        }
        for (i, v) in variants.iter().enumerate() {
            let bad = |field| ManifestError::BadValue { index: i, field };
            if !(v.depth_frac.is_finite() && v.depth_frac > 0.0 && v.depth_frac <= 1.0) {
                return Err(bad("depth_frac"));
            }
            if !(64..=2048).contains(&v.input_res) {
                return Err(bad("input_res"));
            }
            if !(v.accuracy.is_finite() && v.accuracy > 0.0 && v.accuracy <= 100.0) {
                return Err(bad("accuracy"));
            }
            if !(v.perf_mult.is_finite() && v.perf_mult >= 1.0) {
                return Err(bad("perf_mult"));
            }
            if !(v.power_mult.is_finite() && v.power_mult > 0.0 && v.power_mult <= 1.0) {
                return Err(bad("power_mult"));
            }
            if !(v.mem_mult.is_finite() && v.mem_mult > 0.0 && v.mem_mult <= 1.0) {
                return Err(bad("mem_mult"));
            }
            if v.depth_frac < min_runnable_depth {
                return Err(ManifestError::BelowDepthFloor { index: i });
            }
        }
        if !(variants[0].is_identity() && variants[0].depth_frac == 1.0) {
            return Err(ManifestError::BaselineNotIdentity);
        }
        for i in 1..variants.len() {
            let (prev, cur) = (&variants[i - 1], &variants[i]);
            if cur.perf_mult <= prev.perf_mult
                || cur.power_mult > prev.power_mult
                || cur.mem_mult > prev.mem_mult
            {
                return Err(ManifestError::CostNotDecreasing { index: i });
            }
            if cur.accuracy > prev.accuracy {
                return Err(ManifestError::AccuracyIncreased { index: i });
            }
        }
        Ok(VariantManifest { model, variants, min_runnable_depth })
    }

    /// The singleton identity manifest — the default on every device,
    /// under which all surfaces are byte-identical to the pre-variant
    /// model.
    pub fn full(model: ModelKind) -> VariantManifest {
        VariantManifest {
            model,
            variants: vec![ModelVariant::identity(model)],
            min_runnable_depth: Self::DEFAULT_MIN_RUNNABLE_DEPTH,
        }
    }

    /// The standard degraded family used by the accuracy scenarios:
    /// fp16 baseline, int8 at full resolution, int8 at 512 px, and a
    /// three-quarter-depth int8 at 416 px. Multipliers follow the usual
    /// TensorRT int8/resolution scaling on Jetson-class boards; mAP
    /// deltas are the typical post-training-quantization and
    /// small-input losses.
    pub fn standard(model: ModelKind) -> VariantManifest {
        let map = model.map();
        let v = |depth, precision, res, acc, perf, power, mem| ModelVariant {
            depth_frac: depth,
            precision,
            input_res: res,
            accuracy: acc,
            perf_mult: perf,
            power_mult: power,
            mem_mult: mem,
        };
        VariantManifest::new(
            model,
            vec![
                ModelVariant::identity(model),
                v(1.0, Precision::Int8, 640, map - 1.2, 1.55, 0.90, 0.72),
                v(1.0, Precision::Int8, 512, map - 3.0, 2.15, 0.86, 0.64),
                v(0.75, Precision::Int8, 416, map - 5.8, 2.90, 0.82, 0.50),
            ],
            Self::DEFAULT_MIN_RUNNABLE_DEPTH,
        )
        .expect("the standard family satisfies its own invariants")
    }

    pub fn model(&self) -> ModelKind {
        self.model
    }

    pub fn variants(&self) -> &[ModelVariant] {
        &self.variants
    }

    pub fn len(&self) -> usize {
        self.variants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.variants.is_empty()
    }

    /// Whether this is the trivial single-variant manifest (the variant
    /// axis stays a legacy singleton).
    pub fn is_singleton(&self) -> bool {
        self.variants.len() == 1
    }

    /// The validity floor this manifest was validated against.
    pub fn min_runnable_depth(&self) -> f64 {
        self.min_runnable_depth
    }

    /// The variant a `Dim::Variant` grid value indexes. Panics on an
    /// out-of-range index — the config space and manifest are built
    /// together, so a miss is a wiring bug, not a runtime condition.
    pub fn get(&self, index: u32) -> &ModelVariant {
        &self.variants[index as usize]
    }

    /// Content words for cache identity: two manifests hash equal iff
    /// every field of every variant (and the model and floor) is
    /// bit-identical. Feeds `SimEnv::fingerprint`, so cached
    /// measurements never replay across different manifests.
    pub fn content_words(&self) -> Vec<u64> {
        let mut words = vec![
            self.model.id(),
            self.min_runnable_depth.to_bits(),
            self.variants.len() as u64,
        ];
        for v in &self.variants {
            words.extend_from_slice(&v.words());
        }
        words
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn standard_all() -> Vec<VariantManifest> {
        ModelKind::ALL.iter().map(|m| VariantManifest::standard(*m)).collect()
    }

    #[test]
    fn full_manifest_is_identity_singleton() {
        for m in ModelKind::ALL {
            let f = VariantManifest::full(m);
            assert!(f.is_singleton());
            assert!(f.get(0).is_identity());
            assert_eq!(f.get(0).accuracy, m.map());
            assert_eq!(f.get(0).scaled_profile(m), m.profile());
        }
    }

    #[test]
    fn standard_manifests_validate_and_degrade() {
        for man in standard_all() {
            assert_eq!(man.len(), 4);
            assert!(man.get(0).is_identity());
            for w in man.variants().windows(2) {
                assert!(w[1].perf_mult > w[0].perf_mult, "strictly cheaper");
                assert!(w[1].accuracy < w[0].accuracy, "strictly less accurate here");
                assert!(w[1].power_mult <= w[0].power_mult);
                assert!(w[1].mem_mult <= w[0].mem_mult);
            }
        }
    }

    #[test]
    fn scaled_profile_shrinks_work_and_memory() {
        let man = VariantManifest::standard(ModelKind::RetinaNet);
        let base = ModelKind::RetinaNet.profile();
        let v = man.get(3);
        let p = v.scaled_profile(ModelKind::RetinaNet);
        assert!((p.gpu_work - base.gpu_work / v.perf_mult).abs() < 1e-9);
        assert!((p.mem_gb_per_instance - base.mem_gb_per_instance * v.mem_mult).abs() < 1e-12);
        assert!(p.gpu_work < base.gpu_work && p.mem_gb_base < base.mem_gb_base);
    }

    #[test]
    fn rejections_name_the_violated_invariant() {
        let m = ModelKind::Yolo;
        let id = ModelVariant::identity(m);
        let cheap = ModelVariant {
            depth_frac: 1.0,
            precision: Precision::Int8,
            input_res: 640,
            accuracy: 25.0,
            perf_mult: 1.5,
            power_mult: 0.9,
            mem_mult: 0.7,
        };
        let floor = VariantManifest::DEFAULT_MIN_RUNNABLE_DEPTH;
        assert_eq!(VariantManifest::new(m, vec![], floor), Err(ManifestError::Empty));
        assert_eq!(
            VariantManifest::new(m, vec![cheap], floor),
            Err(ManifestError::BaselineNotIdentity)
        );
        let shallow = ModelVariant { depth_frac: 0.25, ..cheap };
        assert_eq!(
            VariantManifest::new(m, vec![id, shallow], floor),
            Err(ManifestError::BelowDepthFloor { index: 1 })
        );
        let pricier = ModelVariant { perf_mult: 1.0, ..cheap };
        assert_eq!(
            VariantManifest::new(m, vec![id, pricier], floor),
            Err(ManifestError::CostNotDecreasing { index: 1 })
        );
        let magic = ModelVariant { accuracy: 99.0, ..cheap };
        assert_eq!(
            VariantManifest::new(m, vec![id, magic], floor),
            Err(ManifestError::AccuracyIncreased { index: 1 })
        );
        let nan = ModelVariant { power_mult: f64::NAN, ..cheap };
        assert_eq!(
            VariantManifest::new(m, vec![id, nan], floor),
            Err(ManifestError::BadValue { index: 1, field: "power_mult" })
        );
        assert_eq!(
            VariantManifest::new(m, vec![id, ModelVariant { input_res: 16, ..cheap }], floor),
            Err(ManifestError::BadValue { index: 1, field: "input_res" })
        );
    }

    #[test]
    fn content_words_distinguish_any_field_change() {
        let a = VariantManifest::standard(ModelKind::Yolo);
        let b = VariantManifest::standard(ModelKind::Frcnn);
        assert_ne!(a.content_words(), b.content_words(), "different model");
        assert_ne!(
            a.content_words(),
            VariantManifest::full(ModelKind::Yolo).content_words(),
            "different variant list"
        );
        // A one-ulp nudge to one multiplier of one entry must change
        // the words — cache entries may never replay across manifests.
        let mut tweaked = a.variants().to_vec();
        tweaked[2].power_mult = f64::from_bits(tweaked[2].power_mult.to_bits() + 1);
        let t = VariantManifest::new(
            ModelKind::Yolo,
            tweaked,
            VariantManifest::DEFAULT_MIN_RUNNABLE_DEPTH,
        )
        .unwrap();
        assert_ne!(a.content_words(), t.content_words());
        assert_eq!(
            a.content_words(),
            VariantManifest::standard(ModelKind::Yolo).content_words(),
            "reconstruction is bit-stable"
        );
    }

    #[test]
    fn labels_read_naturally() {
        let man = VariantManifest::standard(ModelKind::Yolo);
        assert_eq!(man.get(0).label(), "fp16-640");
        assert_eq!(man.get(1).label(), "int8-640");
        assert_eq!(man.get(3).label(), "int8-416-d0.75");
    }

    /// Satellite: ≥100-case seeded property — a randomly generated
    /// manifest either validates, or is rejected with the *specific*
    /// invariant its construction violated.
    #[test]
    fn prop_random_manifests_validate_or_name_their_violation() {
        prop::check("manifest validation is total and specific", 300, |g| {
            let model = *g.rng.choose(&ModelKind::ALL);
            let floor = 0.5;
            let n = g.rng.range_usize(1, 6);
            // Build a valid-by-construction family...
            let mut variants = vec![ModelVariant::identity(model)];
            let mut perf = 1.0;
            let mut power = 1.0;
            let mut mem = 1.0;
            let mut acc = model.map();
            for _ in 1..n {
                perf += g.rng.range_f64(0.05, 1.0);
                power *= g.rng.range_f64(0.85, 1.0);
                mem *= g.rng.range_f64(0.7, 1.0);
                acc -= g.rng.range_f64(0.0, 3.0);
                variants.push(ModelVariant {
                    depth_frac: g.rng.range_f64(floor, 1.0),
                    precision: Precision::Int8,
                    input_res: 64 + 32 * g.rng.below(60) as u32,
                    accuracy: acc.max(1.0),
                    perf_mult: perf,
                    power_mult: power,
                    mem_mult: mem,
                });
            }
            // ... then maybe inject one specific violation.
            let expect = match g.rng.below(6) {
                0 => {
                    variants.clear();
                    Some(ManifestError::Empty)
                }
                1 => {
                    variants[0].perf_mult = 1.2;
                    Some(ManifestError::BaselineNotIdentity)
                }
                2 if n > 1 => {
                    let i = g.rng.range_usize(1, n - 1);
                    variants[i].mem_mult = f64::NAN;
                    Some(ManifestError::BadValue { index: i, field: "mem_mult" })
                }
                3 if n > 1 => {
                    let i = g.rng.range_usize(1, n - 1);
                    variants[i].depth_frac = floor / 2.0;
                    Some(ManifestError::BelowDepthFloor { index: i })
                }
                4 if n > 1 => {
                    let i = g.rng.range_usize(1, n - 1);
                    variants[i].perf_mult = variants[i - 1].perf_mult;
                    Some(ManifestError::CostNotDecreasing { index: i })
                }
                5 if n > 1 => {
                    let i = g.rng.range_usize(1, n - 1);
                    variants[i].accuracy = variants[i - 1].accuracy + 0.5;
                    Some(ManifestError::AccuracyIncreased { index: i })
                }
                _ => None,
            };
            let got = VariantManifest::new(model, variants.clone(), floor);
            match expect {
                Some(err) => prop::assert_true(
                    got == Err(err),
                    &format!("expected {err:?}, got {got:?}"),
                ),
                None => {
                    let man = got.map_err(|e| format!("valid family rejected: {e}"))?;
                    prop::assert_true(man.len() == n, "length preserved")?;
                    prop::assert_eq_dbg(&man.variants().to_vec(), &variants)
                }
            }
        });
    }
}
